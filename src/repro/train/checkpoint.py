"""Fault-tolerant checkpoint manager.

Design points for 1000+-node deployments (scaled here to one host):
  * checkpoints are written to a temp dir and atomically renamed — a
    preempted save never corrupts the latest checkpoint;
  * async save: the host-side serialization runs on a background thread so
    the train loop only blocks for the device→host copy;
  * logical storage: arrays are saved by *name* with full (unsharded)
    shapes; on restore they are re-sharded for whatever mesh the restart
    uses — this is what makes elastic scaling (e.g. 512→256 chips) work;
  * keep-N retention + "latest" symlink; data-iterator state rides along.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_into(template, flat, prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}/")
                for k, v in template.items()}
    if isinstance(template, (list, tuple)):
        typ = type(template)
        return typ(_unflatten_into(v, flat, f"{prefix}{i}/")
                   for i, v in enumerate(template))
    return flat[prefix[:-1]]


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Dict[str, Any],
             extra: Optional[Dict] = None) -> None:
        """state: pytree of jax/np arrays. Blocks only for device→host."""
        flat = _flatten(state)
        host, dtypes = {}, {}
        for k, v in flat.items():
            a = np.asarray(v)
            dtypes[k] = str(a.dtype)
            if a.dtype.kind == "V" or a.dtype.name.startswith(
                    ("bfloat16", "float8")):
                # ml_dtypes extension types degrade to void under npz;
                # store the raw bits and the dtype name for the view-back
                a = a.view(np.dtype(f"u{a.dtype.itemsize}"))
            host[k] = a
        self.wait()  # one in-flight save at a time

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **host)
            meta = {"step": step, "time": time.time(), "extra": extra or {},
                    "dtypes": dtypes}
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)          # atomic publish
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None,
                shardings=None) -> Dict[str, Any]:
        """Restore into the structure of ``template``. With ``shardings``
        (same pytree structure), arrays are placed directly into their
        (possibly different-mesh) target sharding — elastic restart."""
        step = step if step is not None else self.latest_step()
        assert step is not None, f"no checkpoints in {self.dir}"
        path = os.path.join(self.dir, f"step_{step:08d}")
        dtypes = self.meta(step).get("dtypes", {})
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {}
            for k in z.files:
                a = z[k]
                want = dtypes.get(k)
                if want and str(a.dtype) != want:
                    import ml_dtypes  # noqa: F401  # registers bfloat16/float8 with numpy
                    a = a.view(np.dtype(want))
                flat[k] = a
        tree = _unflatten_into(template, flat)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree

    def meta(self, step: Optional[int] = None) -> Dict:
        step = step if step is not None else self.latest_step()
        path = os.path.join(self.dir, f"step_{step:08d}", "meta.json")
        with open(path) as f:
            return json.load(f)
