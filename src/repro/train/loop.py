"""Training loop with fault tolerance: auto-resume, async checkpoints,
preemption handling, straggler watchdog, elastic restart support.
"""
from __future__ import annotations

import contextlib
import dataclasses
import signal
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import api
from repro.core.plan import GemmPolicy
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.obs import Timer
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.train.checkpoint import CheckpointManager


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    seed: int = 0
    base_lr: float = 3e-4
    warmup: int = 20
    straggler_factor: float = 3.0   # step slower than 3× EMA → flagged
    aux_weight: float = 0.01
    compress_grads: bool = False
    gemm: Optional[GemmPolicy] = None   # None → the ambient/default policy


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, tc: TrainConfig):
    policy_scope = ((lambda: api.use_policy(tc.gemm)) if tc.gemm is not None
                    else contextlib.nullcontext)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = T.lm_loss(p, cfg, batch,
                                      aux_weight=tc.aux_weight)
            return loss, metrics

        with policy_scope():
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
        lr = cosine_schedule(opt_state["step"], base_lr=tc.base_lr,
                             warmup=tc.warmup, total=tc.steps)
        params, opt_state, opt_metrics, _ = adamw_update(
            params, grads, opt_state, opt_cfg, lr)
        metrics = dict(metrics, loss=loss, lr=lr, **opt_metrics)
        return params, opt_state, metrics

    return train_step


class Trainer:
    """Single-host trainer (CPU demo scale); the pjit path in launch/train.py
    reuses make_train_step under a mesh."""

    def __init__(self, cfg: ModelConfig, data_cfg: DataConfig,
                 tc: TrainConfig, opt_cfg: Optional[AdamWConfig] = None):
        self.cfg, self.tc = cfg, tc
        self.opt_cfg = opt_cfg or AdamWConfig(
            lr=tc.base_lr, compress_grads=tc.compress_grads)
        self.data = TokenPipeline(data_cfg)
        self.ckpt = (CheckpointManager(tc.ckpt_dir)
                     if tc.ckpt_dir else None)
        key = jax.random.PRNGKey(tc.seed)
        self.params, self.axes = T.init_model(key, cfg)
        self.opt_state = adamw_init(self.params)
        self.start_step = 0
        self._preempted = False
        if self.ckpt and self.ckpt.latest_step() is not None:
            self._resume()
        self._step_fn = jax.jit(
            make_train_step(cfg, self.opt_cfg, tc), donate_argnums=(0, 1))

    # -- fault tolerance ------------------------------------------------
    def _resume(self):
        state = self.ckpt.restore(
            {"params": self.params, "opt": self.opt_state})
        self.params, self.opt_state = state["params"], state["opt"]
        meta = self.ckpt.meta()
        self.start_step = meta["step"]
        self.data.load_state_dict(meta["extra"]["data"])
        print(f"[trainer] resumed from step {self.start_step}")

    def _save(self, step: int):
        if self.ckpt:
            self.ckpt.save(step, {"params": self.params,
                                  "opt": self.opt_state},
                           extra={"data": self.data.state_dict()})

    def _on_sigterm(self, *_):
        self._preempted = True

    # -- loop -------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        old = signal.signal(signal.SIGTERM, self._on_sigterm)
        ema = None
        history = []
        try:
            for step in range(self.start_step, self.tc.steps):
                with Timer() as tm:
                    batch = {k: jnp.asarray(v)
                             for k, v in self.data.next_batch().items()}
                    self.params, self.opt_state, metrics = self._step_fn(
                        self.params, self.opt_state, batch)
                dt = tm.dt
                ema = dt if ema is None else 0.9 * ema + 0.1 * dt
                if dt > self.tc.straggler_factor * ema:
                    print(f"[watchdog] step {step} straggled: "
                          f"{dt:.3f}s vs EMA {ema:.3f}s")
                if step % self.tc.log_every == 0 or step == self.tc.steps - 1:
                    loss = float(metrics["loss"])
                    history.append((step, loss))
                    print(f"[trainer] step {step} loss {loss:.4f} "
                          f"({dt*1e3:.0f} ms)")
                if self.tc.ckpt_every and (step + 1) % self.tc.ckpt_every == 0:
                    self._save(step + 1)
                if self._preempted:
                    print("[trainer] SIGTERM — checkpointing and exiting")
                    self._save(step + 1)
                    break
            final_step = step + 1
            self._save(final_step)
            if self.ckpt:
                self.ckpt.wait()
        finally:
            signal.signal(signal.SIGTERM, old)
        return {"history": history, "final_loss": history[-1][1],
                "params": self.params}
