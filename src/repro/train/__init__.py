from repro.train.checkpoint import CheckpointManager  # noqa: F401
from repro.train.loop import TrainConfig, Trainer  # noqa: F401
