"""Three-term roofline analysis from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
  memory term     = HLO_bytes / (chips × HBM_bw)
  collective term = collective_bytes / (chips × link_bw)

cost_analysis() on an SPMD executable is per-partition (per-chip), so the
per-chip terms drop out directly. Collective bytes are parsed from the
optimized per-partition HLO text (compiled.as_text()) by summing the result
shapes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e-class hardware constants (per assignment)
@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per link


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(pred|[suf]\d+|bf16|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes per collective category (per partition)."""
    out = {c: 0.0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for coll in _COLLECTIVES:
            # result type sits between "=" and the op name; instruction
            # *names* also contain the op string, so anchor on "= <type> op("
            m = re.search(rf"=\s+(.*?)\s*{coll}(-start)?\(", stripped)
            if m:
                total = sum(_shape_bytes(d, s)
                            for d, s in _SHAPE_RE.findall(m.group(1)))
                if total:
                    out[coll] += total
                    counts[coll] += 1
                break
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    out["counts"] = counts  # type: ignore
    return out


def roofline_terms(cost: Dict[str, float], collective_bytes: float,
                   hw: HW = HW(), model_flops: Optional[float] = None,
                   links_per_chip: int = 1) -> Dict[str, float]:
    """cost: compiled.cost_analysis() dict (per-partition)."""
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / hw.peak_flops
    t_memory = bytes_accessed / hw.hbm_bw
    t_collective = collective_bytes / (hw.ici_bw * links_per_chip)
    terms = {
        "flops_per_chip": flops,
        "bytes_per_chip": bytes_accessed,
        "coll_bytes_per_chip": collective_bytes,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
    }
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_collective), key=lambda kv: kv[1])
    terms["bottleneck"] = dominant[0]
    t_bound = max(t_compute, t_memory, t_collective)
    terms["roofline_fraction"] = (t_compute / t_bound) if t_bound > 0 else 0.0
    if model_flops is not None and flops > 0:
        terms["model_flops"] = model_flops
        terms["useful_flops_ratio"] = model_flops / flops
    return terms


def model_flops_estimate(n_params_active: float, tokens: float,
                         kind: str = "train") -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference forward)."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * tokens
