"""GPipe-style pipeline parallelism over a 'stage' mesh axis.

Off by default (no assigned arch *needs* PP to fit 256 chips — §Dry-run
memory analysis), but provided as a first-class capability for >10B-scale
depth scaling: stages hold contiguous layer slices, microbatches stream
through a shard_map with collective_permute hops between neighbours, and
the classic GPipe bubble (S − 1 of μ + S − 1 slots) amortizes away as μ
grows.

Design notes:
  * params are stacked (S, L/S, ...) and sharded P('stage') on axis 0 —
    each stage's device group holds only its slice (pipeline = depth FSDP);
  * the schedule is a lax.fori_loop over μ + S − 1 ticks; at tick t,
    stage s processes microbatch (t − s) when 0 ≤ t − s < μ;
  * inter-stage transfer is one collective_permute per tick (point-to-point
    neighbour traffic — ICI-cheap, never an all-gather);
  * differentiable end-to-end (jax.grad through shard_map + permute), so
    the same engine serves training; remat composes inside stage_fn.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def stack_for_stages(layer_params, n_stages: int):
    """(L, ...) stacked layer params → (S, L/S, ...) stage-major."""
    def resplit(t):
        L = t.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return t.reshape((n_stages, L // n_stages) + t.shape[1:])
    return jax.tree_util.tree_map(resplit, layer_params)


def pipeline_apply(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params,              # (S, L/S, ...) pytree, sharded P('stage')
    x: jax.Array,              # (μ, mb, ...) microbatched input
    *,
    mesh: Mesh,
    axis: str = "stage",
) -> jax.Array:
    """Run x through S pipeline stages; returns (μ, mb, ...) outputs.

    stage_fn(stage_local_params, x_mb) applies one stage's layer slice to
    one microbatch. The caller supplies microbatched inputs; outputs arrive
    in microbatch order.
    """
    n_stages = mesh.shape[axis]
    mu = x.shape[0]
    n_ticks = mu + n_stages - 1

    def per_stage(params_local, x_local):
        # params_local: (1, L/S, ...) local slice; x_local: full (μ, mb, …)
        # (inputs are replicated; only stage 0 consumes them).
        params_local = jax.tree_util.tree_map(lambda t: t[0], params_local)
        s = jax.lax.axis_index(axis)
        mb_shape = x_local.shape[1:]
        zero_mb = jnp.zeros(mb_shape, x_local.dtype)
        out_buf = jnp.zeros((mu,) + mb_shape, x_local.dtype)

        def tick(t, carry):
            prev_out, out_buf = carry
            # receive neighbour's last output (stage s gets stage s-1's)
            recv = jax.lax.ppermute(
                prev_out, axis,
                [(i, i + 1) for i in range(n_stages - 1)])
            mb_idx = t - s
            active = (mb_idx >= 0) & (mb_idx < mu)
            feed = jnp.where(
                s == 0,
                jax.lax.dynamic_index_in_dim(
                    x_local, jnp.clip(mb_idx, 0, mu - 1), 0, keepdims=False),
                recv)
            y = stage_fn(params_local, feed)
            y = jnp.where(active, y, zero_mb)
            # last stage writes its (t - s)th microbatch output
            write_idx = jnp.clip(mb_idx, 0, mu - 1)
            do_write = active & (s == n_stages - 1)
            cur = jax.lax.dynamic_index_in_dim(out_buf, write_idx, 0,
                                               keepdims=False)
            new = jnp.where(do_write, y, cur)
            out_buf = jax.lax.dynamic_update_index_in_dim(out_buf, new,
                                                          write_idx, 0)
            return (y, out_buf)

        _, out_buf = jax.lax.fori_loop(0, n_ticks, tick, (zero_mb, out_buf))
        # every stage holds a (μ, mb, …) buffer; only the last stage's is
        # real — psum_scatter/broadcast it. Simplest: max over stages (all
        # others are zero) via psum of masked buffer.
        mask = (s == n_stages - 1).astype(out_buf.dtype)
        return jax.lax.psum(out_buf * mask, axis)

    in_specs = (
        jax.tree_util.tree_map(lambda _: P(axis), stage_params),
        P(),                        # microbatches replicated in
    )
    fn = shard_map(per_stage, mesh=mesh, in_specs=in_specs, out_specs=P(),
                   check_rep=False)
    return fn(stage_params, x)
