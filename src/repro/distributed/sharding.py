"""Logical-axis sharding rule engine (DP / FSDP / TP / EP / SP).

Models annotate params and activations with *logical* axis names (see
models/module.py). This module maps logical names → mesh axes with
divisibility-checked fallbacks, so one model definition serves every mesh
(single-pod 16×16, multi-pod 2×16×16, or the 1-device CPU smoke mesh) and
every architecture (e.g. smollm's 9 heads silently fall back to replicated
attention while its d_ff still tensor-parallelizes).

Conventions:
  pod    — pure data parallelism across pods (gradient all-reduce only)
  data   — data parallel + FSDP/ZeRO parameter & optimizer sharding
  model  — tensor parallel (heads / d_ff / vocab) and expert parallel (MoE)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.module import AxisLeaf, is_axis_leaf

MeshAxes = Union[None, str, Tuple[str, ...]]

# Logical axis → mesh axes. Params use bare names; activations use act_*.
DEFAULT_RULES: Dict[str, MeshAxes] = {
    # parameter axes
    "layers": None,
    "embed": "data",        # FSDP/ZeRO: weights' d_model dim sharded over data
    "mlp": "model",         # TP: FFN hidden
    "heads": "model",       # TP: fused (n_heads·d_head) projection dim
    "kv_heads": "model",    # TP: fused KV projection dim (falls back for MQA)
    "vocab": "model",       # TP: embedding / LM head vocab dim
    "experts": "model",     # EP: MoE expert dim
    "kv_lora": None,        # MLA latent dims stay replicated
    "conv": None,
    "state": None,
    # activation axes
    "act_batch": ("pod", "data"),
    "act_seq": None,         # flipped to "model" under sequence parallelism
    "act_embed": None,
    "act_mlp": "model",
    "act_heads": "model",
    "act_kv_heads": "model",
    "act_vocab": "model",
    "act_experts": "model",
    "act_state": None,
}


class ShardingRules:
    def __init__(self, mesh: Optional[Mesh],
                 overrides: Optional[Dict[str, MeshAxes]] = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if overrides:
            self.rules.update(overrides)

    def _mesh_size(self, axes: MeshAxes) -> int:
        if axes is None or self.mesh is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        n = 1
        for a in axes:
            n *= dict(zip(self.mesh.axis_names, self.mesh.devices.shape)).get(a, 1)
        return n

    def _resolve(self, axes: MeshAxes) -> MeshAxes:
        """Drop mesh axes that don't exist on the current mesh (e.g. 'pod'
        on the single-pod mesh)."""
        if axes is None or self.mesh is None:
            return None
        names = set(self.mesh.axis_names)
        if isinstance(axes, str):
            return axes if axes in names else None
        kept = tuple(a for a in axes if a in names)
        if not kept:
            return None
        return kept[0] if len(kept) == 1 else kept

    def spec(self, logical: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for the given logical axes, with divisibility
        fallback when ``shape`` is provided.

        A mesh axis may appear at most once in a spec. When two dims
        resolve to the same mesh axis (e.g. sequence parallelism's
        act_seq→model colliding with a TP feature dim), the tensor/feature
        dim wins and the sequence dim replicates — Megatron-SP semantics:
        SP shards the residual stream, TP owns the block interiors.
        """
        parts = []
        for i, name in enumerate(logical):
            axes = self._resolve(self.rules.get(name)) if name else None
            if axes is not None and shape is not None:
                if shape[i] % max(self._mesh_size(axes), 1) != 0:
                    axes = None  # fallback: replicate this dim
            parts.append(axes)
        # duplicate-axis resolution: act_seq yields first, then earlier dims
        def axes_set(a):
            return set((a,) if isinstance(a, str) else (a or ()))
        seq_dims = [i for i, n in enumerate(logical) if n == "act_seq"]
        order = seq_dims + [i for i in range(len(parts)) if i not in
                            seq_dims]
        used: set = set()
        for i in reversed(order):      # last in order = highest precedence
            a = axes_set(parts[i])
            if a & used:
                parts[i] = None
            else:
                used |= a
        return P(*parts)


_state = threading.local()


def current_rules() -> Optional[ShardingRules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Activation sharding constraint; no-op outside a rules context."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    spec = rules.spec(logical, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def param_specs(axes_tree, shapes_tree, rules: ShardingRules):
    """Map the (axes, shapes) trees to a PartitionSpec tree."""
    def one(axes_leaf, shape):
        assert is_axis_leaf(axes_leaf), axes_leaf
        shp = shape.shape if hasattr(shape, "shape") else shape
        return rules.spec(tuple(axes_leaf), shp)
    return jax.tree_util.tree_map(one, axes_tree, shapes_tree,
                                  is_leaf=lambda x: is_axis_leaf(x))


def param_shardings(axes_tree, shapes_tree, rules: ShardingRules):
    specs = param_specs(axes_tree, shapes_tree, rules)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(rules.mesh, s), specs)


def stack_axes(axes_tree):
    """Prepend the 'layers' scan axis to every axes leaf (stacked params)."""
    return jax.tree_util.tree_map(
        lambda a: AxisLeaf(("layers",) + tuple(a)), axes_tree,
        is_leaf=is_axis_leaf)
