"""Tensor-parallel execution: shard_map'd GEMM + attention over a mesh.

The GSPMD rule engine (distributed/sharding.py) covers the training and
dry-run paths, where XLA may partition every op automatically. Serving
cannot rely on that alone: the fused/paged attention backends are *Pallas
kernels*, which GSPMD does not partition — they must run per-shard on
shard-local operands. This module is that layer: it wraps
:func:`repro.core.api.matmul`/:func:`~repro.core.api.linear` and
:func:`repro.core.api.attention` in ``shard_map`` over a ``(data, model)``
mesh, so the kernels underneath run unmodified on their local slice.

Layout (Megatron TP, the paper's multi-unit dataflow applied to serving):

  * **column-parallel** projections (QKV, MLP up/gate, LM head): the weight
    is split along N over ``model``; every shard computes its output
    columns from the full K — bitwise identical to the unsharded GEMM.
  * **row-parallel** projections (attention out, MLP down): the weight is
    split along K, each shard contracts its slice, and a ``psum`` over
    ``model`` completes the contraction. Partial products are accumulated
    and summed in fp32 *before* the cast to the model dtype, so the only
    difference from the unsharded GEMM is fp32 summation order.
  * **attention**: heads shard over ``model``; each shard runs the active
    attention backend (fused flash kernel, unfused baseline, or the
    block-table paged kernel) on its head slice. With a paged cache every
    model shard owns its own slice of the page pool — pool tensors
    ``(P, page_size, Hkv, D)`` shard on the KV-head dim, the block table
    replicates, and ``kernels/paged_attention.py`` runs unmodified inside
    the shard_map body (the engine's page accounting is in logical tokens,
    identical on every shard — docs/serving.md).

Head divisibility (``head_sharding``) follows the ShardingRules discipline
— shard only what divides, fall back to replicated otherwise — with one
extra constraint the rules cannot see: backends derive the GQA head→KV-head
grouping from *local* shapes, so query heads may shard without KV heads
only for MQA (Hkv == 1, every query head maps to KV head 0 on any shard).
A GQA slice over replicated KV heads would re-derive a wrong grouping;
those configs replicate attention entirely.

Everything degrades to the plain api.* call when no TP context is active
(or the model axis has size 1), so model code routes through this module
unconditionally and single-device behavior is untouched.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import api
from repro.core.plan import (PackedWeight, QuantizedPackedWeight,
                             ShardingPolicy)
from repro.distributed.sharding import ShardingRules
from repro.models.module import is_axis_leaf

__all__ = [
    "TPContext", "make_context", "use_tp", "current_tp", "head_sharding",
    "linear", "matmul", "attention", "shard_params", "shard_caches",
    "replicate",
]


@dataclasses.dataclass
class TPContext:
    """A mesh + resolved sharding rules, carried thread-local (use_tp)."""

    mesh: Mesh
    rules: ShardingRules
    policy: ShardingPolicy

    @property
    def model_axis(self) -> str:
        return self.policy.model_axis

    @property
    def data_axis(self) -> str:
        return self.policy.data_axis

    @property
    def model_size(self) -> int:
        return int(self.mesh.shape.get(self.model_axis, 1))

    def wants_model(self, logical: Optional[str]) -> bool:
        """True when the rule for ``logical`` resolves to the model axis."""
        if logical is None:
            return False
        axes = self.rules._resolve(self.rules.rules.get(logical))
        if axes is None:
            return False
        axes = (axes,) if isinstance(axes, str) else axes
        return self.model_axis in axes


def make_context(mesh: Optional[Mesh],
                 policy: Optional[ShardingPolicy] = None,
                 overrides: Optional[Dict[str, Any]] = None
                 ) -> Optional[TPContext]:
    """Build a TPContext (None mesh → None, the single-device no-op).

    ``overrides`` layer on top of the policy's own (model configs pass
    ``cfg.overrides_dict()`` — e.g. smollm pins heads replicated).
    """
    if mesh is None:
        return None
    policy = policy if policy is not None else ShardingPolicy()
    merged = policy.overrides_dict()
    if overrides:
        merged.update(overrides)
    return TPContext(mesh=mesh, rules=ShardingRules(mesh, merged),
                     policy=policy)


_state = threading.local()


def current_tp() -> Optional[TPContext]:
    return getattr(_state, "tp", None)


@contextlib.contextmanager
def use_tp(ctx: Optional[TPContext]):
    """Pin the active TP context for the enclosed region (thread-local,
    mirrors api.use_policy; read at trace time inside jitted functions)."""
    prev = getattr(_state, "tp", None)
    _state.tp = ctx
    try:
        yield ctx
    finally:
        _state.tp = prev


def replicate(x, ctx: Optional[TPContext] = None):
    """device_put ``x`` replicated over the mesh (host inputs must not be
    left committed to a single device once params/caches span the mesh)."""
    ctx = ctx if ctx is not None else current_tp()
    if ctx is None:
        return jnp.asarray(x)
    return jax.device_put(jnp.asarray(x), NamedSharding(ctx.mesh, P()))


# ---------------------------------------------------------------------------
# Head sharding decision (shared by attention, cache placement, benchmarks)
# ---------------------------------------------------------------------------

def head_sharding(ctx: Optional[TPContext], H: int, Hkv: int
                  ) -> Tuple[bool, bool]:
    """(shard_q, shard_kv) over the model axis for an (H, Hkv) layer.

    Both shard when both divide the model-axis size (rep = H/Hkv is then
    preserved per shard). Query heads shard alone only for MQA (Hkv == 1):
    backends compute the GQA grouping from local shapes, so a GQA query
    slice over replicated KV heads would regroup wrongly — replicate
    instead (see module docstring).
    """
    if ctx is None:
        return False, False
    mp = ctx.model_size
    if mp <= 1 or not ctx.wants_model("heads") or H % mp:
        return False, False
    if H == Hkv:
        return True, True         # MHA/MLA: one head set, one rule
    if ctx.wants_model("kv_heads") and Hkv % mp == 0:
        return True, True
    if Hkv == 1:
        return True, False        # MQA replication fallback
    return False, False


# ---------------------------------------------------------------------------
# shard_map'd GEMM
# ---------------------------------------------------------------------------

def _sharded_dim(ctx: TPContext, name: Optional[str], size: int,
                 units: Optional[int]) -> bool:
    """Does dim ``name`` of width ``size`` shard over the model axis?
    ``units`` is the count of indivisible groups along the dim (head
    boundaries); both it and the raw width must divide."""
    if not ctx.wants_model(name):
        return False
    mp = ctx.model_size
    return size % mp == 0 and (units is None or units % mp == 0)


def linear(x: jax.Array, w, bias=None, *,
           axes: Sequence[Optional[str]],
           units: Optional[int] = None,
           policy=None) -> jax.Array:
    """y = x @ w (+ bias) sharded over the active TP context.

    ``axes`` are the weight's logical axis names (the same pair its init
    recorded — ("embed", "heads") etc.); the rule engine decides which dim,
    if any, carries the model axis. N sharded → column-parallel (bias
    sharded along, output model-sharded on the last dim); K sharded →
    row-parallel (fp32 psum over the contraction, bias applied once after).
    ``units`` bounds the split to whole head groups. Falls back to
    :func:`api.linear` with no context, a trivial model axis, a packed
    weight, or no rule match.
    """
    ctx = current_tp()
    if (ctx is None or ctx.model_size <= 1
            or isinstance(w, (PackedWeight, QuantizedPackedWeight))
            or getattr(w, "ndim", 0) != 2):
        return api.linear(x, w, bias, policy=policy)
    m = ctx.model_axis
    k_name, n_name = axes
    K, N = w.shape

    if _sharded_dim(ctx, n_name, N, units):
        # column parallel: full-K contraction per shard, bitwise identical
        def body(x_, w_, *b_):
            return api.linear(x_, w_, b_[0] if b_ else None, policy=policy)

        xs = P(*([None] * x.ndim))
        in_specs = [xs, P(None, m)]
        operands = [x, w]
        if bias is not None:
            in_specs.append(P(m))
            operands.append(bias)
        fn = shard_map(body, mesh=ctx.mesh, in_specs=tuple(in_specs),
                       out_specs=P(*([None] * (x.ndim - 1)), m),
                       check_rep=False)
        return fn(*operands)

    if _sharded_dim(ctx, k_name, K, units):
        # row parallel: per-shard partial products, fp32 psum, then cast —
        # the sum over model shards happens before the model-dtype rounding
        out_dtype = jnp.promote_types(x.dtype, w.dtype)
        acc = (jnp.float32 if jnp.issubdtype(out_dtype, jnp.floating)
               else None)

        def body(x_, w_):
            part = api.matmul(x_, w_, policy=policy, out_dtype=acc)
            return jax.lax.psum(part, m)

        fn = shard_map(
            body, mesh=ctx.mesh,
            in_specs=(P(*([None] * (x.ndim - 1)), m), P(m, None)),
            out_specs=P(*([None] * x.ndim)), check_rep=False)
        y = fn(x, w)
        if acc is not None:
            y = y.astype(out_dtype)
        if bias is not None:
            y = y + bias
        return y

    return api.linear(x, w, bias, policy=policy)


def matmul(a: jax.Array, b: jax.Array, *,
           axes: Sequence[Optional[str]],
           units: Optional[int] = None,
           policy=None) -> jax.Array:
    """Bias-less :func:`linear` (parity/benchmark cells)."""
    return linear(a, b, None, axes=axes, units=units, policy=policy)


# ---------------------------------------------------------------------------
# shard_map'd attention (heads over model; per-shard paged pools)
# ---------------------------------------------------------------------------

def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              q_positions: jax.Array, kv_valid_len: jax.Array,
              causal: bool = True, scale: Optional[float] = None,
              soft_cap: Optional[float] = None,
              block_tables: Optional[jax.Array] = None,
              kv_scales=None,
              policy=None) -> jax.Array:
    """api.attention with heads sharded over the model axis.

    q is model layout (B, Sq, H, D); k/v are either dense caches
    (B, T, Hkv, D) or, with ``block_tables``, page pools
    (P, page_size, Hkv, D). Either way the head dim is axis 2, so one
    spec covers both: q (and the output) shard on H, k/v shard on Hkv
    when :func:`head_sharding` allows, and positions/lengths/tables
    replicate. An int8 pool's ``kv_scales`` (two (P, Hkv) fp32 arrays)
    shard on their KV-head dim — the LAST — alongside the pools. The
    backend — including the Pallas paged kernel — runs unmodified on its
    shard-local slice.
    """
    ctx = current_tp()
    shard_q, shard_kv = head_sharding(
        ctx, q.shape[2], k.shape[2]) if ctx is not None else (False, False)
    if not shard_q:
        return api.attention(q, k, v, q_positions=q_positions,
                             kv_valid_len=kv_valid_len, causal=causal,
                             scale=scale, soft_cap=soft_cap,
                             block_tables=block_tables,
                             kv_scales=kv_scales, policy=policy)
    pol = policy if policy is not None else api.current_attention_policy()
    if scale is None:
        scale = q.shape[-1] ** -0.5
    m = ctx.model_axis
    hs = P(None, None, m, None)
    kv_spec = hs if shard_kv else P(None, None, None, None)
    operands = [q, k, v, q_positions, kv_valid_len]
    in_specs = [hs, kv_spec, kv_spec, P(None, None), P(None)]
    has_bt = block_tables is not None
    if has_bt:
        operands.append(block_tables)
        in_specs.append(P(None, None))
    has_scales = kv_scales is not None
    if has_scales:
        scale_spec = P(None, m) if shard_kv else P(None, None)
        operands.extend(kv_scales)
        in_specs.extend([scale_spec, scale_spec])

    def body(q_, k_, v_, qp_, kl_, *rest):
        rest = list(rest)
        bt_ = rest.pop(0) if has_bt else None
        sc_ = tuple(rest) if has_scales else None
        return api.attention(q_, k_, v_, q_positions=qp_, kv_valid_len=kl_,
                             causal=causal, scale=scale, soft_cap=soft_cap,
                             block_tables=bt_, kv_scales=sc_, policy=pol)

    fn = shard_map(body, mesh=ctx.mesh, in_specs=tuple(in_specs),
                   out_specs=hs, check_rep=False)
    return fn(*operands)


# ---------------------------------------------------------------------------
# Placement: params + caches resident in their shard_map layout
# ---------------------------------------------------------------------------

def _model_only(spec: P, ctx: TPContext) -> P:
    """Strip every mesh axis except the model axis from a PartitionSpec —
    TP serving replicates weights along data/pod (no FSDP at inference)."""
    m = ctx.model_axis

    def keep(entry):
        axes = (entry,) if isinstance(entry, str) else (entry or ())
        return m if m in axes else None

    return P(*(keep(e) for e in spec))


def shard_params(params, axes_tree, ctx: Optional[TPContext]):
    """device_put every param in the layout tp.linear's in_specs expect:
    model-axis dims sharded, everything else replicated. Placement is a
    performance property only — shard_map slices the global value per its
    specs regardless — but resident placement avoids re-distributing every
    weight on every step."""
    if ctx is None:
        return params

    def one(axes_leaf, param):
        if not is_axis_leaf(axes_leaf) or not hasattr(param, "shape"):
            return param
        spec = _model_only(
            ctx.rules.spec(tuple(axes_leaf), param.shape), ctx)
        return jax.device_put(param, NamedSharding(ctx.mesh, spec))

    return jax.tree_util.tree_map(
        lambda a, p: one(a, p), axes_tree, params,
        is_leaf=is_axis_leaf)


_KV_LEAVES = ("k", "v", "kp", "vp")
_KV_SCALE_LEAVES = ("k_scale", "v_scale")


def shard_caches(caches, ctx: Optional[TPContext], *, shard_kv: bool):
    """device_put decode caches: K/V leaves (dense ``k``/``v`` slabs or
    paged ``kp``/``vp`` pools, stacked or not) shard on their KV-head dim
    (always axis -2) when ``shard_kv``; int8 pools' ``k_scale``/``v_scale``
    side-tensors shard on *their* KV-head dim (the last — (…, P, Hkv));
    lengths, block tables, MLA latent and SSM state replicate. ``shard_kv``
    must be the :func:`head_sharding` decision for the model's (H, Hkv),
    so placement agrees with tp.attention's in_specs."""
    if ctx is None:
        return caches
    mesh, m = ctx.mesh, ctx.model_axis

    def rec(node):
        if isinstance(node, dict):
            out = {}
            for key, val in node.items():
                if isinstance(val, (dict, list, tuple)):
                    out[key] = rec(val)
                elif (key in _KV_LEAVES and shard_kv
                      and getattr(val, "ndim", 0) >= 4
                      and val.shape[-2] % ctx.model_size == 0):
                    spec = P(*([None] * (val.ndim - 2)), m, None)
                    out[key] = jax.device_put(val, NamedSharding(mesh, spec))
                elif (key in _KV_SCALE_LEAVES and shard_kv
                      and getattr(val, "ndim", 0) >= 2
                      and val.shape[-1] % ctx.model_size == 0):
                    spec = P(*([None] * (val.ndim - 1)), m)
                    out[key] = jax.device_put(val, NamedSharding(mesh, spec))
                else:
                    out[key] = jax.device_put(val,
                                              NamedSharding(mesh, P()))
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(rec(v) for v in node)
        return jax.device_put(node, NamedSharding(mesh, P()))

    return rec(caches)
