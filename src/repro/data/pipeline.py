"""Sharded token data pipeline with checkpointable iterator state.

Sources:
  * "synthetic" — deterministic PRNG token stream (reproducible; used by the
    examples, smoke tests, and the dry-run-adjacent training demos).
  * "memmap"    — flat uint16/uint32 token file (numpy memmap), the standard
    pre-tokenized-corpus format; sharded by host.

The iterator state is a single integer cursor (plus the PRNG seed), so
checkpoint/restore and elastic restarts (different data-parallel size) are
exact: each host recomputes its shard slice from the global cursor.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    source: str = "synthetic"          # "synthetic" | "memmap"
    path: Optional[str] = None
    seed: int = 0
    n_codebooks: int = 0               # musicgen-style multi-stream tokens
    n_image_tokens: int = 0            # vlm stub: embeds prepended


class TokenPipeline:
    """Deterministic, restartable token batch iterator."""

    def __init__(self, cfg: DataConfig, *, host_id: int = 0, n_hosts: int = 1):
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.cursor = 0  # global step cursor — THE checkpointable state
        if cfg.source == "memmap":
            assert cfg.path, "memmap source needs a path"
            self._data = np.memmap(cfg.path, dtype=np.uint16, mode="r")
        else:
            self._data = None

    # -- checkpointable state ------------------------------------------------
    def state_dict(self) -> Dict:
        return {"cursor": self.cursor, "seed": self.cfg.seed}

    def load_state_dict(self, state: Dict) -> None:
        self.cursor = int(state["cursor"])

    # -- batch synthesis -----------------------------------------------------
    def _host_batch_range(self):
        per_host = self.cfg.global_batch // self.n_hosts
        lo = self.host_id * per_host
        return lo, lo + per_host

    def next_batch(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        lo, hi = self._host_batch_range()
        rows = []
        for b in range(lo, hi):
            rows.append(self._row(self.cursor, b))
        self.cursor += 1
        tokens = np.stack(rows)
        batch = {"tokens": tokens}
        if cfg.n_image_tokens:
            rng = np.random.default_rng(cfg.seed + self.cursor)
            batch["embeds"] = rng.standard_normal(
                (hi - lo, cfg.n_image_tokens, 1)).astype(np.float32)
        return batch

    def _row(self, step: int, row: int) -> np.ndarray:
        cfg = self.cfg
        shape = ((cfg.seq_len, cfg.n_codebooks) if cfg.n_codebooks
                 else (cfg.seq_len,))
        if self._data is not None:
            n = self._data.shape[0] - cfg.seq_len - 1
            off = (step * cfg.global_batch + row) * cfg.seq_len % max(n, 1)
            return np.asarray(self._data[off:off + cfg.seq_len],
                              dtype=np.int32)
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + row)
        # structured synthetic stream: next-token == current-token with
        # p=0.9 (a copy task) — steep, model-agnostic learning signal for
        # the examples and loss-decreases tests; CE floor ≈ 0.6 nats.
        base = rng.integers(0, cfg.vocab, size=shape).astype(np.int32)
        out = base.copy()
        copy_mask = rng.random(shape) < 0.9
        for t in range(1, shape[0]):
            out[t] = np.where(copy_mask[t], out[t - 1], base[t])
        return out.astype(np.int32)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
