"""Pure-jnp oracles for every Pallas kernel in this package.

Each oracle is the semantic ground truth the kernels are allclose-tested
against (tests/test_kernels.py sweeps shapes × dtypes in interpret mode).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import blockflow

# ---------------------------------------------------------------------------
# MatrixFlow GEMM
# ---------------------------------------------------------------------------

def matmul_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    """Plain jnp oracle with the paper's accumulator policy (int32/fp32)."""
    acc = blockflow.acc_dtype_for(a.dtype)
    c = jnp.dot(a.astype(acc), b.astype(acc), preferred_element_type=acc)
    return c.astype(out_dtype or acc)


# Faithful Algorithm-1 rendering (block-major, lax control flow); also an oracle.
block_matmul_ref = blockflow.block_matmul


# ---------------------------------------------------------------------------
# Flash attention (beyond-paper fusion; faithful mode uses separate GEMMs)
# ---------------------------------------------------------------------------

def mha_ref(
    q: jax.Array,            # (B, Sq, H, D)
    k: jax.Array,            # (B, Sk, Hkv, D)
    v: jax.Array,            # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    soft_cap: Optional[float] = None,
    q_positions: Optional[jax.Array] = None,   # (B, Sq) int32; <0 → masked
    kv_valid_len: Optional[jax.Array] = None,  # (B,) int32; None → Sk
) -> jax.Array:
    """Reference grouped-query attention, fp32 softmax.

    Offset/length semantics (the decode/serving contract shared with the
    flash kernel and the policy backends): key j of batch row b is visible
    to query i iff ``j < kv_valid_len[b]`` and, when causal,
    ``j <= q_positions[b, i]``. The default positions are bottom-right
    aligned (``arange(Sq) + Sk - Sq``). A query row with no visible key —
    e.g. a serving slot masked at position −1 — returns an all-zero row.
    """
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    assert H % Hkv == 0
    rep = H // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = scale if scale is not None else D ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if soft_cap:
        logits = soft_cap * jnp.tanh(logits / soft_cap)
    if q_positions is None:
        q_positions = jnp.broadcast_to(
            jnp.arange(Sq, dtype=jnp.int32) + (Sk - Sq), (B, Sq))
    if kv_valid_len is None:
        kv_valid_len = jnp.full((B,), Sk, jnp.int32)
    kv_pos = jnp.arange(Sk)[None, None, :]                    # (1,1,Sk)
    valid = kv_pos < kv_valid_len[:, None, None]              # (B,1,Sk)
    if causal:
        valid = valid & (kv_pos <= q_positions[:, :, None])   # (B,Sq,Sk)
    valid = jnp.broadcast_to(valid, (B, Sq, Sk))[:, None]     # (B,1,Sq,Sk)
    logits = jnp.where(valid, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(valid, p, 0.0)     # fully-masked rows → zeros, not uniform
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
    return out


# ---------------------------------------------------------------------------
# Mamba-2 SSD (state-space duality) chunked scan
# ---------------------------------------------------------------------------

def ssd_ref(
    x: jax.Array,        # (B, S, H, P)   heads × head-dim
    dt: jax.Array,       # (B, S, H)      softplus-ed step sizes
    A: jax.Array,        # (H,)           negative decay rates
    Bc: jax.Array,       # (B, S, N)      input projection (shared across heads)
    Cc: jax.Array,       # (B, S, N)      output projection
) -> jax.Array:
    """Sequential-scan oracle of the SSD recurrence (Mamba-2 §3, minimal form).

    h_t = exp(A * dt_t) * h_{t-1} + dt_t * B_t x_t ;  y_t = C_t · h_t
    State h: (H, P, N).
    """
    Bsz, S, H, P = x.shape
    N = Bc.shape[-1]

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        decay = jnp.exp(A[:, None, None] * dt_t[:, None, None])   # (H,1,1)
        dBx = (dt_t[:, None, None] * x_t[:, :, None]) * b_t[None, None, :]
        h = decay * h + dBx                                        # (H,P,N)
        y = jnp.einsum("hpn,n->hp", h, c_t)
        return h, y

    def per_batch(xb, dtb, bb, cb):
        h0 = jnp.zeros((H, P, N), jnp.float32)
        _, ys = jax.lax.scan(step, h0, (xb.astype(jnp.float32),
                                        dtb.astype(jnp.float32),
                                        bb.astype(jnp.float32),
                                        cb.astype(jnp.float32)))
        return ys                                                  # (S,H,P)

    return jax.vmap(per_batch)(x, dt, Bc, Cc).astype(x.dtype)
