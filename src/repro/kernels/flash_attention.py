"""Fused (flash) attention as a Pallas TPU kernel — beyond-paper optimization.

The paper keeps softmax on the CPU and runs QKᵀ / PV as separate accelerator
GEMMs (§4.4), and measures 13.3 % non-GEMM + 24.25 % control overhead left
on the table (§4.5). On TPU we can close that gap by fusing the whole
attention inner loop into one kernel: the MatrixFlow insight (stream
page/block-sized operand tiles through the systolic datapath, never spill
the intermediate) applies directly — the (bq × bk) score tile lives only in
VMEM, exactly like the paper's Buffer C, and is consumed by the online
softmax before the next block arrives.

Layout: grid = (B, H, nQ, nK), K innermost ("arbitrary" = sequential), with
running max / denominator / output accumulator in VMEM scratch (the flash
recurrence). GQA is expressed in the BlockSpec index map (kv head = h//rep),
so no repeated K/V materialization in HBM — the MatrixFlow-style "fetch the
block you need, once" property.

Validated in interpret mode against kernels/ref.py::mha_ref.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None)
except ImportError:  # pragma: no cover
    pltpu = None
    _CompilerParams = None

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, bq: int, bk: int, nk: int):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip key blocks strictly in the future of the whole q block
    run = (iq * bq + bq - 1 >= ik * bk) if causal else True

    @pl.when(run)
    def _step():
        q = q_ref[0, 0]                                   # (bq, d)
        k = k_ref[0, 0]                                   # (bk, d)
        v = v_ref[0, 0]                                   # (bk, dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        if causal:
            rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_ref[...]                               # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                            # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                    # (bq, 1)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,             # (B, H, Sq, D)
    k: jax.Array,             # (B, Hkv, Sk, D)
    v: jax.Array,             # (B, Hkv, Sk, Dv)
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, D = q.shape
    _, Hkv, Sk, Dv = v.shape
    assert H % Hkv == 0, (H, Hkv)
    rep = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    # pad S to block multiples (masked out by the causal/validity logic)
    pq = (-Sq) % bq
    pk = (-Sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        # padded keys get +inf-masked via causality only when causal; for
        # non-causal, mask by padding k with NEG_INF-producing zeros and
        # relying on the extra keys' scores: instead explicitly disallow.
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    Sq_p, Sk_p = Sq + pq, Sk + pk
    nq, nk = Sq_p // bq, Sk_p // bk

    if pk and not causal:
        raise ValueError("non-causal flash requires Sk % block_k == 0")

    grid = (B, H, nq, nk)
    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               bq=bq, bk=bk, nk=nk)
    kwargs = {}
    if _CompilerParams is not None and not interpret:
        kwargs["compiler_params"] = _CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, Dv),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dv), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq_p, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        interpret=interpret,
        **kwargs,
    )(q, k, v)
    return out[:, :, :Sq]
