"""Fused (flash) attention as a Pallas TPU kernel — beyond-paper optimization.

The paper keeps softmax on the CPU and runs QKᵀ / PV as separate accelerator
GEMMs (§4.4), and measures 13.3 % non-GEMM + 24.25 % control overhead left
on the table (§4.5). On TPU we can close that gap by fusing the whole
attention inner loop into one kernel: the MatrixFlow insight (stream
page/block-sized operand tiles through the systolic datapath, never spill
the intermediate) applies directly — the (bq × bk) score tile lives only in
VMEM, exactly like the paper's Buffer C, and is consumed by the online
softmax before the next block arrives.

Layout: grid = (B, H, nQ, nK), K innermost ("arbitrary" = sequential), with
running max / denominator / output accumulator in VMEM scratch (the flash
recurrence). GQA is expressed in the BlockSpec index map (kv head = h//rep),
so no repeated K/V materialization in HBM — the MatrixFlow-style "fetch the
block you need, once" property.

Decode/serving semantics (the offset-aware extension):

  * ``q_positions`` (B, Sq) gives each query row its absolute sequence
    position. Causal masking compares key index against *that* position, so
    a single query (Sq=1) against a long KV cache attends exactly its
    prefix. The default — ``arange(Sq) + (Sk - Sq)`` — is bottom-right
    aligned, matching :func:`repro.kernels.ref.mha_ref`.
  * ``kv_valid_len`` (B,) bounds the populated keys per batch row: padded /
    not-yet-written cache slots contribute exactly zero weight, causal or
    not (this replaces the old ``Sk % block_k == 0`` ValueError for ragged
    non-causal keys).
  * A query row with *no* valid key (e.g. the serving engine's masked
    position −1 slots) produces an all-zero output row — deterministic and
    finite, never NaN.

Key blocks entirely outside a row-block's reach (beyond the causal frontier
or past every row's valid length) are skipped at runtime — decode against a
mostly-empty cache touches only the populated blocks.

Validated in interpret mode against kernels/ref.py::mha_ref.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None)
except ImportError:  # pragma: no cover
    pltpu = None
    _CompilerParams = None

from repro.analysis.kernel_contracts import (KernelContract, OperandSpec,
                                             Precondition, register_contract,
                                             require)

NEG_INF = -1e30

# ---------------------------------------------------------------------------
# The dataflow mapping, stated once: these index maps are handed to
# pl.BlockSpec below AND cited by the registered KernelContract, so the
# static checker verifies the very callables the kernel executes.
# ---------------------------------------------------------------------------

ATTN_DIMENSION_SEMANTICS = ("parallel", "parallel", "parallel", "arbitrary")


def _qpos_index_map(b, h, i, j):
    return (b, i, 0)


def _kvlen_index_map(b, h, i, j):
    return (b, 0)


def _q_index_map(b, h, i, j):
    return (b, h, i, 0)


def _make_kv_index_map(rep: int):
    """K/V fetch under GQA: query head h reads kv head h // rep — the
    BlockSpec expression of grouped heads (no HBM repeat)."""
    def _kv_index_map(b, h, i, j):
        return (b, h // rep, j, 0)
    return _kv_index_map


def _o_index_map(b, h, i, j):
    return (b, h, i, 0)


def attention_preconditions(H: int, Hkv: int):
    """Structured entry guards shared between the runtime ``require`` and
    the static contract."""
    return (
        Precondition.check(
            "GQA head divisibility", Hkv > 0 and H % Hkv == 0,
            f"H={H} query heads must be an integer multiple of Hkv={Hkv} "
            f"kv heads (GQA groups of H // Hkv); got remainder "
            f"{H % Hkv if Hkv else 'undefined'}"),
    )


@register_contract("flash_attention")
def flash_attention_contract(*, B, H, Hkv, Sq, Sk, D, Dv,
                             block_q: int = 128,
                             block_k: int = 128) -> KernelContract:
    """Contract of :func:`flash_attention` for one logical shape.

    Mirrors the kernel's own derivation: bq/bk clamp to Sq/Sk, the padded
    extents round up to block multiples, and the output o is revisited
    along grid axis 3 (the key stream) — the declared reduction axis.
    K/V coverage under GQA is partial by construction (each kv head is
    fetched rep times; every (b, hkv, j) block is still touched).
    """
    rep = H // Hkv if Hkv and H % Hkv == 0 else 1
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    pq, pk = (-Sq) % bq, (-Sk) % bk
    nq, nk = (Sq + pq) // bq, (Sk + pk) // bk
    kv_map = _make_kv_index_map(rep)
    operands = (
        OperandSpec("q_positions", "input", (B, nq, 1), (1, bq, 1),
                    _qpos_index_map, expected_blocks=None),
        OperandSpec("kv_valid_len", "input", (B, 1), (1, 1),
                    _kvlen_index_map),
        OperandSpec("q", "input", (B, H, nq, 1), (1, 1, bq, D),
                    _q_index_map),
        OperandSpec("k", "input", (B, Hkv, nk, 1), (1, 1, bk, D),
                    kv_map),
        OperandSpec("v", "input", (B, Hkv, nk, 1), (1, 1, bk, Dv),
                    kv_map),
        OperandSpec("o", "output", (B, H, nq, 1), (1, 1, bq, Dv),
                    _o_index_map, reduction_axes=(3,)),
    )
    return KernelContract(
        kernel="flash_attention",
        grid=(B, H, nq, nk),
        operands=operands,
        dimension_semantics=ATTN_DIMENSION_SEMANTICS,
        preconditions=attention_preconditions(H, Hkv),
        description="fused online-softmax attention, K innermost")


# ---------------------------------------------------------------------------
# The streaming-softmax recurrence, shared by the fused and paged kernels
# (kernels/paged_attention.py). The two kernels differ ONLY in where a key
# block comes from — contiguous cache layout vs a block-table page fetch —
# never in these numerics: the masked-row zero contract, the fp32 online
# softmax, and the l==0 flush guard must stay bit-identical across them.
# ---------------------------------------------------------------------------

def attention_block_init(m_ref, l_ref, acc_ref):
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)


def attention_block_step(q, k, v, cols, qpos, kvlen, m_ref, l_ref, acc_ref,
                         *, scale: float, causal: bool,
                         soft_cap: Optional[float]):
    """One online-softmax step over a key block.

    q (bq, d); k (bk, d); v (bk, dv); cols (bq, bk) — the *logical* key
    positions of this block (a paged caller derives them from the logical
    block index, not the physical page); qpos (bq, 1); kvlen scalar;
    m/l/acc are the VMEM scratch of the flash recurrence.
    """
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale       # (bq, bk)
    if soft_cap:
        s = soft_cap * jnp.tanh(s / soft_cap)
    valid = cols < kvlen                                  # KV length mask
    if causal:
        valid = jnp.logical_and(valid, cols <= qpos)      # per-row offset
    s = jnp.where(valid, s, NEG_INF)
    m_prev = m_ref[...]                                   # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    # p is zeroed where invalid (not just -inf-masked): for a fully
    # masked row m_new stays NEG_INF and exp(s - m_new) would be 1.
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)         # (bq, bk)
    corr = jnp.exp(m_prev - m_new)                        # (bq, 1)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def attention_block_flush(l_ref, acc_ref, dtype):
    """l == 0 (no valid key anywhere) → zero output row, not NaN."""
    return (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(dtype)


def _kernel(qpos_ref, kvlen_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, soft_cap: Optional[float],
            bq: int, bk: int, nk: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        attention_block_init(m_ref, l_ref, acc_ref)

    qpos = qpos_ref[0]                                    # (bq, 1) int32
    kvlen = kvlen_ref[0, 0]                               # scalar int32
    # Skip key blocks no row of this q block can see: past every valid key,
    # or (causal) strictly in the future of the furthest query position.
    run = ik * bk < kvlen
    if causal:
        run = jnp.logical_and(run, ik * bk <= jnp.max(qpos))

    @pl.when(run)
    def _step():
        cols = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        attention_block_step(q_ref[0, 0], k_ref[0, 0], v_ref[0, 0], cols,
                             qpos, kvlen, m_ref, l_ref, acc_ref,
                             scale=scale, causal=causal, soft_cap=soft_cap)

    @pl.when(ik == nk - 1)
    def _flush():
        o_ref[0, 0] = attention_block_flush(l_ref, acc_ref, o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "soft_cap", "block_q", "block_k",
                     "interpret"),
)
def flash_attention(
    q: jax.Array,             # (B, H, Sq, D)
    k: jax.Array,             # (B, Hkv, Sk, D)
    v: jax.Array,             # (B, Hkv, Sk, Dv)
    q_positions: Optional[jax.Array] = None,   # (B, Sq) int32; <0 → masked
    kv_valid_len: Optional[jax.Array] = None,  # (B,) int32; None → Sk
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    soft_cap: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, D = q.shape
    _, Hkv, Sk, Dv = v.shape
    require(*attention_preconditions(H, Hkv))
    rep = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    if q_positions is None:
        # bottom-right aligned (mha_ref's tril(k=Sk-Sq)); == arange for Sq==Sk
        q_positions = jnp.broadcast_to(
            jnp.arange(Sq, dtype=jnp.int32) + (Sk - Sq), (B, Sq))
    q_positions = q_positions.astype(jnp.int32)
    if kv_valid_len is None:
        kv_valid_len = jnp.full((B,), Sk, jnp.int32)
    kv_valid_len = jnp.minimum(kv_valid_len.astype(jnp.int32), Sk)

    # pad S to block multiples; padded queries carry position -1 (fully
    # masked → zero rows, sliced off below) and padded keys sit at indices
    # >= Sk >= kv_valid_len (zero weight via the KV length mask).
    pq = (-Sq) % bq
    pk = (-Sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pq)),
                              constant_values=-1)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    Sq_p, Sk_p = Sq + pq, Sk + pk
    nq, nk = Sq_p // bq, Sk_p // bk

    # (B, Sq_p, 1) so the kernel reads a (bq, 1) tile that broadcasts
    # directly against the (bq, bk) score tile; (B, 1) for the scalar len.
    qpos_in = q_positions[..., None]
    kvlen_in = kv_valid_len[:, None]

    grid = (B, H, nq, nk)
    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               soft_cap=soft_cap, bq=bq, bk=bk, nk=nk)
    kwargs = {}
    if _CompilerParams is not None and not interpret:
        kwargs["compiler_params"] = _CompilerParams(
            dimension_semantics=ATTN_DIMENSION_SEMANTICS)
    kv_index_map = _make_kv_index_map(rep)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1), _qpos_index_map),
            pl.BlockSpec((1, 1), _kvlen_index_map),
            pl.BlockSpec((1, 1, bq, D), _q_index_map),
            pl.BlockSpec((1, 1, bk, D), kv_index_map),
            pl.BlockSpec((1, 1, bk, Dv), kv_index_map),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dv), _o_index_map),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq_p, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        interpret=interpret,
        **kwargs,
    )(qpos_in, kvlen_in, q, k, v)
    return out[:, :, :Sq]
