"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

The SSD "state-space duality" (arXiv:2405.21060) decomposes the linear
recurrence into block-matrix GEMMs — structurally the same move as the
paper's Algorithm 1 (stream block operands, accumulate block outputs). This
kernel maps it onto the TPU grid:

  grid = (B, H, n_chunks); the chunk axis is sequential ("arbitrary") and
  carries the running (P × N) state in VMEM scratch — the direct analogue of
  the paper's Buffer-C accumulator that lives on-accelerator across the
  K-stream. Per chunk, all heavy ops are MXU matmuls:

    CBᵀ  : (Q,N)@(N,Q)    intra-chunk scores
    ·L   : causal decay mask (elementwise, VPU)
    @dtx : (Q,Q)@(Q,P)    intra-chunk output
    Cᵀh  : (Q,N)@(N,P)    inter-chunk contribution from carried state
    Bᵀx  : (N,Q)@(Q,P)    state update GEMM

Validated in interpret mode against kernels/ref.py::ssd_ref and
models/ssm.py::ssd_chunked.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None)
except ImportError:  # pragma: no cover
    pltpu = None
    _CompilerParams = None

from repro.analysis.kernel_contracts import (KernelContract, OperandSpec,
                                             Precondition, register_contract)


# ---------------------------------------------------------------------------
# The dataflow mapping, stated once: handed to pl.BlockSpec below AND cited
# by the registered KernelContract. The chunk axis carries the (P, N) state
# scratch, so the contract declares it sequential even though no output
# block is revisited along it.
# ---------------------------------------------------------------------------

SSD_DIMENSION_SEMANTICS = ("parallel", "parallel", "arbitrary")


def _x_index_map(b, h, k):
    return (b, h, k, 0, 0)


def _a_index_map(b, h, k):
    return (b, h, k, 0)


def _bc_index_map(b, h, k):
    # B/C are shared across heads: fetched once per (batch, chunk)
    return (b, k, 0, 0)


def _y_index_map(b, h, k):
    return (b, h, k, 0, 0)


def ssd_chunk_size(S: int, chunk: int) -> int:
    """The kernel's chunk derivation: clamp to S, then shrink until the
    sequence divides evenly (the contract's divisibility precondition is
    satisfied by construction — this is where)."""
    Q = min(chunk, S)
    while S % Q:
        Q -= 1
    return Q


@register_contract("ssd_scan")
def ssd_contract(*, B, S, H, P, N, chunk: int = 128) -> KernelContract:
    """Contract of :func:`ssd_scan` for one logical shape.

    No output block is revisited (each (b, h, k) writes its own chunk), but
    the chunk axis threads the carried (P, N) state through VMEM scratch —
    ``sequential_axes=(2,)`` makes the checker reject a "parallel"
    declaration there (the recurrence would be reordered).
    """
    Q = ssd_chunk_size(S, chunk)
    nc = S // Q
    operands = (
        OperandSpec("x", "input", (B, H, nc, 1, 1), (1, 1, 1, Q, P),
                    _x_index_map),
        OperandSpec("a", "input", (B, H, nc, 1), (1, 1, 1, Q),
                    _a_index_map),
        OperandSpec("Bc", "input", (B, nc, 1, 1), (1, 1, Q, N),
                    _bc_index_map),
        OperandSpec("Cc", "input", (B, nc, 1, 1), (1, 1, Q, N),
                    _bc_index_map),
        OperandSpec("y", "output", (B, H, nc, 1, 1), (1, 1, 1, Q, P),
                    _y_index_map),
    )
    return KernelContract(
        kernel="ssd_scan",
        grid=(B, H, nc),
        operands=operands,
        dimension_semantics=SSD_DIMENSION_SEMANTICS,
        sequential_axes=(2,),
        preconditions=(
            Precondition.check(
                "chunk divides sequence", S % Q == 0,
                f"derived chunk Q={Q} does not divide S={S}; "
                f"ssd_chunk_size guarantees this by construction"),
        ),
        description="Mamba-2 SSD chunked scan, state carried along chunks")


def _kernel(x_ref, a_ref, b_ref, c_ref, o_ref, state_ref, *, nc: int, Q: int):
    """One (batch, head, chunk) step. Block shapes:
    x (1,1,Q,P) pre-scaled by dt; a (1,1,Q) per-step log-decay dt·A;
    b/c (1,1,Q,N); carried state scratch (P,N) fp32."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)       # (Q, P)  = dt_j * x_j
    a = a_ref[0, 0, 0].astype(jnp.float32)       # (Q,)
    bmat = b_ref[0, 0].astype(jnp.float32)       # (Q, N)
    cmat = c_ref[0, 0].astype(jnp.float32)       # (Q, N)

    cum = jnp.cumsum(a)                          # (Q,)
    # L[i,j] = exp(cum_i - cum_j) for j <= i  (segment-sum decay)
    diff = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    Lmask = jnp.where(jj <= ii, jnp.exp(diff), 0.0)

    # intra-chunk: Y = (L ∘ C Bᵀ) (dt x)
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    y_intra = jax.lax.dot_general(Lmask * cb, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk: Y += diag(exp(cum)) C h_prevᵀ        h_prev: (P,N)
    y_inter = jax.lax.dot_general(
        cmat, state_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * jnp.exp(cum)[:, None]

    # state update: h = exp(sum a) h_prev + (decay_end ∘ x)ᵀ B
    decay_end = jnp.exp(cum[-1] - cum)           # (Q,)
    xw = x * decay_end[:, None]                  # (Q, P)
    dstate = jax.lax.dot_general(xw, bmat, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (P,N)
    state_ref[...] = state_ref[...] * jnp.exp(cum[-1]) + dstate

    o_ref[0, 0, 0] = (y_intra + y_inter).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,      # (B, S, H, P)
    dt: jax.Array,     # (B, S, H)  (softplus-ed step sizes)
    A: jax.Array,      # (H,)       negative decay rates
    Bc: jax.Array,     # (B, S, N)
    Cc: jax.Array,     # (B, S, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Pallas SSD: returns y (B, S, H, P). Head-major grid; B/C shared
    across heads via the BlockSpec index map (fetched once per (b, chunk))."""
    B, S, H, P = x.shape
    N = Bc.shape[-1]
    Q = ssd_chunk_size(S, chunk)
    nc = S // Q

    # pre-scale x by dt and form per-step log-decay a = dt * A
    dtx = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])
    a = dt.astype(jnp.float32) * A[None, None, :]

    # head-major layouts: (B, H, nc, Q, ·)
    xq = dtx.reshape(B, nc, Q, H, P).transpose(0, 3, 1, 2, 4)
    aq = a.reshape(B, nc, Q, H).transpose(0, 3, 1, 2)
    bq = Bc.astype(jnp.float32).reshape(B, nc, Q, N)
    cq = Cc.astype(jnp.float32).reshape(B, nc, Q, N)

    grid = (B, H, nc)
    kernel = functools.partial(_kernel, nc=nc, Q=Q)
    kwargs = {}
    if _CompilerParams is not None and not interpret:
        kwargs["compiler_params"] = _CompilerParams(
            dimension_semantics=SSD_DIMENSION_SEMANTICS)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), _x_index_map),
            pl.BlockSpec((1, 1, 1, Q), _a_index_map),
            pl.BlockSpec((1, 1, Q, N), _bc_index_map),
            pl.BlockSpec((1, 1, Q, N), _bc_index_map),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, Q, P), _y_index_map),
        out_shape=jax.ShapeDtypeStruct((B, H, nc, Q, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(xq, aq, bq, cq)
    # (B, H, nc, Q, P) → (B, S, H, P)
    return out.reshape(B, H, S, P).transpose(0, 2, 1, 3)
