"""MatrixFlow blocked GEMM as a Pallas TPU kernel (paper Algorithm 1, C2).

The kernel executes the paper's dataflow on the TPU grid:

  grid = (M/bm, N/bn, K/bk), K innermost ("arbitrary"), M/N "parallel"
  A operand : block-major (M/bm, K/bk, bm, bk)   — one contiguous DMA per tile
  B operand : block-major (N/bn, K/bk, bk, bn)   — the paper's horizontal split
  C output  : block-major (M/bm, N/bn, bm, bn)   — written once per (i, j)
  accumulator: VMEM scratch (bm, bn) in int32/fp32 — the paper's Buffer C

Because the operands are stored block-major, each BlockSpec fetch is a single
contiguous HBM region: the Mosaic pipeline issues exactly one DMA descriptor
per tile — the TPU realization of the paper's one-page-one-transaction
property. The double-buffered VMEM windows Pallas maintains for A/B plus the
scratch accumulator are the analogue of the paper's three small local buffers.

Validated on CPU via interpret=True against kernels/ref.py (pure jnp) and
core/blockflow.py (faithful Algorithm-1 rendering).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU compiler params: name moved across jax versions
    from jax.experimental.pallas import tpu as pltpu
    _CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None)
except ImportError:  # pragma: no cover
    pltpu = None
    _CompilerParams = None

from repro.analysis.kernel_contracts import (KernelContract, OperandSpec,
                                             Precondition, register_contract,
                                             require)
from repro.core import layout as L


def _acc_dtype(dtype) -> jnp.dtype:
    if jnp.issubdtype(jnp.dtype(dtype), jnp.integer):
        return jnp.dtype(jnp.int32)
    return jnp.dtype(jnp.float32)


# ---------------------------------------------------------------------------
# The dataflow mapping, stated once: these index maps are handed to
# pl.BlockSpec below AND cited by the registered KernelContract, so the
# static checker (repro/analysis/kernel_contracts.py) verifies the very
# callables the kernel executes — coverage, bounds, and the K-revisit
# discipline of the paper's Algorithm 1.
# ---------------------------------------------------------------------------

GEMM_DIMENSION_SEMANTICS = ("parallel", "parallel", "arbitrary")


def _a_index_map(i, j, k):
    return (i, k, 0, 0)


def _b_index_map(i, j, k):
    return (j, k, 0, 0)


def _c_index_map(i, j, k):
    return (i, j, 0, 0)


def _sa_index_map(i, j, k):
    return (i, 0)


def _sb_index_map(i, j, k):
    return (0, j)


def gemm_preconditions(a_shape, b_shape, blk: L.BlockLayout):
    """The kernel's structured entry guards, shared verbatim between the
    runtime ``require`` below and the static contract."""
    nbm, nbk, bm, bk = a_shape
    nbn, nbk2, bk2, bn = b_shape
    return (
        Precondition.check(
            "A/B K-stream agreement",
            (nbk, bk) == (nbk2, bk2),
            f"block-major operands disagree on the K stream: a_bm "
            f"{tuple(a_shape)} walks {nbk} blocks of bk={bk}, b_bm "
            f"{tuple(b_shape)} walks {nbk2} blocks of bk={bk2}"),
        Precondition.check(
            "blocks match layout",
            (bm, bn, bk) == (blk.bm, blk.bn, blk.bk),
            f"operand blocks (bm={bm}, bn={bn}, bk={bk}) do not match the "
            f"BlockLayout (bm={blk.bm}, bn={blk.bn}, bk={blk.bk}); "
            f"re-layout with core.layout.to_block_major_* under this blk"),
    )


@register_contract("matrixflow_gemm")
def gemm_contract(*, a_shape, b_shape, blk: L.BlockLayout,
                  fused: bool = False) -> KernelContract:
    """Contract of :func:`matrixflow_gemm_block_major` for one instance.

    ``a_shape``/``b_shape`` are the block-major operand shapes
    ``(nbm, nbk, bm, bk)`` / ``(nbn, nbk, bk, bn)``; ``fused`` adds the
    W8A8 dequant scale panels. The C output is revisited along grid axis 2
    (the K stream) — the declared reduction axis the checker verifies.
    """
    nbm, nbk, bm, bk = a_shape
    nbn, _, _, bn = b_shape
    operands = [
        OperandSpec("a_bm", "input", (nbm, nbk, 1, 1), (1, 1, bm, bk),
                    _a_index_map),
        OperandSpec("b_bm", "input", (nbn, nbk, 1, 1), (1, 1, bk, bn),
                    _b_index_map),
        OperandSpec("c_bm", "output", (nbm, nbn, 1, 1), (1, 1, bm, bn),
                    _c_index_map, reduction_axes=(2,)),
    ]
    if fused:
        operands += [
            OperandSpec("scale_a", "input", (nbm, 1), (bm, 1),
                        _sa_index_map),
            OperandSpec("scale_b", "input", (1, nbn), (1, bn),
                        _sb_index_map),
        ]
    return KernelContract(
        kernel="matrixflow_gemm",
        grid=(nbm, nbn, nbk),
        operands=tuple(operands),
        dimension_semantics=GEMM_DIMENSION_SEMANTICS,
        preconditions=gemm_preconditions(a_shape, b_shape, blk),
        description="paper Algorithm 1 on the TPU grid (K innermost)")


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, nbk: int, acc_dtype):
    """One grid step: MultiAcc(A[i,k], B[j,k]) into the VMEM accumulator."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[0, 0]            # (bm, bk) — one contiguous MatrixFlow block
    b = b_ref[0, 0]            # (bk, bn)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=acc_dtype)

    @pl.when(k == nbk - 1)
    def _flush():
        o_ref[0, 0] = acc_ref[...].astype(o_ref.dtype)


def _kernel_fused_dequant(a_ref, b_ref, sa_ref, sb_ref, o_ref, acc_ref, *,
                          nbk: int, acc_dtype):
    """Same schedule, with the W8A8 dequant fused into the C-block flush:
    the finished int32 accumulator is rescaled by the per-row activation
    scale and the per-channel weight scale before the single HBM write
    (core/quant.py's rank-1 dequant — no second pass over C)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[0, 0]
    b = b_ref[0, 0]
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=acc_dtype)

    @pl.when(k == nbk - 1)
    def _flush():
        scaled = (acc_ref[...].astype(jnp.float32)
                  * sa_ref[...] * sb_ref[...])      # (bm,1)*(1,bn) broadcast
        o_ref[0, 0] = scaled.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("blk", "out_dtype", "interpret", "acc_dtype"),
)
def matrixflow_gemm_block_major(
    a_bm: jax.Array,
    b_bm: jax.Array,
    *,
    blk: L.BlockLayout,
    out_dtype: Optional[jnp.dtype] = None,
    interpret: bool = False,
    acc_dtype: Optional[jnp.dtype] = None,
    scale_a: Optional[jax.Array] = None,
    scale_b: Optional[jax.Array] = None,
) -> jax.Array:
    """C_bm = A_bm @ B_bm over MatrixFlow block-major operands.

    a_bm: (nbm, nbk, bm, bk); b_bm: (nbn, nbk, bk, bn) →
    returns C block-major (nbm, nbn, bm, bn). ``acc_dtype`` overrides the
    default accumulator policy (int → int32, float → fp32) — a GemmPolicy
    knob at the ExecutionPlan layer.

    ``scale_a`` (≤ nbm·bm rows) / ``scale_b`` (≤ nbn·bn channels) switch in
    the dequant-fused kernel for the int8 W8A8 route: each finished int32
    C block is rescaled by ``s_a[m] * s_b[n]`` in VMEM before its single
    HBM write. With scales present the default out_dtype is float32.
    """
    nbm, nbk, bm, bk = a_bm.shape
    nbn, _, _, bn = b_bm.shape
    require(*gemm_preconditions(a_bm.shape, b_bm.shape, blk))
    acc_dtype = jnp.dtype(acc_dtype or _acc_dtype(a_bm.dtype))
    fused = scale_a is not None or scale_b is not None
    out_dtype = jnp.dtype(out_dtype or
                          (jnp.float32 if fused else acc_dtype))

    grid = (nbm, nbn, nbk)
    kwargs = {}
    if _CompilerParams is not None and not interpret:
        kwargs["compiler_params"] = _CompilerParams(
            dimension_semantics=GEMM_DIMENSION_SEMANTICS,
        )
    scratch = [pltpu.VMEM((bm, bn), acc_dtype)]

    in_specs = [
        pl.BlockSpec((1, 1, bm, bk), _a_index_map),
        pl.BlockSpec((1, 1, bk, bn), _b_index_map),
    ]
    operands = [a_bm, b_bm]
    if fused:
        # Scales enter as (M, 1) / (1, N) fp32 panels, zero-padded to the
        # block grid; each tile sees its (bm, 1) / (1, bn) slice.
        sa = (jnp.ones((nbm * bm,), jnp.float32) if scale_a is None
              else jnp.pad(scale_a.astype(jnp.float32),
                           (0, nbm * bm - scale_a.shape[0])))
        sb = (jnp.ones((nbn * bn,), jnp.float32) if scale_b is None
              else jnp.pad(scale_b.astype(jnp.float32),
                           (0, nbn * bn - scale_b.shape[0])))
        in_specs += [
            pl.BlockSpec((bm, 1), _sa_index_map),
            pl.BlockSpec((1, bn), _sb_index_map),
        ]
        operands += [sa.reshape(nbm * bm, 1), sb.reshape(1, nbn * bn)]
        kernel = functools.partial(_kernel_fused_dequant, nbk=nbk,
                                   acc_dtype=acc_dtype)
    else:
        kernel = functools.partial(_kernel, nbk=nbk, acc_dtype=acc_dtype)

    call = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, bm, bn), _c_index_map),
        out_shape=jax.ShapeDtypeStruct((nbm, nbn, bm, bn), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )
    return call(*operands)


def matrixflow_gemm(
    a: jax.Array,
    b: jax.Array,
    *,
    blk: Optional[L.BlockLayout] = None,
    mode: str = "dm",
    out_dtype: Optional[jnp.dtype] = None,
    interpret: bool = False,
    acc_dtype: Optional[jnp.dtype] = None,
    scale_a: Optional[jax.Array] = None,
    scale_b: Optional[jax.Array] = None,
) -> jax.Array:
    """C = A @ B: re-layout (the paper's data-structure step) + blocked kernel.

    a: (M, K), b: (K, N) row-major. For persistent weights prefer packing
    block-major once (core/plan.py's PackedWeight) — api.linear then calls
    matrixflow_gemm_block_major directly, skipping the per-call re-layout.
    ``scale_a`` (M,) / ``scale_b`` (N,) select the dequant-fused int8 kernel.
    """
    M, K = a.shape
    K2, N = b.shape
    require(Precondition.check(
        "A/B contraction agreement", K == K2,
        f"a has K={K} columns but b has K={K2} rows; C = A @ B needs the "
        f"contraction dims to agree (a {a.shape}, b {b.shape})"))
    if blk is None:
        blk = L.choose_layout(M, N, K, a.dtype, mode=mode)
    a_bm = L.to_block_major_a(a, blk.bm, blk.bk)
    b_bm = L.to_block_major_b(b, blk.bk, blk.bn)
    c_bm = matrixflow_gemm_block_major(
        a_bm, b_bm, blk=blk, out_dtype=out_dtype, interpret=interpret,
        acc_dtype=acc_dtype, scale_a=scale_a, scale_b=scale_b)
    return L.from_block_major_c(c_bm, M, N)
