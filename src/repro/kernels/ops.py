"""jit'd dispatch wrappers over the Pallas kernels.

One entry point per kernel, handling:
  * backend policy (real TPU pallas vs CPU interpret vs pure-jnp oracle),
  * the paper's DC/DM access-mode block geometries,
  * layout plumbing (row-major model tensors ↔ kernel-native layouts).

Models call repro.core.api (which routes GEMMs here under the pallas
backends); tests call these directly for shape/dtype sweeps.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import layout as L
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.matrixflow_gemm import (matrixflow_gemm,
                                           matrixflow_gemm_block_major)
from repro.kernels.ssd_scan import ssd_scan


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def gemm(a: jax.Array, b: jax.Array, *, mode: str = "dm",
         out_dtype: Optional[jnp.dtype] = None,
         impl: Optional[str] = None) -> jax.Array:
    """MatrixFlow GEMM. impl: None (auto) | 'pallas' | 'interpret' | 'ref'."""
    impl = impl or ("pallas" if _on_tpu() else "interpret")
    if impl == "ref":
        return ref.matmul_ref(a, b, out_dtype)
    return matrixflow_gemm(a, b, mode=mode, out_dtype=out_dtype,
                           interpret=(impl == "interpret"))


def gemm_preformatted(a_bm: jax.Array, b_bm: jax.Array, *, blk: L.BlockLayout,
                      out_dtype: Optional[jnp.dtype] = None,
                      impl: Optional[str] = None) -> jax.Array:
    """Deploy path: operands already block-major (weights formatted once at
    load; activations produced block-major by the previous GEMM — Fig. 5)."""
    impl = impl or ("pallas" if _on_tpu() else "interpret")
    return matrixflow_gemm_block_major(a_bm, b_bm, blk=blk,
                                       out_dtype=out_dtype,
                                       interpret=(impl == "interpret"))


def mha(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
        scale: Optional[float] = None, soft_cap: Optional[float] = None,
        q_positions: Optional[jax.Array] = None,
        kv_valid_len: Optional[jax.Array] = None,
        impl: Optional[str] = None,
        block_q: int = 128, block_k: int = 128) -> jax.Array:
    """Fused attention over (B, S, H, D)-layout tensors (model layout).

    q_positions (B, Sq) / kv_valid_len (B,) carry the decode/serving offset
    and cache-length semantics (see kernels/flash_attention.py). impl 'ref'
    uses the pure-jnp oracle; otherwise the Pallas flash kernel (interpret
    mode off-TPU)."""
    impl = impl or ("pallas" if _on_tpu() else "interpret")
    if impl == "ref":
        return ref.mha_ref(q, k, v, causal=causal, scale=scale,
                           soft_cap=soft_cap, q_positions=q_positions,
                           kv_valid_len=kv_valid_len)
    out = flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), q_positions, kv_valid_len,
        causal=causal, scale=scale, soft_cap=soft_cap,
        block_q=block_q, block_k=block_k,
        interpret=(impl == "interpret"))
    return out.transpose(0, 2, 1, 3)


def ssd(x: jax.Array, dt: jax.Array, A: jax.Array, Bc: jax.Array,
        Cc: jax.Array, *, chunk: int = 128,
        impl: Optional[str] = None) -> jax.Array:
    """Chunked SSD scan (B, S, H, P). impl as in mha()."""
    impl = impl or ("pallas" if _on_tpu() else "interpret")
    if impl == "ref":
        return ref.ssd_ref(x, dt, A, Bc, Cc)
    return ssd_scan(x, dt, A, Bc, Cc, chunk=chunk,
                    interpret=(impl == "interpret"))
