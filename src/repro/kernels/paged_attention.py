"""Paged (block-table) flash attention as a Pallas TPU kernel.

The serving engine's largest tensor is the KV cache, and a contiguous
``(batch_slots, max_len)`` slab violates the paper's core discipline —
stream page/block-sized operand tiles and never materialize the worst case
(§4.3–4.4). This kernel closes that gap: K/V live in a **page pool** of
fixed ``page_size``-token pages (``serving/kv_pool.py``) and each request
owns a **block table** mapping its logical key blocks to physical pages.
The block table drives the BlockSpec index maps through Pallas scalar
prefetch (``pltpu.PrefetchScalarGridSpec``): grid step ``(b, h, i, j)``
DMA-fetches physical page ``block_tables[b, j]`` — the MatrixFlow "fetch
exactly the block you need" property, applied to the KV cache.

Everything else is PR 3's offset-aware flash recurrence, unchanged:

  * the logical position of page-``j`` slot ``t`` is ``j * page_size + t``,
    so ``q_positions`` (per-row absolute query positions, −1 → masked row)
    and ``kv_valid_len`` (populated cache slots per row) mask *logical*
    key indices exactly as ``kernels/flash_attention.py`` does — one kernel
    covers paged prefill, paged decode, and GQA (kv head = h // rep in the
    index map);
  * key blocks past a row's valid length or causal frontier are skipped at
    runtime, so decode against a mostly-empty pool touches only the
    populated pages;
  * a fully masked query row produces exactly zeros, never NaN.

Unallocated block-table entries must simply be *valid* page indices (the
engine leaves them at 0): the length mask already gives their keys zero
weight, so the fetched bytes are dead — they only have to be fetchable.

Validated in interpret mode against kernels/ref.py::mha_ref (the pool is
gathered back to a dense cache for the oracle) in tests/parity.py and
tests/test_paged_attention.py.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None)
except ImportError:  # pragma: no cover
    pltpu = None
    _CompilerParams = None

from repro.kernels.flash_attention import (attention_block_flush,
                                           attention_block_init,
                                           attention_block_step)


def _kernel(bt_ref, kvlen_ref, qpos_ref, q_ref, k_ref, v_ref, *rest,
            scale: float, causal: bool, soft_cap: Optional[float],
            bq: int, ps: int, nb: int, quantized: bool):
    # rest is [ks_ref, vs_ref,] o_ref, m_ref, l_ref, acc_ref — the scale
    # operands exist only on the int8 path (pallas passes refs positionally
    # in in_specs order, then outputs, then scratch).
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    ij = pl.program_id(3)                                 # logical key block

    @pl.when(ij == 0)
    def _init():
        attention_block_init(m_ref, l_ref, acc_ref)

    qpos = qpos_ref[0]                                    # (bq, 1) int32
    kvlen = kvlen_ref[b]                                  # scalar int32
    # Skip logical key blocks no row of this q block can see: past every
    # valid key, or (causal) strictly beyond the furthest query position.
    run = ij * ps < kvlen
    if causal:
        run = jnp.logical_and(run, ij * ps <= jnp.max(qpos))

    @pl.when(run)
    def _step():
        # cols are LOGICAL key positions: the block table only redirects the
        # physical fetch (this kernel's BlockSpec index maps), never the
        # masking arithmetic — the numerics are flash_attention.py's
        # recurrence, shared verbatim.
        cols = ij * ps + jax.lax.broadcasted_iota(jnp.int32, (bq, ps), 1)
        kblk = k_ref[0, :, 0]
        vblk = v_ref[0, :, 0]
        if quantized:
            # dequantize the int8 page in-register: the HBM→VMEM stream
            # stayed int8, the recurrence below runs fp32 as always. The
            # scale tile is (1, 1) — this page, this kv head.
            kblk = kblk.astype(jnp.float32) * ks_ref[0, 0]
            vblk = vblk.astype(jnp.float32) * vs_ref[0, 0]
        attention_block_step(q_ref[0, :, 0], kblk, vblk,
                             cols, qpos, kvlen, m_ref, l_ref, acc_ref,
                             scale=scale, causal=causal, soft_cap=soft_cap)

    @pl.when(ij == nb - 1)
    def _flush():
        o_ref[0, :, 0] = attention_block_flush(l_ref, acc_ref, o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "soft_cap", "block_q", "interpret"),
)
def paged_attention(
    q: jax.Array,             # (B, Sq, H, D)   — model layout
    k_pages: jax.Array,       # (P, page_size, Hkv, D)
    v_pages: jax.Array,       # (P, page_size, Hkv, Dv)
    block_tables: jax.Array,  # (B, n_blocks) int32 physical page per block
    q_positions: Optional[jax.Array] = None,   # (B, Sq) int32; <0 → masked
    kv_valid_len: Optional[jax.Array] = None,  # (B,) int32; None → all keys
    *,
    kv_scales=None,           # int8 pools: ((P, Hkv), (P, Hkv)) fp32 scales
    causal: bool = True,
    scale: Optional[float] = None,
    soft_cap: Optional[float] = None,
    block_q: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Flash attention reading K/V through a block table.

    The key-block size IS the page size (``k_pages.shape[1]``): page
    granularity and kernel block granularity coincide by construction, the
    alignment the paper's block-streaming datapath assumes. Returns
    (B, Sq, H, Dv) in model layout.

    With int8 pools, ``kv_scales`` must carry the per-page-per-head fp32
    ``(k_scales, v_scales)`` arrays of shape (P, Hkv) (docs/quant.md
    #kv-pages); the kernel fetches each page's (1, 1) scale alongside the
    page and dequantizes in-register, so the HBM stream stays int8.
    """
    B, Sq, H, D = q.shape
    P, ps, Hkv, Dv = v_pages.shape
    assert H % Hkv == 0, (H, Hkv)
    assert k_pages.shape[:3] == (P, ps, Hkv), (k_pages.shape, v_pages.shape)
    quantized = k_pages.dtype == jnp.int8
    if quantized != (v_pages.dtype == jnp.int8):
        raise ValueError(
            f"k_pages/v_pages dtype mismatch: {k_pages.dtype} vs "
            f"{v_pages.dtype}")
    if quantized:
        if kv_scales is None:
            raise ValueError(
                "int8 k_pages/v_pages need kv_scales=(k_scales, v_scales) "
                "per-page-per-head fp32 arrays of shape (P, Hkv)")
        k_scales, v_scales = kv_scales
        for name, s in (("k_scales", k_scales), ("v_scales", v_scales)):
            if tuple(s.shape) != (P, Hkv):
                raise ValueError(
                    f"{name} has shape {tuple(s.shape)}, expected "
                    f"(P, Hkv) = {(P, Hkv)}")
        k_scales = k_scales.astype(jnp.float32)
        v_scales = v_scales.astype(jnp.float32)
    elif kv_scales is not None:
        raise ValueError(
            f"kv_scales given but pages are {k_pages.dtype}, not int8")
    nb = block_tables.shape[1]
    if nb == 0:
        # Empty block table: no key block is visible (kv_valid_len is
        # clamped to nb * ps == 0 below), so every query row is fully
        # masked — the contract says exactly zeros. The grid (B, H, nq, 0)
        # would never run the flush step, so short-circuit here.
        return jnp.zeros((B, Sq, H, Dv), q.dtype)
    rep = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    bq = min(block_q, Sq)
    if q_positions is None:
        q_positions = jnp.broadcast_to(
            jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
    q_positions = q_positions.astype(jnp.int32)
    if kv_valid_len is None:
        kv_valid_len = jnp.full((B,), nb * ps, jnp.int32)
    kv_valid_len = jnp.minimum(kv_valid_len.astype(jnp.int32), nb * ps)
    block_tables = block_tables.astype(jnp.int32)

    # pad Sq to a block multiple; padded query rows carry position -1
    # (fully masked → zero rows, sliced off below).
    pq = (-Sq) % bq
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pq)),
                              constant_values=-1)
    Sq_p = Sq + pq
    nq = Sq_p // bq

    qpos_in = q_positions[..., None]        # (B, Sq_p, 1): (bq, 1) tiles

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               soft_cap=soft_cap, bq=bq, ps=ps, nb=nb,
                               quantized=quantized)
    in_specs = [
        pl.BlockSpec((1, bq, 1), lambda b, h, i, j, bt, kvl: (b, i, 0)),
        pl.BlockSpec((1, bq, 1, D),
                     lambda b, h, i, j, bt, kvl: (b, i, h, 0)),
        # the paged indirection: the block table entry IS the index
        pl.BlockSpec((1, ps, 1, D),
                     lambda b, h, i, j, bt, kvl, rep=rep:
                     (bt[b, j], 0, h // rep, 0)),
        pl.BlockSpec((1, ps, 1, Dv),
                     lambda b, h, i, j, bt, kvl, rep=rep:
                     (bt[b, j], 0, h // rep, 0)),
    ]
    operands = [block_tables, kv_valid_len, qpos_in, q, k_pages, v_pages]
    if quantized:
        # each page's scale rides the same block-table indirection as the
        # page itself: one (1, 1) fp32 element per (page, kv head).
        scale_spec = pl.BlockSpec((1, 1),
                                  lambda b, h, i, j, bt, kvl, rep=rep:
                                  (bt[b, j], h // rep))
        in_specs += [scale_spec, scale_spec]
        operands += [k_scales, v_scales]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,              # block_tables, kv_valid_len
        grid=(B, H, nq, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bq, 1, Dv),
                               lambda b, h, i, j, bt, kvl: (b, i, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
    )
    kwargs = {}
    if _CompilerParams is not None and not interpret:
        kwargs["compiler_params"] = _CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Sq_p, H, Dv), q.dtype),
        interpret=interpret,
        **kwargs,
    )(*operands)
    return out[:, :Sq]


def gather_pages(pages: jax.Array, block_tables: jax.Array,
                 max_len: Optional[int] = None) -> jax.Array:
    """Gather a (P, page_size, Hkv, D) pool back to dense (B, T, Hkv, D)
    caches through the block tables — the oracle/debug inverse of the paged
    layout (used by parity tests to feed mha_ref, never by the hot path)."""
    P, ps, Hkv, D = pages.shape
    B, nb = block_tables.shape
    dense = pages[block_tables.astype(jnp.int32)]       # (B, nb, ps, Hkv, D)
    dense = dense.reshape(B, nb * ps, Hkv, D)
    return dense if max_len is None else dense[:, :max_len]
