"""Paged (block-table) flash attention as a Pallas TPU kernel.

The serving engine's largest tensor is the KV cache, and a contiguous
``(batch_slots, max_len)`` slab violates the paper's core discipline —
stream page/block-sized operand tiles and never materialize the worst case
(§4.3–4.4). This kernel closes that gap: K/V live in a **page pool** of
fixed ``page_size``-token pages (``serving/kv_pool.py``) and each request
owns a **block table** mapping its logical key blocks to physical pages.
The block table drives the BlockSpec index maps through Pallas scalar
prefetch (``pltpu.PrefetchScalarGridSpec``): grid step ``(b, h, i, j)``
DMA-fetches physical page ``block_tables[b, j]`` — the MatrixFlow "fetch
exactly the block you need" property, applied to the KV cache.

Everything else is PR 3's offset-aware flash recurrence, unchanged:

  * the logical position of page-``j`` slot ``t`` is ``j * page_size + t``,
    so ``q_positions`` (per-row absolute query positions, −1 → masked row)
    and ``kv_valid_len`` (populated cache slots per row) mask *logical*
    key indices exactly as ``kernels/flash_attention.py`` does — one kernel
    covers paged prefill, paged decode, and GQA (kv head = h // rep in the
    index map);
  * key blocks past a row's valid length or causal frontier are skipped at
    runtime, so decode against a mostly-empty pool touches only the
    populated pages;
  * a fully masked query row produces exactly zeros, never NaN.

Unallocated block-table entries must simply be *valid* page indices (the
engine leaves them at 0): the length mask already gives their keys zero
weight, so the fetched bytes are dead — they only have to be fetchable.

The offset/mask semantics buy speculative decoding for free: the
engine's verify pass (docs/serving.md#speculative-decoding) runs this
same kernel at ``Sq = 1 + k`` with ``q_positions`` starting at the
slot's current offset (−1 padding for unused rows), scoring a pending
token plus ``k`` drafted tokens in one call — chunked prefill, plain
decode, and speculative verify are all just different ``(Sq,
q_positions)`` shapes of one contract.

Validated in interpret mode against kernels/ref.py::mha_ref (the pool is
gathered back to a dense cache for the oracle) in tests/parity.py and
tests/test_paged_attention.py.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None)
except ImportError:  # pragma: no cover
    pltpu = None
    _CompilerParams = None

from repro.analysis.kernel_contracts import (KernelContract, OperandSpec,
                                             Precondition, register_contract,
                                             require)
from repro.kernels.flash_attention import (ATTN_DIMENSION_SEMANTICS,
                                           attention_block_flush,
                                           attention_block_init,
                                           attention_block_step)


# ---------------------------------------------------------------------------
# The dataflow mapping, stated once. All maps take the scalar-prefetch
# signature (b, h, i, j, bt, kvl); the registered contract binds a concrete
# block table and hands the checker the very callables pallas_call runs.
# ---------------------------------------------------------------------------

def _pg_qpos_index_map(b, h, i, j, bt, kvl):
    return (b, i, 0)


def _pg_q_index_map(b, h, i, j, bt, kvl):
    return (b, i, h, 0)


def _make_page_index_map(rep: int):
    """The paged indirection: logical key block j of batch row b lives in
    physical page ``bt[b, j]``; GQA folds the query head to kv head
    h // rep. The block-table entry IS the index."""
    def _page_index_map(b, h, i, j, bt, kvl):
        return (bt[b, j], 0, h // rep, 0)
    return _page_index_map


def _make_page_scale_index_map(rep: int):
    """Each int8 page's (1, 1) fp32 scale rides the same indirection."""
    def _page_scale_index_map(b, h, i, j, bt, kvl):
        return (bt[b, j], h // rep)
    return _page_scale_index_map


def _pg_o_index_map(b, h, i, j, bt, kvl):
    return (b, i, h, 0)


def paged_preconditions(H, Hkv, k_pages_shape, v_pages_shape, nb):
    """Structured entry guards shared between runtime and static checker."""
    P, ps, Hkv_v = v_pages_shape[0], v_pages_shape[1], v_pages_shape[2]
    return (
        Precondition.check(
            "GQA head divisibility", Hkv > 0 and H % Hkv == 0,
            f"H={H} query heads must be an integer multiple of Hkv={Hkv} "
            f"kv heads"),
        Precondition.check(
            "K/V pool agreement",
            tuple(k_pages_shape[:3]) == (P, ps, Hkv_v),
            f"k_pages {tuple(k_pages_shape)} and v_pages "
            f"{tuple(v_pages_shape)} disagree on (P, page_size, Hkv); the "
            f"pools must be allocated as one paged cache"),
        Precondition.check(
            "populated block table", nb > 0,
            f"block table has {nb} blocks: the grid's key axis would have "
            f"zero extent and the flush step would never run (the caller "
            f"must short-circuit nb == 0 to zeros)"),
    )


@register_contract("paged_attention")
def paged_attention_contract(*, B, Sq, H, Hkv, D, Dv, P, page_size,
                             block_tables, block_q: int = 128,
                             quantized: bool = False) -> KernelContract:
    """Contract of :func:`paged_attention` for one concrete block table.

    ``block_tables`` is the actual (B, nb) int array: the checker evaluates
    the kernel's scalar-prefetch index maps against it, so out-of-range
    page indices surface as bounds violations and pool coverage narrows to
    exactly the pages the table references (distractor pages are dead by
    design). Output o is revisited along grid axis 3 (the key stream).
    """
    bt = np.asarray(block_tables, dtype=np.int64)
    nb = bt.shape[1] if bt.ndim == 2 else 0
    rep = H // Hkv if Hkv and H % Hkv == 0 else 1
    ps = page_size
    bq = min(block_q, Sq)
    nq = (Sq + (-Sq) % bq) // bq
    page_map = _make_page_index_map(rep)
    scale_map = _make_page_scale_index_map(rep)

    def bind(m):
        # close over the concrete table, exactly like PrefetchScalarGridSpec
        return lambda b, h, i, j: m(b, h, i, j, bt, None)

    referenced = frozenset(
        (int(bt[b, j]), 0, hk, 0)
        for b in range(bt.shape[0]) for j in range(nb)
        for hk in range(Hkv))
    operands = [
        OperandSpec("q_positions", "input", (B, nq, 1), (1, bq, 1),
                    bind(_pg_qpos_index_map)),
        OperandSpec("q", "input", (B, nq, H, 1), (1, bq, 1, D),
                    bind(_pg_q_index_map)),
        OperandSpec("k_pages", "input", (P, 1, Hkv, 1), (1, ps, 1, D),
                    bind(page_map), expected_blocks=referenced),
        OperandSpec("v_pages", "input", (P, 1, Hkv, 1), (1, ps, 1, Dv),
                    bind(page_map), expected_blocks=referenced),
        OperandSpec("o", "output", (B, nq, H, 1), (1, bq, 1, Dv),
                    bind(_pg_o_index_map), reduction_axes=(3,)),
    ]
    if quantized:
        scale_blocks = frozenset(
            (p, hk) for (p, _z, hk, _w) in referenced)
        operands += [
            OperandSpec("k_scales", "input", (P, Hkv), (1, 1),
                        bind(scale_map), expected_blocks=scale_blocks),
            OperandSpec("v_scales", "input", (P, Hkv), (1, 1),
                        bind(scale_map), expected_blocks=scale_blocks),
        ]
    k_shape = (P, ps, Hkv, D)
    v_shape = (P, ps, Hkv, Dv)
    return KernelContract(
        kernel="paged_attention",
        grid=(B, H, nq, nb),
        operands=tuple(operands),
        dimension_semantics=ATTN_DIMENSION_SEMANTICS,
        preconditions=paged_preconditions(H, Hkv, k_shape, v_shape, nb),
        description="block-table paged flash attention (scalar prefetch)")


def _kernel(bt_ref, kvlen_ref, qpos_ref, q_ref, k_ref, v_ref, *rest,
            scale: float, causal: bool, soft_cap: Optional[float],
            bq: int, ps: int, nb: int, quantized: bool):
    # rest is [ks_ref, vs_ref,] o_ref, m_ref, l_ref, acc_ref — the scale
    # operands exist only on the int8 path (pallas passes refs positionally
    # in in_specs order, then outputs, then scratch).
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    ij = pl.program_id(3)                                 # logical key block

    @pl.when(ij == 0)
    def _init():
        attention_block_init(m_ref, l_ref, acc_ref)

    qpos = qpos_ref[0]                                    # (bq, 1) int32
    kvlen = kvlen_ref[b]                                  # scalar int32
    # Skip logical key blocks no row of this q block can see: past every
    # valid key, or (causal) strictly beyond the furthest query position.
    run = ij * ps < kvlen
    if causal:
        run = jnp.logical_and(run, ij * ps <= jnp.max(qpos))

    @pl.when(run)
    def _step():
        # cols are LOGICAL key positions: the block table only redirects the
        # physical fetch (this kernel's BlockSpec index maps), never the
        # masking arithmetic — the numerics are flash_attention.py's
        # recurrence, shared verbatim.
        cols = ij * ps + jax.lax.broadcasted_iota(jnp.int32, (bq, ps), 1)
        kblk = k_ref[0, :, 0]
        vblk = v_ref[0, :, 0]
        if quantized:
            # dequantize the int8 page in-register: the HBM→VMEM stream
            # stayed int8, the recurrence below runs fp32 as always. The
            # scale tile is (1, 1) — this page, this kv head.
            kblk = kblk.astype(jnp.float32) * ks_ref[0, 0]
            vblk = vblk.astype(jnp.float32) * vs_ref[0, 0]
        attention_block_step(q_ref[0, :, 0], kblk, vblk,
                             cols, qpos, kvlen, m_ref, l_ref, acc_ref,
                             scale=scale, causal=causal, soft_cap=soft_cap)

    @pl.when(ij == nb - 1)
    def _flush():
        o_ref[0, :, 0] = attention_block_flush(l_ref, acc_ref, o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "soft_cap", "block_q", "interpret"),
)
def paged_attention(
    q: jax.Array,             # (B, Sq, H, D)   — model layout
    k_pages: jax.Array,       # (P, page_size, Hkv, D)
    v_pages: jax.Array,       # (P, page_size, Hkv, Dv)
    block_tables: jax.Array,  # (B, n_blocks) int32 physical page per block
    q_positions: Optional[jax.Array] = None,   # (B, Sq) int32; <0 → masked
    kv_valid_len: Optional[jax.Array] = None,  # (B,) int32; None → all keys
    *,
    kv_scales=None,           # int8 pools: ((P, Hkv), (P, Hkv)) fp32 scales
    causal: bool = True,
    scale: Optional[float] = None,
    soft_cap: Optional[float] = None,
    block_q: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Flash attention reading K/V through a block table.

    The key-block size IS the page size (``k_pages.shape[1]``): page
    granularity and kernel block granularity coincide by construction, the
    alignment the paper's block-streaming datapath assumes. Returns
    (B, Sq, H, Dv) in model layout.

    With int8 pools, ``kv_scales`` must carry the per-page-per-head fp32
    ``(k_scales, v_scales)`` arrays of shape (P, Hkv) (docs/quant.md
    #kv-pages); the kernel fetches each page's (1, 1) scale alongside the
    page and dequantizes in-register, so the HBM stream stays int8.
    """
    B, Sq, H, D = q.shape
    P, ps, Hkv, Dv = v_pages.shape
    nb_early = block_tables.shape[1]
    pre = paged_preconditions(H, Hkv, k_pages.shape, v_pages.shape, nb_early)
    # nb == 0 is legal here (short-circuited below); the other two guards
    # are hard errors shared verbatim with the static contract.
    require(*pre[:2])
    quantized = k_pages.dtype == jnp.int8
    if quantized != (v_pages.dtype == jnp.int8):
        raise ValueError(
            f"k_pages/v_pages dtype mismatch: {k_pages.dtype} vs "
            f"{v_pages.dtype}")
    if quantized:
        if kv_scales is None:
            raise ValueError(
                "int8 k_pages/v_pages need kv_scales=(k_scales, v_scales) "
                "per-page-per-head fp32 arrays of shape (P, Hkv)")
        k_scales, v_scales = kv_scales
        for name, s in (("k_scales", k_scales), ("v_scales", v_scales)):
            if tuple(s.shape) != (P, Hkv):
                raise ValueError(
                    f"{name} has shape {tuple(s.shape)}, expected "
                    f"(P, Hkv) = {(P, Hkv)}")
        k_scales = k_scales.astype(jnp.float32)
        v_scales = v_scales.astype(jnp.float32)
    elif kv_scales is not None:
        raise ValueError(
            f"kv_scales given but pages are {k_pages.dtype}, not int8")
    nb = nb_early
    if nb == 0:
        # Empty block table: no key block is visible (kv_valid_len is
        # clamped to nb * ps == 0 below), so every query row is fully
        # masked — the contract says exactly zeros. The grid (B, H, nq, 0)
        # would never run the flush step, so short-circuit here.
        return jnp.zeros((B, Sq, H, Dv), q.dtype)
    rep = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    bq = min(block_q, Sq)
    if q_positions is None:
        q_positions = jnp.broadcast_to(
            jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
    q_positions = q_positions.astype(jnp.int32)
    if kv_valid_len is None:
        kv_valid_len = jnp.full((B,), nb * ps, jnp.int32)
    kv_valid_len = jnp.minimum(kv_valid_len.astype(jnp.int32), nb * ps)
    block_tables = block_tables.astype(jnp.int32)

    # pad Sq to a block multiple; padded query rows carry position -1
    # (fully masked → zero rows, sliced off below).
    pq = (-Sq) % bq
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pq)),
                              constant_values=-1)
    Sq_p = Sq + pq
    nq = Sq_p // bq

    qpos_in = q_positions[..., None]        # (B, Sq_p, 1): (bq, 1) tiles

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               soft_cap=soft_cap, bq=bq, ps=ps, nb=nb,
                               quantized=quantized)
    page_index_map = _make_page_index_map(rep)
    in_specs = [
        pl.BlockSpec((1, bq, 1), _pg_qpos_index_map),
        pl.BlockSpec((1, bq, 1, D), _pg_q_index_map),
        # the paged indirection: the block table entry IS the index
        pl.BlockSpec((1, ps, 1, D), page_index_map),
        pl.BlockSpec((1, ps, 1, Dv), page_index_map),
    ]
    operands = [block_tables, kv_valid_len, qpos_in, q, k_pages, v_pages]
    if quantized:
        # each page's scale rides the same block-table indirection as the
        # page itself: one (1, 1) fp32 element per (page, kv head).
        scale_spec = pl.BlockSpec((1, 1), _make_page_scale_index_map(rep))
        in_specs += [scale_spec, scale_spec]
        operands += [k_scales, v_scales]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,              # block_tables, kv_valid_len
        grid=(B, H, nq, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bq, 1, Dv), _pg_o_index_map),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
    )
    kwargs = {}
    if _CompilerParams is not None and not interpret:
        kwargs["compiler_params"] = _CompilerParams(
            dimension_semantics=ATTN_DIMENSION_SEMANTICS)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Sq_p, H, Dv), q.dtype),
        interpret=interpret,
        **kwargs,
    )(*operands)
    return out[:, :Sq]


def gather_pages(pages: jax.Array, block_tables: jax.Array,
                 max_len: Optional[int] = None) -> jax.Array:
    """Gather a (P, page_size, Hkv, D) pool back to dense (B, T, Hkv, D)
    caches through the block tables — the oracle/debug inverse of the paged
    layout (used by parity tests to feed mha_ref, never by the hot path)."""
    P, ps, Hkv, D = pages.shape
    B, nb = block_tables.shape
    dense = pages[block_tables.astype(jnp.int32)]       # (B, nb, ps, Hkv, D)
    dense = dense.reshape(B, nb * ps, Hkv, D)
    return dense if max_len is None else dense[:, :max_len]
