"""Public MatrixFlow API: backend policy + matmul/linear entry points.

Every GEMM in the model substrate routes through :func:`matmul`, which
dispatches on the active backend:

  "xla"               jnp.dot — used for distributed dry-run lowering and CPU
                      training examples (XLA already tiles for the MXU; the
                      MatrixFlow schedule is a kernel-level concern).
  "pallas"            the MatrixFlow Pallas kernel (TPU target).
  "pallas_interpret"  same kernel, interpret mode (CPU validation).
  "blockflow"         the faithful Algorithm-1 lax rendering (paper baseline).

The default is "pallas" on TPU and "xla" elsewhere, matching how the
framework would deploy. Tests/benchmarks use `gemm_backend(...)` to pin.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import blockflow, layout as L

_state = threading.local()


def _default_backend() -> str:
    try:
        plat = jax.default_backend()
    except Exception:  # pragma: no cover
        plat = "cpu"
    return "pallas" if plat == "tpu" else "xla"


def current_backend() -> str:
    return getattr(_state, "backend", None) or _default_backend()


@contextlib.contextmanager
def gemm_backend(name: str):
    """Context manager pinning the GEMM backend ("xla"|"pallas"|"pallas_interpret"|"blockflow")."""
    prev = getattr(_state, "backend", None)
    _state.backend = name
    try:
        yield
    finally:
        _state.backend = prev


def matmul(a: jax.Array, b: jax.Array, *, mode: str = "dm",
           out_dtype: Optional[jnp.dtype] = None) -> jax.Array:
    """C = A @ B through the active MatrixFlow backend.

    a: (..., M, K); b: (K, N) or (..., K, N). Output dtype defaults to the
    promoted input dtype (not the accumulator) to keep model code natural.
    """
    backend = current_backend()
    out_dtype = out_dtype or jnp.promote_types(a.dtype, b.dtype)
    if backend == "xla":
        acc = blockflow.acc_dtype_for(a.dtype)
        return jnp.matmul(a, b, preferred_element_type=acc).astype(out_dtype)

    # Collapse leading dims to a single M for the 2-D kernels.
    if b.ndim != 2:
        # batched rhs: vmap over shared leading dims
        assert a.shape[:-2] == b.shape[:-2], (a.shape, b.shape)
        lead = a.shape[:-2]
        a2 = a.reshape((-1,) + a.shape[-2:])
        b2 = b.reshape((-1,) + b.shape[-2:])
        out = jax.vmap(lambda x, y: matmul(x, y, mode=mode, out_dtype=out_dtype))(a2, b2)
        return out.reshape(lead + out.shape[-2:])
    lead = a.shape[:-1]
    a2 = a.reshape(-1, a.shape[-1])
    M, K = a2.shape
    N = b.shape[-1]

    if backend == "blockflow":
        c = blockflow.block_matmul(a2, b, out_dtype=out_dtype)
    elif backend in ("pallas", "pallas_interpret"):
        from repro.kernels import matrixflow_gemm as mf  # lazy: pallas import
        interpret = backend == "pallas_interpret"
        blk = L.choose_layout(M, N, K, a2.dtype, mode=mode)
        c = mf.matrixflow_gemm(a2, b, blk=blk, out_dtype=out_dtype,
                               interpret=interpret)
    else:
        raise ValueError(f"unknown GEMM backend {backend!r}")
    return c.reshape(lead + (N,)).astype(out_dtype)


def linear(x: jax.Array, w: jax.Array, bias: Optional[jax.Array] = None,
           *, mode: str = "dm") -> jax.Array:
    """y = x @ w (+ bias): the layer-level entry point used by models."""
    y = matmul(x, w, mode=mode)
    if bias is not None:
        y = y + bias
    return y
