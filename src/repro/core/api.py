"""Public MatrixFlow API: typed GEMM policies over an extensible registry.

Every GEMM in the model substrate routes through :func:`matmul` /
:func:`linear`. *How* it executes is described by a frozen
:class:`~repro.core.plan.GemmPolicy` — backend, DC/DM access mode, layout
override, accumulator dtype, VMEM budget — resolved per shape into a
memoized :class:`~repro.core.plan.ExecutionPlan` (see repro/core/plan.py).

Built-in backends (registered here; add your own via
:func:`~repro.core.plan.register_backend`):

  "xla"               jnp.dot — distributed dry-run lowering and CPU training
                      (XLA already tiles for the MXU; the MatrixFlow schedule
                      is a kernel-level concern). Consumes batched
                      contractions natively.
  "pallas"            the MatrixFlow Pallas kernel (TPU target).
  "pallas_interpret"  same kernel, interpret mode (CPU validation).
  "blockflow"         the faithful Algorithm-1 lax rendering (paper baseline).

The default policy is ``GemmPolicy()`` — backend "auto" (pallas on TPU, xla
elsewhere), access mode "auto" (the sysmodel's analytic DC-vs-DM choice).
Pin a policy for a region with :func:`use_policy`::

    with api.use_policy(GemmPolicy(backend="blockflow", mode="dc")):
        logits = forward(params, cfg, batch)

Weights that persist across calls should be packed block-major once
(:func:`~repro.core.plan.pack_weight` / ``pack_model_weights``) — ``linear``
and ``matmul`` consume :class:`~repro.core.plan.PackedWeight` directly,
realizing the paper's Fig. 5 reuse (no per-call re-layout).
``GemmPolicy(weight_dtype="int8")`` switches weights to the quantized W8A8
route (core/quant.py): int8 blocks + per-channel scales
(:class:`~repro.core.quant.QuantizedPackedWeight`), int32 accumulation,
dequant fused into the C-block flush on the block-major backends.

Attention has the same shape: :func:`attention` routes every model
attention call through an :class:`~repro.core.plan.AttentionPolicy` and its
own backend registry — ``fused`` (the offset-aware flash Pallas kernel,
kernels/flash_attention.py), ``fused_interpret`` (CPU validation),
``unfused`` (the paper's §4.4 einsum + host-softmax split), and ``paged`` /
``paged_interpret`` (the block-table paged-KV kernel,
kernels/paged_attention.py — K/V live in a page pool and a per-request
block table drives the fetch; docs/serving.md). Pin with
:func:`use_attention_policy`; see docs/attention.md.

Migration from the old stringly-typed API (kept as deprecation shims for one
release): ``gemm_backend("xla")`` → ``use_policy(GemmPolicy(backend="xla"))``;
``matmul(..., mode="dc")`` → ``GemmPolicy(mode="dc")``. See docs/api.md.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import warnings
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core import blockflow
from repro.core import layout as L
from repro.core import plan as P
from repro.core import quant as Q
from repro.core.plan import (  # re-exported: the public policy surface
    GemmPolicy, ExecutionPlan, PackedWeight, QuantizedPackedWeight,
    AttentionPolicy, ShardingPolicy, pack_weight, pack_model_weights,
    plan, plan_cache_info, plan_cache_clear, register_backend,
    unregister_backend, registered_backends,
    register_attention_backend, unregister_attention_backend,
    registered_attention_backends,
)

__all__ = [
    "GemmPolicy", "ExecutionPlan", "PackedWeight", "QuantizedPackedWeight",
    "pack_weight",
    "pack_model_weights", "plan", "plan_cache_info", "plan_cache_clear",
    "register_backend", "unregister_backend", "registered_backends",
    "matmul", "linear", "use_policy", "current_policy", "resolved_backend",
    "prefers_einsum", "gemm_backend", "current_backend",
    "AttentionPolicy", "ShardingPolicy", "attention", "use_attention_policy",
    "current_attention_policy", "resolved_attention_backend",
    "register_attention_backend", "unregister_attention_backend",
    "registered_attention_backends",
]

_state = threading.local()


def current_policy() -> GemmPolicy:
    """The active GemmPolicy (innermost use_policy, else the default)."""
    stack = getattr(_state, "policies", None)
    return stack[-1] if stack else GemmPolicy()


@contextlib.contextmanager
def use_policy(policy: GemmPolicy):
    """Pin the active GEMM policy for the enclosed region (thread-local)."""
    stack = getattr(_state, "policies", None)
    if stack is None:
        stack = _state.policies = []
    stack.append(policy)
    try:
        yield policy
    finally:
        stack.pop()


def resolved_backend(policy: Optional[GemmPolicy] = None) -> str:
    """Registry name the active (or given) policy resolves to."""
    return (policy or current_policy()).resolved_backend()


def current_attention_policy() -> AttentionPolicy:
    """The active AttentionPolicy (innermost use_attention_policy, else the
    default — backend "auto": fused on TPU, unfused elsewhere)."""
    stack = getattr(_state, "attn_policies", None)
    return stack[-1] if stack else AttentionPolicy()


@contextlib.contextmanager
def use_attention_policy(policy: AttentionPolicy):
    """Pin the active attention policy for the enclosed region
    (thread-local, mirrors :func:`use_policy`)."""
    stack = getattr(_state, "attn_policies", None)
    if stack is None:
        stack = _state.attn_policies = []
    stack.append(policy)
    try:
        yield policy
    finally:
        stack.pop()


def resolved_attention_backend(policy: Optional[AttentionPolicy] = None) -> str:
    """Registry name the active (or given) attention policy resolves to."""
    return (policy or current_attention_policy()).resolved_backend()


def prefers_einsum(policy: Optional[GemmPolicy] = None) -> bool:
    """True when the resolved backend consumes batched contractions natively
    (so model code should use einsum instead of the batched 2-D kernel)."""
    return P.get_backend_spec(resolved_backend(policy)).batched


# ---------------------------------------------------------------------------
# Built-in backends
# ---------------------------------------------------------------------------

def _xla_gemm(a, b, pln: ExecutionPlan, out_dtype):
    if isinstance(b, QuantizedPackedWeight):
        aq, sa = Q.quantize_activations(a)
        c = jnp.matmul(aq, b.unpack_quantized(),
                       preferred_element_type=jnp.int32)
        return Q.dequantize_gemm(c, sa, b.scales, out_dtype)
    if isinstance(b, PackedWeight):
        b = b.unpack()
    return jnp.matmul(a, b, preferred_element_type=pln.acc).astype(out_dtype)


def _blockflow_gemm(a2, b, pln: ExecutionPlan, out_dtype):
    if isinstance(b, QuantizedPackedWeight):
        aq, sa = Q.quantize_activations(a2)
        blk = P.layout_for_packed(a2.shape[0], b, jnp.int8, pln.policy)
        return blockflow.block_matmul(
            aq, b.data, blk=blk, b_shape=(b.k, b.n), out_dtype=out_dtype,
            acc_dtype=jnp.int32, scale_a=sa, scale_b=b.scales)
    if isinstance(b, PackedWeight):
        # consume the resident blocks directly — no unpack/re-block round
        # trip (the Fig. 5 reuse property on this backend too)
        blk = P.layout_for_packed(a2.shape[0], b, a2.dtype, pln.policy)
        return blockflow.block_matmul(
            a2, b.data, blk=blk, b_shape=(b.k, b.n), out_dtype=out_dtype,
            acc_dtype=pln.acc)
    return blockflow.block_matmul(a2, b, blk=pln.layout, out_dtype=out_dtype,
                                  acc_dtype=pln.acc)


def _make_pallas_gemm(interpret: bool):
    def pallas_gemm(a2, b, pln: ExecutionPlan, out_dtype):
        from repro.kernels import matrixflow_gemm as mf  # lazy: pallas import
        if isinstance(b, QuantizedPackedWeight):
            aq, sa = Q.quantize_activations(a2)
            blk = P.layout_for_packed(a2.shape[0], b, jnp.int8, pln.policy)
            a_bm = L.to_block_major_a(aq, blk.bm, blk.bk)
            c_bm = mf.matrixflow_gemm_block_major(
                a_bm, b.data, blk=blk, out_dtype=out_dtype,
                interpret=interpret, acc_dtype=jnp.int32,
                scale_a=sa, scale_b=b.scales)
            return L.from_block_major_c(c_bm, a2.shape[0], b.n)
        if isinstance(b, PackedWeight):
            blk = P.layout_for_packed(a2.shape[0], b, a2.dtype, pln.policy)
            a_bm = L.to_block_major_a(a2, blk.bm, blk.bk)
            c_bm = mf.matrixflow_gemm_block_major(
                a_bm, b.data, blk=blk, out_dtype=out_dtype,
                interpret=interpret, acc_dtype=pln.acc)
            return L.from_block_major_c(c_bm, a2.shape[0], b.n)
        return mf.matrixflow_gemm(a2, b, blk=pln.layout, out_dtype=out_dtype,
                                  interpret=interpret, acc_dtype=pln.acc)
    return pallas_gemm


register_backend("xla", _xla_gemm, batched=True, needs_layout=False)
register_backend("blockflow", _blockflow_gemm)
register_backend("pallas", _make_pallas_gemm(interpret=False))
register_backend("pallas_interpret", _make_pallas_gemm(interpret=True))


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def matmul(a: jax.Array, b: Union[jax.Array, PackedWeight], *,
           policy: Optional[GemmPolicy] = None,
           out_dtype: Optional[jnp.dtype] = None,
           mode: Optional[str] = None) -> jax.Array:
    """C = A @ B through the plan the active policy resolves to.

    a: (..., M, K); b: (K, N), (..., K, N), or a PackedWeight (resident
    block-major). Output dtype defaults to the promoted input dtype (not the
    accumulator) to keep model code natural.
    """
    pol = policy if policy is not None else current_policy()
    if mode is not None:  # deprecated keyword, one-release shim
        warnings.warn("matmul(mode=...) is deprecated; use "
                      "GemmPolicy(mode=...)", DeprecationWarning,
                      stacklevel=2)
        pol = dataclasses.replace(pol, mode=mode)
    quantized = isinstance(b, QuantizedPackedWeight)
    packed = quantized or isinstance(b, PackedWeight)
    if out_dtype is None:
        if quantized:
            # the route dequantizes back to the weight's original fp dtype
            out_dtype = jnp.promote_types(a.dtype, jnp.dtype(b.dequant_dtype))
        else:
            out_dtype = jnp.promote_types(
                a.dtype, b.data.dtype if packed else b.dtype)
            if jnp.issubdtype(out_dtype, jnp.integer):
                # paper MAC policy: integer GEMMs surface their int32
                # accumulator (an int8 result would truncate, Table 2)
                out_dtype = blockflow.acc_dtype_for(out_dtype)
    spec = P.get_backend_spec(pol.resolved_backend())

    if spec.batched and not packed:
        # native batched contraction (jnp broadcasting semantics)
        M = int(a.size // a.shape[-1]) if a.ndim > 1 else 1
        pln = plan(M, b.shape[-1], a.shape[-1], a.dtype, pol)
        return spec.fn(a, b, pln, out_dtype)

    if not packed and b.ndim != 2:
        # batched rhs: vmap over shared leading dims
        assert a.shape[:-2] == b.shape[:-2], (a.shape, b.shape)
        lead = a.shape[:-2]
        a2 = a.reshape((-1,) + a.shape[-2:])
        b2 = b.reshape((-1,) + b.shape[-2:])
        out = jax.vmap(lambda x, y: matmul(x, y, policy=pol,
                                           out_dtype=out_dtype))(a2, b2)
        return out.reshape(lead + out.shape[-2:])

    # Collapse leading dims to a single M for the 2-D kernels.
    lead = a.shape[:-1]
    a2 = a.reshape(-1, a.shape[-1])
    M, K = a2.shape
    N = b.n if packed else b.shape[-1]
    # Quantized weights execute int8×int8→int32: plan for the int8 problem
    # (sysmodel auto-mode and acc resolution both see the kernel dtype).
    pln = plan(M, N, K, jnp.int8 if quantized else a2.dtype, pol)
    c = spec.fn(a2, b, pln, out_dtype)
    return c.reshape(lead + (N,)).astype(out_dtype)


def linear(x: jax.Array, w: Union[jax.Array, PackedWeight,
                                  QuantizedPackedWeight],
           bias: Optional[jax.Array] = None, *,
           policy: Optional[GemmPolicy] = None,
           mode: Optional[str] = None) -> jax.Array:
    """y = x @ w (+ bias): the layer-level entry point used by models.

    ``w`` may be a PackedWeight — laid out block-major once at model build —
    in which case block-major backends consume the blocks directly; or a
    QuantizedPackedWeight, which runs the int8 W8A8 route (core/quant.py).

    Under ``GemmPolicy(weight_dtype="int8")`` a raw fp weight is quantized
    on the fly (per call — pack once with ``pack_model_weights`` for the
    resident deployment shape). Only ``linear`` applies the knob to raw
    arrays: ``matmul``'s operands include activation×activation contractions
    (attention scores), which stay in their stored dtype.
    """
    pol = policy if policy is not None else current_policy()
    if (pol.weight_dtype is not None
            and getattr(w, "ndim", 0) == 2
            and not isinstance(w, (PackedWeight, QuantizedPackedWeight))
            and jnp.issubdtype(x.dtype, jnp.floating)
            and jnp.issubdtype(w.dtype, jnp.floating)):
        m_hint = max(int(x.size // x.shape[-1]), 1)
        w = P.pack_weight(w, pol, m_hint=m_hint, quantize=pol.weight_dtype)
    y = matmul(x, w, policy=pol, mode=mode)
    if bias is not None:
        y = y + bias
    return y


# ---------------------------------------------------------------------------
# Attention: policy-selectable fused/unfused execution (docs/attention.md)
# ---------------------------------------------------------------------------

def _reject_paged(backend: str, block_tables, kv_scales=None):
    if block_tables is not None:
        raise ValueError(
            f"attention backend {backend!r} cannot consume a paged KV cache "
            f"(got a block table); use AttentionPolicy(backend='paged') — "
            f"docs/serving.md")
    if kv_scales is not None:
        raise ValueError(
            f"attention backend {backend!r} cannot consume a quantized KV "
            f"pool (got kv_scales); use AttentionPolicy(backend='paged', "
            f"kv_dtype='int8') — docs/quant.md#kv-pages")


def _unfused_attention(q, k, v, *, q_positions, kv_valid_len, causal, scale,
                       soft_cap, policy, block_tables=None, kv_scales=None):
    """The einsum + host-softmax baseline (the paper's §4.4 split: GEMMs on
    the accelerator, softmax on the host). GQA via reshape; score/value
    contractions follow the ambient *GEMM* policy — einsum when the resolved
    GEMM backend consumes batched contractions natively, the batched
    MatrixFlow kernel otherwise."""
    _reject_paged("unfused", block_tables, kv_scales)
    B, Sq, H, Dk = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    qg = q.reshape(B, Sq, Hkv, rep, Dk)
    if prefers_einsum():
        logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k,
                            preferred_element_type=jnp.float32)
    else:  # MatrixFlow path: fold (B,Hkv,rep) into the vmapped batch
        qm = qg.transpose(0, 2, 3, 1, 4).reshape(B * Hkv * rep, Sq, Dk)
        km = (jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1)
              .reshape(B * Hkv * rep, T, Dk))
        logits = matmul(qm, km.transpose(0, 2, 1), out_dtype=jnp.float32)
        logits = logits.reshape(B, Hkv, rep, Sq, T)
    logits = logits.astype(jnp.float32) * scale
    if soft_cap:
        logits = soft_cap * jnp.tanh(logits / soft_cap)
    kv_pos = jnp.arange(T)[None, None, :]                     # (1,1,T)
    valid = kv_pos < kv_valid_len[:, None, None]              # (B,1,T)
    if causal:
        valid = valid & (kv_pos <= q_positions[:, :, None])   # (B,Sq,T)
    valid = jnp.broadcast_to(valid, (B, Sq, T))[:, None, None]  # (B,1,1,Sq,T)
    logits = jnp.where(valid, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)                   # host-side op
    # fully-masked rows → zeros (the shared contract with the fused kernel)
    probs = jnp.where(valid, probs, 0.0)
    if prefers_einsum():
        out = jnp.einsum("bgrqk,bkgd->bqgrd", probs.astype(v.dtype), v)
    else:
        pm = probs.reshape(B * Hkv * rep, Sq, T).astype(v.dtype)
        vm = (jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1)
              .reshape(B * Hkv * rep, T, v.shape[-1]))
        out = matmul(pm, vm)
        out = (out.reshape(B, Hkv, rep, Sq, v.shape[-1])
               .transpose(0, 3, 1, 2, 4))
    return out.reshape(B, Sq, H, v.shape[-1])


def _make_fused_attention(interpret: bool):
    def fused_attention(q, k, v, *, q_positions, kv_valid_len, causal, scale,
                        soft_cap, policy, block_tables=None, kv_scales=None):
        _reject_paged("fused_interpret" if interpret else "fused",
                      block_tables, kv_scales)
        from repro.kernels import ops  # lazy: pallas import
        return ops.mha(q, k, v, causal=causal, scale=scale,
                       soft_cap=soft_cap, q_positions=q_positions,
                       kv_valid_len=kv_valid_len,
                       impl="interpret" if interpret else "pallas",
                       block_q=policy.block_q, block_k=policy.block_k)
    return fused_attention


def _make_paged_attention(interpret: bool):
    def paged(q, k, v, *, q_positions, kv_valid_len, causal, scale,
              soft_cap, policy, block_tables=None, kv_scales=None):
        """Block-table paged flash attention (kernels/paged_attention.py).

        With a block table, k/v are the page pools (P, page_size, Hkv, D)
        and the table drives the kernel's BlockSpec index maps — int8 pools
        additionally carry ``kv_scales`` (per-page-per-head fp32), which the
        kernel dequantizes in its K/V-block fetch. Without a block table —
        cache-less training/scoring, or an MLA latent cache that stays
        contiguous — the operands are dense and the paged policy degrades
        to the fused flash kernel (identical contract), so a single policy
        covers a model end to end.
        """
        if block_tables is None:
            _reject_paged("paged_interpret" if interpret else "paged",
                          None, kv_scales)
            from repro.kernels import ops  # lazy: pallas import
            return ops.mha(q, k, v, causal=causal, scale=scale,
                           soft_cap=soft_cap, q_positions=q_positions,
                           kv_valid_len=kv_valid_len,
                           impl="interpret" if interpret else "pallas",
                           block_q=policy.block_q, block_k=policy.block_k)
        from repro.kernels import paged_attention as PA  # lazy: pallas
        return PA.paged_attention(
            q, k, v, block_tables, q_positions, kv_valid_len,
            kv_scales=kv_scales, causal=causal, scale=scale,
            soft_cap=soft_cap, block_q=policy.block_q, interpret=interpret)
    return paged


register_attention_backend("unfused", _unfused_attention)
register_attention_backend("fused", _make_fused_attention(interpret=False))
register_attention_backend("fused_interpret",
                           _make_fused_attention(interpret=True))
register_attention_backend("paged", _make_paged_attention(interpret=False))
register_attention_backend("paged_interpret",
                           _make_paged_attention(interpret=True))


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              q_positions: jax.Array,
              kv_valid_len: jax.Array,
              causal: bool = True,
              scale: Optional[float] = None,
              soft_cap: Optional[float] = None,
              block_tables: Optional[jax.Array] = None,
              kv_scales=None,
              policy: Optional[AttentionPolicy] = None) -> jax.Array:
    """Scaled-dot-product attention through the active AttentionPolicy.

    Model-layout operands: q (B,Sq,H,Dk), k (B,T,Hkv,Dk), v (B,T,Hkv,Dv),
    with GQA/MQA expressed by Hkv dividing H. Every backend implements one
    contract (see kernels/ref.py::mha_ref): key j of batch row b is visible
    to query i iff ``j < kv_valid_len[b]`` and, when causal,
    ``j <= q_positions[b, i]``; query rows with no visible key — serving's
    masked position −1 slots — return zeros.

    q_positions: (B, Sq) absolute positions of the queries (int32).
    kv_valid_len: (B,) populated keys/cache slots per batch row.
    block_tables: (B, n_blocks) int32 — only with the ``paged`` backends,
    where k/v are page pools (P, page_size, Hkv, D) and the table maps each
    row's logical key blocks to physical pages (docs/serving.md). Dense
    backends reject a non-None block table.
    kv_scales: ((P, Hkv), (P, Hkv)) fp32 — only with ``paged`` backends
    whose pools are int8 (AttentionPolicy.kv_dtype='int8'); the per-page-
    per-head K and V scales the kernel dequantizes with
    (docs/quant.md#kv-pages). Dense backends reject non-None kv_scales.
    """
    pol = policy if policy is not None else current_attention_policy()
    spec = P.get_attention_backend_spec(pol.resolved_backend())
    if scale is None:
        scale = q.shape[-1] ** -0.5
    # block_tables/kv_scales are forwarded only when present: backends
    # registered before the paged subsystem (without the kwargs) keep
    # working for every dense call, and a paged call against one fails
    # loudly on the kwarg.
    kwargs = ({"block_tables": block_tables} if block_tables is not None
              else {})
    if kv_scales is not None:
        kwargs["kv_scales"] = kv_scales
    return spec.fn(q, k, v, q_positions=q_positions,
                   kv_valid_len=kv_valid_len, causal=causal, scale=scale,
                   soft_cap=soft_cap, policy=pol, **kwargs)


# ---------------------------------------------------------------------------
# Deprecation shims (one release): the old stringly-typed surface
# ---------------------------------------------------------------------------

def current_backend() -> str:
    """Deprecated: use current_policy() / resolved_backend()."""
    return resolved_backend()


@contextlib.contextmanager
def gemm_backend(name: str):
    """Deprecated context manager: pin by backend name.

    Use ``use_policy(GemmPolicy(backend=name))`` instead (docs/api.md has
    the migration table).
    """
    warnings.warn("gemm_backend(name) is deprecated; use "
                  "use_policy(GemmPolicy(backend=name))", DeprecationWarning,
                  stacklevel=3)
    with use_policy(GemmPolicy(backend=name)) as pol:
        yield pol
