"""INT8 symmetric quantization for MatrixFlow GEMMs (paper Table 2, int MACs).

The paper sizes MAC units per dtype and reports its largest accelerator wins
on the integer designs (int8 MACs at 1 GHz vs fp at 600 MHz). This module is
the software half of that path:

  * **weights** are quantized offline, symmetric **per output channel**
    (one fp32 scale per column of the (K, N) operand) — the granularity that
    keeps GEMM dequantization a rank-1 rescale of the int32 result;
  * **activations** are quantized dynamically, symmetric **per row** (one
    fp32 scale per row of the (M, K) operand), at the GEMM entry;
  * the GEMM itself runs **int8 × int8 → int32** through the same three
    backends as the fp path (blockflow oracle, Pallas kernel, XLA), with the
    dequantization ``C_fp[m, n] = C_i32[m, n] * s_a[m] * s_b[n]`` fused into
    the C-block flush on the block-major backends;
  * :class:`QuantizedPackedWeight` stores the int8 blocks block-major (the
    paper's horizontally-split B, Fig. 4 bottom) plus the per-channel scales,
    so serving keeps quantized weights resident exactly like fp
    :class:`~repro.core.plan.PackedWeight`.

The int8 grid is symmetric in [-QMAX, QMAX] (−128 unused) so that
``q = -q`` never overflows and the dequant scale is a single positive fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import layout as L

__all__ = [
    "QMAX", "KV_HEADROOM", "QuantizedPackedWeight",
    "quantize_weight", "dequantize_weight",
    "quantize_activations", "dequantize_gemm",
    "quantize_kv_pages", "dequantize_kv_pages",
    "kv_write_scale", "quantize_kv_rows",
]

QMAX = 127  # symmetric int8 grid [-127, 127]; -128 excluded


def _safe_scale(amax: jax.Array) -> jax.Array:
    """amax/QMAX with all-zero slices mapped to scale 1 (q = 0 exactly)."""
    amax = amax.astype(jnp.float32)
    return jnp.where(amax > 0, amax / QMAX, jnp.float32(1.0))


def quantize_weight(w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(…, K, N) fp weight → (int8 (…, K, N), fp32 scales (…, N)).

    Symmetric per-output-channel: each N column gets scale max|w[:, n]|/127.
    Round-half-to-even (jnp.round), clipped to the symmetric grid.
    """
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2)
    scales = _safe_scale(amax)
    q = jnp.round(w.astype(jnp.float32) / scales[..., None, :])
    q = jnp.clip(q, -QMAX, QMAX).astype(jnp.int8)
    return q, scales


def dequantize_weight(q: jax.Array, scales: jax.Array,
                      dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`quantize_weight` up to the rounding error."""
    return (q.astype(jnp.float32) * scales[..., None, :]).astype(dtype)


def quantize_activations(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(…, K) fp activations → (int8 (…, K), fp32 scales (…,)).

    Symmetric per-row (per token): the dynamic half of the W8A8 scheme.
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scales = _safe_scale(amax)
    q = jnp.round(x.astype(jnp.float32) / scales[..., None])
    q = jnp.clip(q, -QMAX, QMAX).astype(jnp.int8)
    return q, scales


def dequantize_gemm(c_int: jax.Array, scale_a: jax.Array, scale_b: jax.Array,
                    out_dtype=jnp.float32) -> jax.Array:
    """int32 GEMM result (M, N) → fp: C * s_a[m] * s_b[n] (rank-1 rescale).

    This is the reference (unfused) dequant; the block-major backends fuse
    the identical expression into their C-block flush, so all backends agree
    bitwise on the fp32 product before the final out_dtype cast.
    """
    c = c_int.astype(jnp.float32)
    c = c * scale_a.astype(jnp.float32)[..., :, None]
    c = c * scale_b.astype(jnp.float32)[..., None, :]
    return c.astype(out_dtype)


# ---------------------------------------------------------------------------
# KV-cache pages (docs/quant.md#kv-pages)
#
# The paged KV pool (serving/kv_pool.py + kernels/paged_attention.py) can
# store K/V int8, symmetric **per page per KV head**: one fp32 scale per
# (page, kv_head) pair, shape (P, Hkv), dequantized inside the paged
# kernel's K/V-block fetch so the HBM stream stays int8. Two quantization
# regimes share the int8 grid:
#
#   * quantize_kv_pages — one-shot, true per-page amax. Used by tests and
#     offline conversion where the whole pool content is known at once.
#   * kv_write_scale + quantize_kv_rows — the *serving write path*. A page's
#     scale is FROZEN when its first row (position % page_size == 0) is
#     written, from that row's per-head amax times KV_HEADROOM; every later
#     row of the page quantizes against the frozen scale (clipped to the
#     grid). Freezing makes the int8 payload a pure function of the page's
#     logical content — bitwise identical whether written token-at-a-time
#     (decode) or in bulk (resume re-prefill, prefix-cache miss) — which is
#     what keeps token streams exactly reproducible across preempt/resume
#     and prefix-COW (tests/test_serving.py).
# ---------------------------------------------------------------------------

# Frozen-scale headroom: later rows of a page routinely exceed the first
# row's amax; 2x headroom absorbs the typical spread (activations in a
# layer share magnitude statistics) at the cost of one effective bit.
KV_HEADROOM = 2.0


def quantize_kv_pages(pages: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(…, P, ps, Hkv, dh) fp pages → (int8 pages, fp32 scales (…, P, Hkv)).

    Symmetric per page per KV head, true amax (no headroom) — the one-shot
    regime for tests/offline conversion, NOT the serving write path.
    """
    amax = jnp.max(jnp.abs(pages.astype(jnp.float32)), axis=(-3, -1))
    scales = _safe_scale(amax)
    q = jnp.round(pages.astype(jnp.float32)
                  / scales[..., :, None, :, None])
    q = jnp.clip(q, -QMAX, QMAX).astype(jnp.int8)
    return q, scales


def dequantize_kv_pages(q: jax.Array, scales: jax.Array,
                        dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`quantize_kv_pages` up to the rounding error."""
    return (q.astype(jnp.float32)
            * scales[..., :, None, :, None].astype(jnp.float32)).astype(dtype)


def kv_write_scale(rows: jax.Array) -> jax.Array:
    """(…, Hkv, dh) first-row K/V → the page's frozen fp32 scale (…, Hkv).

    amax * KV_HEADROOM / QMAX per head (all-zero heads → scale 1). Called
    exactly once per page lifetime, on the row with position % ps == 0.
    """
    amax = jnp.max(jnp.abs(rows.astype(jnp.float32)), axis=-1)
    return _safe_scale(amax * KV_HEADROOM)


def quantize_kv_rows(rows: jax.Array, scales: jax.Array) -> jax.Array:
    """(…, Hkv, dh) fp rows / (…, Hkv) scales → int8 rows on the grid."""
    q = jnp.round(rows.astype(jnp.float32)
                  / scales[..., :, None].astype(jnp.float32))
    return jnp.clip(q, -QMAX, QMAX).astype(jnp.int8)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class QuantizedPackedWeight:
    """An int8 GEMM rhs held resident block-major, with per-channel scales.

    data   int8 ``(…, N/bn, K/bk, bk, bn)`` — the paper's horizontally-split
           B operand, quantized; leading dims are stacked-layer axes.
    scales fp32 ``(…, N)`` — one symmetric scale per output channel.

    Mirrors :class:`~repro.core.plan.PackedWeight` (same geometry fields, so
    layout resolution duck-types across both); built by
    ``pack_weight(w, policy, quantize="int8")``.
    """

    data: jax.Array
    scales: jax.Array
    k: int                   # logical (unpadded) K
    n: int                   # logical (unpadded) N
    bk: int
    bn: int
    mode: str = "dm"
    dequant_dtype: str = "float32"   # the original weight dtype name

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.k, self.n)

    @property
    def dtype(self):
        return self.data.dtype

    def unpack_quantized(self) -> jax.Array:
        """Back to row-major int8 (…, K, N) — for layout-free backends."""
        return L.from_block_major_b(self.data, self.k, self.n)

    def unpack(self) -> jax.Array:
        """Dequantized row-major weight in the original dtype."""
        return dequantize_weight(self.unpack_quantized(), self.scales,
                                 jnp.dtype(self.dequant_dtype))

    # pytree protocol: data + scales are traced leaves; geometry is static.
    def tree_flatten(self):
        return ((self.data, self.scales),
                (self.k, self.n, self.bk, self.bn, self.mode,
                 self.dequant_dtype))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)
