"""MatrixFlow block-major layouts (paper §3.3, contribution C1).

The paper's core data-structure insight: store each GEMM operand as
*rectangular blocks sized to one transfer unit* so that every block the
accelerator consumes is a single contiguous region in memory — one DMA
descriptor, one address translation, no fragmentation.

On TPU the transfer unit is the HBM→VMEM DMA tile rather than a 4 KB page.
We realize the paper's layout as an explicit 4-D "block-major" array:

    A : (M, K)  row-major        →  A_bm : (M//bm, K//bk, bm, bk)
    B : (K, N)  row-major        →  B_bm : (N//bn, K//bk, bk, bn)   ("horizontal split")
    C : (M, N)                   ←  C_bm : (M//bm, N//bn, bm, bn)

A_bm[i, k] is the (bm × bk) block the kernel consumes at grid step (i, ·, k),
stored contiguously (last two axes are minor).  B is *horizontally split*
exactly as in Fig. 4 (bottom): the K-walk for one output column-block
(B_bm[j, 0], B_bm[j, 1], ...) is a contiguous streak, resolving the
column-read fragmentation of conventional layouts.

`PAGE_BYTES = 4096` retained for fidelity experiments: `page_block_shape`
returns the paper-exact block geometry where a block is one 4 KB page.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

PAGE_BYTES = 4096          # the paper's memory-page transfer unit
MXU_DIM = 128              # TPU MXU systolic dimension (paper's SA is 16×16)
SUBLANE = 8                # TPU VREG sublane granularity


@dataclasses.dataclass(frozen=True)
class BlockLayout:
    """Geometry of a MatrixFlow block decomposition for C = A @ B.

    bm/bn/bk are the block dims. ``mode`` follows the paper's two access
    policies: ``dc`` (direct-cache: fine-grained K, deeper pipeline) and
    ``dm`` (direct-memory: large bursts, fewer grid steps).
    """

    bm: int
    bn: int
    bk: int
    mode: str = "dm"

    def grid(self, M: int, N: int, K: int) -> Tuple[int, int, int]:
        return (cdiv(M, self.bm), cdiv(N, self.bn), cdiv(K, self.bk))

    def vmem_bytes(self, dtype_bytes: int, acc_bytes: int = 4) -> int:
        """Working set claimed in VMEM: double-buffered A/B windows + C accum.

        Mirrors the paper's three-local-buffer design (A, B, C); the factor 2
        on A/B is the Pallas pipeline's double buffering.
        """
        a = self.bm * self.bk * dtype_bytes
        b = self.bk * self.bn * dtype_bytes
        c = self.bm * self.bn * acc_bytes
        return 2 * (a + b) + c


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def page_block_shape(dtype: jnp.dtype, *, lanes: int = MXU_DIM) -> Tuple[int, int]:
    """Paper-exact geometry: one block == one 4 KB page.

    Rows are chosen so rows*lanes*itemsize == PAGE_BYTES (e.g. int8 → 32×128,
    fp32 → 8×128). Used by the fidelity benchmarks; production kernels use
    MXU-aligned 128×…×128 blocks.
    """
    itemsize = jnp.dtype(dtype).itemsize
    rows = PAGE_BYTES // (lanes * itemsize)
    if rows < 1:
        raise ValueError(f"lane count {lanes} too wide for 4KB page at {dtype}")
    return rows, lanes


def choose_layout(
    M: int,
    N: int,
    K: int,
    dtype: jnp.dtype = jnp.bfloat16,
    *,
    mode: str = "dm",
    vmem_budget: int = 96 * 1024 * 1024,
) -> BlockLayout:
    """Pick MXU-aligned block dims for the given problem and access mode.

    ``dm`` takes the largest K-burst that fits the VMEM budget; ``dc`` uses a
    fine K granularity (256) for maximal pipeline overlap — the TPU analogue
    of the paper's 64 B cache-line-granularity DC mode.
    """
    itemsize = jnp.dtype(dtype).itemsize
    # Sublane-align M, capped at 512 (the max profitable row-panel height).
    bm = min(round_up(M, SUBLANE), 512)
    bn = min(round_up(N, MXU_DIM), 512)
    if mode == "dc":
        bk = min(round_up(K, MXU_DIM), 256)
    elif mode == "dm":
        bk = min(round_up(K, MXU_DIM), 2048)
    else:
        raise ValueError(f"unknown access mode: {mode!r}")
    # Shrink until the double-buffered working set fits the budget.
    layout = BlockLayout(bm, bn, bk, mode)
    while layout.vmem_bytes(itemsize) > vmem_budget and layout.bk > MXU_DIM:
        layout = BlockLayout(layout.bm, layout.bn, layout.bk // 2, mode)
    while layout.vmem_bytes(itemsize) > vmem_budget and layout.bn > MXU_DIM:
        layout = BlockLayout(layout.bm, layout.bn // 2, layout.bk, mode)
    while layout.vmem_bytes(itemsize) > vmem_budget and layout.bm > SUBLANE:
        layout = BlockLayout(layout.bm // 2, layout.bn, layout.bk, mode)
    return layout


# ---------------------------------------------------------------------------
# Layout transforms (pure, invertible; property-tested in tests/test_layout.py)
# ---------------------------------------------------------------------------

def pad_to_blocks(x: jax.Array, b0: int, b1: int) -> jax.Array:
    """Zero-pad trailing 2 dims of ``x`` up to multiples of (b0, b1)."""
    *lead, m, n = x.shape
    pm, pn = round_up(m, b0) - m, round_up(n, b1) - n
    if pm == 0 and pn == 0:
        return x
    pad = [(0, 0)] * len(lead) + [(0, pm), (0, pn)]
    return jnp.pad(x, pad)


def to_block_major_a(a: jax.Array, bm: int, bk: int) -> jax.Array:
    """(…, M, K) row-major → (…, M/bm, K/bk, bm, bk) block-major.

    Paper Fig. 4 (bottom-left): A's blocks aligned with the SA input dims,
    each block contiguous.
    """
    a = pad_to_blocks(a, bm, bk)
    *lead, M, K = a.shape
    a = a.reshape(*lead, M // bm, bm, K // bk, bk)
    return jnp.moveaxis(a, -3, -2)  # (…, M/bm, K/bk, bm, bk)


def from_block_major_a(a_bm: jax.Array, M: int, K: int) -> jax.Array:
    *lead, nbm, nbk, bm, bk = a_bm.shape
    a = jnp.moveaxis(a_bm, -2, -3).reshape(*lead, nbm * bm, nbk * bk)
    return a[..., :M, :K]


def to_block_major_b(b: jax.Array, bk: int, bn: int) -> jax.Array:
    """(…, K, N) row-major → (…, N/bn, K/bk, bk, bn) block-major.

    The paper's *horizontal split* of B: blocks are indexed output-column-
    major so the K-walk for a fixed output tile j is contiguous in memory —
    this is the transform that removes the column-read page fragmentation.
    """
    b = pad_to_blocks(b, bk, bn)
    *lead, K, N = b.shape
    b = b.reshape(*lead, K // bk, bk, N // bn, bn)
    # (…, K/bk, bk, N/bn, bn) → (…, N/bn, K/bk, bk, bn)
    b = jnp.moveaxis(b, -2, -4)
    return b


def from_block_major_b(b_bm: jax.Array, K: int, N: int) -> jax.Array:
    *lead, nbn, nbk, bk, bn = b_bm.shape
    b = jnp.moveaxis(b_bm, -4, -2).reshape(*lead, nbk * bk, nbn * bn)
    return b[..., :K, :N]


def to_block_major_c(c: jax.Array, bm: int, bn: int) -> jax.Array:
    c = pad_to_blocks(c, bm, bn)
    *lead, M, N = c.shape
    c = c.reshape(*lead, M // bm, bm, N // bn, bn)
    return jnp.moveaxis(c, -3, -2)


def from_block_major_c(c_bm: jax.Array, M: int, N: int) -> jax.Array:
    *lead, nbm, nbn, bm, bn = c_bm.shape
    c = jnp.moveaxis(c_bm, -2, -3).reshape(*lead, nbm * bm, nbn * bn)
    return c[..., :M, :N]


# ---------------------------------------------------------------------------
# Transfer-contiguity accounting (feeds core/sysmodel.py)
# ---------------------------------------------------------------------------

def descriptors_per_block_conventional(
    rows: int, cols: int, row_stride_bytes: int, itemsize: int,
    page_bytes: int = PAGE_BYTES,
) -> int:
    """DMA descriptors to fetch a (rows × cols) block from a *row-major* matrix.

    Each row of the block is a separate contiguous segment; a segment that
    crosses a page boundary costs an extra translation/descriptor. This is the
    fragmentation the paper's Fig. 4 (top) illustrates.
    """
    seg_bytes = cols * itemsize
    total = 0
    for r in range(rows):
        start = r * row_stride_bytes
        first_page = start // page_bytes
        last_page = (start + seg_bytes - 1) // page_bytes
        total += 1 + (last_page - first_page)
    return total


def descriptors_per_block_matrixflow(
    rows: int, cols: int, itemsize: int, page_bytes: int = PAGE_BYTES,
) -> int:
    """Block-major: the block is one contiguous region → ceil(bytes / page)."""
    return cdiv(rows * cols * itemsize, page_bytes)
