"""Analytic system-performance model calibrated to the paper's gem5 setup.

The paper evaluates MatrixFlow in gem5 full-system simulation (Table 1:
ARM @1 GHz, DDR3-1600, PCIe 6.0 ×16 = 64 Gb/s; SA 16×16 @1 GHz int /
600 MHz fp — Table 2). gem5 is not available in this container, so this
module is the quantitative stand-in: a transaction-level analytic model that
reproduces the paper's reported trends and magnitudes (Figs 6, 7, 9;
Table 3) from first principles plus a small set of calibration constants.

Model structure (derived from the paper's own accounting, §4.5):
  * The accelerator is *streaming*: transfer overlaps compute, so a GEMM
    costs max(compute, transfer) + per-offload control. MatrixFlow's whole
    point (C1/C2) is that the block-major layout keeps `transfer` at link
    speed so the max() lands on compute for transformer GEMMs.
  * In a transformer pipeline, weights are laid out block-major offline and
    every activation is *already* block-major because it was written as the
    previous GEMM's C blocks (Fig. 5). Re-layout cost therefore only appears
    in the standalone GEMM benchmarks (include_layout_cost=True ⇒ Fig. 7's
    ~400× at 1024³ instead of the transformer-regime ~1000× GEMM speedup).
  * Conventional row-major feeding (Fig. 4 top) fragments each block fetch
    into per-row DMA descriptors; the DMA engine's descriptor issue rate
    then becomes the binding resource — this is the loosely-coupled-baseline
    penalty MatrixFlow removes.
  * DC routes fine-grained (64 B) requests through the LLC — stationary
    panels get cached, descriptor issue is cheap; DM uses big bursts straight
    to DRAM — slightly higher per-descriptor cost and DRAM contention
    (paper: DC 400× vs DM 385× on GEMM-1024).

Modeled backends (the paper's comparison set, §4):
  cpu1        single-thread naive loop GEMM          (baseline, speedup=1)
  omp         256-core OpenMP                        (parallel-efficiency model)
  neon        128-bit SIMD                           (lane count × efficiency)
  smaug       loosely-coupled fp16 accel, conventional layout [19]
  ticsat      tightly-coupled 16×16 SA in the CPU pipeline [2]
  mf_dc/mf_dm MatrixFlow (this paper), DC / DM access modes

Calibration constants were fitted once against the paper's headline numbers;
benchmarks/transformer_e2e.py prints model vs paper side by side with ratios.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.core import layout as L

# ---------------------------------------------------------------------------
# Hardware constants (paper Tables 1 & 2) + calibration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SystemConfig:
    cpu_freq_hz: float = 1.0e9          # ARM @ 1 GHz (Table 1)
    llc_bytes: int = 2 * 2**20          # 2 MB LLC
    dram_bw: float = 12.8e9             # DDR3-1600 ≈ 12.8 GB/s
    # Table 1: "PCIe 6.0, 64 Gb/s, 16 Lanes" — 64 Gb/s is the *total* link
    # rate (Fig. 9's configs "16 lanes-64 Gbps / 4 lanes-16 Gbps /
    # 4 lanes-5 Gbps" are consistent at ~4 Gb/s per lane).
    pcie_total_gbps: float = 64.0
    pcie_lanes: int = 16
    pcie_efficiency: float = 0.92
    sa_dim: int = 16                    # 16×16 systolic array
    sa_freq_int_hz: float = 1.0e9       # Table 2: int designs close at 1 GHz
    sa_freq_fp_hz: float = 0.6e9        # Table 2: fp designs close at 600 MHz
    page_bytes: int = L.PAGE_BYTES
    # --- calibration (documented fits) ---
    cpu_cpi_mac: float = 4.0            # naive scalar loop, in-order ARM
    cpu_fp16_penalty: float = 2.5       # §4.3.2: no native fp16 → converts
    cpu_cpi_vec_elem: float = 1.0       # Neon-vectorized non-GEMM layers
    relayout_cyc_per_byte: float = 3.0  # CPU block-major transform (GEMM bench)
    desc_issue_dc_s: float = 30e-9      # DMA descriptor issue, DC
    desc_issue_dm_s: float = 45e-9      # DMA descriptor issue, DM bursts
    dm_contention: float = 1.06         # DM bypasses LLC → DRAM contention
    dm_burst_panels: int = 16           # DM burst covers N row-panels of B
    tlp_header_bytes: float = 64.0      # per-descriptor PCIe TLP+DLLP cost
    cmd_overhead_s: float = 45e-6       # driver doorbell+descr ring+IRQ per offload
    omp_cores: int = 256
    omp_efficiency: float = 0.096       # paper: 23.7–25.6× on 256 cores
    neon_lanes_bytes: int = 16          # 128-bit SIMD
    neon_efficiency: float = 0.45
    ticsat_tile_cycles: float = 200.0   # per 16×16×16 tile pass issue cost [2]
    smaug_macs: int = 48                # NVDLA-class fp16 datapath [19]
    smaug_chunk_bytes: int = 256 * 1024 # SMAUG SPM tile granularity
    smaug_chunk_overhead_s: float = 45e-6
    # Non-SA-aligned sequence lengths (ViT: 197/257) break the Fig. 5 C→A
    # block handoff: the CPU repacks each layer's activations into padded
    # block-major form before DMA (scalar gather/scatter, ~8 cyc/byte).
    # BERT's S=128 is aligned → no repack. TiC-SAT shows no BERT↔ViT gap in
    # the paper's Table 3 while MatrixFlow does — this is the mechanism.
    repack_cyc_per_elem: float = 32.0   # 8 cyc/B × 4 B/elem

    @property
    def pcie_bw(self) -> float:         # bytes/s, one direction
        return self.pcie_total_gbps / 8 * 1e9 * self.pcie_efficiency


DEFAULT = SystemConfig()

_DTYPE_BYTES = {"int8": 1, "int16": 2, "int32": 4, "fp16": 2, "fp32": 4,
                "bf16": 2}


def _dtype_bytes(dtype: str) -> int:
    return _DTYPE_BYTES[dtype]


def _is_int(dtype: str) -> bool:
    return dtype.startswith("int")


# ---------------------------------------------------------------------------
# Workload description: a model forward = list of GEMMs + elementwise ops
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Gemm:
    M: int
    K: int
    N: int
    count: int = 1          # per-layer / per-head repeats
    tag: str = "gemm"       # FF1 / FF2 / QKV / scores / ... for Fig-8 breakdown

    @property
    def macs(self) -> int:
        return self.M * self.K * self.N * self.count


@dataclasses.dataclass(frozen=True)
class Elementwise:
    elems: int
    count: int = 1
    tag: str = "nongemm"    # softmax / layernorm / transpose / residual


Workload = Tuple[Tuple[Gemm, ...], Tuple[Elementwise, ...]]


# ---------------------------------------------------------------------------
# Per-backend GEMM time models
# ---------------------------------------------------------------------------

def cpu1_gemm_time(g: Gemm, dtype: str, sys: SystemConfig = DEFAULT) -> float:
    cpi = sys.cpu_cpi_mac
    if dtype == "fp16":
        cpi *= sys.cpu_fp16_penalty
    return g.macs * cpi / sys.cpu_freq_hz


def omp_gemm_time(g: Gemm, dtype: str, sys: SystemConfig = DEFAULT) -> float:
    return cpu1_gemm_time(g, dtype, sys) / (sys.omp_cores * sys.omp_efficiency)


def neon_gemm_time(g: Gemm, dtype: str, sys: SystemConfig = DEFAULT) -> float:
    lanes = max(sys.neon_lanes_bytes // _dtype_bytes(dtype), 1)
    eff = sys.neon_efficiency
    if dtype == "fp16":  # emulated through fp32 lanes + converts (§4.3.2)
        lanes, eff = 4, eff * 0.5
    base = cpu1_gemm_time(g, "int32" if _is_int(dtype) else "fp32", sys)
    return base / (lanes * eff)


def _sa_compute_time(g: Gemm, dtype: str, sys: SystemConfig,
                     macs_per_cycle: float | None = None) -> float:
    """SA time for all ``g.count`` instances (g.macs already includes count)."""
    freq = sys.sa_freq_int_hz if _is_int(dtype) else sys.sa_freq_fp_hz
    mpc = macs_per_cycle or float(sys.sa_dim ** 2)
    fill = 2 * sys.sa_dim  # pipeline fill/drain per output-tile pass
    n_tiles = L.cdiv(g.M, sys.sa_dim) * L.cdiv(g.N, sys.sa_dim) * g.count
    cycles = g.macs / mpc + n_tiles * fill
    return cycles / freq


def _traffic_bytes(g: Gemm, itemsize: int, sys: SystemConfig,
                   llc_streaming: bool) -> int:
    """PCIe traffic of Algorithm 1.

    DC (llc_streaming): the A row-strip and the C accumulator strip are
    served from the LLC, so whenever (A + C) fits the 2 MB LLC the weight
    matrix B streams across the link exactly ONCE — the co-design's key
    property. When (A + C) exceeds the LLC, the M dimension is processed in
    groups and B re-streams once per group (the "LLC residency cliff":
    BERT's S=128 strips fit; ViT's S=197/257 strips do not — this is what
    makes the paper's ViT speedups systematically lower than BERT's).

    DM: no cache assist; B re-streams once per burst-group of
    ``dm_burst_panels`` SA row-panels (large adjustable bursts, §4.3).
    """
    a, b = g.M * g.K * itemsize, g.K * g.N * itemsize
    c = g.M * g.N * 4  # int32/fp32 accumulators written back
    if llc_streaming:
        # the C accumulator strip is read-modify-written across the whole
        # K-walk, so it must stay LLC-resident; A and B blocks stream.
        groups = max(L.cdiv(c, sys.llc_bytes), 1)
    else:
        groups = L.cdiv(g.M, sys.sa_dim * sys.dm_burst_panels)
    return (a + b * groups + c) * g.count


def matrixflow_gemm_time(
    g: Gemm, dtype: str, mode: str = "dc", sys: SystemConfig = DEFAULT,
    conventional_layout: bool = False,
    include_layout_cost: bool = False,
) -> Dict[str, float]:
    """MatrixFlow GEMM: total = max(compute, transfer) + control [+ relayout]."""
    itemsize = _dtype_bytes(dtype)
    compute = _sa_compute_time(g, dtype, sys)
    traffic = _traffic_bytes(g, itemsize, sys, llc_streaming=(mode == "dc"))
    bw = sys.pcie_bw / (sys.dm_contention if mode == "dm" else 1.0)
    # block geometry: one 4 kB page per block (paper §3.3)
    bk_elems = sys.page_bytes // (sys.sa_dim * itemsize)
    n_blocks = L.cdiv(traffic, sys.page_bytes)
    if conventional_layout:
        desc_per_block = L.descriptors_per_block_conventional(
            sys.sa_dim, bk_elems, g.K * itemsize, itemsize, sys.page_bytes)
    else:
        desc_per_block = L.descriptors_per_block_matrixflow(
            sys.sa_dim, bk_elems, itemsize, sys.page_bytes)
    issue = sys.desc_issue_dc_s if mode == "dc" else sys.desc_issue_dm_s
    n_desc = n_blocks * desc_per_block
    # every descriptor is a separate PCIe transaction → TLP header bytes;
    # the conventional layout's per-row fragments pay this ~16× more often
    wire_bytes = traffic + n_desc * sys.tlp_header_bytes
    transfer = max(wire_bytes / bw, n_desc * issue)
    control = sys.cmd_overhead_s * g.count
    if mode == "dm":
        # DM's coarse bursts pipeline less finely with compute than DC's
        # cache-line-granularity stream → a residual non-overlapped tail.
        control += 0.1 * min(compute, transfer)
    relayout = 0.0
    if include_layout_cost:
        relayout = ((g.M * g.K + g.K * g.N) * itemsize * g.count *
                    sys.relayout_cyc_per_byte / sys.cpu_freq_hz)
    total = max(compute, transfer) + control + relayout
    return {"compute": compute, "transfer": transfer, "control": control,
            "relayout": relayout, "total": total}


def smaug_gemm_time(g: Gemm, dtype: str, sys: SystemConfig = DEFAULT) -> float:
    """SMAUG [19]: fp16 NVDLA-class datapath, conventional layout, SPM chunks;
    compute and transfer serialize per chunk (no streaming co-design)."""
    t = matrixflow_gemm_time(g, "fp16", mode="dm", sys=sys,
                             conventional_layout=True)
    compute = _sa_compute_time(g, "fp16", sys, macs_per_cycle=sys.smaug_macs)
    traffic = _traffic_bytes(g, 2, sys, llc_streaming=False)
    chunks = L.cdiv(traffic, sys.smaug_chunk_bytes)
    return compute + t["transfer"] + chunks * sys.smaug_chunk_overhead_s


def ticsat_gemm_time(g: Gemm, dtype: str, sys: SystemConfig = DEFAULT) -> float:
    """TiC-SAT [2]: SA as a functional unit — no PCIe, but every 16×16×16
    tile pass issues custom instructions through the CPU pipeline (loads
    into the SA regs, compute, drain)."""
    compute = _sa_compute_time(g, dtype, sys)
    tiles = (L.cdiv(g.M, sys.sa_dim) * L.cdiv(g.N, sys.sa_dim)
             * L.cdiv(g.K, sys.sa_dim)) * g.count
    issue = tiles * sys.ticsat_tile_cycles / sys.cpu_freq_hz
    return compute + issue


def nongemm_time(e: Elementwise, sys: SystemConfig = DEFAULT) -> float:
    return e.elems * e.count * sys.cpu_cpi_vec_elem / sys.cpu_freq_hz


# ---------------------------------------------------------------------------
# Full-workload evaluation (drives Table 3 / Figs 6-9 benchmarks)
# ---------------------------------------------------------------------------

BACKENDS = ("cpu1", "omp", "neon", "smaug", "ticsat", "mf_dc", "mf_dm")


def workload_time(
    workload: Workload, dtype: str, backend: str,
    sys: SystemConfig = DEFAULT,
    include_layout_cost: bool = False,
) -> Dict[str, object]:
    gemms, elems = workload
    parts: Dict[str, float] = {}
    gemm_t = control_t = 0.0
    for g in gemms:
        if backend == "cpu1":
            t = cpu1_gemm_time(g, dtype, sys)
        elif backend == "omp":
            t = omp_gemm_time(g, dtype, sys)
        elif backend == "neon":
            t = neon_gemm_time(g, dtype, sys)
        elif backend == "smaug":
            t = smaug_gemm_time(g, dtype, sys)
        elif backend == "ticsat":
            t = ticsat_gemm_time(g, dtype, sys)
        elif backend in ("mf_dc", "mf_dm"):
            d = matrixflow_gemm_time(g, dtype, mode=backend[3:], sys=sys,
                                     include_layout_cost=include_layout_cost)
            t = d["total"]
            control_t += d["control"]
        else:
            raise ValueError(backend)
        gemm_t += t
        parts[g.tag] = parts.get(g.tag, 0.0) + t
    nong_t = 0.0
    for e in elems:
        if e.tag == "repack":
            # block-major repack of unaligned activations: an accelerator-
            # only cost (CPU/Neon/TiC-SAT consume row-major directly)
            if backend in ("mf_dc", "mf_dm", "smaug"):
                t = (e.elems * e.count * sys.repack_cyc_per_elem
                     / sys.cpu_freq_hz)
            else:
                continue
        else:
            # non-GEMM layers stay on the (vectorized) CPU in every scenario
            t = nongemm_time(e, sys)
            if backend == "omp":
                t /= sys.omp_cores * sys.omp_efficiency
        nong_t += t
        parts[e.tag] = parts.get(e.tag, 0.0) + t
    total = gemm_t + nong_t
    return {"total": total, "gemm": gemm_t, "nongemm": nong_t,
            "control": control_t, "parts": parts}


def speedup_table(workload: Workload, dtype: str,
                  sys: SystemConfig = DEFAULT,
                  include_layout_cost: bool = False) -> Dict[str, float]:
    base = workload_time(workload, dtype, "cpu1", sys)["total"]
    return {b: base / workload_time(workload, dtype, b, sys,
                                    include_layout_cost)["total"]
            for b in BACKENDS}
