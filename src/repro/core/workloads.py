"""Op-level workload extraction: model config → (GEMMs, elementwise ops).

Feeds core/sysmodel.py; the GEMM tags mirror the paper's Fig. 8 runtime
breakdown categories (QKV / scores / attn·V / proj / FF1 / FF2 / softmax /
layernorm / residual / transpose).
"""
from __future__ import annotations

from typing import Optional

from repro.core.sysmodel import Elementwise, Gemm, Workload


def transformer_workload(
    n_layers: int,
    d_model: int,
    n_heads: int,
    seq: int,
    d_ff: Optional[int] = None,
    n_kv_heads: Optional[int] = None,
    vocab: int = 0,
    batch: int = 1,
) -> Workload:
    """Encoder/decoder transformer forward pass as a GEMM + elementwise list."""
    d_ff = d_ff or 4 * d_model
    n_kv = n_kv_heads or n_heads
    dh = d_model // n_heads
    S = seq
    Lc = n_layers * batch
    gemms = (
        Gemm(S, d_model, d_model, count=Lc, tag="QKV"),                 # Q
        Gemm(S, d_model, n_kv * dh, count=2 * Lc, tag="QKV"),           # K,V
        Gemm(S, dh, S, count=Lc * n_heads, tag="scores"),               # QKᵀ
        Gemm(S, S, dh, count=Lc * n_heads, tag="attnV"),                # PV
        Gemm(S, d_model, d_model, count=Lc, tag="proj"),
        Gemm(S, d_model, d_ff, count=Lc, tag="FF1"),
        Gemm(S, d_ff, d_model, count=Lc, tag="FF2"),
    )
    if vocab:
        gemms = gemms + (Gemm(S, d_model, vocab, count=batch, tag="head"),)
    elems = (
        Elementwise(n_heads * S * S, count=Lc, tag="softmax"),
        Elementwise(S * d_model, count=2 * Lc, tag="layernorm"),
        Elementwise(S * d_model, count=2 * Lc, tag="residual"),
        Elementwise(S * d_model, count=2 * Lc, tag="transpose"),
        Elementwise(S * d_ff, count=Lc, tag="activation"),
    )
    if S % 16 != 0:
        # unaligned sequence (ViT 197/257): per-layer CPU block repack —
        # accelerator-only cost, see sysmodel.SystemConfig.repack_cyc_per_elem
        elems = elems + (Elementwise(S * d_model, count=Lc, tag="repack"),)
    return gemms, elems


# The paper's evaluated models (§4.1): BERT medium/base/large, ViT base/large/huge.
PAPER_MODELS = {
    "bert-medium": dict(n_layers=8, d_model=512, n_heads=8, seq=128),
    "bert-base": dict(n_layers=12, d_model=768, n_heads=12, seq=128),
    "bert-large": dict(n_layers=24, d_model=1024, n_heads=16, seq=128),
    "vit-base": dict(n_layers=12, d_model=768, n_heads=12, seq=197),
    "vit-large": dict(n_layers=24, d_model=1024, n_heads=16, seq=197),
    "vit-huge": dict(n_layers=32, d_model=1280, n_heads=16, seq=257),
}

# Paper Table 3 (speedup vs single-thread CPU) for validation side-by-side.
PAPER_TABLE3 = {
    "bert-medium": {"omp": 23.7, "smaug": 88.0, "ticsat": 58.3, "mf_dc": 453.9},
    "bert-base": {"omp": 24.3, "ticsat": 69.3, "mf_dc": 633.7},
    "bert-large": {"omp": 25.6, "ticsat": 89.5, "mf_dc": 698.2},
    "vit-base": {"omp": 23.7, "ticsat": 69.4, "mf_dc": 327.9},
    "vit-large": {"omp": 24.3, "ticsat": 82.5, "mf_dc": 392.0},
    "vit-huge": {"omp": 25.6, "ticsat": 82.7, "mf_dc": 427.6},
}


def paper_workload(name: str) -> Workload:
    return transformer_workload(**PAPER_MODELS[name])
