"""Algorithm 1 (Optimized Block Matrix Multiplication) in pure JAX.

This is the *faithful* software rendering of the paper's dataflow: iterate
over output blocks (i, j), stream the K-blocks of A and B through MultiAcc,
and write each finished C block exactly once (paper §3.3, Algorithm 1).

It serves three roles:
  1. the paper-faithful baseline (lax control flow, block-major operands);
  2. the oracle for the Pallas kernel (kernels/ref.py re-exports it);
  3. the op the analytic sysmodel instruments for DMA-descriptor counting.

The Pallas kernel in kernels/matrixflow_gemm.py executes the same schedule
on the TPU grid; XLA on CPU executes this one.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.analysis.kernel_contracts import (KernelContract, OperandSpec,
                                             Precondition, register_contract,
                                             require)
from repro.core import layout as L


# ---------------------------------------------------------------------------
# Algorithm 1's block addressing, stated once: the fori_loop body below
# walks these functions, and the registered KernelContract hands the same
# callables to the static checker. The conceptual grid is
# (i, j, k) = (nbm, nbn, nbk) with the K-stream innermost and sequential.
# ---------------------------------------------------------------------------

BLOCKFLOW_DIMENSION_SEMANTICS = ("parallel", "parallel", "arbitrary")


def _a_block_index(i, j, k):
    return (i, k)


def _b_block_index(i, j, k):
    return (j, k)


def _c_block_index(i, j, k):
    return (i, j)


def blockflow_preconditions(a_shape, b, blk, b_shape):
    """Structured entry guards shared between the runtime ``require`` and
    the static contract (``b`` may be a 4-D block-major array)."""
    M, K = a_shape
    pre = []
    if getattr(b, "ndim", 2) == 4:
        pre.append(Precondition.check(
            "block-major b metadata",
            blk is not None and b_shape is not None,
            "block-major b needs an explicit blk and b_shape=(K, N) giving "
            "the logical (unpadded) dims"))
        if blk is not None:
            pre.append(Precondition.check(
                "b blocks match layout",
                tuple(b.shape[-2:]) == (blk.bk, blk.bn),
                f"block-major b {tuple(b.shape)} carries "
                f"({b.shape[-2]}, {b.shape[-1]}) blocks but the BlockLayout "
                f"says (bk={blk.bk}, bn={blk.bn})"))
        K2 = b_shape[0] if b_shape is not None else K
    else:
        K2 = b.shape[0]
    pre.append(Precondition.check(
        "A/B contraction agreement", K == K2,
        f"a has K={K} columns but b has K={K2} rows; C = A @ B needs the "
        f"contraction dims to agree"))
    return tuple(pre)


@register_contract("blockflow")
def blockflow_contract(*, nbm, nbn, nbk) -> KernelContract:
    """Contract of :func:`block_matmul`'s dataflow (Algorithm 1).

    The software rendering has no pallas grid, but the schedule is the
    same: output block (i, j) accumulates along k — the declared reduction
    axis — and every A/B block is streamed exactly where the paper's
    dc/dm orders place it.
    """
    operands = (
        OperandSpec("a_bm", "input", (nbm, nbk), (1, 1), _a_block_index),
        OperandSpec("b_bm", "input", (nbn, nbk), (1, 1), _b_block_index),
        OperandSpec("c_bm", "output", (nbm, nbn), (1, 1), _c_block_index,
                    reduction_axes=(2,)),
    )
    return KernelContract(
        kernel="blockflow",
        grid=(nbm, nbn, nbk),
        operands=operands,
        dimension_semantics=BLOCKFLOW_DIMENSION_SEMANTICS,
        description="paper Algorithm 1, pure-JAX rendering (fori_loop "
                    "K-stream)")


def acc_dtype_for(dtype: jnp.dtype) -> jnp.dtype:
    """Accumulator policy mirroring the paper's MAC units (Table 2)."""
    d = jnp.dtype(dtype)
    if d in (jnp.dtype(jnp.int8), jnp.dtype(jnp.int16), jnp.dtype(jnp.int32)):
        return jnp.dtype(jnp.int32)
    return jnp.dtype(jnp.float32)


def multi_acc(a_blk: jax.Array, b_blk: jax.Array, c_blk: jax.Array) -> jax.Array:
    """MultiAcc(A_block, B_block, Res_block): one SA pass, accumulate into C."""
    acc = jnp.dot(a_blk, b_blk, preferred_element_type=c_blk.dtype)
    return c_blk + acc


def block_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    blk: Optional[L.BlockLayout] = None,
    out_dtype: Optional[jnp.dtype] = None,
    acc_dtype: Optional[jnp.dtype] = None,
    scale_a: Optional[jax.Array] = None,
    scale_b: Optional[jax.Array] = None,
    b_shape: Optional[tuple] = None,
) -> jax.Array:
    """C = A @ B via the paper's Algorithm 1 over block-major operands.

    a: (M, K), b: (K, N) in conventional row-major; the function performs the
    MatrixFlow re-layout (the paper's data-structure step), then the blocked
    dataflow with lax.fori_loop as the K-stream. ``acc_dtype`` overrides the
    paper's MAC accumulator policy (a GemmPolicy knob).

    ``b`` may instead be already block-major — 4-D ``(N/bn, K/bk, bk, bn)``,
    a resident PackedWeight's blocks — with ``b_shape=(K, N)`` giving the
    logical (unpadded) dims; the re-layout is then skipped entirely (the
    paper's Fig. 5 reuse on this backend).

    ``scale_a`` (M,) / ``scale_b`` (N,) fuse the quantized-GEMM dequant into
    each C-block flush: the finished int32 block is rescaled by
    ``s_a[m] * s_b[n]`` before it is written (the int8 W8A8 route — see
    core/quant.py). With scales present the default out_dtype is float32.
    """
    M, K = a.shape
    require(*blockflow_preconditions(a.shape, b, blk, b_shape))
    N = b_shape[1] if b.ndim == 4 else b.shape[1]
    if blk is None:
        blk = L.choose_layout(M, N, K, a.dtype)
    acc_dtype = jnp.dtype(acc_dtype or acc_dtype_for(a.dtype))
    fused = scale_a is not None or scale_b is not None
    out_dtype = out_dtype or (jnp.float32 if fused else acc_dtype)

    a_bm = L.to_block_major_a(a, blk.bm, blk.bk)      # (nbm, nbk, bm, bk)
    b_bm = b if b.ndim == 4 else \
        L.to_block_major_b(b, blk.bk, blk.bn)         # (nbn, nbk, bk, bn)
    nbm, nbk = a_bm.shape[0], a_bm.shape[1]
    nbn = b_bm.shape[0]
    if fused:
        sa = (jnp.ones((M,), jnp.float32) if scale_a is None
              else scale_a.astype(jnp.float32))
        sb = (jnp.ones((N,), jnp.float32) if scale_b is None
              else scale_b.astype(jnp.float32))
        sa_bm = jnp.pad(sa, (0, nbm * blk.bm - M)).reshape(nbm, blk.bm)
        sb_bm = jnp.pad(sb, (0, nbn * blk.bn - N)).reshape(nbn, blk.bn)

    def out_block(i: jax.Array, j: jax.Array) -> jax.Array:
        c0 = jnp.zeros((blk.bm, blk.bn), acc_dtype)

        def body(k, c_blk):
            ai, ak = _a_block_index(i, j, k)
            bj, bk_ = _b_block_index(i, j, k)
            a_blk = jax.lax.dynamic_index_in_dim(
                jax.lax.dynamic_index_in_dim(a_bm, ai, 0, keepdims=False),
                ak, 0, keepdims=False)
            b_blk = jax.lax.dynamic_index_in_dim(
                jax.lax.dynamic_index_in_dim(b_bm, bj, 0, keepdims=False),
                bk_, 0, keepdims=False)
            return multi_acc(a_blk.astype(acc_dtype), b_blk.astype(acc_dtype), c_blk)

        c_blk = jax.lax.fori_loop(0, nbk, body, c0)
        if fused:  # dequant fused at the block flush (paper's Buffer-C write)
            sa_blk = jax.lax.dynamic_index_in_dim(sa_bm, i, 0, keepdims=False)
            sb_blk = jax.lax.dynamic_index_in_dim(sb_bm, j, 0, keepdims=False)
            c_blk = (c_blk.astype(jnp.float32)
                     * sa_blk[:, None] * sb_blk[None, :])
        return c_blk

    ii, jj = jnp.meshgrid(jnp.arange(nbm), jnp.arange(nbn), indexing="ij")
    c_bm = jax.vmap(jax.vmap(out_block))(ii, jj)       # (nbm, nbn, bm, bn)
    c = L.from_block_major_c(c_bm, M, N)
    return c.astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("blk", "out_dtype"))
def block_matmul_jit(a, b, blk=None, out_dtype=None):
    return block_matmul(a, b, blk=blk, out_dtype=out_dtype)
