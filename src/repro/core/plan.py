"""ExecutionPlan API: typed GEMM policies, backend registry, resident weights.

The paper's co-design claim (§3.3, Fig. 5) is that transformer GEMMs stay
fast because operands *stay* block-major across layers: weights are laid out
offline, exactly once, and every activation is already block-major because it
was written as the previous GEMM's C blocks. This module is the API that
expresses that property:

  * :class:`GemmPolicy` — a frozen, hashable description of *how* GEMMs
    should execute (backend, DC/DM access mode, layout override, accumulator
    dtype, VMEM budget). Replaces the old thread-local string switch.
  * the **backend registry** — :func:`register_backend` maps a policy's
    backend name to an implementation, replacing the if/elif chain the old
    ``api.matmul`` carried. Downstream autotuning/sharding backends plug in
    without touching dispatch.
  * :func:`plan` — resolves a :class:`GemmPolicy` against a concrete
    ``(M, N, K, dtype)`` problem into an :class:`ExecutionPlan` holding the
    chosen :class:`~repro.core.layout.BlockLayout`. ``mode="auto"`` consults
    the analytic system model (:mod:`repro.core.sysmodel`) to pick DC vs DM
    per shape. Plans are memoized in a process-wide cache keyed on
    ``(shape, dtype, policy)`` so repeated shapes (every decode step, every
    layer of the same width) resolve exactly once.
  * :class:`PackedWeight` — a weight held *resident in block-major form*
    (the paper's horizontally-split B operand, Fig. 4 bottom). Layers pack
    each weight once at model build; every subsequent GEMM consumes the
    blocks directly — the Fig. 5 pipeline-reuse property.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import layout as L
from repro.core import quant as Q
from repro.core.quant import QuantizedPackedWeight

__all__ = [
    "GemmPolicy", "ExecutionPlan", "PackedWeight", "QuantizedPackedWeight",
    "BackendSpec",
    "plan", "plan_cache_info", "plan_cache_clear",
    "register_backend", "unregister_backend", "get_backend_spec",
    "registered_backends", "resolve_backend",
    "pack_weight", "pack_model_weights", "layout_for_packed",
    "AttentionPolicy", "AttentionBackendSpec",
    "register_attention_backend", "unregister_attention_backend",
    "get_attention_backend_spec", "registered_attention_backends",
    "resolve_attention_backend",
    "ShardingPolicy",
]

DEFAULT_VMEM_BUDGET = 96 * 1024 * 1024

# Weight dtypes the quantized GEMM route understands (core/quant.py).
_WEIGHT_DTYPES = (None, "int8")

# KV-pool dtypes the paged attention route understands (docs/quant.md).
_KV_DTYPES = (None, "int8")


# ---------------------------------------------------------------------------
# Policy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GemmPolicy:
    """How GEMMs should execute. Frozen → hashable → a plan-cache key.

    backend      registry name, or "auto" (pallas on TPU, xla elsewhere).
    mode         paper access mode: "dc" | "dm" | "auto" (per-shape choice by
                 the sysmodel analytic cost model).
    layout       explicit BlockLayout override (skips mode resolution).
    acc_dtype    accumulator dtype name ("float32"/"int32"); None → the
                 paper's MAC policy (int inputs → int32, float → float32).
    vmem_budget  VMEM bytes the layout chooser may claim for the working set.
    weight_dtype None → weights execute in their stored dtype; "int8" →
                 GEMM weights run the quantized W8A8 route (core/quant.py):
                 per-channel int8 weights, dynamic per-row int8 activations,
                 int32 accumulation, dequant fused into the C-block flush.
    """

    backend: str = "auto"
    mode: str = "auto"
    layout: Optional[L.BlockLayout] = None
    acc_dtype: Optional[str] = None
    vmem_budget: int = DEFAULT_VMEM_BUDGET
    weight_dtype: Optional[str] = None

    def __post_init__(self):
        if self.weight_dtype not in _WEIGHT_DTYPES:
            raise ValueError(
                f"unsupported weight_dtype {self.weight_dtype!r}; "
                f"expected one of {_WEIGHT_DTYPES}")
        if self.weight_dtype is not None and self.acc_dtype is not None:
            raise ValueError(
                f"acc_dtype={self.acc_dtype!r} cannot be combined with "
                f"weight_dtype={self.weight_dtype!r}: the quantized route "
                "accumulates int8×int8 in int32 by construction (the "
                "rank-1 dequant is exact only over the integer result)")

    def resolved_backend(self) -> str:
        return resolve_backend(self.backend)


# Common pinned policies (tests, benchmarks, CLI flags).
XLA = GemmPolicy(backend="xla")
BLOCKFLOW = GemmPolicy(backend="blockflow")
PALLAS = GemmPolicy(backend="pallas")
PALLAS_INTERPRET = GemmPolicy(backend="pallas_interpret")


def resolve_backend(name: str) -> str:
    """Map "auto" to the platform default; pass anything else through."""
    if name != "auto":
        return name
    try:
        plat = jax.default_backend()
    except Exception:  # pragma: no cover
        plat = "cpu"
    return "pallas" if plat == "tpu" else "xla"


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------

# A backend implementation: fn(a, b, plan, out_dtype) -> c.
#   * batched=False backends receive a 2-D a (M, K) and a 2-D b (K, N) or a
#     PackedWeight; api.matmul collapses/vmaps leading dims around them.
#   * batched=True backends receive the operands as the caller passed them
#     (any leading dims, jnp broadcasting semantics) — e.g. XLA einsum.
BackendFn = Callable[..., jax.Array]


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    name: str
    fn: BackendFn
    batched: bool = False        # consumes batched contractions natively
    needs_layout: bool = True    # plan() must resolve a BlockLayout


_REGISTRY: Dict[str, BackendSpec] = {}
_registry_lock = threading.Lock()


def register_backend(name: str, fn: BackendFn, *, batched: bool = False,
                     needs_layout: bool = True,
                     overwrite: bool = False) -> BackendSpec:
    """Register a GEMM backend under ``name`` (the GemmPolicy.backend key)."""
    spec = BackendSpec(name=name, fn=fn, batched=batched,
                       needs_layout=needs_layout)
    with _registry_lock:
        if name in _REGISTRY and not overwrite:
            raise ValueError(f"backend {name!r} already registered "
                             f"(pass overwrite=True to replace)")
        _REGISTRY[name] = spec
    plan_cache_clear()   # plans embed the backend name; don't serve stale ones
    return spec


def unregister_backend(name: str) -> None:
    with _registry_lock:
        _REGISTRY.pop(name, None)
    plan_cache_clear()


def get_backend_spec(name: str) -> BackendSpec:
    spec = _REGISTRY.get(resolve_backend(name))
    if spec is None:
        # The built-ins are registered by repro.core.api at import time;
        # make plan.py usable standalone by pulling them in on first miss.
        import repro.core.api  # noqa: F401  (registers built-in backends)
        spec = _REGISTRY.get(resolve_backend(name))
    if spec is None:
        raise ValueError(
            f"unknown GEMM backend {name!r}; registered: "
            f"{sorted(_REGISTRY)}")
    return spec


def registered_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# Attention policy + backend registry (mirrors the GEMM registry above)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttentionPolicy:
    """How attention executes. Frozen → hashable → jit-static.

    backend    registry name, or "auto" (fused Pallas kernel on TPU, the
               unfused einsum + host-softmax baseline elsewhere — mirroring
               the GEMM registry's pallas/xla auto split).
    block_q    flash-kernel query-block rows (fused/paged backends).
    block_k    flash-kernel key-block columns (fused backends only).
    page_size  tokens per KV page for the ``paged`` backends: the key-block
               size of the paged kernel IS the page size, so keep it
               MXU-friendly (a multiple of the sublane tile; the fused
               kernel's block_k is its natural TPU value). Consumed by
               ``models/transformer.py::init_paged_caches`` and the serving
               engine's PagePool (serving/kv_pool.py, docs/serving.md).
    kv_dtype   None → the KV pool stores the model's cache dtype; "int8" →
               paged backends store int8 pages with per-page-per-head fp32
               scales, dequantized inside the kernel's K/V-block fetch
               (docs/quant.md#kv-pages). Paged backends only — the dense
               backends reject it (core/api.py).

    All backends share one contract (kernels/ref.py::mha_ref): key j of
    batch row b is visible to query i iff ``j < kv_valid_len[b]`` and, when
    causal, ``j <= q_positions[b, i]``; rows with no visible key (serving's
    masked position −1 slots) produce zeros. The paged backends add one
    input — a per-request block table mapping logical key blocks to
    physical pool pages — and keep the same logical-position semantics.
    """

    backend: str = "auto"
    block_q: int = 128
    block_k: int = 128
    page_size: int = 16
    kv_dtype: Optional[str] = None

    def __post_init__(self):
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.kv_dtype not in _KV_DTYPES:
            raise ValueError(
                f"unsupported kv_dtype {self.kv_dtype!r}; "
                f"expected one of {_KV_DTYPES}")

    def resolved_backend(self) -> str:
        return resolve_attention_backend(self.backend)


# Common pinned policies (tests, benchmarks, CLI flags).
FUSED = AttentionPolicy(backend="fused")
FUSED_INTERPRET = AttentionPolicy(backend="fused_interpret")
UNFUSED = AttentionPolicy(backend="unfused")
PAGED = AttentionPolicy(backend="paged")
PAGED_INTERPRET = AttentionPolicy(backend="paged_interpret")


def resolve_attention_backend(name: str) -> str:
    """Map "auto" to the platform default; pass anything else through."""
    if name != "auto":
        return name
    try:
        plat = jax.default_backend()
    except Exception:  # pragma: no cover
        plat = "cpu"
    return "fused" if plat == "tpu" else "unfused"


# ---------------------------------------------------------------------------
# Sharding policy (consumed by repro/distributed/tp.py)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """How execution shards over a (data, model) device mesh.

    The third member of the policy family (GemmPolicy: how GEMMs execute;
    AttentionPolicy: how attention executes; ShardingPolicy: how both span
    a mesh). Frozen → hashable → safe to carry in jit-static config. The
    mesh itself is a runtime handle (ServeConfig.mesh, launch/mesh.py);
    this policy only names the axes and rule overrides.

    data_axis    mesh axis for data parallelism (activations' batch dim;
                 TP serving keeps weights/caches replicated along it).
    model_axis   mesh axis for tensor parallelism: QKV/up projections
                 column-parallel, out/down projections row-parallel with a
                 psum on the contraction, attention/KV-pool heads sharded
                 (repro/distributed/tp.py, docs/serving.md).
    overrides    logical-rule overrides layered onto
                 :data:`repro.distributed.sharding.DEFAULT_RULES`, as a
                 hashable tuple of ``(logical_name, mesh_axes)`` pairs —
                 e.g. ``(("heads", None),)`` pins attention replicated.
    """

    data_axis: str = "data"
    model_axis: str = "model"
    overrides: Tuple[Tuple[str, Any], ...] = ()

    def overrides_dict(self) -> Dict[str, Any]:
        return dict(self.overrides)


# An attention backend implementation:
#   fn(q, k, v, *, q_positions, kv_valid_len, causal, scale, soft_cap,
#      policy, block_tables) -> out
# with model-layout operands: q (B,Sq,H,Dk), k (B,T,Hkv,Dk), v (B,T,Hkv,Dv),
# returning (B,Sq,H,Dv). block_tables is None for dense caches; the paged
# backends instead receive pool-shaped k/v (P, page_size, Hkv, D) plus the
# (B, n_blocks) block table (docs/serving.md); dense backends must reject a
# non-None block table rather than misread the pool layout.
AttentionBackendFn = Callable[..., jax.Array]


@dataclasses.dataclass(frozen=True)
class AttentionBackendSpec:
    name: str
    fn: AttentionBackendFn


_ATTN_REGISTRY: Dict[str, AttentionBackendSpec] = {}


def register_attention_backend(name: str, fn: AttentionBackendFn, *,
                               overwrite: bool = False) -> AttentionBackendSpec:
    """Register an attention backend under ``name`` (the
    AttentionPolicy.backend key)."""
    spec = AttentionBackendSpec(name=name, fn=fn)
    with _registry_lock:
        if name in _ATTN_REGISTRY and not overwrite:
            raise ValueError(f"attention backend {name!r} already registered "
                             f"(pass overwrite=True to replace)")
        _ATTN_REGISTRY[name] = spec
    return spec


def unregister_attention_backend(name: str) -> None:
    with _registry_lock:
        _ATTN_REGISTRY.pop(name, None)


def get_attention_backend_spec(name: str) -> AttentionBackendSpec:
    spec = _ATTN_REGISTRY.get(resolve_attention_backend(name))
    if spec is None:
        # Built-ins are registered by repro.core.api at import time; make
        # plan.py usable standalone by pulling them in on first miss.
        import repro.core.api  # noqa: F401  (registers built-in backends)
        spec = _ATTN_REGISTRY.get(resolve_attention_backend(name))
    if spec is None:
        raise ValueError(
            f"unknown attention backend {name!r}; registered: "
            f"{sorted(_ATTN_REGISTRY)}")
    return spec


def registered_attention_backends() -> Tuple[str, ...]:
    return tuple(sorted(_ATTN_REGISTRY))


# ---------------------------------------------------------------------------
# Plan resolution
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """A GemmPolicy resolved against one (M, N, K, dtype) problem."""

    M: int
    N: int
    K: int
    dtype: str                       # canonical jnp dtype name
    backend: str                     # resolved registry name
    mode: Optional[str]              # "dc"/"dm"; None for layout-free backends
    layout: Optional[L.BlockLayout]
    acc_dtype: str
    policy: GemmPolicy

    @property
    def acc(self) -> jnp.dtype:
        return jnp.dtype(self.acc_dtype)


_SYSMODEL_DTYPE = {"int8": "int8", "int16": "int16", "int32": "int32",
                   "float16": "fp16", "bfloat16": "bf16", "float32": "fp32"}


def _default_acc_dtype(dtype: jnp.dtype) -> str:
    from repro.core import blockflow  # single source for the MAC acc policy
    return blockflow.acc_dtype_for(dtype).name


def _auto_mode(M: int, N: int, K: int, dtype: str) -> str:
    """DC vs DM per shape, from the analytic system model (paper §4.3).

    DC's LLC streaming wins while the C strip stays cache-resident; DM's
    large bursts win once it does not. The sysmodel encodes exactly that
    cliff, so we ask it instead of hardcoding a default.
    """
    from repro.core import sysmodel as SM  # deferred: keep import cost off
    g = SM.Gemm(M=M, K=K, N=N)
    sm_dtype = _SYSMODEL_DTYPE.get(dtype, "fp32")
    t_dc = SM.matrixflow_gemm_time(g, sm_dtype, mode="dc")["total"]
    t_dm = SM.matrixflow_gemm_time(g, sm_dtype, mode="dm")["total"]
    return "dc" if t_dc <= t_dm else "dm"


@functools.lru_cache(maxsize=4096)
def _plan_cached(M: int, N: int, K: int, dtype: str,
                 policy: GemmPolicy) -> ExecutionPlan:
    backend = policy.resolved_backend()
    spec = get_backend_spec(backend)
    acc = policy.acc_dtype or _default_acc_dtype(dtype)
    if not spec.needs_layout:
        return ExecutionPlan(M=M, N=N, K=K, dtype=dtype, backend=backend,
                             mode=None, layout=None, acc_dtype=acc,
                             policy=policy)
    if policy.layout is not None:
        layout = policy.layout
        mode = layout.mode
    else:
        mode = policy.mode
        if mode == "auto":
            mode = _auto_mode(M, N, K, dtype)
        layout = L.choose_layout(M, N, K, jnp.dtype(dtype), mode=mode,
                                 vmem_budget=policy.vmem_budget)
    return ExecutionPlan(M=M, N=N, K=K, dtype=dtype, backend=backend,
                         mode=mode, layout=layout, acc_dtype=acc,
                         policy=policy)


def plan(M: int, N: int, K: int, dtype: Any,
         policy: Optional[GemmPolicy] = None, *,
         validate: bool = False) -> ExecutionPlan:
    """Resolve ``policy`` for one GEMM problem; memoized on all arguments.

    ``validate=True`` additionally runs the resolved block choice through
    the static contract checker (repro/analysis): the auto-mode layout is
    instantiated as the kernel's registered :class:`KernelContract` and
    checked for coverage/bounds/race violations before anything executes.
    Raises :class:`~repro.analysis.kernel_contracts.ContractViolationError`
    on the first bad plan — the gate ``python -m repro.analysis`` sweeps.
    """
    p = _plan_cached(int(M), int(N), int(K), jnp.dtype(dtype).name,
                     policy if policy is not None else GemmPolicy())
    if validate:
        _validate_plan(p)
    return p


def _validate_plan(p: ExecutionPlan) -> None:
    """Check a resolved plan's block geometry against the kernel contract
    it will dispatch to (lazy import: analysis is optional at runtime)."""
    if p.layout is None:
        return                      # layout-free backend (xla): no contract
    from repro.analysis.kernel_contracts import (ContractViolationError,
                                                 check_contract,
                                                 get_contract_builder)
    blk = p.layout
    nbm = -(-p.M // blk.bm)
    nbn = -(-p.N // blk.bn)
    nbk = -(-p.K // blk.bk)
    if p.backend == "blockflow":
        contract = get_contract_builder("blockflow")(
            nbm=nbm, nbn=nbn, nbk=nbk)
    else:
        contract = get_contract_builder("matrixflow_gemm")(
            a_shape=(nbm, nbk, blk.bm, blk.bk),
            b_shape=(nbn, nbk, blk.bk, blk.bn),
            blk=blk, fused=p.policy.weight_dtype == "int8")
    violations = check_contract(contract)
    if violations:
        raise ContractViolationError(violations)


def plan_cache_info():
    """Hits/misses of the process-wide plan cache (functools CacheInfo)."""
    return _plan_cached.cache_info()


def plan_cache_clear() -> None:
    _plan_cached.cache_clear()


# ---------------------------------------------------------------------------
# Resident block-major weights (paper Fig. 5: lay out once, reuse per layer)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PackedWeight:
    """A GEMM rhs stored block-major (the paper's horizontally-split B).

    data is ``(..., N/bn, K/bk, bk, bn)``; leading dims are stacked-layer
    axes (lax.scan / tree indexing slice only ``data``, so a stacked
    PackedWeight indexes down to a per-layer one for free).
    """

    data: jax.Array
    k: int                   # logical (unpadded) K
    n: int                   # logical (unpadded) N
    bk: int
    bn: int
    mode: str = "dm"

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.k, self.n)

    @property
    def dtype(self):
        return self.data.dtype

    def unpack(self) -> jax.Array:
        """Back to row-major (…, K, N) — for layout-free backends."""
        return L.from_block_major_b(self.data, self.k, self.n)

    # pytree protocol: data is the only traced leaf; geometry is static.
    def tree_flatten(self):
        return (self.data,), (self.k, self.n, self.bk, self.bn, self.mode)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)


def pack_weight(w: jax.Array, policy: Optional[GemmPolicy] = None,
                *, m_hint: int = 512, quantize: Optional[str] = None):
    """Lay a (…, K, N) weight out block-major exactly once.

    ``m_hint`` stands in for the unknown runtime M when resolving the block
    geometry; bk/bn depend on M only through the VMEM-budget shrink loop, so
    any M that fits the budget yields the same packing.

    ``quantize="int8"`` (default: the policy's ``weight_dtype``) quantizes
    symmetric per-channel at pack time and returns a
    :class:`QuantizedPackedWeight` — int8 blocks + fp32 scales, resident —
    instead of a fp :class:`PackedWeight`. Block geometry is then chosen for
    the int8 itemsize (the paper's per-dtype MAC sizing, Table 2).
    """
    policy = policy if policy is not None else GemmPolicy()
    quantize = quantize if quantize is not None else policy.weight_dtype
    if quantize not in _WEIGHT_DTYPES:
        raise ValueError(f"unsupported quantize={quantize!r}; "
                         f"expected one of {_WEIGHT_DTYPES}")
    K, N = w.shape[-2], w.shape[-1]
    pack_dtype = jnp.dtype(jnp.int8) if quantize == "int8" else w.dtype
    if policy.layout is not None:
        blk = policy.layout
    else:
        mode = policy.mode
        if mode == "auto":
            mode = _auto_mode(m_hint, N, K, jnp.dtype(pack_dtype).name)
        blk = L.choose_layout(m_hint, N, K, pack_dtype, mode=mode,
                              vmem_budget=policy.vmem_budget)
    if quantize == "int8":
        q, scales = Q.quantize_weight(w)
        data = L.to_block_major_b(q, blk.bk, blk.bn)
        return QuantizedPackedWeight(
            data=data, scales=scales, k=K, n=N, bk=blk.bk, bn=blk.bn,
            mode=blk.mode, dequant_dtype=jnp.dtype(w.dtype).name)
    data = L.to_block_major_b(w, blk.bk, blk.bn)
    return PackedWeight(data=data, k=K, n=N, bk=blk.bk, bn=blk.bn,
                        mode=blk.mode)


def layout_for_packed(M: int, pw, dtype: Any,
                      policy: Optional[GemmPolicy] = None) -> L.BlockLayout:
    """A BlockLayout consistent with a packed weight's frozen bk/bn.

    ``pw`` is a :class:`PackedWeight` or :class:`QuantizedPackedWeight`
    (both carry the same k/n/bk/bn/mode geometry).

    The packed geometry is immutable (re-packing would defeat the resident-
    weight point), so when it differs from what the calling policy would
    have planned, bk/bn come from the pack and bm — the only free dim left —
    shrinks until the working set honors the *calling* policy's VMEM budget.
    """
    policy = policy if policy is not None else GemmPolicy()
    pln = plan(M, pw.n, pw.k, dtype, policy)
    blk = pln.layout or L.choose_layout(M, pw.n, pw.k, jnp.dtype(dtype),
                                        mode=pw.mode,
                                        vmem_budget=policy.vmem_budget)
    if (blk.bk, blk.bn) != (pw.bk, pw.bn):
        blk = L.BlockLayout(bm=blk.bm, bn=pw.bn, bk=pw.bk, mode=blk.mode)
        itemsize = jnp.dtype(dtype).itemsize
        while (blk.vmem_bytes(itemsize) > policy.vmem_budget
               and blk.bm > L.SUBLANE):
            blk = L.BlockLayout(blk.bm // 2, blk.bn, blk.bk, blk.mode)
        if blk.vmem_bytes(itemsize) > policy.vmem_budget:
            raise ValueError(
                f"PackedWeight geometry (bk={pw.bk}, bn={pw.bn}) cannot fit "
                f"the calling policy's vmem_budget={policy.vmem_budget} even "
                f"at bm={blk.bm}; re-pack the weight under this policy "
                f"(pack_weight(w, policy)) or raise the budget")
    return blk


# Keys that name GEMM right-hand sides in the model parameter trees
# (models/layers.py, models/ssm.py, models/transformer.py).
_PACK_KEYS = frozenset({
    "wq", "wk", "wv", "wo", "wi", "wq_a", "wq_b", "wkv_a", "wkv_b",
    "w_z", "w_x", "w_B", "w_C", "w_dt", "w_out", "head", "router",
})
# MoE expert banks live directly under the "moe" dict and run as grouped
# einsums (E, d_in, d_out) — never pack those. The shared-expert MLP nests
# one level deeper ("moe" → "shared" → "wi") and is a plain linear.
_EINSUM_BANKS = frozenset({"wi", "wo"})


def pack_model_weights(params, policy: Optional[GemmPolicy] = None,
                       *, m_hint: int = 512,
                       quantize: Optional[str] = None):
    """Pack every GEMM weight in a model param tree into a PackedWeight.

    Realizes the paper's offline weight arrangement (Fig. 5): each weight is
    laid out block-major once at model build/load; api.linear consumes the
    blocks directly. Non-GEMM params (norms, biases, conv kernels, embeds,
    MoE expert banks) pass through untouched.

    ``quantize="int8"`` (default: the policy's ``weight_dtype``) makes every
    packed weight a :class:`QuantizedPackedWeight` — the quantize-at-pack
    deployment shape where serving holds int8 blocks + scales resident.
    """
    quantize = quantize if quantize is not None else (
        policy.weight_dtype if policy is not None else None)
    def rec(node, parent_key):
        if isinstance(node, dict):
            return {k: rec(v, k) if isinstance(v, (dict, list))
                    else maybe_pack(parent_key, k, v)
                    for k, v in node.items()}
        if isinstance(node, list):
            return [rec(v, parent_key) for v in node]
        return node

    def maybe_pack(parent_key, key, leaf):
        if key not in _PACK_KEYS or not hasattr(leaf, "ndim"):
            return leaf
        if parent_key == "moe" and key in _EINSUM_BANKS:
            return leaf
        if leaf.ndim < 2:
            return leaf
        return pack_weight(leaf, policy, m_hint=m_hint, quantize=quantize)

    return rec(params, None)
