"""Production mesh construction.

A function (not a module constant) so importing this module never touches
jax device state — the dry-run must set XLA_FLAGS before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally, as a (data, model) mesh (CPU tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
