"""Production mesh construction.

A function (not a module constant) so importing this module never touches
jax device state — the dry-run must set XLA_FLAGS before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, model: int = 1):
    """Whatever devices exist locally, as a (data, model) mesh (CPU tests,
    TP serving on one host).

    ``model`` is the model-axis (tensor-parallel) factor; the data axis
    takes the rest. The old signature silently pinned the model axis to 1
    — callers asking for TP got a mesh that could never shard. Now the
    factor is explicit and an impossible split fails loudly.
    """
    n = len(jax.devices())
    if model < 1:
        raise ValueError(f"model axis factor must be >= 1, got {model}")
    if n % model:
        raise ValueError(
            f"cannot build a (data, model) host mesh: {n} local device(s) "
            f"not divisible by model={model}; pick a factor of {n} (or "
            f"force host devices via XLA_FLAGS="
            f"--xla_force_host_platform_device_count=N before jax init)")
    return jax.make_mesh((n // model, model), ("data", "model"))
