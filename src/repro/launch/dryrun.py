import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: the 512
placeholder host devices build the production meshes (16×16 single-pod,
2×16×16 multi-pod); every cell must lower and compile, and the compiled
artifact yields memory_analysis / cost_analysis / the collective schedule
for §Dry-run and §Roofline.

Cost-accounting methodology (see DESIGN.md §Roofline-methodology): XLA's
cost_analysis counts a while-loop (lax.scan) body ONCE regardless of trip
count, so a scanned-layers lowering under-reports flops/bytes/collectives
by ~n_layers. Each cell therefore compiles three programs:

  1. the production (scanned) step — compile proof + memory_analysis
     (buffer reuse across layers is real there);
  2. an unrolled depth-1 and
  3. an unrolled depth-2 variant at FULL width on the same mesh —
     their cost difference is the exact per-layer-body cost, and

     true_cost = scan_cost + (n_body_units − 1) × body_cost

  composes the exact full-depth accounting (the scanned program already
  contains the body once). For hybrid archs the body unit is one
  (attn_every SSD + shared-attn) group.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.jsonl]
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from repro.configs.registry import (SHAPES, all_cells, cell_applicable,
                                    get_config)
from repro.distributed import sharding as shd
from repro.launch import specs as SP
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh
from repro.optim import adamw_init
from repro.roofline import analysis as RA
from repro.serving.engine import make_decode_step, make_prefill_step


def _lower_step(cfg, shape, mesh, rules_overrides=None):
    """Lower + compile the cell's step for ``cfg`` as-is. Returns
    (compiled, lower_s, compile_s)."""
    shape_kind = shape.kind
    rules = ST.make_rules(cfg, mesh, shape, rules_overrides)
    params_abs, axes = SP.abstract_params_and_axes(cfg)
    p_shard = ST.model_shardings(cfg, params_abs, axes, rules)
    t0 = time.time()
    with shd.use_rules(rules):
        if shape_kind == "train":
            step = ST.make_train_step_fn(cfg, grad_shardings=p_shard)
            opt_abs = jax.eval_shape(adamw_init, params_abs)
            o_shard = ST.opt_shardings(p_shard, rules)
            in_specs = SP.input_specs(cfg, shape)
            b_shard = ST.batch_shardings(in_specs["batch"], rules)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, o_shard, b_shard),
                             out_shardings=(p_shard, o_shard, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, in_specs["batch"])
        elif shape_kind == "prefill":
            step = make_prefill_step(cfg)
            in_specs = SP.input_specs(cfg, shape)
            b_shard = ST.batch_shardings(in_specs["batch"], rules)
            c_shard = ST.cache_shardings(in_specs["caches"], rules)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, b_shard, c_shard),
                             out_shardings=(None, c_shard),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_abs, in_specs["batch"],
                                   in_specs["caches"])
        else:  # decode
            step = make_decode_step(cfg)
            in_specs = SP.input_specs(cfg, shape)
            tok_shard = ST.batch_shardings(in_specs["tokens"], rules)
            pos_shard = ST.batch_shardings(in_specs["positions"], rules)
            c_shard = ST.cache_shardings(in_specs["caches"], rules)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, tok_shard, pos_shard,
                                           c_shard),
                             donate_argnums=(3,),
                             out_shardings=(None, c_shard))
            lowered = jitted.lower(params_abs, in_specs["tokens"],
                                   in_specs["positions"], in_specs["caches"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    return compiled, t_lower, t_compile


def _cost_vector(compiled):
    """(flops, bytes_accessed, collective_bytes) per partition."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # newer jax: one dict per partition
        cost = cost[0] if cost else {}
    coll = RA.collective_bytes_from_hlo(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(coll["total"]), coll)


def _body_unit(cfg) -> int:
    """Layers per scan step: one hybrid group for zamba-style archs."""
    return cfg.attn_every if cfg.attn_every else 1


def _depth_cfg(cfg, n_units: int):
    """Full-width config with ``n_units`` unrolled body units (and the
    dense lead layers dropped — they are already unrolled, hence exactly
    counted, in the scanned program). Keeps the config's remat setting so
    the body diff includes remat recompute — required for remat-policy
    A/B arms to be visible in the composed accounting."""
    g = _body_unit(cfg)
    return dataclasses.replace(
        cfg, n_layers=n_units * g, first_dense_layers=0, scan_layers=False)


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                rules_overrides=None, verbose: bool = True,
                skip_body_probe: bool = False):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not cell_applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "full-attention arch at 500k decode (DESIGN §3)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size

    # ---- 1. production (scanned) program: compile proof + memory ----------
    compiled, t_lower, t_compile = _lower_step(cfg, shape, mesh,
                                               rules_overrides)
    mem = compiled.memory_analysis()
    f_scan, b_scan, x_scan, coll_detail = _cost_vector(compiled)

    # ---- 2/3. per-layer body cost via depth-1 vs depth-2 unrolled ----------
    g = _body_unit(cfg)
    n_units = (cfg.n_layers - cfg.first_dense_layers) // g
    if skip_body_probe or n_units <= 1:
        f_body = b_body = x_body = 0.0
        n_units = max(n_units, 1)
    else:
        c1, _, t_c1 = _lower_step(_depth_cfg(cfg, 1), shape, mesh,
                                  rules_overrides)
        c2, _, t_c2 = _lower_step(_depth_cfg(cfg, 2), shape, mesh,
                                  rules_overrides)
        f1, b1, x1, _ = _cost_vector(c1)
        f2, b2, x2, _ = _cost_vector(c2)
        f_body, b_body = max(f2 - f1, 0.0), max(b2 - b1, 0.0)
        x_body = max(x2 - x1, 0.0)

    # The probes inherit the config's remat setting, so the per-body diff
    # includes remat recompute exactly. Composition:
    flops = f_scan + (n_units - 1) * f_body
    bytes_acc = b_scan + (n_units - 1) * b_body
    coll_bytes = x_scan + (n_units - 1) * x_body

    n_params = count_params_abstract_cfg(cfg)
    tokens = shape.batch * (shape.seq if shape.kind != "decode" else 1)
    mf = RA.model_flops_estimate(
        active_params(cfg, n_params), tokens,
        "train" if shape.kind == "train" else "infer")
    terms = RA.roofline_terms({"flops": flops, "bytes accessed": bytes_acc},
                              coll_bytes, model_flops=mf / n_chips)

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind, "n_chips": n_chips,
        "n_params": n_params,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": describe_memory(mem),
        "cost": {"flops": flops, "bytes accessed": bytes_acc,
                 "scan_flops": f_scan, "body_flops": f_body,
                 "n_body_units": n_units},
        "collectives": {"total": coll_bytes, "scan_total": x_scan,
                        "body_total": x_body},
        "collective_counts": coll_detail["counts"],
        "roofline": {k: v for k, v in terms.items()},
    }
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {result['mesh']}: "
              f"compile {t_compile:.1f}s, "
              f"bottleneck={terms['bottleneck']}, "
              f"t=({terms['t_compute_s']:.2e},{terms['t_memory_s']:.2e},"
              f"{terms['t_collective_s']:.2e})s "
              f"frac={terms['roofline_fraction']:.3f}")
        if mem is not None:
            print(f"  memory_analysis: {result['memory']}")
        print(f"  cost: flops={flops:.3e}/chip bytes={bytes_acc:.3e}/chip "
              f"coll={coll_bytes:.3e}B/chip")
    return result


def count_params_abstract_cfg(cfg) -> int:
    import numpy as np
    params_abs, _ = SP.abstract_params_and_axes(cfg)
    return int(sum(np.prod(leaf.shape) for leaf in
                   jax.tree_util.tree_leaves(params_abs)))


def count_params_abstract(params_abs) -> int:
    import numpy as np
    return int(sum(np.prod(leaf.shape) for leaf in
                   jax.tree_util.tree_leaves(params_abs)))


def active_params(cfg, n_params: int) -> float:
    """6·N_active·D for MoE: discount inactive routed experts."""
    if not cfg.is_moe:
        return float(n_params)
    per_expert = 3 * cfg.d_model * (cfg.moe_d_ff or cfg.d_ff)
    n_moe_layers = cfg.n_layers - cfg.first_dense_layers
    routed = n_moe_layers * cfg.n_experts * per_expert
    active = n_moe_layers * cfg.n_experts_active * per_expert
    return float(n_params - routed + active)


def describe_memory(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = int(v)
    out["total_gb_per_device"] = round(
        (out.get("argument_size_in_bytes", 0)
         + out.get("temp_size_in_bytes", 0)) / 2**30, 3)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-body-probe", action="store_true",
                    help="compile only the scanned program (fast sanity)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    results = []
    if args.all:
        cells = [(a, s.name) for a, s in all_cells()]
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for arch, shape_name in cells:
        for mp in meshes:
            try:
                r = dryrun_cell(arch, shape_name, multi_pod=mp,
                                skip_body_probe=args.skip_body_probe)
            except Exception as e:  # a failure here is a bug in the system
                traceback.print_exc()
                r = {"arch": arch, "shape": shape_name,
                     "mesh": "2x16x16" if mp else "16x16",
                     "error": f"{type(e).__name__}: {e}"}
                failures += 1
            results.append(r)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(r) + "\n")
    print(f"[dryrun] {len(results)} cells, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
