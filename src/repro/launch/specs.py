"""ShapeDtypeStruct input stand-ins for every (arch × shape) cell.

No device allocation: the dry-run lowers against these abstract values.
Modality frontends are stubs per the assignment — musicgen gets 4-stream
EnCodec token ids, internvl2 gets 256 precomputed patch embeddings.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.registry import ShapeCell
from repro.models import transformer as T
from repro.models.config import ModelConfig

SDS = jax.ShapeDtypeStruct


def token_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, SDS]:
    if cfg.n_codebooks:
        return {"tokens": SDS((batch, seq, cfg.n_codebooks), jnp.int32)}
    if cfg.family == "vlm":
        from repro.configs.internvl2_76b import N_IMAGE_TOKENS
        n_img = min(N_IMAGE_TOKENS, max(seq // 2, 1))
        return {
            "tokens": SDS((batch, seq - n_img), jnp.int32),
            "embeds": SDS((batch, n_img, cfg.d_model), cfg.param_dtype),
        }
    if cfg.family == "vit":
        return {"embeds": SDS((batch, seq, cfg.d_model), cfg.param_dtype)}
    return {"tokens": SDS((batch, seq), jnp.int32)}


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    """Abstract cache pytree matching models.transformer.init_caches."""
    caches = jax.eval_shape(
        lambda: T.init_caches(cfg, batch, max_len, cfg.param_dtype))
    return caches


def input_specs(cfg: ModelConfig, shape: ShapeCell) -> Dict:
    """Returns the kwargs pytree for the step function of the cell's kind.

    train   → {"batch": {tokens,...}}
    prefill → {"batch": ..., "caches": ...}
    decode  → {"tokens": (B,1), "positions": (B,1), "caches": ...}
    """
    B, S = shape.batch, shape.seq
    if shape.kind == "train":
        return {"batch": token_specs(cfg, B, S)}
    if shape.kind == "prefill":
        out = {"batch": dict(token_specs(cfg, B, S)), "caches": None}
        out["batch"]["positions"] = SDS((B, S), jnp.int32)
        out["caches"] = cache_specs(cfg, B, S)
        return out
    if shape.kind == "decode":
        return {
            "tokens": SDS((B, 1, cfg.n_codebooks), jnp.int32)
            if cfg.n_codebooks else SDS((B, 1), jnp.int32),
            "positions": SDS((B, 1), jnp.int32),
            "caches": cache_specs(cfg, B, S),
        }
    raise ValueError(shape.kind)


def abstract_params_and_axes(cfg: ModelConfig):
    """(param ShapeDtypeStructs, logical-axes tree) with no allocation.

    Param shapes come from eval_shape on the full config; the axes tree is
    structure-only (no arrays), so it is taken from a concrete init of the
    *reduced* config, which shares the exact tree topology.
    """
    from repro.models.config import reduced
    params = jax.eval_shape(
        lambda k: T.init_model(k, cfg)[0], jax.random.PRNGKey(0))
    _, axes = T.init_model(jax.random.PRNGKey(0), reduced(cfg))
    return params, axes
