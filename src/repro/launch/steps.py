"""pjit-able step functions + sharding trees for the dry-run and launchers."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ShapeCell
from repro.distributed import sharding as shd
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_update, cosine_schedule


def make_train_step_fn(cfg: ModelConfig, opt_cfg: Optional[AdamWConfig] = None,
                       total_steps: int = 10000, microbatches: int = 1,
                       grad_shardings=None):
    """One optimizer step. With microbatches > 1, the global batch is split
    and gradients are accumulated in a lax.scan — same math, same total
    FLOPs/bytes, but the live activation working set divides by the count
    (the standard fit-on-chip lever for the train_4k cells).

    grad_shardings (optional, = the param sharding tree): constrains each
    gradient to its parameter's sharding at the autodiff boundary, steering
    GSPMD to reduce-scatter (half the bytes, sharded result) instead of
    all-reduce + slice for the data-parallel gradient reduction — §Perf H4."""
    opt_cfg = opt_cfg or AdamWConfig()

    def grad_of(params, batch):
        def loss_fn(p):
            return T.lm_loss(p, cfg, batch)
        (loss, metrics), grads = jax.value_and_grad(loss_fn,
                                                    has_aux=True)(params)
        if grad_shardings is not None:
            grads = jax.tree_util.tree_map(
                jax.lax.with_sharding_constraint, grads, grad_shardings)
        return (loss, metrics), grads

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_of(params, batch)  # noqa: E501
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mb = jax.tree_util.tree_map(split, batch)

            def acc_body(carry, mbatch):
                g_acc, l_acc = carry
                (loss_mb, _), g = grad_of(params, mbatch)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (g_acc, l_acc + loss_mb), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(acc_body,
                                            (g0, jnp.zeros((), jnp.float32)),
                                            mb)
            scale = 1.0 / microbatches
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
            loss = loss * scale
            metrics = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}
        lr = cosine_schedule(opt_state["step"], base_lr=opt_cfg.lr,
                             warmup=100, total=total_steps)
        params, opt_state, om, _ = adamw_update(params, grads, opt_state,
                                                opt_cfg, lr)
        return params, opt_state, dict(metrics, loss=loss, **om)

    return train_step


# ---------------------------------------------------------------------------
# Sharding trees
# ---------------------------------------------------------------------------

def batch_shardings(batch_specs, rules: shd.ShardingRules):
    """tokens/labels (B,S[,CB]) and embeds (B,N,D) shard batch over DP."""
    def one(leaf):
        dims = ["act_batch"] + [None] * (len(leaf.shape) - 1)
        return NamedSharding(rules.mesh, rules.spec(dims, leaf.shape))
    return jax.tree_util.tree_map(one, batch_specs)


_CACHE_DIM_RULES = {
    "k": ("act_batch", "act_kv_seq", "act_kv_heads", None),
    "v": ("act_batch", "act_kv_seq", "act_kv_heads", None),
    "ckv": ("act_batch", "act_kv_seq", None),
    "krope": ("act_batch", "act_kv_seq", None),
    "len": ("act_batch",),
    "x": ("act_batch", None, "act_mlp"),      # conv state
    "B": ("act_batch", None, None),
    "C": ("act_batch", None, None),
    "state": ("act_batch", "act_heads", None, None),
}


def cache_shardings(cache_specs, rules: shd.ShardingRules):
    """Right-aligned role-based specs; leading (layer-stack) dims replicate."""
    def one(path, leaf):
        name = None
        for entry in reversed(path):
            key = getattr(entry, "key", None) or getattr(entry, "idx", None)
            if isinstance(key, str):
                name = key
                break
        dims = _CACHE_DIM_RULES.get(name)
        if dims is None:
            spec = P()
        else:
            pad = (None,) * (len(leaf.shape) - len(dims))
            spec = rules.spec(pad + tuple(dims), leaf.shape)
        return NamedSharding(rules.mesh, spec)
    return jax.tree_util.tree_map_with_path(one, cache_specs)


def model_shardings(cfg: ModelConfig, params_abs, axes,
                    rules: shd.ShardingRules):
    return shd.param_shardings(axes, params_abs, rules)


def opt_shardings(param_shardings_tree, rules: shd.ShardingRules):
    return {
        "mu": param_shardings_tree,
        "nu": param_shardings_tree,
        "step": NamedSharding(rules.mesh, P()),
    }


def make_rules(cfg: ModelConfig, mesh, shape: Optional[ShapeCell] = None,
               extra_overrides: Optional[Dict] = None) -> shd.ShardingRules:
    overrides = cfg.overrides_dict()
    if shape is not None and shape.name == "long_500k":
        # SP for the huge decode context: shard cache seq + SSM state heads
        overrides.setdefault("act_kv_seq", "data")
    if extra_overrides:
        overrides.update(extra_overrides)
    return shd.ShardingRules(mesh, overrides)
