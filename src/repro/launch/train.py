"""Distributed training launcher.

Runs the pjit train step under a mesh with FSDP/TP/EP sharding, fault
tolerance (auto-resume from the latest checkpoint, SIGTERM-safe save),
and the straggler watchdog. On this CPU container it runs reduced configs
on the host mesh; on a real cluster the same entry point runs the full
configs on the production mesh (launch with --production-mesh under
jax.distributed initialization — one process per host).

Usage:
  python -m repro.launch.train --arch smollm-135m --smoke --steps 50
  python -m repro.launch.train --arch qwen3-8b --smoke --steps 100 \
      --ckpt-dir /tmp/ckpt --global-batch 8
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, get_smoke_config
from repro.core import api
from repro.core.plan import GemmPolicy
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.distributed import sharding as shd
from repro.launch import steps as ST
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer as T
from repro.obs import Timer
from repro.optim import AdamWConfig, adamw_init
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import TrainConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 mesh (requires 256 devices)")
    ap.add_argument("--gemm-backend", default="auto",
                    help="GEMM backend (auto|xla|pallas|pallas_interpret|"
                         "blockflow|<registered>)")
    ap.add_argument("--gemm-mode", default="auto",
                    choices=["auto", "dc", "dm"],
                    help="paper access mode; auto = per-shape sysmodel pick")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    rules = ST.make_rules(cfg, mesh)
    policy = GemmPolicy(backend=args.gemm_backend, mode=args.gemm_mode)
    print(f"[train] arch={cfg.name} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"steps={args.steps} gemm={policy.resolved_backend()}/{policy.mode}")

    tc = TrainConfig(steps=args.steps, log_every=args.log_every,
                     ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
                     seed=args.seed, base_lr=args.lr, warmup=args.warmup,
                     compress_grads=args.compress_grads, gemm=policy)
    opt_cfg = AdamWConfig(lr=args.lr, compress_grads=args.compress_grads)
    dc = DataConfig(seq_len=args.seq_len, global_batch=args.global_batch,
                    vocab=cfg.vocab, seed=args.seed,
                    n_codebooks=cfg.n_codebooks)
    data = TokenPipeline(dc)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    with shd.use_rules(rules), api.use_policy(policy):
        params, axes = T.init_model(jax.random.PRNGKey(args.seed), cfg)
        opt_state = adamw_init(params)
        p_shard = ST.model_shardings(cfg, params, axes, rules)
        o_shard = ST.opt_shardings(p_shard, rules)
        params = jax.device_put(params, p_shard)
        opt_state = jax.device_put(opt_state, o_shard)

        start = 0
        if ckpt and ckpt.latest_step() is not None:
            state = ckpt.restore({"params": params, "opt": opt_state},
                                 shardings={"params": p_shard,
                                            "opt": o_shard})
            params, opt_state = state["params"], state["opt"]
            meta = ckpt.meta()
            start = meta["step"]
            data.load_state_dict(meta["extra"]["data"])
            print(f"[train] resumed from step {start}")

        step_fn = ST.make_train_step_fn(
            cfg, opt_cfg, total_steps=args.steps)
        sample = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        data.load_state_dict({"cursor": data.cursor - 1, "seed": args.seed})
        b_shard = ST.batch_shardings(sample, rules)
        jitted = jax.jit(step_fn, in_shardings=(p_shard, o_shard, b_shard),
                         out_shardings=(p_shard, o_shard, None),
                         donate_argnums=(0, 1))

        ema = None
        for step in range(start, args.steps):
            with Timer() as tm:
                batch = {k: jnp.asarray(v)
                         for k, v in data.next_batch().items()}
                params, opt_state, metrics = jitted(params, opt_state,
                                                    batch)
            dt = tm.dt
            ema = dt if ema is None else 0.9 * ema + 0.1 * dt
            if dt > 3.0 * ema:
                print(f"[watchdog] step {step} straggled "
                      f"({dt:.2f}s vs EMA {ema:.2f}s)")
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[train] step {step} loss {float(metrics['loss']):.4f}"
                      f" grad_norm {float(metrics['grad_norm']):.3f}"
                      f" ({dt * 1e3:.0f} ms)")
            if ckpt and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt_state},
                          extra={"data": data.state_dict()})
        if ckpt:
            ckpt.save(args.steps, {"params": params, "opt": opt_state},
                      extra={"data": data.state_dict()})
            ckpt.wait()
    print("[train] done")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
