"""Serving launcher: batched prefill + decode with continuous batching.

Usage:
  python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --n-requests 8 --prompt-len 16 --gen-len 24
"""
from __future__ import annotations

import argparse
import asyncio
import json

import jax
import numpy as np

from repro.configs.registry import get_config, get_smoke_config
from repro.core.plan import AttentionPolicy, GemmPolicy
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.obs import NULL_OBS, Observability, Timer
from repro.serving.engine import ServeConfig, ServingEngine
from repro.serving.frontend import AsyncServingEngine
from repro.serving.scheduler import Scheduler


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--gemm-backend", default="auto",
                    help="GEMM backend (auto|xla|pallas|pallas_interpret|"
                         "blockflow|<registered>)")
    ap.add_argument("--gemm-mode", default="auto",
                    choices=["auto", "dc", "dm"],
                    help="paper access mode; auto = per-shape sysmodel pick")
    ap.add_argument("--pack-weights", action="store_true",
                    help="lay weights out block-major once (resident)")
    ap.add_argument("--weight-dtype", default=None, choices=["int8"],
                    help="int8 → quantized W8A8 GEMM route (docs/quant.md); "
                         "with --pack-weights the int8 blocks stay resident")
    ap.add_argument("--attn-backend", default="auto",
                    help="attention backend (auto|fused|fused_interpret|"
                         "unfused|paged|paged_interpret|<registered>); "
                         "fused = the offset-aware flash kernel for prefill "
                         "AND decode (docs/attention.md); paged = the "
                         "block-table paged KV cache with page-bound "
                         "admission and preemption (docs/serving.md)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged backends: tokens per KV page (the paged "
                         "kernel's key-block size)")
    ap.add_argument("--kv-dtype", default=None, choices=["int8"],
                    help="paged backends: int8 → quantized KV pages with "
                         "per-page-per-head scales, dequantized inside the "
                         "paged kernel — ~2x resident requests per pool "
                         "byte (docs/quant.md#kv-pages)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree: builds a (data, model) "
                         "host mesh with a model axis of this size and "
                         "runs prefill/decode sharded over it — "
                         "column/row-parallel GEMMs, head-sharded "
                         "attention, per-shard paged KV pools "
                         "(docs/serving.md). Needs len(jax.devices()) "
                         "divisible by --tp")
    ap.add_argument("--cache-pages", type=int, default=None,
                    help="paged backends: total pages in the KV pool; "
                         "default = the contiguous-equivalent "
                         "batch_slots * ceil(max_len / page_size). Smaller "
                         "values oversubscribe memory (page-bound "
                         "admission + preemption)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="paged backends: share full prompt-prefix KV "
                         "pages across requests (copy-on-write radix "
                         "cache — docs/serving.md#prefix-cache)")
    ap.add_argument("--prefix-watermark", type=int, default=0,
                    help="with --prefix-cache: evict cold cached entries "
                         "each step until this many pool pages are free "
                         "(0 = evict only on demand)")
    ap.add_argument("--spec", default=None, metavar="DRAFTER",
                    help="speculative decoding on the continuous-batching "
                         "path: 'ngram' (prompt-lookup self-speculation) or "
                         "'draft:<arch>' (a registry draft model, e.g. "
                         "draft:smollm-135m) — greedy only; step() then "
                         "emits bursts of verified tokens "
                         "(docs/serving.md#speculative-decoding)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="with --spec: draft budget per request per step")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="tokens of prefill per engine step (chunked "
                         "prefill, interleaved with decode to bound decode "
                         "latency jitter); default: whole prompt at submit")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="continuous-batching workload: prepend this many "
                         "shared tokens to every prompt (the system-prompt "
                         "traffic shape the prefix cache serves)")
    ap.add_argument("--async-demo", type=int, default=0, metavar="N",
                    help="also run N concurrent requests through the "
                         "AsyncServingEngine streaming frontend "
                         "(serving/frontend.py)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable observability (repro/obs) and write a "
                         "Perfetto/Chrome trace of the serving engines to "
                         "PATH — one track per engine phase plus one async "
                         "track per request id; open at ui.perfetto.dev "
                         "(docs/observability.md)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="enable observability and write the metrics "
                         "registry snapshot (counters/gauges/histograms) "
                         "to PATH as JSON")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    policy = GemmPolicy(backend=args.gemm_backend, mode=args.gemm_mode)
    attn = AttentionPolicy(backend=args.attn_backend,
                           page_size=args.page_size)
    if args.kv_dtype and not args.attn_backend.startswith("paged"):
        ap.error("--kv-dtype requires a paged attention backend "
                 "(--attn-backend paged|paged_interpret)")
    mesh = make_host_mesh(model=args.tp) if args.tp > 1 else None
    scheduler = (Scheduler(prefill_chunk=args.prefill_chunk)
                 if args.prefill_chunk else None)
    # one recorder across the continuous-batching and async engines: their
    # phase spans land on shared tracks, request ids on async tracks
    obs = (Observability() if (args.trace_out or args.metrics_json)
           else NULL_OBS)
    print(f"[serve] arch={cfg.name} slots={args.batch_slots} "
          f"max_len={args.max_len} gemm={policy.resolved_backend()}/"
          f"{policy.mode} attn={attn.resolved_backend()} "
          f"packed={args.pack_weights} "
          f"weight_dtype={args.weight_dtype or 'native'}")
    if mesh is not None:
        print(f"[serve] TP: mesh={dict(mesh.shape)} "
              f"(model axis = {args.tp}-way tensor parallel)")
    sc = ServeConfig(
        batch_slots=args.batch_slots, max_len=args.max_len,
        temperature=args.temperature, gemm=policy, attention=attn,
        pack_weights=args.pack_weights, weight_dtype=args.weight_dtype,
        kv_dtype=args.kv_dtype, cache_pages=args.cache_pages, mesh=mesh)
    if sc.paged():
        print(f"[serve] paged KV: page_size={args.page_size} pages="
              f"{args.cache_pages or 'contiguous-equivalent'} "
              f"kv_dtype={args.kv_dtype or 'cache-dtype'} "
              f"prefix_cache={args.prefix_cache} "
              f"prefill_chunk={args.prefill_chunk or 'whole-prompt'}")
    elif args.prefix_cache:
        ap.error("--prefix-cache requires a paged attention backend "
                 "(--attn-backend paged|paged_interpret)")
    params, axes = T.init_model(jax.random.PRNGKey(args.seed), cfg)
    engine = ServingEngine(cfg, params, sc, axes=axes)

    rng = np.random.default_rng(args.seed)
    # batched generate path (one full batch)
    prompts = rng.integers(0, cfg.vocab,
                           (args.batch_slots, args.prompt_len)).astype(np.int32)
    with Timer() as tm:
        out = engine.generate(prompts, args.gen_len)
    dt = tm.dt
    tput = args.batch_slots * args.gen_len / dt
    print(f"[serve] batched generate: {out.shape} in {dt:.2f}s "
          f"({tput:.1f} tok/s)")

    # continuous-batching path (slot admission needs position-masked cache
    # updates; SSM/hybrid recurrent state has none, so multi-slot submit is
    # refused — see ServingEngine.submit)
    if cfg.family in ("ssm", "hybrid") and args.batch_slots > 1:
        print("[serve] continuous batching skipped: ssm/hybrid families "
              "support slot admission only with --batch-slots 1")
        _write_obs(args, obs)
        return 0
    spec = None
    if args.spec:
        if args.temperature > 0:
            ap.error("--spec requires greedy sampling (--temperature 0)")
        from repro.serving.spec_decode import make_drafter
        spec = make_drafter(args.spec, k=args.spec_k,
                            max_len=args.max_len, smoke=args.smoke,
                            seed=args.seed + 1)
        print(f"[serve] speculative decoding: {args.spec} k={args.spec_k}")
    sc2 = ServeConfig(
        batch_slots=args.batch_slots, max_len=args.max_len, gemm=policy,
        attention=attn, pack_weights=args.pack_weights,
        weight_dtype=args.weight_dtype, kv_dtype=args.kv_dtype,
        cache_pages=args.cache_pages,
        mesh=mesh, prefix_cache=args.prefix_cache and sc.paged(),
        prefix_watermark=args.prefix_watermark, scheduler=scheduler,
        spec=spec, obs=obs)
    engine2 = ServingEngine(cfg, params, sc2, axes=axes)
    lo = max(1, min(4, args.prompt_len))
    shared = rng.integers(0, cfg.vocab, args.shared_prefix_len).tolist()
    pending = [shared + rng.integers(0, cfg.vocab,
                                     rng.integers(lo, args.prompt_len + 1))
               .tolist() for _ in range(args.n_requests)]
    done_tokens = 0
    live = 0
    with Timer() as tm:
        while pending or live:
            while pending:
                slot = engine2.submit(pending[0])
                if slot is None:
                    break
                pending.pop(0)
                live += 1
            stepped = engine2.step()
            # spec engines emit {handle: [tokens]} bursts, plain ones
            # {handle: token}
            done_tokens += sum(
                len(t) if isinstance(t, list) else 1
                for t in stepped.values())
            # retire a random live request occasionally to exercise
            # recycling (cancel frees the slot — and, when paged, its
            # pool pages)
            if live and done_tokens % 29 == 0 and stepped:
                engine2.cancel(next(iter(stepped)))
                live -= 1
            if done_tokens > args.n_requests * args.gen_len:
                break
            live = int(engine2.slot_live.sum())
    dt = tm.dt
    print(f"[serve] continuous batching: {done_tokens} tokens in {dt:.2f}s "
          f"({done_tokens / max(dt, 1e-9):.1f} tok/s)")
    print(f"[serve] stats: {engine2.stats()}")

    if args.async_demo > 0:
        engine3 = ServingEngine(cfg, params, sc2, axes=axes)
        aeng = AsyncServingEngine(engine3)

        async def one(i: int) -> int:
            prompt = (shared + rng.integers(
                0, cfg.vocab, max(lo, args.prompt_len // 2)).tolist())
            n = 0
            async for _tok in aeng.stream(prompt, args.gen_len,
                                          priority=i % 2):
                n += 1
            return n

        async def demo():
            return await asyncio.gather(
                *(one(i) for i in range(args.async_demo)))

        with Timer() as tm:
            counts = asyncio.run(demo())
        dt = tm.dt
        print(f"[serve] async streaming: {args.async_demo} concurrent "
              f"requests, {sum(counts)} tokens in {dt:.2f}s "
              f"({sum(counts) / max(dt, 1e-9):.1f} tok/s)")
        print(f"[serve] async stats: {engine3.stats()}")
        print(f"[serve] async slo: {json.dumps(aeng.slo_report())}")
    _write_obs(args, obs)
    return 0


def _write_obs(args, obs) -> None:
    """Write the requested observability artifacts (no-op when neither
    --trace-out nor --metrics-json was given)."""
    if args.trace_out:
        n = obs.trace.write(args.trace_out)
        print(f"[serve] trace: {n} events -> {args.trace_out} "
              f"(open at https://ui.perfetto.dev)")
    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            json.dump(obs.metrics.snapshot(), f, indent=2, sort_keys=True)
        print(f"[serve] metrics: snapshot -> {args.metrics_json}")


if __name__ == "__main__":
    raise SystemExit(main())
