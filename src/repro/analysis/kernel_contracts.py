"""Declarative kernel contracts + a static BlockSpec/grid checker.

MatrixFlow's correctness story (paper §3.3) rests on a *provably correct
dataflow mapping*: every operand block is fetched exactly when the schedule
needs it, every output block is written by a deterministic revisit sequence
along the K-stream, and nothing ever reads past the blocked array. Our
Pallas kernels encode that mapping as BlockSpec index-map lambdas — which
nothing checked until a runtime test happened to hit the broken cell (the
PR 7 ``nb == 0`` uninitialized output, the PR 2 cross-slot cache
corruption were both exactly this defect class).

This module makes the mapping a first-class, checkable object:

  * each kernel registers a **contract builder**
    (:func:`register_contract`) that, for concrete shapes, produces a
    :class:`KernelContract` — the grid, the per-operand block geometry and
    *the kernel's own index-map callables* (the builders live in the
    kernel modules and close over the very functions ``pallas_call``
    receives, so the checker verifies the shipped code, not a copy);
  * :func:`check_contract` exhaustively enumerates the grid and verifies

      - **preconditions** — the structured divisibility/shape guards the
        kernels raise as ``ValueError`` (page_size == block_k, H % Hkv,
        block-geometry agreement), evaluated without running anything;
      - **bounds** — no index map ever exceeds the blocked array;
      - **coverage** — every input block is fetched and every output block
        written (a paged contract narrows coverage to the pages its block
        table actually references — distractor pages are dead by design);
      - **write races / revisit order** — grid points aliasing an output
        block must differ only along declared reduction axes, those axes
        must be sequential (``"arbitrary"`` dimension semantics — a
        parallel axis revisiting an output block is a race), and the
        revisit must be one contiguous run in grid-linear order (the
        paper's dc/dm discipline: leave a C block and come back, and the
        flush order is undefined).

Violations surface as structured :class:`ContractViolation` records —
``python -m repro.analysis`` sweeps them over the backend registry
(docs/analysis.md), ``plan(validate=True)`` gates auto-mode block choices
(core/plan.py), and tests/test_analysis.py's mutation suite proves each
defect class is actually caught.

This module is dependency-light on purpose (dataclasses + numpy): kernel
modules import it at module scope to register their contracts without
dragging in anything beyond what they already load.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "Precondition", "OperandSpec", "KernelContract",
    "ContractViolation", "ContractViolationError",
    "check_contract", "require",
    "register_contract", "registered_contracts", "get_contract_builder",
    "load_builtin_contracts",
]

# Kinds a ContractViolation can carry (the violation catalog —
# docs/analysis.md#violation-catalog documents each with its defect class).
VIOLATION_KINDS = (
    "precondition",    # a structured divisibility/shape guard failed
    "grid",            # degenerate grid: an output exists but never runs
    "bounds",          # an index map exceeded the blocked array
    "coverage",        # an input/output block is never fetched/written
    "write_race",      # output block aliased across non-reduction axes
    "revisit_order",   # output revisit is not one contiguous sequential run
    "semantics",       # a reduction/carry axis is declared "parallel"
)


@dataclasses.dataclass(frozen=True)
class Precondition:
    """One structured kernel precondition, evaluated at contract build.

    The kernel modules build these from the same predicates their runtime
    ``ValueError`` guards raise (:func:`require`), so the static checker
    and the runtime cite identical conditions.
    """

    name: str          # short predicate, e.g. "H % Hkv == 0"
    ok: bool
    message: str       # full diagnostic with the concrete values

    @classmethod
    def check(cls, name: str, ok: bool, message: str) -> "Precondition":
        return cls(name=name, ok=bool(ok), message=message)


def require(*preconditions: Precondition) -> None:
    """Raise ``ValueError`` listing every failed precondition.

    The runtime twin of the static pass: kernels call this where a bare
    ``assert`` used to sit (asserts vanish under ``python -O``; these
    don't), and their contract builders hand the same Precondition tuple
    to the checker.
    """
    bad = [p for p in preconditions if not p.ok]
    if bad:
        raise ValueError("; ".join(p.message for p in bad))


@dataclasses.dataclass(frozen=True)
class OperandSpec:
    """One kernel operand: blocked geometry + the kernel's index map.

    nblocks        blocked-array shape — blocks per dim, the index map's
                   codomain (bounds: 0 <= idx[d] < nblocks[d]).
    block_shape    elements per block per dim (documentation + divisibility
                   context in reports; the checker works at block
                   granularity — padding to block multiples is the
                   kernels' own precondition).
    index_map      the callable handed to ``pl.BlockSpec`` (grid indices →
                   block indices). Paged operands close over the concrete
                   block table, exactly like the kernel's scalar-prefetch
                   lambda.
    role           "input" | "output".
    reduction_axes grid axes along which an *output* block may legally be
                   revisited (the accumulation stream; e.g. the GEMM K
                   axis). Inputs ignore this.
    expected_blocks  when set, coverage requires exactly this set of block
                   indices to be touched instead of the full cartesian
                   product — the paged pool's contract, where distractor
                   pages are intentionally never fetched.
    check_coverage False skips the coverage pass for this operand (e.g.
                   scalar-prefetch operands the grid consumes wholesale).
    """

    name: str
    role: str
    nblocks: Tuple[int, ...]
    block_shape: Tuple[int, ...]
    index_map: Callable[..., Tuple[int, ...]]
    reduction_axes: Tuple[int, ...] = ()
    expected_blocks: Optional[FrozenSet[Tuple[int, ...]]] = None
    check_coverage: bool = True

    def __post_init__(self):
        if self.role not in ("input", "output"):
            raise ValueError(f"operand role must be input/output, "
                             f"got {self.role!r}")
        if len(self.nblocks) != len(self.block_shape):
            raise ValueError(
                f"operand {self.name!r}: nblocks rank {len(self.nblocks)} "
                f"!= block_shape rank {len(self.block_shape)}")


@dataclasses.dataclass(frozen=True)
class KernelContract:
    """The declarative dataflow mapping of one kernel instance.

    grid                 the pallas grid (already concrete).
    dimension_semantics  "parallel"/"arbitrary" per grid axis, exactly as
                         handed to the TPU compiler params.
    sequential_axes      grid axes that carry VMEM state across steps
                         (accumulators, the SSD chunk scan) and therefore
                         must be "arbitrary" — checked even when no output
                         block is revisited along them.
    preconditions        the structured guards (see :class:`Precondition`).
    """

    kernel: str
    grid: Tuple[int, ...]
    operands: Tuple[OperandSpec, ...]
    dimension_semantics: Tuple[str, ...]
    sequential_axes: Tuple[int, ...] = ()
    preconditions: Tuple[Precondition, ...] = ()
    description: str = ""

    def __post_init__(self):
        if len(self.dimension_semantics) != len(self.grid):
            raise ValueError(
                f"contract {self.kernel!r}: {len(self.grid)} grid axes but "
                f"{len(self.dimension_semantics)} dimension semantics")

    def outputs(self) -> Tuple[OperandSpec, ...]:
        return tuple(op for op in self.operands if op.role == "output")


@dataclasses.dataclass(frozen=True)
class ContractViolation:
    """One structured defect found by the static pass."""

    kernel: str
    kind: str                          # one of VIOLATION_KINDS
    detail: str
    operand: Optional[str] = None
    grid_point: Optional[Tuple[int, ...]] = None

    def __str__(self) -> str:
        loc = f" operand={self.operand}" if self.operand else ""
        at = f" at grid{self.grid_point}" if self.grid_point else ""
        return f"[{self.kind}] {self.kernel}{loc}{at}: {self.detail}"


class ContractViolationError(ValueError):
    """Raised by callers that want violations to be fatal (plan validate)."""

    def __init__(self, violations: Sequence[ContractViolation]):
        self.violations = tuple(violations)
        super().__init__(
            f"{len(self.violations)} contract violation(s):\n  "
            + "\n  ".join(str(v) for v in self.violations))


# Exhaustive enumeration is the point — but guard against a pathological
# contract (a serving-scale grid) locking up the analysis run.
MAX_GRID_POINTS = 1 << 20


def _grid_points(grid: Tuple[int, ...]) -> np.ndarray:
    """All grid points in TPU execution order (row-major, last axis
    innermost) as an (n_points, rank) int array."""
    return np.stack(np.meshgrid(*[np.arange(g) for g in grid],
                                indexing="ij"),
                    axis=-1).reshape(-1, len(grid))


def check_contract(contract: KernelContract, *,
                   max_grid_points: int = MAX_GRID_POINTS,
                   ) -> List[ContractViolation]:
    """Statically verify one contract; returns every violation found.

    Nothing is executed: the checker walks the grid exactly as the Mosaic
    pipeline would, evaluates each operand's index map at every point, and
    compares the resulting fetch/write pattern against the declared
    dataflow. An empty list is the proof obligation every registered
    kernel must meet (python -m repro.analysis).
    """
    v: List[ContractViolation] = []
    name = contract.kernel

    # -- preconditions: if the declared guards fail, the geometry below is
    # meaningless — report them and stop (the kernel would have raised).
    for p in contract.preconditions:
        if not p.ok:
            v.append(ContractViolation(name, "precondition",
                                       f"{p.name}: {p.message}"))
    if v:
        return v

    # -- grid sanity: a zero-extent axis means the flush step never runs —
    # outputs would be returned uninitialized (the PR 7 nb==0 regression).
    if any(g == 0 for g in contract.grid) and contract.outputs():
        v.append(ContractViolation(
            name, "grid",
            f"grid {contract.grid} has a zero-extent axis but the kernel "
            f"has outputs: the flush step never runs and the output "
            f"buffer is returned uninitialized"))
        return v

    n_points = int(np.prod([max(g, 1) for g in contract.grid], dtype=np.int64))
    if n_points > max_grid_points:
        raise ValueError(
            f"contract {name!r}: grid {contract.grid} has {n_points} points "
            f"(> {max_grid_points}); check a reduced shape — the contract "
            f"is shape-generic, the enumeration is not")

    # -- declared semantics: reduction/carry axes must be sequential.
    seq_axes = set(contract.sequential_axes)
    for op in contract.operands:
        if op.role == "output":
            seq_axes.update(op.reduction_axes)
    for ax in sorted(seq_axes):
        if ax >= len(contract.grid):
            v.append(ContractViolation(
                name, "semantics",
                f"declared sequential/reduction axis {ax} is outside the "
                f"{len(contract.grid)}-axis grid"))
        elif contract.dimension_semantics[ax] != "arbitrary":
            v.append(ContractViolation(
                name, "semantics",
                f"grid axis {ax} carries accumulation/state but is "
                f"declared {contract.dimension_semantics[ax]!r}; a "
                f"parallel axis gives the compiler license to reorder "
                f"revisits — it must be 'arbitrary'"))

    points = _grid_points(contract.grid)

    for op in contract.operands:
        rank = len(op.nblocks)
        touched: Dict[Tuple[int, ...], List[int]] = {}
        bounds_bad = 0
        for step, pt in enumerate(points):
            gp = tuple(int(x) for x in pt)
            idx = op.index_map(*gp)
            if not isinstance(idx, tuple):
                idx = (idx,)
            idx = tuple(int(i) for i in idx)
            if len(idx) != rank:
                v.append(ContractViolation(
                    name, "bounds",
                    f"index map returned rank {len(idx)} for a rank-{rank} "
                    f"blocked array", operand=op.name, grid_point=gp))
                return v  # geometry broken; everything below is noise
            if any(i < 0 or i >= n for i, n in zip(idx, op.nblocks)):
                bounds_bad += 1
                if bounds_bad <= 3:     # cap the per-operand spam
                    v.append(ContractViolation(
                        name, "bounds",
                        f"index map hit block {idx}, outside the blocked "
                        f"array {op.nblocks} (block_shape="
                        f"{op.block_shape})", operand=op.name,
                        grid_point=gp))
                continue
            touched.setdefault(idx, []).append(step)
        if bounds_bad > 3:
            v.append(ContractViolation(
                name, "bounds",
                f"... and {bounds_bad - 3} more out-of-bounds fetches",
                operand=op.name))
        if bounds_bad:
            continue                    # coverage/races would double-count

        # -- coverage
        if op.check_coverage:
            required = (op.expected_blocks if op.expected_blocks is not None
                        else None)
            if required is None:
                total = int(np.prod(op.nblocks, dtype=np.int64))
                if len(touched) != total:
                    missing = _first_missing(op.nblocks, touched)
                    verb = ("written" if op.role == "output" else "fetched")
                    v.append(ContractViolation(
                        name, "coverage",
                        f"{total - len(touched)} of {total} blocks never "
                        f"{verb} (first missing: {missing})",
                        operand=op.name))
            else:
                missing_set = required - set(touched)
                if missing_set:
                    verb = ("written" if op.role == "output" else "fetched")
                    v.append(ContractViolation(
                        name, "coverage",
                        f"{len(missing_set)} required blocks never {verb} "
                        f"(first: {sorted(missing_set)[0]})",
                        operand=op.name))

        # -- write races + revisit order (outputs only)
        if op.role != "output":
            continue
        red = set(op.reduction_axes)
        for blk, steps in touched.items():
            if len(steps) == 1:
                continue
            pts = points[steps]
            varying = {ax for ax in range(len(contract.grid))
                       if len(np.unique(pts[:, ax])) > 1}
            illegal = varying - red
            if illegal:
                v.append(ContractViolation(
                    name, "write_race",
                    f"block {blk} is written from {len(steps)} grid points "
                    f"that differ along non-reduction axes "
                    f"{sorted(illegal)} (declared reduction axes: "
                    f"{sorted(red)}) — concurrent grid points would race "
                    f"on the same output window",
                    operand=op.name))
                continue
            lo, hi = steps[0], steps[-1]
            if hi - lo + 1 != len(steps):
                v.append(ContractViolation(
                    name, "revisit_order",
                    f"block {blk} is revisited non-contiguously (grid-"
                    f"linear steps {steps[:4]}...): the block is flushed, "
                    f"left, and re-entered — the dc/dm revisit order must "
                    f"be one sequential run",
                    operand=op.name))
    return v


def _first_missing(nblocks: Tuple[int, ...], touched) -> Tuple[int, ...]:
    for idx in np.ndindex(*nblocks):
        if tuple(int(i) for i in idx) not in touched:
            return tuple(int(i) for i in idx)
    return ()


# ---------------------------------------------------------------------------
# Registry: kernels register a builder; the sweep/CLI resolves by name
# ---------------------------------------------------------------------------

_CONTRACTS: Dict[str, Callable[..., KernelContract]] = {}

# Modules whose import registers the built-in contracts (each kernel
# registers its own builder at import time, next to its index maps).
_BUILTIN_MODULES = (
    "repro.core.blockflow",
    "repro.kernels.matrixflow_gemm",
    "repro.kernels.flash_attention",
    "repro.kernels.paged_attention",
    "repro.kernels.ssd_scan",
)


def register_contract(name: str, *, overwrite: bool = False):
    """Decorator: register ``fn(**shape_kwargs) -> KernelContract``."""
    def deco(fn):
        if name in _CONTRACTS and not overwrite:
            raise ValueError(f"contract {name!r} already registered")
        _CONTRACTS[name] = fn
        return fn
    return deco


def load_builtin_contracts() -> None:
    """Import every kernel module so its contract builder registers."""
    for mod in _BUILTIN_MODULES:
        importlib.import_module(mod)


def get_contract_builder(name: str) -> Callable[..., KernelContract]:
    if name not in _CONTRACTS:
        load_builtin_contracts()
    if name not in _CONTRACTS:
        raise ValueError(f"unknown kernel contract {name!r}; registered: "
                         f"{sorted(_CONTRACTS)}")
    return _CONTRACTS[name]


def registered_contracts() -> Tuple[str, ...]:
    load_builtin_contracts()
    return tuple(sorted(_CONTRACTS))
