"""``python -m repro.analysis`` — the static-analysis gate.

Sweeps every registered kernel contract over the backend registry × the
parity shape/dtype grid (and the configs/ registry), printing a violation
report; optionally lints a live serving engine's prefill/decode jaxprs.
Exit status is the violation count clamped to 1 — CI's ``static-analysis``
job fails on any finding (docs/analysis.md).
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional, Sequence

from repro.analysis import sweep as S


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description=__doc__.splitlines()[0])
    ap.add_argument("--all-backends", action="store_true",
                    help="sweep every backend in the registry (default "
                         "sweeps them too; the flag is the explicit CI "
                         "spelling)")
    ap.add_argument("--backends", nargs="+", default=None,
                    help="restrict the GEMM sweep to these backends")
    ap.add_argument("--dtypes", nargs="+", default=list(S.GEMM_DTYPES),
                    choices=list(S.GEMM_DTYPES))
    ap.add_argument("--no-configs", action="store_true",
                    help="skip the configs/ registry sweep")
    ap.add_argument("--lint-engine", metavar="ARCH", default=None,
                    help="additionally build a smoke ServingEngine for "
                         "ARCH (configs/ registry) and lint its traced "
                         "prefill/decode jaxprs (repro.analysis.trace_lint)")
    args = ap.parse_args(argv)

    backends = None if args.all_backends else args.backends
    _, n_bad = S.run_sweep(gemm_backends=backends, dtypes=args.dtypes,
                           include_configs=not args.no_configs)

    if args.lint_engine:
        n_bad += _lint_engine(args.lint_engine)

    return 1 if n_bad else 0


def _lint_engine(arch: str) -> int:
    """Build a tiny engine for ``arch`` and lint its hot-path traces."""
    import jax

    from repro.analysis.trace_lint import lint_engine
    from repro.configs.registry import get_smoke_config
    from repro.models import transformer as T
    from repro.obs import Observability
    from repro.serving.engine import ServeConfig, ServingEngine

    cfg = get_smoke_config(arch, n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    # obs enabled on purpose: the lint proves the instrumented engine's
    # jitted prefill/decode closures stayed free of host callbacks — all
    # telemetry must live host-side of the jit boundary
    eng = ServingEngine(cfg, params,
                        ServeConfig(batch_slots=2, max_len=32,
                                    obs=Observability()))
    findings = lint_engine(eng)
    for f in findings:
        print(f"lint FAIL {f}")
    print(f"lint: {arch} prefill+decode, {len(findings)} finding(s)")
    return len(findings)


if __name__ == "__main__":
    sys.exit(main())
