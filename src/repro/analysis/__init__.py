"""Static analysis for the MatrixFlow kernel substrate.

Two passes, both *ahead of execution*:

  * :mod:`repro.analysis.kernel_contracts` — declarative
    :class:`~repro.analysis.kernel_contracts.KernelContract`\\ s registered
    by each Pallas kernel (and the blockflow oracle), plus a checker that
    exhaustively enumerates the kernel grid and verifies coverage, bounds,
    divisibility preconditions, and write-ordering (the paper's dc/dm
    block-revisit discipline — checked, not assumed).
  * :mod:`repro.analysis.trace_lint` — a jaxpr linter for the serving hot
    path: host callbacks/syncs, silent fp64 promotions, weak-type retrace
    triggers, and int8 KV pools flowing into a kernel without scales.

``python -m repro.analysis --all-backends`` sweeps every registered
GEMM/attention backend over the parity shape×dtype grid and the configs/
registry and prints a violation report (docs/analysis.md).
"""
from repro.analysis.kernel_contracts import (
    ContractViolation,
    ContractViolationError,
    KernelContract,
    OperandSpec,
    Precondition,
    check_contract,
    get_contract_builder,
    load_builtin_contracts,
    register_contract,
    registered_contracts,
    require,
)
from repro.analysis.trace_lint import (
    LintFinding,
    lint_engine,
    lint_jaxpr,
)

__all__ = [
    "ContractViolation", "ContractViolationError", "KernelContract",
    "OperandSpec", "Precondition", "check_contract", "get_contract_builder",
    "load_builtin_contracts", "register_contract", "registered_contracts",
    "require",
    "LintFinding", "lint_engine", "lint_jaxpr",
]
