"""Jaxpr linter for the serving hot path.

The second static pass (docs/analysis.md#trace-lint): where
``kernel_contracts`` verifies the *dataflow mapping* of each kernel, this
module verifies the *trace* the jitted serving closures actually compile —
``ServingEngine.prefill`` and ``ServingEngine.decode`` are the two programs
that run per request, and a single host sync or silent fp64 upcast in
either one is a fleet-wide regression no parity test notices.

Rules (each is a ``LintFinding.rule``):

  host-callback        a host round-trip primitive (``pure_callback``,
                       ``io_callback``, ``debug_callback``/``debug_print``,
                       infeed/outfeed) inside the jitted trace — every
                       decode step would block on the host.
  fp64-promotion       an equation *produces* float64 from non-float64
                       inputs: a silent promotion (Python float + weak
                       types, ``np.float64`` constants) that doubles the
                       bandwidth of everything downstream.
  weak-type            a weakly-typed input to the traced closure: a
                       Python scalar reached ``jax.jit`` as an argument,
                       so every distinct value (or dtype context)
                       retraces and recompiles the whole program.
  int8-pool-no-scales  an int8 KV page pool flows into a ``pallas_call``
                       that receives no fp32 ``(P, Hkv)`` scale operands —
                       the kernel would consume raw quantized codes as if
                       they were values (docs/quant.md#kv-pages).

``lint_jaxpr`` walks any ClosedJaxpr recursively (pjit bodies, scan/while
carries, cond branches); ``lint_engine`` traces a live ``ServingEngine``'s
prefill and decode closures with ``jax.make_jaxpr`` — abstract evaluation
only, nothing is executed and no device memory moves.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import jax
import jax.numpy as jnp

__all__ = ["LintFinding", "lint_jaxpr", "lint_engine", "LINT_RULES"]

LINT_RULES = (
    "host-callback",
    "fp64-promotion",
    "weak-type",
    "int8-pool-no-scales",
)

# Primitive names that imply a host round-trip inside the trace.
_HOST_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "python_callback",
    "debug_callback", "debug_print",
    "infeed", "outfeed", "host_local_array_to_global_array",
})


@dataclasses.dataclass(frozen=True)
class LintFinding:
    """One hazard found in a traced program."""

    rule: str                  # one of LINT_RULES
    message: str
    path: str                  # e.g. "decode/pjit:decode_step/scan"

    def __str__(self) -> str:
        return f"[{self.rule}] {self.path}: {self.message}"


def _aval(var) -> Optional[Any]:
    return getattr(var, "aval", None)


def _is_f64(var) -> bool:
    a = _aval(var)
    return a is not None and getattr(a, "dtype", None) == jnp.float64


def _sub_jaxprs(params: dict):
    """Yield (name, jaxpr) for every sub-jaxpr in an eqn's params —
    pjit/scan/while bodies, cond branches — by duck-typing, so the walk
    survives jax version renames."""
    for key, val in params.items():
        vals = val if isinstance(val, (list, tuple)) else (val,)
        for i, v in enumerate(vals):
            inner = getattr(v, "jaxpr", None)       # ClosedJaxpr → Jaxpr
            if inner is not None and hasattr(inner, "eqns"):
                suffix = f"[{i}]" if len(vals) > 1 else ""
                yield f"{key}{suffix}", inner
            elif hasattr(v, "eqns"):                # bare Jaxpr
                suffix = f"[{i}]" if len(vals) > 1 else ""
                yield f"{key}{suffix}", v


def _walk(jaxpr, path: str, findings: List[LintFinding],
          check_weak_invars: bool) -> None:
    if check_weak_invars:
        for var in jaxpr.invars:
            a = _aval(var)
            if a is not None and getattr(a, "weak_type", False):
                findings.append(LintFinding(
                    "weak-type",
                    f"traced input {var} has a weak type "
                    f"({getattr(a, 'dtype', '?')}): a Python scalar reached "
                    f"the jitted closure as an argument — every new value "
                    f"context retraces; pass a committed jnp array instead",
                    path))
    for eqn in jaxpr.eqns:
        pname = eqn.primitive.name
        if pname in _HOST_PRIMITIVES:
            findings.append(LintFinding(
                "host-callback",
                f"primitive {pname!r} performs a host round-trip inside "
                f"the jitted trace; the accelerator stalls on the host "
                f"every step — hoist it out of the hot path",
                path))
        if (any(_is_f64(o) for o in eqn.outvars)
                and not any(_is_f64(i) for i in eqn.invars)):
            out_shapes = [getattr(_aval(o), "shape", ()) for o in eqn.outvars]
            findings.append(LintFinding(
                "fp64-promotion",
                f"primitive {pname!r} produces float64 {out_shapes} from "
                f"non-float64 inputs: a silent promotion (Python float / "
                f"np.float64 constant?) doubling downstream bandwidth — "
                f"cast explicitly or enable jax_default_dtype_bits=32",
                path))
        if pname == "pallas_call":
            _check_pallas_scales(eqn, path, findings)
        for sub_name, sub in _sub_jaxprs(eqn.params):
            sub_path = f"{path}/{pname}:{sub_name}"
            _walk(sub, sub_path, findings, check_weak_invars=False)


def _check_pallas_scales(eqn, path: str, findings: List[LintFinding]) -> None:
    """int8 KV pools (rank >= 4 int8 operands: (P, page_size, Hkv, D)) must
    be accompanied by fp32 rank-2 (P, Hkv) scale operands in the same call
    — the paged kernel's in-register dequant contract."""
    pools = []
    scales = 0
    for var in eqn.invars:
        a = _aval(var)
        if a is None:
            continue
        dtype = getattr(a, "dtype", None)
        shape = getattr(a, "shape", ())
        if dtype == jnp.int8 and len(shape) >= 4:
            pools.append(shape)
        elif dtype == jnp.float32 and len(shape) == 2:
            scales += 1
    if pools and scales < len(pools):
        findings.append(LintFinding(
            "int8-pool-no-scales",
            f"pallas_call consumes {len(pools)} int8 page pool(s) "
            f"{pools} but only {scales} rank-2 fp32 scale operand(s): "
            f"the kernel would treat quantized codes as values — pass "
            f"kv_scales=(k_scales, v_scales) of shape (P, Hkv)",
            path))


def lint_jaxpr(closed_jaxpr, *, path: str = "jaxpr",
               check_weak_invars: bool = True) -> List[LintFinding]:
    """Lint one ClosedJaxpr (as returned by ``jax.make_jaxpr(fn)(*args)``).

    Returns every finding; an empty list is the serving hot path's proof
    obligation (tests/test_analysis.py locks it in).
    """
    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    findings: List[LintFinding] = []
    _walk(jaxpr, path, findings, check_weak_invars=check_weak_invars)
    return findings


def lint_engine(engine, *, prompt_len: int = 8,
                ) -> List[LintFinding]:
    """Trace a live ``ServingEngine``'s prefill and decode closures and
    lint both jaxprs.

    Uses the engine's real params/caches/block tables so the traced
    programs are exactly the ones ``generate()``/``step()`` dispatch —
    but via ``jax.make_jaxpr``, so this is abstract evaluation: nothing
    runs, no cache byte is touched.
    """
    B = engine.sc.batch_slots
    S = min(prompt_len, engine.sc.max_len)
    tokens = jnp.zeros((B, S), jnp.int32)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                 (B, S))
    batch = {"tokens": tokens, "positions": positions,
             "last_cols": jnp.full((B,), S - 1, jnp.int32)}
    bt = None
    if engine.paged:
        bt = jnp.asarray(engine.block_tables, dtype=jnp.int32)
        batch["block_tables"] = bt

    findings: List[LintFinding] = []
    pf = jax.make_jaxpr(engine.prefill)(engine.params, batch, engine.caches)
    findings += lint_jaxpr(pf, path="prefill")
    tok1 = jnp.zeros((B, 1), jnp.int32)
    pos1 = jnp.full((B, 1), S, jnp.int32)
    dc = jax.make_jaxpr(engine.decode)(engine.params, tok1, pos1,
                                       engine.caches, bt)
    findings += lint_jaxpr(dc, path="decode")
    return findings
