"""Contract sweep: every registered backend × the parity shape/dtype grid.

The executable form of the tentpole claim (docs/analysis.md): for each
GEMM backend in the :mod:`repro.core.plan` registry, each attention
backend, and each architecture in :mod:`repro.configs.registry`, resolve
the concrete block geometry the backend would run — via :func:`plan`
itself for GEMMs, via the kernels' own derivations for attention/SSD —
and run the registered :class:`~repro.analysis.kernel_contracts
.KernelContract` through :func:`check_contract`. Zero violations across
the whole sweep is the acceptance gate CI enforces
(``python -m repro.analysis --all-backends``).

The shape/dtype grids MIRROR ``tests/parity.py`` (SHAPES / DTYPES /
ATTN_CASES / ATTN_PAGE_SIZE): the static pass must cover exactly the
cells the differential harness proves at runtime.
tests/test_analysis.py::test_sweep_grid_matches_parity is the drift
guard — extend both together.
"""
from __future__ import annotations

import dataclasses
import sys
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.kernel_contracts import (ContractViolation,
                                             check_contract,
                                             get_contract_builder)

# -- mirrored from tests/parity.py (drift-guarded there) --------------------
GEMM_SHAPES = (
    (8, 8, 8),
    (64, 96, 48),
    (33, 17, 65),
    (1, 64, 128),
    (130, 24, 56),
)
GEMM_DTYPES = ("float32", "bfloat16", "int8")

# (name, B, Sq, T, H, Hkv) of every tests/parity.py AttnCase.
ATTN_CASES = (
    ("prefill_mha", 2, 32, 32, 4, 4),
    ("prefill_gqa_ragged", 2, 33, 33, 4, 2),
    ("decode_long_cache", 3, 1, 96, 4, 2),
    ("decode_masked_rows", 3, 1, 64, 2, 1),
    ("prefill_chunk_offset", 2, 8, 64, 2, 2),
    ("noncausal_ragged", 2, 17, 45, 2, 1),
)
ATTN_PAGE_SIZE = 16
ATTN_BLOCK = 32                 # block_q/block_k of the parity cells
ATTN_HEAD_DIM = 16


@dataclasses.dataclass(frozen=True)
class SweepEntry:
    """One checked contract instance."""

    kernel: str
    instance: str               # human-readable cell, e.g. "pallas f32 8x8x8"
    violations: Tuple[ContractViolation, ...]

    @property
    def ok(self) -> bool:
        return not self.violations


def _paged_block_tables(B: int, T: int,
                        page_size: int = ATTN_PAGE_SIZE,
                        seed: int = 0, n_distractors: int = 3) -> np.ndarray:
    """The shuffled page assignment of tests/parity.py::make_paged_operands
    (same rng stream), so the checked block table is the one the parity
    cells actually dispatch."""
    nb = -(-T // page_size)
    P = B * nb + n_distractors
    rng = np.random.default_rng(seed * 31 + B * 101 + T)
    return rng.permutation(P)[:B * nb].reshape(B, nb).astype(np.int32)


def sweep_gemm(backends: Optional[Sequence[str]] = None,
               dtypes: Sequence[str] = GEMM_DTYPES,
               shapes: Sequence[Tuple[int, int, int]] = GEMM_SHAPES,
               ) -> List[SweepEntry]:
    """Contract-check every layout-bearing GEMM backend's resolved plans.

    Goes through :func:`repro.core.plan.plan` itself — the sweep validates
    the block choices auto mode actually makes, not hypothetical ones.
    Layout-free backends (xla) have no dataflow contract and are skipped.
    """
    from repro.core import plan as P
    if backends is None:
        P.get_backend_spec("xla")   # force built-in registration
        backends = P.registered_backends()
    entries: List[SweepEntry] = []
    for backend in backends:
        spec = P.get_backend_spec(backend)
        if not spec.needs_layout:
            continue
        builder_name = "blockflow" if backend == "blockflow" \
            else "matrixflow_gemm"
        builder = get_contract_builder(builder_name)
        for dtype in dtypes:
            for (M, K, N) in shapes:
                pol = P.GemmPolicy(backend=backend)
                pln = P.plan(M, N, K, dtype, pol)
                blk = pln.layout
                nbm, nbn, nbk = (-(-M // blk.bm), -(-N // blk.bn),
                                 -(-K // blk.bk))
                if backend == "blockflow":
                    contract = builder(nbm=nbm, nbn=nbn, nbk=nbk)
                else:
                    contract = builder(
                        a_shape=(nbm, nbk, blk.bm, blk.bk),
                        b_shape=(nbn, nbk, blk.bk, blk.bn),
                        blk=blk, fused=(dtype == "int8"))
                entries.append(SweepEntry(
                    builder_name,
                    f"{backend} {dtype} {M}x{K}x{N} "
                    f"blk=({blk.bm},{blk.bn},{blk.bk})/{pln.mode}",
                    tuple(check_contract(contract))))
    return entries


def sweep_attention(cases: Sequence[Tuple] = ATTN_CASES,
                    page_size: int = ATTN_PAGE_SIZE,
                    ) -> List[SweepEntry]:
    """Contract-check the fused and paged attention kernels over the
    parity attention cases — the paged cells against the same shuffled
    block tables the runtime parity cells scatter into."""
    flash = get_contract_builder("flash_attention")
    paged = get_contract_builder("paged_attention")
    entries: List[SweepEntry] = []
    for (name, B, Sq, T, H, Hkv) in cases:
        c = flash(B=B, H=H, Hkv=Hkv, Sq=Sq, Sk=T, D=ATTN_HEAD_DIM,
                  Dv=ATTN_HEAD_DIM, block_q=ATTN_BLOCK, block_k=ATTN_BLOCK)
        entries.append(SweepEntry(
            "flash_attention", f"fused {name}", tuple(check_contract(c))))
        bt = _paged_block_tables(B, T, page_size)
        P_pages = B * bt.shape[1] + 3
        for quantized in (False, True):
            c = paged(B=B, Sq=Sq, H=H, Hkv=Hkv, D=ATTN_HEAD_DIM,
                      Dv=ATTN_HEAD_DIM, P=P_pages, page_size=page_size,
                      block_tables=bt, block_q=ATTN_BLOCK,
                      quantized=quantized)
            suffix = " int8-kv" if quantized else ""
            entries.append(SweepEntry(
                "paged_attention", f"paged {name}{suffix}",
                tuple(check_contract(c))))
    return entries


def sweep_configs(archs: Optional[Sequence[str]] = None,
                  seq_len: int = 256) -> List[SweepEntry]:
    """Contract-check every architecture in the configs/ registry: the
    attention geometry (H, Hkv, head_dim) each config serves with, and
    the SSD scan for the SSM/hybrid families."""
    from repro.configs.registry import ARCHS, get_config
    flash = get_contract_builder("flash_attention")
    ssd = get_contract_builder("ssd_scan")
    entries: List[SweepEntry] = []
    for arch in (archs if archs is not None else sorted(ARCHS)):
        cfg = get_config(arch)
        c = flash(B=1, H=cfg.n_heads, Hkv=cfg.n_kv_heads,
                  Sq=128, Sk=seq_len, D=cfg.head_dim, Dv=cfg.head_dim,
                  block_q=128, block_k=128)
        entries.append(SweepEntry(
            "flash_attention",
            f"config {arch} H={cfg.n_heads} Hkv={cfg.n_kv_heads}",
            tuple(check_contract(c))))
        if cfg.ssm_state > 0:
            c = ssd(B=1, S=seq_len, H=cfg.n_heads, P=cfg.head_dim,
                    N=cfg.ssm_state, chunk=128)
            entries.append(SweepEntry(
                "ssd_scan", f"config {arch} N={cfg.ssm_state}",
                tuple(check_contract(c))))
    return entries


def run_sweep(*, gemm_backends: Optional[Sequence[str]] = None,
              dtypes: Sequence[str] = GEMM_DTYPES,
              include_configs: bool = True,
              out=sys.stdout) -> Tuple[List[SweepEntry], int]:
    """The full sweep; prints the violation report and returns
    (entries, total_violations)."""
    entries = sweep_gemm(gemm_backends, dtypes)
    entries += sweep_attention()
    if include_configs:
        entries += sweep_configs()
    n_bad = 0
    for e in entries:
        status = "OK " if e.ok else "FAIL"
        print(f"contract {status} {e.kernel:17s} {e.instance}", file=out)
        for viol in e.violations:
            n_bad += 1
            print(f"  {viol}", file=out)
    print(f"analysis: {len(entries)} contract instances, "
          f"{n_bad} violation(s)", file=out)
    return entries, n_bad
