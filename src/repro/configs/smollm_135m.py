"""SmolLM-135M — llama-arch small [hf:HuggingFaceTB/SmolLM-135M].

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab=49152,
    rope_theta=1e4,
    # 9/3 heads not divisible by TP=16 → replicate attention, keep d_ff TP
    sharding_overrides=(("heads", None), ("kv_heads", None)),
    source="hf:HuggingFaceTB/SmolLM-135M; hf",
)
