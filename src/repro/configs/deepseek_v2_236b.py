"""DeepSeek-V2 236B — MLA + fine-grained MoE [arXiv:2405.04434; hf].

60L d_model=5120 128H (MLA kv_lora=512) vocab=102400; 2 shared + 160 routed
experts, top-6, expert d_ff=1536; first layer dense (d_ff=12288)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=12288,                 # dense first layer width
    vocab=102400,
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    n_experts=160,
    n_experts_active=6,
    n_shared_experts=2,
    moe_d_ff=1536,
    first_dense_layers=1,
    mlp_act="swiglu",
    rope_theta=1e4,
    source="arXiv:2405.04434; hf",
)
