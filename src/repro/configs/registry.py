"""Architecture registry + input-shape cells (the 40-cell assignment grid)."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict


from repro.models.config import ModelConfig, reduced

ARCHS = {
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "smollm-135m": "repro.configs.smollm_135m",
    "granite-20b": "repro.configs.granite_20b",
    "qwen2-1.5b": "repro.configs.qwen2_1_5b",
    "internvl2-76b": "repro.configs.internvl2_76b",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
}


def get_config(arch: str) -> ModelConfig:
    if arch.startswith("bert-") or arch.startswith("vit-"):
        from repro.models import transformer as T
        kind, variant = arch.split("-", 1)
        return (T.bert_config if kind == "bert" else T.vit_config)(variant)
    return importlib.import_module(ARCHS[arch]).CONFIG


def get_smoke_config(arch: str, **kw) -> ModelConfig:
    return reduced(get_config(arch), **kw)


# ---------------------------------------------------------------------------
# Input-shape cells
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq: int           # train/prefill: sequence length; decode: KV context
    batch: int         # global batch


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}

# long_500k requires sub-quadratic sequence mixing: only SSM/hybrid archs
# run it; pure full-attention archs skip (recorded in DESIGN.md §3).
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def cell_applicable(cfg: ModelConfig, shape: ShapeCell) -> bool:
    if shape.name == "long_500k":
        return cfg.family in LONG_CONTEXT_FAMILIES
    return True


def all_cells():
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if cell_applicable(cfg, shape):
                yield arch, shape
