"""Granite-20B (code) — GPT-BigCode-style MQA [arXiv:2405.04324; hf].

52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152. GELU MLP (not GLU)
per the GPT-BigCode lineage — with gelu the param count lands at ~20B;
swiglu would overshoot to ~28B."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    rope_theta=1e4,
    mlp_act="gelu",
    sharding_overrides=(("kv_heads", None),),  # MQA: single KV head replicated
    source="arXiv:2405.04324; hf",
)
