"""DBRX 132B — 16-expert top-4 fine-grained MoE [hf:databricks/dbrx-base].

40L d_model=6144 48H (GQA kv=8) expert d_ff=10752 vocab=100352."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    n_experts=16,
    n_experts_active=4,
    moe_d_ff=10752,
    mlp_act="swiglu",
    rope_theta=5e5,
    # kv=8 heads not divisible by TP=16 → replicate KV projections
    sharding_overrides=(("kv_heads", None),),
    source="hf:databricks/dbrx-base; unverified",
)
