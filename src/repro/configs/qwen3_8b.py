"""Qwen3-8B — dense GQA with qk_norm [hf:Qwen/Qwen3-8B].

36L d_model=4096 32H (GQA kv=8, head_dim=128) d_ff=12288 vocab=151936."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=12288,
    vocab=151936,
    qk_norm=True,
    rope_theta=1e6,
    sharding_overrides=(("kv_heads", None),),  # kv=8 < TP=16
    source="hf:Qwen/Qwen3-8B; hf",
)
