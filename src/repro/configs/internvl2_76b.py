"""InternVL2-76B — InternViT frontend (stub) + InternLM2/Llama3-70B-class
backbone [arXiv:2404.16821].

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256. The vision tower is
a stub: input_specs() provides 256 precomputed patch embeddings per image,
already projected to d_model; they are prepended to the text tokens."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    rope_theta=5e5,
    sharding_overrides=(("kv_heads", None),),
    source="arXiv:2404.16821; unverified",
)

N_IMAGE_TOKENS = 256
