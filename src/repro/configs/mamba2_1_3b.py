"""Mamba-2 1.3B — attention-free SSD [arXiv:2405.21060].

48L d_model=2048, ssm_state=128, expand=2 (d_inner=4096, 64 SSD heads)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=64,              # SSD heads = d_inner / head_dim
    n_kv_heads=64,
    d_ff=0,                  # attention-free, no FFN (SSD block only)
    vocab=50280,             # not divisible by 16 → vocab dim replicates
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    source="arXiv:2405.21060; unverified",
)
