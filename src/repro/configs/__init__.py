"""Assigned-architecture configs (one module per arch) + paper models."""
from repro.configs.registry import ARCHS, get_config, get_smoke_config  # noqa: F401
