"""Zamba2-2.7B — Mamba2 backbone + shared attention block [arXiv:2411.15242].

54 Mamba2 layers, d_model=2560, ssm_state=64; one weight-shared attention+
MLP block (32H MHA, d_ff=10240) applied every 6 SSM blocks."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_head=80,
    d_ff=10240,
    vocab=32000,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
    source="arXiv:2411.15242; hf",
)
