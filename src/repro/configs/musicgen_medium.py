"""MusicGen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

48L d_model=1536 24H (MHA) d_ff=6144 vocab=2048 per codebook × 4 codebooks.
The EnCodec frontend is a stub: input_specs() provides the 4-stream token
ids; the model embeds each stream and sums (the MusicGen token interleave)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    n_codebooks=4,
    mlp_act="gelu",
    norm="layernorm",
    # 24 heads not divisible by TP=16 → replicate head projections
    sharding_overrides=(("heads", None), ("kv_heads", None)),
    source="arXiv:2306.05284; hf",
)
