"""AdamW with ZeRO-sharded fp32 moments and optional int8 gradient
compression (error feedback) for the cross-pod all-reduce.

Moment tensors inherit each parameter's sharding (FSDP over the 'data'
axis per the rules engine) — this *is* ZeRO: optimizer state lives
sharded, updates run sharded, no parameter-sized replication anywhere.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    compress_grads: bool = False  # int8 all-reduce with error feedback


def adamw_init(params):
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)

    state = {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    return state


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in leaves))


def quantize_int8(g: jax.Array, err: Optional[jax.Array] = None):
    """Symmetric per-tensor int8 quantization with error feedback."""
    gf = g.astype(jnp.float32)
    if err is not None:
        gf = gf + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_err = gf - deq
    return deq, new_err


def adamw_update(params, grads, state, cfg: AdamWConfig, lr: jax.Array,
                 err_state=None):
    """One AdamW step. Returns (new_params, new_state, metrics, new_err)."""
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    new_err = err_state
    if cfg.compress_grads:
        if err_state is None:
            err_state = jax.tree_util.tree_map(
                lambda g: jnp.zeros(g.shape, jnp.float32), grads)
        pairs = jax.tree_util.tree_map(quantize_int8, grads, err_state)
        grads = jax.tree_util.tree_map(lambda pe: pe[0], pairs,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree_util.tree_map(lambda pe: pe[1], pairs,
                                         is_leaf=lambda x: isinstance(x, tuple))

    step = state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    out = jax.tree_util.tree_map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree_util.tree_map(
        lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree_util.tree_map(
        lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree_util.tree_map(
        lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_params, new_state, {"grad_norm": gnorm}, new_err
