"""LR schedules."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    # ramp hits base_lr at step==warmup-1 and is non-zero at step 0
    # (an lr-0 first step would silently waste the first batch)
    warm = base_lr * jnp.minimum((step + 1) / max(warmup, 1), 1.0)
    t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 *
                     (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup, warm, cos)
