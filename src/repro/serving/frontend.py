"""Async streaming frontend over the serving engine's submit/step loop.

``ServingEngine`` exposes a *pull* interface: ``submit()`` returns a
handle, ``step()`` returns ``{handle: token}`` for whoever advanced this
iteration. A server wants the transpose — per-request *push* streams
("give me request X's tokens as they arrive"). :class:`AsyncServingEngine`
is that transpose, built on stdlib asyncio (no server framework):

* :meth:`stream` is an async generator yielding one request's tokens as
  the engine produces them;
* :meth:`complete` awaits a whole stream and returns it as a list;
* one shared **pump** coroutine drives admission + ``step()`` while any
  request is in flight, fanning each step's tokens out to per-request
  queues. It starts lazily with the first request and exits when the
  last one finishes.

Admission order is (priority, submission order); a request the engine
refuses (no slot/pages yet) stays queued and is retried every pump
iteration *without blocking later submissions* — the same skip-not-bail
rule the engine's own resume path uses, so a small request is never
head-of-line blocked behind a large one. Priorities/deadlines pass
through to the engine's scheduler (serving/scheduler.py); preemption and
resume stay invisible here — a preempted request's stream simply pauses
until the engine resumes it.

The pump calls the engine synchronously (JAX dispatch blocks the event
loop for one step at a time). That is the intended single-host shape:
the event loop interleaves *waiting* (network handlers, many concurrent
``stream`` consumers), while the device does one batched step at a time
— exactly the continuous-batching contract. docs/serving.md#streaming
has a worked example.
"""
from __future__ import annotations

import asyncio
import itertools
import time
from typing import AsyncIterator, Dict, List, Optional

from repro.obs.metrics import quantile
from repro.serving.engine import ServingEngine

__all__ = ["AsyncServingEngine"]


class _Flight:
    """One in-flight request: its submission parameters until admitted,
    its token queue and progress after. ``t_submit``/``t_admit``/``t_last``
    are ``time.perf_counter`` marks feeding the SLO accounting
    (:meth:`AsyncServingEngine.slo_report`)."""

    __slots__ = ("prompt", "n_tokens", "key", "priority", "deadline",
                 "seq", "queue", "handle", "got", "t_submit", "t_admit",
                 "t_last")

    def __init__(self, prompt, n_tokens, key, priority, deadline, seq):
        self.prompt = prompt
        self.n_tokens = n_tokens
        self.key = key
        self.priority = priority
        self.deadline = deadline
        self.seq = seq
        self.queue: asyncio.Queue = asyncio.Queue()
        self.handle: Optional[int] = None
        self.got = 0
        self.t_submit = time.perf_counter()
        self.t_admit: Optional[float] = None
        self.t_last: Optional[float] = None


class AsyncServingEngine:
    """Per-request async token streams over one :class:`ServingEngine`.

    ::

        aeng = AsyncServingEngine(engine)

        async def handler(prompt):
            async for tok in aeng.stream(prompt, n_tokens=64):
                ...  # forward to the client as it arrives

    Any number of ``stream``/``complete`` consumers may run concurrently;
    the single pump batches them through the engine. The wrapped engine
    must not be driven manually (generate()/submit()/step()) while any
    stream is active — the pump owns it.
    """

    def __init__(self, engine: ServingEngine):
        self.engine = engine
        self._waiting: List[_Flight] = []     # submitted here, not admitted
        self._active: Dict[int, _Flight] = {}  # engine handle → flight
        self._pump_task: Optional[asyncio.Task] = None
        self._seq = itertools.count()
        # SLO accounting (slo_report): raw samples on the perf_counter
        # clock — stream()-call → admission, → first token, and
        # token-to-token gaps. Deadlines are interpreted on the same
        # clock: an absolute perf_counter time the first token must beat
        # (the engine itself only ever *compares* deadlines; the meaning
        # lives here, docs/observability.md#slo-definitions).
        self._queue_waits: List[float] = []
        self._ttfts: List[float] = []
        self._itls: List[float] = []
        self._deadline_misses = 0
        self._completed = 0
        obs = engine.obs
        self._m_misses = (obs.metrics.counter("frontend_deadline_misses_total")
                          if obs.enabled else None)

    # -- public API ---------------------------------------------------------
    async def stream(self, prompt: List[int], n_tokens: int,
                     key=None, *, priority: int = 0,
                     deadline: Optional[float] = None
                     ) -> AsyncIterator[int]:
        """Yield up to ``n_tokens`` generated tokens for ``prompt`` as the
        engine produces them. ``priority``/``deadline`` feed the engine's
        scheduler; ``key`` enables temperature sampling (engine._sample).

        The stream ends early if the request retires at the engine's
        ``max_len`` horizon. Breaking out of the iteration cancels the
        request (its slot and pages are released)."""
        if n_tokens < 1:
            raise ValueError(f"n_tokens must be >= 1, got {n_tokens}")
        flight = _Flight(list(prompt), n_tokens, key, priority, deadline,
                         next(self._seq))
        self._waiting.append(flight)
        self._ensure_pump()
        try:
            while flight.got < n_tokens:
                tok = await flight.queue.get()
                if tok is None:        # retired at the engine's horizon
                    return
                yield tok
        finally:
            self._abort(flight)

    async def complete(self, prompt: List[int], n_tokens: int,
                       key=None, *, priority: int = 0,
                       deadline: Optional[float] = None) -> List[int]:
        """Await the whole stream; returns the generated tokens."""
        return [t async for t in self.stream(prompt, n_tokens, key,
                                             priority=priority,
                                             deadline=deadline)]

    @property
    def in_flight(self) -> int:
        return len(self._waiting) + len(self._active)

    def slo_report(self) -> Dict[str, object]:
        """Aggregated SLO accounting over everything this frontend has
        streamed: exact p50/p95/p99 of queue wait, TTFT (stream() call →
        first token, engine queue wait included) and ITL, plus the
        deadline-miss count (first token after the request's absolute
        ``deadline`` on the perf_counter clock). Plain-JSON dict; all
        times in seconds. Cumulative — a long-running server may snapshot
        it repeatedly."""
        def pcts(xs: List[float]) -> Dict[str, Optional[float]]:
            if not xs:
                return {"p50": None, "p95": None, "p99": None}
            return {"p50": round(quantile(xs, 0.50), 6),
                    "p95": round(quantile(xs, 0.95), 6),
                    "p99": round(quantile(xs, 0.99), 6)}
        return {
            "n_completed": self._completed,
            "n_first_tokens": len(self._ttfts),
            "queue_wait_s": pcts(self._queue_waits),
            "ttft_s": pcts(self._ttfts),
            "itl_s": pcts(self._itls),
            "deadline_misses": self._deadline_misses,
        }

    # -- pump ---------------------------------------------------------------
    def _ensure_pump(self):
        if self._pump_task is None or self._pump_task.done():
            self._pump_task = asyncio.ensure_future(self._pump())

    def _admit(self):
        """Try to admit queued flights, most urgent first; refusals are
        skipped, not barriers (see module docstring)."""
        for flight in sorted(self._waiting,
                             key=lambda f: (f.priority, f.seq)):
            handle = self.engine.submit(flight.prompt, flight.key,
                                        priority=flight.priority,
                                        deadline=flight.deadline)
            if handle is None:
                continue
            flight.handle = handle
            self._waiting.remove(flight)
            self._active[handle] = flight
            flight.t_admit = time.perf_counter()
            wait = flight.t_admit - flight.t_submit
            self._queue_waits.append(wait)
            rt = self.engine.request_traces.get(handle)
            if rt is not None:
                rt.queue_wait_s = wait

    async def _pump(self):
        eng = self.engine
        while self._waiting or self._active:
            self._admit()
            if not self._active:
                if not self._waiting:
                    break
                # queued work that cannot admit while nothing is live:
                # stepping would never free capacity — the prompts simply
                # exceed the pool/slots. Fail them rather than spin.
                for flight in list(self._waiting):
                    self._waiting.remove(flight)
                    flight.queue.put_nowait(None)
                break
            produced = eng.step()
            now = time.perf_counter()
            for handle, tok in produced.items():
                flight = self._active.get(handle)
                if flight is None:
                    continue           # cancelled while its step ran
                # speculative engines (ServeConfig.spec) emit a *burst* of
                # accepted tokens per request per step; plain engines one
                burst = tok if isinstance(tok, list) else (tok,)
                for t in burst:
                    if flight.got >= flight.n_tokens:
                        break          # burst overshot the request: drop
                    flight.got += 1
                    flight.queue.put_nowait(t)
                    if flight.got == 1:
                        self._ttfts.append(now - flight.t_submit)
                        if (flight.deadline is not None
                                and now > flight.deadline):
                            self._deadline_misses += 1
                            if self._m_misses is not None:
                                self._m_misses.inc()
                            rt = eng.request_traces.get(handle)
                            if rt is not None:
                                rt.deadline_missed = True
                        elif flight.deadline is not None:
                            rt = eng.request_traces.get(handle)
                            if rt is not None:
                                rt.deadline_missed = False
                    else:
                        self._itls.append(now - flight.t_last)
                    flight.t_last = now
                if flight.got >= flight.n_tokens:
                    self._completed += 1
                    self._finish(flight)
            # a request that retired at max_len stops producing: close its
            # stream so consumers don't wait forever. Live means: in a slot,
            # or (paged) parked in the wait queue between preempt and resume.
            for handle, flight in list(self._active.items()):
                if handle in produced:
                    continue
                if eng.paged:
                    live = any(eng.slot_live[s]
                               and int(eng.slot_rid[s]) == handle
                               for s in range(eng.sc.batch_slots)) \
                        or any(w.rid == handle for w in eng.wait)
                else:
                    live = bool(eng.slot_live[handle])
                if not live:
                    self._finish(flight, close=True)
            await asyncio.sleep(0)     # let consumers drain their queues
        self._pump_task = None

    def _finish(self, flight: _Flight, close: bool = False):
        """Release a completed flight's engine-side resources."""
        self._active.pop(flight.handle, None)
        self.engine.cancel(flight.handle)
        if close:
            flight.queue.put_nowait(None)

    def _abort(self, flight: _Flight):
        """Consumer stopped iterating (done, or broke out early)."""
        if flight in self._waiting:
            self._waiting.remove(flight)
        elif flight.handle in self._active:
            self._finish(flight)
