"""Speculative decoding drafters for the serving engine.

Decode is memory-bound per token: every step streams the whole resident
KV working set to produce ONE token per slot. Speculative decoding
amortizes that traffic — a cheap **drafter** proposes ``k`` continuation
tokens per request, the target model verifies all of them in a single
masked forward pass (Sq = 1 + k at the slot's current offset — the same
offset-aware kernels that serve chunked prefill), and the engine keeps
the longest prefix of drafts the target's own greedy choice agrees with,
plus the "bonus" token the verify logits supply after the last accepted
draft. Greedy streams are therefore **token-identical** to
non-speculative decoding by construction: every accepted token is the
target's argmax given exactly the tokens before it.

Rejected drafts have already been written into the KV cache by the
verify pass; the engine rolls them back host-side — valid lengths reset
to the accepted count, and in paged mode the block table's wholly-
rejected tail pages return to the pool (:meth:`BlockTable.truncate`,
serving/kv_pool.py). docs/serving.md#speculative-decoding walks the full
accept/rollback lifecycle and its invariants.

Two drafters ship here:

* :class:`NGramDrafter` — prompt-lookup self-speculation: propose the
  continuation that followed the most recent earlier occurrence of the
  stream's current suffix n-gram. Zero model cost (pure host list
  matching), and highly effective on self-similar streams — repetitive
  generations, retrieval-grounded prompts, code.
* :class:`DraftModelDrafter` — a small registry model (e.g.
  ``smollm-135m`` drafting for a larger target) generating ``k`` greedy
  tokens via its own single-slot :class:`~repro.serving.engine
  .ServingEngine` (bucketed masked prefill bounds recompiles). The draft
  model's *quality* only moves the acceptance rate, never the output:
  the target verifies every proposal.

Engine wiring: ``ServeConfig(spec=<drafter>)``; the drafter's ``k`` is
the per-step draft budget (the engine may trim it when the page pool or
the ``max_len`` horizon cannot back all drafted positions). With
speculation on, ``ServingEngine.step`` returns ``{handle: [tokens]}`` —
a *burst* of accepted tokens per request — instead of one token each.
"""
from __future__ import annotations

from typing import List, Optional

__all__ = ["Drafter", "NGramDrafter", "DraftModelDrafter", "make_drafter"]


class Drafter:
    """Interface: propose up to ``k`` continuation tokens for a stream.

    ``context`` is the request's full visible stream — prompt, reported
    output, and the pending (sampled-but-unreported) token — and the
    return value is a list of 0..``k`` proposed next tokens. Returning
    fewer than ``k`` (or ``[]``) is always legal: the engine verifies
    whatever is proposed and falls back to plain one-token decode for a
    slot with no drafts. Proposals must be valid *target* token ids.

    ``k`` on the instance is the engine's per-step draft budget; the
    per-call ``k`` argument may be smaller when the engine trimmed the
    budget to its page pool or ``max_len`` horizon.
    """

    k: int = 4

    def draft(self, context: List[int], k: int) -> List[int]:
        raise NotImplementedError


class NGramDrafter(Drafter):
    """Prompt-lookup self-speculation: match the stream's trailing
    n-gram against its own earlier content and propose the tokens that
    followed the most recent match.

    Tries n-gram sizes from ``ngram`` down to ``min_ngram`` (longer
    matches are more specific, so they are preferred); proposes nothing
    when no earlier occurrence exists — costless honesty, since the
    engine then just decodes normally. Deterministic: the most recent
    match wins, so drafting never depends on iteration order. A match
    whose continuation is cut off by the end of the stream overlaps the
    suffix itself — the stream is locally *periodic* there (constant
    runs, short cycles), so the continuation is extended cyclically to
    the full draft budget; mispredictions only cost acceptance, never
    correctness, and the verify pass is fixed-shape regardless.
    """

    def __init__(self, k: int = 4, ngram: int = 3, min_ngram: int = 1):
        if k < 1 or ngram < min_ngram or min_ngram < 1:
            raise ValueError(
                f"NGramDrafter(k={k}, ngram={ngram}, min_ngram={min_ngram})"
                f" needs k >= 1 and ngram >= min_ngram >= 1")
        self.k = int(k)
        self.ngram = int(ngram)
        self.min_ngram = int(min_ngram)

    def draft(self, context: List[int], k: int) -> List[int]:
        k = min(k, self.k)
        if k < 1:
            return []
        for n in range(min(self.ngram, len(context) - 1),
                       self.min_ngram - 1, -1):
            suffix = context[-n:]
            # scan right-to-left: the most recent earlier occurrence is
            # the best predictor of what follows now
            for i in range(len(context) - n - 1, -1, -1):
                if context[i:i + n] == suffix:
                    start = i + n
                    cont = context[start:start + k]
                    if len(cont) < k:
                        # the continuation runs off the end of the
                        # stream, i.e. the match overlaps the suffix:
                        # the stream is locally periodic with period
                        # len - n - i (a constant run is period 1) —
                        # extend cyclically to the full budget
                        p = len(context) - n - i
                        cont = [context[start + (j % p)]
                                for j in range(k)]
                    return [int(t) for t in cont]
        return []


class DraftModelDrafter(Drafter):
    """Draft with a small registry model: ``k`` greedy tokens from its
    own single-slot serving engine (dense contiguous cache — the draft
    model re-prefills the context each call, so target-side rollback
    never needs mirroring into draft state).

    Each ``draft()`` call is one bucketed masked prefill of the context
    plus ``k - 1`` decode steps, so compile count stays bounded by the
    power-of-two prompt buckets. Acceptance tracks how well the draft
    model's greedy choices agree with the target's; a perfectly-agreeing
    drafter (e.g. the target itself, in tests) accepts everything.
    """

    def __init__(self, cfg, params, k: int = 4, max_len: int = 2048,
                 attention=None):
        from repro.serving.engine import ServeConfig, ServingEngine
        if k < 1:
            raise ValueError(f"DraftModelDrafter k must be >= 1, got {k}")
        self.k = int(k)
        self.cfg = cfg
        # headroom: context up to the target's max_len, plus the drafts.
        # ``attention`` picks the draft engine's backend — matching the
        # target's backend maximizes argmax agreement on near-tied logits
        # (acceptance is exact-match; cross-backend float rounding can
        # flip a tie and cost an otherwise-good draft).
        self._eng = ServingEngine(cfg, params, ServeConfig(
            batch_slots=1, max_len=int(max_len) + self.k + 2,
            attention=attention))

    def draft(self, context: List[int], k: int) -> List[int]:
        k = min(k, self.k)
        if k < 1 or not context:
            return []
        eng = self._eng
        if len(context) >= eng.sc.max_len:
            return []                  # context outgrew the draft horizon
        handle = eng.submit(list(context))
        if handle is None:             # single slot — cannot happen, but
            return []                  # degrade to no drafts, never raise
        out: List[int] = []
        for _ in range(k):
            stepped = eng.step()
            if handle not in stepped:
                break
            out.append(int(stepped[handle]))
        eng.cancel(handle)
        return out


def make_drafter(spec: str, *, k: int = 4, max_len: int = 2048,
                 smoke: bool = False, seed: int = 0,
                 draft_params=None) -> Drafter:
    """Build a drafter from a CLI-style spec string
    (``launch/serve.py --spec``):

    * ``"ngram"`` → :class:`NGramDrafter` with draft budget ``k``;
    * ``"draft:<arch>"`` → :class:`DraftModelDrafter` over the registry
      model ``<arch>`` (smoke-sized when ``smoke``). ``draft_params``
      supplies trained weights; absent, the model is randomly
      initialized from ``seed`` — a wiring demo, with the acceptance
      rate to match.
    """
    if spec == "ngram":
        return NGramDrafter(k=k)
    if spec.startswith("draft:"):
        import jax

        from repro.configs.registry import get_config, get_smoke_config
        from repro.models import transformer as T
        arch = spec[len("draft:"):]
        cfg = get_smoke_config(arch) if smoke else get_config(arch)
        params = draft_params
        if params is None:
            params, _ = T.init_model(jax.random.PRNGKey(seed), cfg)
        return DraftModelDrafter(cfg, params, k=k, max_len=max_len)
    raise ValueError(
        f"unknown drafter spec {spec!r} (expected 'ngram' or "
        f"'draft:<arch>')")
