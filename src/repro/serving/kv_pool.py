"""Page pool for the paged KV cache: fixed-size pages, free list, ref counts.

The contiguous serving cache reserves ``batch_slots × max_len`` KV rows —
memory scales with the *worst case* length of every slot. This module is
the allocator side of the paged subsystem (docs/serving.md): the cache is a
pool of fixed-size pages (``page_size`` tokens each, sized to the paged
attention kernel's key-block — ``kernels/paged_attention.py``), requests
own pages through per-request :class:`BlockTable`\\ s, and memory scales
with the tokens actually resident. Admission becomes **page-bound** instead
of slot-bound, and when the pool runs dry the engine spills the lowest-
priority request back to its wait queue (``serving/engine.py`` owns that
scheduling decision; the pool owns the accounting it relies on).

Everything here is host-side bookkeeping (plain ints/numpy) — the device
only ever sees the resulting ``(B, n_blocks)`` int32 block-table array and
the page-pool tensors it indexes.

With the prefix cache (``serving/prefix_cache.py``) pages ARE shared:
a cached prompt-prefix page carries one reference per holding request
plus one for the cache itself, and a request that must write into a
shared page first **forks** it — :meth:`PagePool.fork` allocates the
copy-target, the engine copies the device contents, and the writer's
block table swaps in the private page (copy-on-write).

Invariants (property-tested in tests/test_kv_pool.py):

  * a page is either on the free list or referenced, never both;
    ``free_pages + pages_in_use == n_pages`` at all times;
  * a page referenced by more than one holder is never *written* — the
    engine only writes pages it allocated or forked (refcount-1 at write
    time); releasing one holder of a shared span leaves it resident;
  * release is idempotent-safe only through ownership: double-free raises.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

__all__ = ["PagePool", "BlockTable", "PoolExhausted", "pages_needed"]


class PoolExhausted(RuntimeError):
    """Raised by :meth:`PagePool.alloc` when the free list cannot cover a
    request — the engine's cue to preempt or defer."""


def pages_needed(n_tokens: int, page_size: int) -> int:
    """Pages covering ``n_tokens`` cache slots (ceil division; 0 → 0)."""
    return -(-n_tokens // page_size)


class PagePool:
    """A pool of ``n_pages`` KV pages of ``page_size`` tokens each.

    ``alloc`` pops from the free list and sets the page's ref count to 1;
    ``release`` decrements and returns count-0 pages to the free list.
    ``retain`` adds a reference for sharing — the prefix cache
    (serving/prefix_cache.py) retains every page it indexes and each
    hitting request retains the pages it borrows. ``fork`` is the
    allocation half of copy-on-write: it hands out the private target a
    shared page's contents are copied into before the first write.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 1 or page_size < 1:
            raise ValueError(
                f"PagePool needs n_pages >= 1 and page_size >= 1, got "
                f"n_pages={n_pages}, page_size={page_size}")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        # popped from the tail → ascending page ids first (determinism)
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self.refcount = np.zeros(n_pages, np.int64)
        # peak pages simultaneously referenced, for capacity reporting
        # (ServingEngine.stats(), benchmarks/serving_sweep.py)
        self.high_water = 0
        # called with the page id whenever a page returns to the free list
        # (eviction hooks: per-shard TP pools assert lockstep, tests audit
        # reclamation without polling)
        self._free_hooks: List[Callable[[int], None]] = []
        # observability instruments (bind_metrics); None → unbound, and the
        # alloc/release paths pay one attribute load + branch
        self._m_alloc = None
        self._m_fork = None
        self._m_freed = None
        self._m_free = None
        self._m_in_use = None
        self._m_hw = None

    # -- accounting ---------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return int((self.refcount > 0).sum())

    def add_free_hook(self, hook: Callable[[int], None]) -> None:
        """Register ``hook(page_id)`` to run whenever a page's last
        reference drops and it rejoins the free list."""
        self._free_hooks.append(hook)

    def bind_metrics(self, metrics) -> None:
        """Register pool instruments on ``metrics`` (a repro.obs.Metrics
        registry, duck-typed) and keep them current: alloc/fork/free
        counters plus free/in-use/high-water gauges. Free accounting rides
        the existing free-hook channel — the same one TP lockstep asserts
        and tests audit — so release() itself needs no metrics branch."""
        self._m_alloc = metrics.counter("pool_pages_alloc_total")
        self._m_fork = metrics.counter("pool_cow_forks_total")
        self._m_freed = metrics.counter("pool_pages_freed_total")
        self._m_free = metrics.gauge("pool_free_pages")
        self._m_in_use = metrics.gauge("pool_pages_in_use")
        self._m_hw = metrics.gauge("pool_high_water_pages")
        self._m_free.set(self.free_pages)
        self._m_in_use.set(self.pages_in_use)
        self._m_hw.set(self.high_water)

        def _on_free(page: int) -> None:
            self._m_freed.inc()
            self._m_free.set(self.free_pages)
            self._m_in_use.set(self.pages_in_use)

        self.add_free_hook(_on_free)

    def pages_needed(self, n_tokens: int) -> int:
        return pages_needed(n_tokens, self.page_size)

    def can_alloc(self, n: int) -> bool:
        return n <= self.free_pages

    # -- alloc / free -------------------------------------------------------
    def alloc(self, n: int) -> List[int]:
        """Pop ``n`` pages off the free list (ref count 1 each); raises
        :class:`PoolExhausted` without side effects when short."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > self.free_pages:
            raise PoolExhausted(
                f"need {n} pages, {self.free_pages} free of {self.n_pages}")
        pages = [self._free.pop() for _ in range(n)]
        self.refcount[pages] += 1
        self.high_water = max(self.high_water, self.pages_in_use)
        if self._m_alloc is not None:
            self._m_alloc.inc(n)
            self._m_free.set(self.free_pages)
            self._m_in_use.set(self.pages_in_use)
            self._m_hw.set_max(self.high_water)
        return pages

    def fork(self, src: int) -> int:
        """Copy-on-write allocation: hand out a private page to receive a
        copy of shared page ``src``. The pool only does the accounting —
        the engine owns the device-side content copy (the (page_size, Hkv,
        dh) slab per layer) and the block-table swap. Raises PoolExhausted
        when no page is free, ValueError when ``src`` isn't allocated."""
        if self.refcount[src] <= 0:
            raise ValueError(f"fork of unallocated page {src}")
        if self._m_fork is not None:
            self._m_fork.inc()
        return self.alloc(1)[0]

    def retain(self, pages: Sequence[int]) -> None:
        """Add a reference to already-allocated pages (sharing)."""
        for p in pages:
            if self.refcount[p] <= 0:
                raise ValueError(f"retain of unallocated page {p}")
        self.refcount[list(pages)] += 1

    def release(self, pages: Sequence[int]) -> None:
        """Drop one reference per page; count-0 pages rejoin the free list.

        All-or-nothing, like :meth:`alloc`: the whole sequence is validated
        (counting duplicates — releasing a page twice in one call needs two
        references) before any ref count moves, so a double free raises with
        the pool untouched."""
        drops = collections.Counter(int(p) for p in pages)
        for p, n in drops.items():
            if not 0 <= p < self.n_pages:
                raise ValueError(f"release of unknown page {p}")
            if self.refcount[p] < n:
                raise ValueError(f"double free of page {p}")
        for p in pages:
            p = int(p)
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self._free.append(p)
                for hook in self._free_hooks:
                    hook(p)

    def check(self) -> None:
        """Assert the free-list/ref-count invariants (tests, debugging)."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate free-list entries"
        used = {int(p) for p in np.nonzero(self.refcount > 0)[0]}
        assert not (free & used), f"pages both free and referenced: {free & used}"
        assert len(free) + len(used) == self.n_pages, (
            f"page leak: {len(free)} free + {len(used)} used != {self.n_pages}")
        assert (self.refcount >= 0).all()


@dataclasses.dataclass
class BlockTable:
    """One request's logical-block → physical-page map.

    ``pages[j]`` backs logical key positions ``[j*ps, (j+1)*ps)``. The
    engine grows it one page at a time during decode (:meth:`ensure`) and
    renders it into the fixed-width device array with :meth:`as_row`
    (unallocated entries are 0 — any *valid* page id works, the kernel's
    length mask gives those keys zero weight).
    """

    pool: PagePool
    pages: List[int] = dataclasses.field(default_factory=list)

    @property
    def n_pages(self) -> int:
        return len(self.pages)

    def capacity(self) -> int:
        """Token positions currently backed by pages."""
        return len(self.pages) * self.pool.page_size

    def ensure(self, n_tokens: int) -> List[int]:
        """Allocate pages until ``n_tokens`` positions are backed; returns
        the newly allocated pages. Raises PoolExhausted (allocating nothing)
        when the pool cannot cover the growth."""
        need = self.pool.pages_needed(n_tokens) - len(self.pages)
        if need <= 0:
            return []
        fresh = self.pool.alloc(need)
        self.pages.extend(fresh)
        return fresh

    def free(self) -> None:
        """Return every page to the pool (request retirement/preemption).
        ``pages`` is cleared only after the release succeeds — a failed
        (double-free) release leaves the table's ownership intact."""
        self.pool.release(self.pages)
        self.pages = []

    def truncate(self, n_tokens: int) -> List[int]:
        """Shrink the table to back only ``n_tokens`` positions, dropping
        this table's reference on every page past them; returns the
        dropped pages. The speculative-decoding rollback primitive
        (docs/serving.md#speculative-decoding): rejected drafted tokens
        live past the accepted length, so their *wholly-rejected* tail
        pages go back to the pool while the final partial page stays —
        its leading rows are still logical content, and stale rows beyond
        ``n_tokens`` are masked by the cache's valid length.

        Refcount/COW-safe by construction: only one *reference* per
        dropped page is released, so a page still held by the prefix
        cache (or any other sharer) stays resident for its other holders.
        Like :meth:`free`, the release is all-or-nothing — a failed
        release leaves the table's ownership record intact. Truncating to
        a count the table already fits (including repeat truncates to the
        same length) is a no-op returning ``[]``."""
        if n_tokens < 0:
            raise ValueError(f"truncate({n_tokens})")
        keep = self.pool.pages_needed(n_tokens)
        if keep >= len(self.pages):
            return []
        dropped = self.pages[keep:]
        self.pool.release(dropped)
        self.pages = self.pages[:keep]
        return dropped

    def as_row(self, n_blocks: int, out: Optional[np.ndarray] = None
               ) -> np.ndarray:
        """The (n_blocks,) int32 device row; unallocated entries are 0."""
        if len(self.pages) > n_blocks:
            raise ValueError(
                f"block table holds {len(self.pages)} pages > n_blocks="
                f"{n_blocks}")
        if out is not None:
            if out.shape != (n_blocks,):
                raise ValueError(
                    f"as_row out buffer has shape {out.shape}, expected "
                    f"({n_blocks},)")
            if out.dtype != np.int32:
                raise ValueError(
                    f"as_row out buffer has dtype {out.dtype}, expected "
                    f"int32")
        row = out if out is not None else np.zeros(n_blocks, np.int32)
        row[:] = 0
        row[:len(self.pages)] = self.pages
        return row
