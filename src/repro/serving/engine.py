"""Batched serving engine: prefill + decode steps over the model's caches.

``prefill_step``/``decode_step`` are the functions the dry-run lowers for the
``prefill_*`` / ``decode_*`` / ``long_*`` shape cells. The engine adds a
simple continuous-batching front end: a slot-based scheduler that admits
queued requests into free batch slots between decode iterations (the
vLLM-style pattern, reduced to its core).

GEMM execution is governed by a GemmPolicy (ServeConfig.gemm); with
``pack_weights=True`` every projection weight is laid out block-major once
at engine construction (api.pack_model_weights) and stays resident — the
paper's Fig. 5 deployment shape, where serving never re-lays-out a weight.
``weight_dtype="int8"`` additionally quantizes at pack: weights live as
int8 blocks + per-channel scales and GEMMs run the W8A8 route
(core/quant.py, docs/quant.md). Attention execution is governed the same
way by ServeConfig.attention (an AttentionPolicy): ``fused`` streams K/V
blocks through the offset-aware flash kernel for both prefill and decode,
``unfused`` keeps the paper's host-softmax split (docs/attention.md).

Slot admission uses *masked* prefill/decode: batch rows at position -1
neither write their KV cache nor advance their valid length, so one slot's
prefill cannot corrupt concurrent slots (SSD/conv caches don't carry
positions and are outside this masking contract).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api
from repro.core.plan import AttentionPolicy, GemmPolicy
from repro.models import transformer as T
from repro.models.config import ModelConfig


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int = 8
    max_len: int = 1024
    temperature: float = 0.0     # 0 → greedy
    cache_dtype: str = "bfloat16"
    gemm: Optional[GemmPolicy] = None   # None → the ambient/default policy
    pack_weights: bool = False          # resident block-major weights
    weight_dtype: Optional[str] = None  # "int8" → quantized W8A8 GEMM route
    attention: Optional[AttentionPolicy] = None  # None → ambient/default
    # (AttentionPolicy(backend="fused") routes prefill AND decode through
    # the offset-aware flash kernel — docs/attention.md)

    def policy(self) -> Optional[GemmPolicy]:
        """The effective GemmPolicy: ``gemm`` with ``weight_dtype`` folded
        in. With ``pack_weights=True`` this makes every projection weight a
        resident QuantizedPackedWeight (quantize-at-pack)."""
        if self.weight_dtype is None:
            return self.gemm
        return dataclasses.replace(self.gemm or GemmPolicy(),
                                   weight_dtype=self.weight_dtype)


def _policy_scope(policy: Optional[GemmPolicy],
                  attn: Optional[AttentionPolicy] = None):
    stack = contextlib.ExitStack()
    if policy is not None:
        stack.enter_context(api.use_policy(policy))
    if attn is not None:
        stack.enter_context(api.use_attention_policy(attn))
    return stack


def make_prefill_step(cfg: ModelConfig, policy: Optional[GemmPolicy] = None,
                      attn: Optional[AttentionPolicy] = None):
    """(params, batch, caches) → (last_logits, caches). Processes the full
    prompt with causal self-attention while writing the caches."""
    def prefill_step(params, batch, caches):
        with _policy_scope(policy, attn):
            logits, caches, _ = T.forward(params, cfg, batch, caches=caches,
                                          remat=False)
        return logits[:, -1], caches
    return prefill_step


def make_decode_step(cfg: ModelConfig, policy: Optional[GemmPolicy] = None,
                     attn: Optional[AttentionPolicy] = None):
    """(params, tokens(B,1), positions(B,1), caches) → (logits, caches)."""
    def decode_step(params, tokens, positions, caches):
        batch = {"tokens": tokens, "positions": positions}
        with _policy_scope(policy, attn):
            logits, caches, _ = T.forward(params, cfg, batch, caches=caches,
                                          remat=False)
        return logits[:, -1], caches
    return decode_step


class ServingEngine:
    """Greedy/temperature sampling with slot-based continuous batching."""

    def __init__(self, cfg: ModelConfig, params, sc: ServeConfig):
        pol = sc.policy()
        # Quantizing per call inside the jitted forward would redo the
        # O(K·N) weight quantization on every decode token; weights are
        # static across calls, so weight_dtype always quantizes-at-pack.
        if sc.pack_weights or sc.weight_dtype is not None:
            params = api.pack_model_weights(params, pol)
        self.cfg, self.params, self.sc = cfg, params, sc
        self.decode = jax.jit(make_decode_step(cfg, pol, sc.attention))
        self.prefill = jax.jit(make_prefill_step(cfg, pol, sc.attention))
        self.caches = T.init_caches(cfg, sc.batch_slots, sc.max_len,
                                    jnp.dtype(sc.cache_dtype))
        self.slot_pos = np.zeros(sc.batch_slots, np.int32)
        self.slot_live = np.zeros(sc.batch_slots, bool)
        self.slot_out: List[List[int]] = [[] for _ in range(sc.batch_slots)]
        # Next sampled token per slot, already decoded but not yet reported:
        # seeded by submit() from the prefill logits, advanced by step().
        self.slot_next = np.zeros(sc.batch_slots, np.int32)
        # Draining slots hold a final pending token but may not decode
        # further (their cache is full): step() reports it, then retires —
        # the freshly decoded last token is never silently dropped.
        self.slot_drain = np.zeros(sc.batch_slots, bool)

    def _sample(self, logits: jax.Array,
                key: Optional[jax.Array] = None) -> jax.Array:
        """The single sampling rule shared by generate(), submit() and
        step(): greedy argmax at temperature 0 (or when no PRNG key is
        supplied), softmax sampling at ServeConfig.temperature otherwise."""
        if self.sc.temperature > 0 and key is not None:
            return jax.random.categorical(
                key, logits.astype(jnp.float32) / self.sc.temperature,
                axis=-1)
        return jnp.argmax(logits, axis=-1)

    def _reset_slot_caches(self, slot: int):
        """Zero a slot's valid lengths so a recycled slot starts from
        position 0 (stale K/V beyond len=0 is invisible to attention)."""
        def rec(node):
            if isinstance(node, dict):
                if "state" in node:
                    # SSD recurrent state carries no positions/len; submit
                    # only admits these with batch_slots == 1 (see below),
                    # where the whole state belongs to this slot.
                    return jax.tree_util.tree_map(jnp.zeros_like, node)
                out = {k: rec(v) for k, v in node.items()}
                if "len" in out:
                    out["len"] = out["len"].at[..., slot].set(0)
                return out
            if isinstance(node, (list, tuple)):
                return type(node)(rec(v) for v in node)
            return node
        self.caches = rec(self.caches)

    # -- single-prompt helpers (used by tests/examples) ---------------------
    def generate(self, prompts: np.ndarray, n_tokens: int,
                 key: Optional[jax.Array] = None) -> np.ndarray:
        """prompts: (B, S) int32 — B must equal batch_slots. Returns
        (B, n_tokens) generated ids."""
        B, S = prompts.shape
        assert B == self.sc.batch_slots
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        logits, self.caches = self.prefill(
            self.params, {"tokens": jnp.asarray(prompts),
                          "positions": positions}, self.caches)
        out = []
        key, sub = (jax.random.split(key) if key is not None
                    else (None, None))
        tok = self._sample(logits, sub)[:, None].astype(jnp.int32)
        for i in range(n_tokens):
            out.append(np.asarray(tok)[:, 0])
            pos = jnp.full((B, 1), S + i, jnp.int32)
            logits, self.caches = self.decode(self.params, tok, pos,
                                              self.caches)
            key, sub = (jax.random.split(key) if key is not None
                        else (None, None))
            tok = self._sample(logits, sub)[:, None].astype(jnp.int32)
        return np.stack(out, axis=1)

    # -- continuous batching -------------------------------------------------
    def submit(self, prompt: List[int],
               key: Optional[jax.Array] = None) -> Optional[int]:
        """Admit a request into a free slot; returns slot id or None.

        Masked single-slot prefill: the whole prompt runs as one prefill
        call in which every *other* batch row carries position -1 — the
        attention cache update skips those rows entirely (no K/V write, no
        valid-length bump), so concurrent slots' caches are untouched.
        (The old per-token full-batch decode wrote zero-token K/V into every
        other live slot's cache and inflated their lengths — the
        interleaved-submit corruption regression in tests/test_serving.py.)

        The prefill's last-position logits seed the slot's pending greedy
        token, so the first decode step is conditioned on the real prompt,
        not a pseudo-BOS; step() reports that token first — no token of the
        stream is lost. Recycled slots restart from position 0 with their
        valid lengths zeroed.

        Known trade: each distinct prompt length S compiles its own (B, S)
        prefill. Callers with many lengths should bucket/pad prompts; the
        position masking is per-row, so column padding needs care.
        """
        if self.cfg.family in ("ssm", "hybrid") and self.sc.batch_slots > 1:
            raise NotImplementedError(
                "slot-based submit() requires position-masked cache updates; "
                "SSD/conv recurrent states carry no positions, so a masked "
                "single-slot prefill cannot leave other slots' SSM state "
                "untouched. Use generate(), or batch_slots=1 where no other "
                "slot exists.")
        if not 0 < len(prompt) < self.sc.max_len:
            raise ValueError(
                f"prompt length {len(prompt)} out of range for "
                f"max_len={self.sc.max_len} (need 1 <= len < max_len)")
        free = np.where(~self.slot_live)[0]
        if free.size == 0:
            return None
        slot = int(free[0])
        if self.slot_pos[slot]:        # recycled slot: restart from pos 0
            self._reset_slot_caches(slot)
            self.slot_pos[slot] = 0
        B, S = self.sc.batch_slots, len(prompt)
        tok = np.zeros((B, S), np.int32)
        tok[slot] = np.asarray(prompt, np.int32)
        pos = np.full((B, S), -1, np.int32)
        pos[slot] = np.arange(S)
        logits, self.caches = self.prefill(
            self.params, {"tokens": jnp.asarray(tok),
                          "positions": jnp.asarray(pos)}, self.caches)
        self.slot_pos[slot] = S
        self.slot_live[slot] = True
        self.slot_drain[slot] = False
        self.slot_out[slot] = []
        self.slot_next[slot] = int(self._sample(logits[slot][None], key)[0])
        return slot

    def step(self, key: Optional[jax.Array] = None) -> Dict[int, int]:
        """One decode iteration across all live slots; non-live and
        draining slots are masked out (position -1 → no cache write, no
        length bump).

        Reports each slot's *pending* token (decoded last round, or by the
        submit prefill) and pipelines the decode of the one after — the
        same order generate() uses, so slot streams match the batched path
        token for token. Sampling honors ServeConfig.temperature when a
        PRNG ``key`` is supplied (the same _sample rule as generate()).

        A slot whose cache fills (slot_pos reaches max_len — every cache
        index written) enters a one-round *drain*: its final pending token
        — freshly decoded last round — is still reported before the slot
        retires, so no token of the stream is ever dropped at retirement.
        """
        if not self.slot_live.any():
            return {}
        decodable = self.slot_live & ~self.slot_drain
        nxt = None
        if decodable.any():
            tok = jnp.asarray(self.slot_next)[:, None]
            pos = jnp.asarray(np.where(decodable, self.slot_pos,
                                       -1).astype(np.int32))[:, None]
            logits, self.caches = self.decode(self.params, tok, pos,
                                              self.caches)
            nxt = np.asarray(self._sample(logits, key))
        out = {}
        for s in range(self.sc.batch_slots):
            if not self.slot_live[s]:
                continue
            t = int(self.slot_next[s])
            self.slot_out[s].append(t)
            out[s] = t
            if self.slot_drain[s]:      # final pending token flushed above
                self.slot_live[s] = False
                self.slot_drain[s] = False
                continue
            self.slot_next[s] = int(nxt[s])
            self.slot_pos[s] += 1
            if self.slot_pos[s] >= self.sc.max_len:
                self.slot_drain[s] = True   # flush slot_next next round
        return out
