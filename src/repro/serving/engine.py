"""Batched serving engine: prefill + decode steps over the model's caches.

``prefill_step``/``decode_step`` are the functions the dry-run lowers for the
``prefill_*`` / ``decode_*`` / ``long_*`` shape cells. The engine adds a
continuous-batching front end: a slot-based scheduler that admits queued
requests into free batch slots between decode iterations (the vLLM-style
pattern, reduced to its core).

GEMM execution is governed by a GemmPolicy (ServeConfig.gemm); with
``pack_weights=True`` every projection weight is laid out block-major once
at engine construction (api.pack_model_weights) and stays resident — the
paper's Fig. 5 deployment shape, where serving never re-lays-out a weight.
``weight_dtype="int8"`` additionally quantizes at pack: weights live as
int8 blocks + per-channel scales and GEMMs run the W8A8 route
(core/quant.py, docs/quant.md). Attention execution is governed the same
way by ServeConfig.attention (an AttentionPolicy): ``fused`` streams K/V
blocks through the offset-aware flash kernel for both prefill and decode,
``unfused`` keeps the paper's host-softmax split (docs/attention.md), and
``paged`` swaps the contiguous ``(batch_slots, max_len)`` KV slab for a
**page pool** with per-request block tables (serving/kv_pool.py,
kernels/paged_attention.py, docs/serving.md). In paged mode admission is
**page-bound** instead of slot-bound: a request is admitted while free
pages cover its prompt, decode steps allocate pages on demand, retirement
returns them, and when the pool runs dry a live request is preempted —
spilled to a wait queue and resumed later with a token stream identical to
an uninterrupted run. ``submit``/``step`` then key their results by
*request id* (the handle submit returns), since a request may migrate
across slots.

Scheduling *policy* — resume order, preemption victims, priority
admission, chunked prefill — lives in serving/scheduler.py
(``ServeConfig.scheduler``; the default reproduces the PR 4/5 FIFO +
youngest-preemption choreography exactly). With ``prefix_cache=True``
the paged engine additionally shares full prompt-prefix KV pages across
requests through a copy-on-write radix cache (serving/prefix_cache.py):
submit looks the prompt up, borrows every cached full page (pool ref
counts), forks the first divergent page, and prefills only the uncached
tail; finished prefills index their prompt pages for later requests, and
cold entries evict by LRU when the pool runs low.

Slot admission uses *masked* prefill/decode: batch rows — and, for the
power-of-two **bucketed prefill** that bounds per-prompt-length recompiles,
padding columns — at position -1 neither write the KV cache nor advance
the valid length, so one slot's prefill cannot corrupt concurrent slots
(SSD/conv caches don't carry positions and are outside this masking
contract).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api
from repro.core.plan import AttentionPolicy, GemmPolicy, ShardingPolicy
from repro.distributed import tp as TP
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.obs import NULL_OBS, Observability, RequestTrace
from repro.obs.metrics import TIME_BUCKETS_S, json_scalars
from repro.serving.kv_pool import BlockTable, PagePool
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import RequestView, Scheduler

PAGED_BACKENDS = ("paged", "paged_interpret")


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length() if n > 1 else 1


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int = 8
    max_len: int = 1024
    temperature: float = 0.0     # 0 → greedy
    cache_dtype: str = "bfloat16"
    gemm: Optional[GemmPolicy] = None   # None → the ambient/default policy
    pack_weights: bool = False          # resident block-major weights
    weight_dtype: Optional[str] = None  # "int8" → quantized W8A8 GEMM route
    attention: Optional[AttentionPolicy] = None  # None → ambient/default
    # (AttentionPolicy(backend="fused") routes prefill AND decode through
    # the offset-aware flash kernel; backend="paged" additionally pages the
    # KV cache — docs/attention.md, docs/serving.md)
    kv_dtype: Optional[str] = None  # paged backends only: "int8" → int8 KV
    # pages with per-page-per-head fp32 scales, quantized at write time and
    # dequantized inside the paged kernel — half the pool bytes per
    # resident token (docs/quant.md#kv-pages).
    cache_pages: Optional[int] = None
    # paged backends only: total pages in the KV pool. None → the
    # contiguous-equivalent budget batch_slots * ceil(max_len / page_size);
    # smaller values make admission page-bound (the memory-oversubscription
    # regime the paged subsystem exists for).
    mesh: Optional[object] = None       # jax.sharding.Mesh → TP serving:
    # prefill/decode run under a repro.distributed.tp context — QKV/up
    # column-parallel, out/down row-parallel (psum), attention heads and
    # the per-shard paged KV pools split over the mesh's model axis
    # (docs/serving.md). None → single-device serving, unchanged.
    sharding: Optional[ShardingPolicy] = None  # axis names + rule overrides
    # for the mesh; None → ShardingPolicy() (("data", "model") axes).
    prefix_cache: bool = False
    # paged backends only: share full prompt-prefix KV pages across
    # requests through a copy-on-write radix cache
    # (serving/prefix_cache.py, docs/serving.md#prefix-cache).
    prefix_watermark: int = 0
    # with prefix_cache: evict cold cached entries at step() start until at
    # least this many pool pages are free. 0 → evict only on demand, when
    # an admission would otherwise fall short of pages.
    scheduler: Optional[Scheduler] = None
    # scheduling policy (serving/scheduler.py): resume order, preemption
    # victims, priority admission, chunked prefill. None → Scheduler(),
    # the FIFO-within-priority default that reproduces the PR 4/5
    # choreography (oldest resumes first, youngest preempts first,
    # whole-prompt prefill).
    spec: Optional[object] = None
    # speculative decoding (serving/spec_decode.py): a Drafter proposing
    # up to ``spec.k`` continuation tokens per request per step; the
    # engine verifies all of them in ONE masked forward (Sq = 1 + k at
    # each slot's offset), keeps the longest target-agreeing prefix plus
    # the bonus token, and rolls rejected tokens back (valid-length
    # reset; paged: BlockTable.truncate). Greedy streams stay
    # token-identical to spec=None; step() returns {handle: [tokens]}
    # bursts instead of single tokens. Greedy only (temperature == 0) —
    # docs/serving.md#speculative-decoding.
    obs: Observability = NULL_OBS
    # observability (repro/obs, docs/observability.md): metrics registry +
    # trace recorder + per-request lifecycle records. The default NULL_OBS
    # is fully disabled — hot paths pay one attribute load + branch and
    # record/allocate nothing. Pass Observability() to collect; sharing
    # one instance across engines merges their metrics (process-wide
    # registry semantics) and interleaves their trace tracks.

    def policy(self) -> Optional[GemmPolicy]:
        """The effective GemmPolicy: ``gemm`` with ``weight_dtype`` folded
        in. With ``pack_weights=True`` this makes every projection weight a
        resident QuantizedPackedWeight (quantize-at-pack)."""
        if self.weight_dtype is None:
            return self.gemm
        return dataclasses.replace(self.gemm or GemmPolicy(),
                                   weight_dtype=self.weight_dtype)

    def attn_policy(self) -> Optional[AttentionPolicy]:
        """The effective AttentionPolicy: ``attention`` with ``kv_dtype``
        folded in (mirrors :meth:`policy`'s weight_dtype folding)."""
        if self.kv_dtype is None:
            return self.attention
        return dataclasses.replace(self.attention or AttentionPolicy(),
                                   kv_dtype=self.kv_dtype)

    def paged(self) -> bool:
        return (self.attention is not None
                and self.attention.resolved_backend() in PAGED_BACKENDS)


@dataclasses.dataclass
class _Waiting:
    """A preempted (or re-queued) request parked off-device: everything
    needed to rebuild its cache by re-prefilling ``prompt + out`` and
    continue the stream exactly where it stopped. ``next_tok`` is None
    only for a request preempted *mid-chunked-prefill* — no token was
    sampled yet; ``key`` then re-seeds the first sample on resume so the
    stream is unchanged under any temperature."""
    rid: int
    prompt: List[int]            # the ORIGINAL prompt, never rewritten
    out: List[int]               # reported tokens — the live stream list
    next_tok: Optional[int]      # sampled but not yet reported/written
    key: Optional[jax.Array] = None
    priority: int = 0
    deadline: Optional[float] = None
    arrival: int = 0


def _policy_scope(policy: Optional[GemmPolicy],
                  attn: Optional[AttentionPolicy] = None,
                  tpctx: Optional[TP.TPContext] = None):
    stack = contextlib.ExitStack()
    if policy is not None:
        stack.enter_context(api.use_policy(policy))
    if attn is not None:
        stack.enter_context(api.use_attention_policy(attn))
    if tpctx is not None:
        stack.enter_context(TP.use_tp(tpctx))
    return stack


def make_prefill_step(cfg: ModelConfig, policy: Optional[GemmPolicy] = None,
                      attn: Optional[AttentionPolicy] = None,
                      tpctx: Optional[TP.TPContext] = None):
    """(params, batch, caches) → (last_logits, caches). Processes the full
    prompt with causal self-attention while writing the caches.

    batch may carry ``last_cols`` (B,) — the column holding each row's last
    *real* token under bucketed (position −1 padded) prefill — and
    ``block_tables`` for paged caches; absent both, this is the plain
    dense prefill returning the final column's logits. ``tpctx`` runs the
    forward tensor-parallel over its mesh (repro/distributed/tp.py)."""
    def prefill_step(params, batch, caches):
        with _policy_scope(policy, attn, tpctx):
            logits, caches, _ = T.forward(params, cfg, batch, caches=caches,
                                          remat=False)
        last = batch.get("last_cols")
        if last is None:
            return logits[:, -1], caches
        picked = jnp.take_along_axis(logits, last[:, None, None], axis=1)
        return picked[:, 0], caches
    return prefill_step


def make_verify_step(cfg: ModelConfig, policy: Optional[GemmPolicy] = None,
                     attn: Optional[AttentionPolicy] = None,
                     tpctx: Optional[TP.TPContext] = None):
    """(params, batch{tokens (B,Sq), positions (B,Sq)[, block_tables]},
    caches) → (greedy (B,Sq) int32, caches). The speculative-verification
    forward: Sq = 1 + k tokens per row — the pending token plus up to k
    drafts — at each slot's current offset, under the same masked-write
    contract as chunked prefill (position −1 rows neither write KV nor
    bump the valid length; the offset-aware kernels already causal-mask
    Sq > 1 at arbitrary offsets, so no new kernel is needed). Unlike
    make_prefill_step this returns the argmax at EVERY query position:
    column i is the target's greedy choice after consuming the row's
    tokens [0..i], which is exactly what acceptance compares drafts
    against. Greedy-only by design — distribution-preserving rejection
    sampling for temperature > 0 is out of scope here."""
    def verify_step(params, batch, caches):
        with _policy_scope(policy, attn, tpctx):
            logits, caches, _ = T.forward(params, cfg, batch, caches=caches,
                                          remat=False)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches
    return verify_step


def make_decode_step(cfg: ModelConfig, policy: Optional[GemmPolicy] = None,
                     attn: Optional[AttentionPolicy] = None,
                     tpctx: Optional[TP.TPContext] = None):
    """(params, tokens(B,1), positions(B,1), caches[, block_tables]) →
    (logits, caches). ``block_tables`` is None for contiguous caches."""
    def decode_step(params, tokens, positions, caches, block_tables=None):
        batch = {"tokens": tokens, "positions": positions}
        if block_tables is not None:
            batch["block_tables"] = block_tables
        with _policy_scope(policy, attn, tpctx):
            logits, caches, _ = T.forward(params, cfg, batch, caches=caches,
                                          remat=False)
        return logits[:, -1], caches
    return decode_step


class ServingEngine:
    """Greedy/temperature sampling with slot-based continuous batching.

    With a paged attention policy (``ServeConfig.attention`` backend
    "paged"/"paged_interpret") the engine runs **memory-bound continuous
    batching**: submit() returns a *request id*, admission holds while free
    pages cover the prompt, decode grows block tables on demand, and pool
    exhaustion preempts a scheduler-chosen victim into a wait queue from
    which step() resumes it once pages and a slot free up —
    docs/serving.md walks the full lifecycle.

    ``ServeConfig.prefix_cache`` adds copy-on-write prompt-prefix sharing
    over the same pool (serving/prefix_cache.py); ``ServeConfig.scheduler``
    swaps the scheduling policy — chunked prefill, priorities, SLO
    deadlines (serving/scheduler.py). Both default OFF/FIFO, reproducing
    the PR 4/5 engine token-for-token.

    With ``ServeConfig.mesh`` the same engine serves **tensor-parallel**:
    prefill/decode run under a repro/distributed/tp.py context (shard_map'd
    column/row-parallel GEMMs, head-sharded attention, per-shard paged KV
    pools), with params and caches placed mesh-resident at construction.
    Host-side scheduling — admission, page accounting, preemption, the
    prefix cache — is unchanged (pages are logical; every shard mirrors
    the allocation over its head slice), so TP token streams are identical
    to single-device streams (tests/test_tp_serving.py).
    """

    def __init__(self, cfg: ModelConfig, params, sc: ServeConfig,
                 axes=None):
        pol = sc.policy()
        self.tp = None
        if sc.mesh is not None:
            if sc.pack_weights or sc.weight_dtype is not None:
                raise NotImplementedError(
                    "TP serving (ServeConfig.mesh) does not yet cover "
                    "resident packed/quantized weights — block-major "
                    "PackedWeight pytrees would need per-shard re-packing; "
                    "drop pack_weights/weight_dtype or the mesh")
            self.tp = TP.make_context(sc.mesh, sc.sharding,
                                      cfg.overrides_dict())
            if axes is None:
                # placement needs the logical-axis tree; derived by
                # abstract tracing (no weight materialization) when the
                # caller didn't keep init_model's second return
                axes = T.model_axes(cfg)
            params = TP.shard_params(params, axes, self.tp)
        # Quantizing per call inside the jitted forward would redo the
        # O(K·N) weight quantization on every decode token; weights are
        # static across calls, so weight_dtype always quantizes-at-pack.
        if sc.pack_weights or sc.weight_dtype is not None:
            params = api.pack_model_weights(params, pol)
        self.cfg, self.params, self.sc = cfg, params, sc
        attn = sc.attn_policy()   # validates kv_dtype via AttentionPolicy
        self.decode = jax.jit(make_decode_step(cfg, pol, attn, self.tp))
        self.prefill = jax.jit(make_prefill_step(cfg, pol, attn, self.tp))
        self.spec = sc.spec
        if self.spec is not None:
            if sc.temperature > 0:
                raise ValueError(
                    "ServeConfig.spec requires greedy sampling "
                    "(temperature == 0): acceptance compares drafts "
                    "against the target's argmax, and the rollback path "
                    "implements no distribution-preserving rejection "
                    "sampling (docs/serving.md#speculative-decoding)")
            if cfg.family in ("ssm", "hybrid"):
                raise ValueError(
                    "ServeConfig.spec requires position-masked multi-"
                    "token cache writes; SSD/conv recurrent state has no "
                    "positions to mask or roll back")
            if int(getattr(self.spec, "k", 0)) < 1:
                raise ValueError(
                    f"ServeConfig.spec drafter needs k >= 1 "
                    f"(got {getattr(self.spec, 'k', None)!r}); see "
                    f"serving/spec_decode.py")
            self.verify = jax.jit(make_verify_step(cfg, pol, attn, self.tp))
        B = sc.batch_slots
        self.paged = sc.paged()
        if sc.kv_dtype is not None and not self.paged:
            raise ValueError(
                "ServeConfig.kv_dtype requires a paged attention policy "
                "(backend 'paged'/'paged_interpret') — only the page pool "
                "stores quantized K/V (docs/quant.md#kv-pages)")
        self.scheduler = sc.scheduler if sc.scheduler is not None \
            else Scheduler()
        # Observability: instruments are registered once here (the slow
        # phase) and held as direct references — the per-token paths only
        # ever do `if obs.enabled:` plus an int add. With NULL_OBS every
        # instrument is the shared no-op and nothing is recorded.
        self.obs = sc.obs if sc.obs is not None else NULL_OBS
        obs = self.obs
        if obs.enabled:
            m = obs.metrics
            self._m_prefill_tokens = m.counter("engine_tokens_total",
                                               stage="prefill")
            self._m_decode_tokens = m.counter("engine_tokens_total",
                                              stage="decode")
            self._m_admissions = m.counter("engine_admissions_total",
                                           kind="fresh")
            self._m_resumes = m.counter("engine_admissions_total",
                                        kind="resume")
            self._m_preemptions = m.counter("engine_preemptions_total")
            self._m_retired = m.counter("engine_retired_total")
            self._m_cancelled = m.counter("engine_cancelled_total")
            self._m_live = m.gauge("engine_live_requests")
            self._m_waiting = m.gauge("engine_waiting_requests")
            self._h_prefill = m.histogram("engine_prefill_chunk_s",
                                          TIME_BUCKETS_S)
            self._h_decode = m.histogram("engine_decode_step_s",
                                         TIME_BUCKETS_S)
            self._h_ttft = m.histogram("request_ttft_s", TIME_BUCKETS_S)
            self._h_itl = m.histogram("request_itl_s", TIME_BUCKETS_S)
            if self.spec is not None:
                self._m_spec_accepted = m.counter("spec_tokens_total",
                                                  verdict="accepted")
                self._m_spec_rejected = m.counter("spec_tokens_total",
                                                  verdict="rejected")
                self._m_spec_rollback = m.counter(
                    "spec_rollback_pages_total")
                self._h_spec_accept = m.histogram(
                    "spec_acceptance_rate",
                    buckets=(0.125, 0.25, 0.375, 0.5,
                             0.625, 0.75, 0.875, 1.0))
            self.scheduler.bind_metrics(m)
        # handle → lifecycle record (RequestTrace), built only when obs is
        # enabled; persists past retirement so finished streams stay
        # readable via request_trace(). Paged handles (request ids) are
        # unique per engine; contiguous handles are slot ids, so a slot's
        # next request replaces the previous record.
        self.request_traces: Dict[int, RequestTrace] = {}
        self.prefix: Optional[PrefixCache] = None
        if sc.prefix_cache and not self.paged:
            raise ValueError(
                "ServeConfig.prefix_cache requires a paged attention "
                "policy (backend 'paged'/'paged_interpret') — prefix "
                "sharing aliases pool pages through block tables, which "
                "the contiguous per-slot KV slab has none of")
        if self.paged:
            ps = sc.attention.page_size
            self.n_blocks = -(-sc.max_len // ps)
            n_pages = (sc.cache_pages if sc.cache_pages is not None
                       else B * self.n_blocks)
            if n_pages < self.n_blocks:
                raise ValueError(
                    f"cache_pages={n_pages} cannot back even one full-length"
                    f" request (ceil(max_len/page_size) = {self.n_blocks} "
                    f"pages); a preempted request could never resume")
            self.pool = PagePool(n_pages, ps)
            if obs.enabled:
                self.pool.bind_metrics(obs.metrics)
            if sc.prefix_cache:
                self.prefix = PrefixCache(
                    self.pool,
                    metrics=obs.metrics if obs.enabled else None)
            self.caches = T.init_paged_caches(cfg, B, n_pages, ps,
                                              jnp.dtype(sc.cache_dtype),
                                              tpctx=self.tp,
                                              kv_dtype=sc.kv_dtype)
            self.block_tables = np.zeros((B, self.n_blocks), np.int32)
            self.slot_tables: List[Optional[BlockTable]] = [None] * B
            self.slot_rid = np.full(B, -1, np.int64)
            self.wait: List[_Waiting] = []
            # rid → accumulated output stream. Entries persist past natural
            # retirement so the caller can read the finished stream; a
            # long-running server should request_out.pop(rid) once consumed
            # (cancel() and generate()'s reset drop theirs automatically).
            self.request_out: Dict[int, List[int]] = {}
            self._next_rid = 0
        else:
            self.caches = T.init_caches(cfg, B, sc.max_len,
                                        jnp.dtype(sc.cache_dtype),
                                        tpctx=self.tp)
        self.slot_pos = np.zeros(B, np.int32)
        self.slot_live = np.zeros(B, bool)
        self.slot_out: List[List[int]] = [[] for _ in range(B)]
        # The ORIGINAL prompt per slot: prefix-cache indexing (paged) and
        # the drafter's context (spec) both need it; dense engines fill it
        # too so speculation works on contiguous caches.
        self.slot_prompt: List[List[int]] = [[] for _ in range(B)]
        # Next sampled token per slot, already decoded but not yet reported:
        # seeded by submit() from the prefill logits, advanced by step().
        self.slot_next = np.zeros(B, np.int32)
        # Draining slots hold a final pending token but may not decode
        # further (their cache is full): step() reports it, then retires —
        # the freshly decoded last token is never silently dropped.
        self.slot_drain = np.zeros(B, bool)
        # Chunked prefill: a prefilling slot is live (it holds its pages
        # and its slot) but not yet decodable; step() advances one chunk
        # per iteration (scheduler.prefill_chunk tokens) until done.
        self.slot_prefilling = np.zeros(B, bool)
        self.slot_pf_tokens: List[Optional[List[int]]] = [None] * B
        self.slot_pf_restore: List[Optional[_Waiting]] = [None] * B
        self.slot_pf_key: List[Optional[jax.Array]] = [None] * B
        # Per-request scheduling metadata the scheduler sees via _view().
        self.slot_priority = np.zeros(B, np.int64)
        self.slot_deadline: List[Optional[float]] = [None] * B
        self.slot_arrival = np.zeros(B, np.int64)
        # Observability (stats()): a monotonic host tick orders arrivals;
        # token counters split prefill from decode work.
        self.tick = 0
        self.n_preemptions = 0
        self.prefill_tokens = 0
        self.decode_tokens = 0
        # Speculative-decoding counters (stats()): drafted tokens the
        # target's greedy choice confirmed vs rejected, and pool pages
        # returned by rejection rollback (BlockTable.truncate).
        self.spec_accepted = 0
        self.spec_rejected = 0
        self.spec_rollback_pages = 0

    # -- shared helpers -----------------------------------------------------
    def _sample(self, logits: jax.Array,
                key: Optional[jax.Array] = None) -> jax.Array:
        """The single sampling rule shared by generate(), submit() and
        step(): greedy argmax at temperature 0 (or when no PRNG key is
        supplied), softmax sampling at ServeConfig.temperature otherwise."""
        if self.sc.temperature > 0 and key is not None:
            return jax.random.categorical(
                key, logits.astype(jnp.float32) / self.sc.temperature,
                axis=-1)
        return jnp.argmax(logits, axis=-1)

    def _reset_slot_caches(self, slot: int):
        """Zero a slot's valid lengths so a recycled slot starts from
        position 0 (stale K/V beyond len=0 — contiguous rows or recycled
        pool pages alike — is invisible to attention)."""
        def rec(node):
            if isinstance(node, dict):
                if "state" in node:
                    # SSD recurrent state carries no positions/len; submit
                    # only admits these with batch_slots == 1 (see below),
                    # where the whole state belongs to this slot.
                    return jax.tree_util.tree_map(jnp.zeros_like, node)
                out = {k: rec(v) for k, v in node.items()}
                if "len" in out:
                    out["len"] = out["len"].at[..., slot].set(0)
                return out
            if isinstance(node, (list, tuple)):
                return type(node)(rec(v) for v in node)
            return node
        self.caches = rec(self.caches)

    def _set_slot_len(self, slot: int, n: int):
        """Preload a slot's valid length: prefix-cache admission reuses
        ``n`` tokens already resident in shared/forked pages, and the
        cache-len update is *additive* (len + tokens written), so the
        partial prefill must start from the reused count — otherwise the
        kernels' kv_valid_len would undercount and mask live keys."""
        def rec(node):
            if isinstance(node, dict):
                out = {k: rec(v) for k, v in node.items()}
                if "len" in out:
                    out["len"] = out["len"].at[..., slot].set(n)
                return out
            if isinstance(node, (list, tuple)):
                return type(node)(rec(v) for v in node)
            return node
        self.caches = rec(self.caches)

    def _set_slot_lens(self, updates: Dict[int, int]):
        """Batched :meth:`_set_slot_len`: one cache-tree pass setting
        several slots' valid lengths at once. The speculative-decoding
        rollback path uses this — a verify pass wrote 1 + k tokens per
        slot (the len update is additive inside the jitted forward), and
        every slot with rejected drafts must shrink back to its accepted
        count before the next forward reads kv_valid_len."""
        if not updates:
            return
        idx = np.fromiter(updates.keys(), np.int32, count=len(updates))
        val = jnp.asarray(
            np.fromiter(updates.values(), np.int32, count=len(updates)))

        def rec(node):
            if isinstance(node, dict):
                out = {k: rec(v) for k, v in node.items()}
                if "len" in out:
                    out["len"] = out["len"].at[..., idx].set(val)
                return out
            if isinstance(node, (list, tuple)):
                return type(node)(rec(v) for v in node)
            return node
        self.caches = rec(self.caches)

    def _copy_page(self, src: int, dst: int):
        """Copy-on-write device copy: duplicate page ``src``'s K/V rows
        into private page ``dst`` across every layer's pools. The page
        axis is -4 in both stacked scan leaves (n_scan, P, ps, Hkv, dh)
        and dense leaves (P, ps, Hkv, dh), and it is never sharded under
        TP (heads are), so the same indexed copy works mesh-resident —
        every shard duplicates its own head slice, keeping per-shard pools
        in lockstep."""
        def rec(node):
            if isinstance(node, dict):
                out = {}
                for k, v in node.items():
                    if k in ("kp", "vp"):
                        out[k] = v.at[..., dst, :, :, :].set(
                            v[..., src, :, :, :])
                    elif k in ("k_scale", "v_scale"):
                        # int8 pools: the (…, P, Hkv) frozen scale travels
                        # with the payload it quantized — a COW fork stays
                        # bitwise identical to the donor page.
                        out[k] = v.at[..., dst, :].set(v[..., src, :])
                    else:
                        out[k] = rec(v)
                return out
            if isinstance(node, (list, tuple)):
                return type(node)(rec(v) for v in node)
            return node
        self.caches = rec(self.caches)

    def _dev(self, x) -> jax.Array:
        """Host → device: replicated over the TP mesh when one is active
        (mixed single-device/committed inputs alongside mesh-sharded params
        would otherwise be placement-ambiguous), plain asarray else."""
        if self.tp is None:
            return jnp.asarray(x)
        return TP.replicate(x, self.tp)

    def kv_shards(self) -> int:
        """Model shards each KV cache/page-pool tensor splits across (1
        when unsharded). Pool admission stays in logical pages — every
        shard mirrors the same allocation over its head slice — so this is
        the divisor turning pool bytes into *per-shard* resident bytes
        (benchmarks/serving_sweep.py --tp, docs/serving.md)."""
        if self.tp is None or self.cfg.is_mla:
            # the MLA latent cache (ckv/krope) has no head dim to split —
            # it replicates on every shard even when attention heads shard
            return 1
        _, shard_kv = TP.head_sharding(self.tp, self.cfg.n_heads,
                                       self.cfg.n_kv_heads)
        return self.tp.model_size if shard_kv else 1

    def kv_page_bytes(self) -> int:
        """Logical device bytes per pool page, summed over layers and K/V —
        including int8 pools' fp32 scale side-tensors, so this is the unit
        the capacity sweep's pool-byte budget is denominated in
        (benchmarks/serving_sweep.py). Divide by :meth:`kv_shards` for
        per-shard bytes under TP."""
        total = 0

        def rec(node):
            nonlocal total
            if isinstance(node, dict):
                for k, v in node.items():
                    if k in ("kp", "vp", "k_scale", "v_scale"):
                        total += v.size * v.dtype.itemsize
                    else:
                        rec(v)
            elif isinstance(node, (list, tuple)):
                for v in node:
                    rec(v)

        rec(self.caches)
        return total // self.pool.n_pages

    def _bt_device(self) -> jnp.ndarray:
        return self._dev(self.block_tables)

    def _handle(self, slot: int) -> int:
        """What submit()/step() key results by: request id in paged mode
        (requests migrate across slots under preemption), slot id else."""
        return int(self.slot_rid[slot]) if self.paged else slot

    def _view(self, slot: int) -> RequestView:
        """The read-only snapshot the scheduler judges a live slot by.
        ``lookahead`` tells the policy how many *speculated* positions
        this request may additionally claim pages for next step — its
        page appetite under ServeConfig.spec is 1 + lookahead, not 1."""
        spec_ahead = (int(self.spec.k)
                      if self.spec is not None
                      and not self.slot_prefilling[slot]
                      and not self.slot_drain[slot] else 0)
        return RequestView(
            rid=self._handle(slot),
            priority=int(self.slot_priority[slot]),
            deadline=self.slot_deadline[slot],
            arrival=int(self.slot_arrival[slot]),
            n_tokens=int(self.slot_pos[slot]),
            prefilling=bool(self.slot_prefilling[slot]),
            lookahead=spec_ahead)

    def _slot_of_rid(self, rid: int) -> int:
        """The live slot holding request ``rid``; raises a descriptive
        RuntimeError when no live slot does. Victim resolution goes
        through here — a Scheduler.victim subclass returning a rid that
        is not live used to surface as a bare StopIteration from
        ``next()``, which reads as an internal iterator bug instead of a
        policy-contract violation."""
        for s in range(self.sc.batch_slots):
            if self.slot_live[s] and self._handle(s) == rid:
                return s
        live = sorted(self._handle(s) for s in range(self.sc.batch_slots)
                      if self.slot_live[s])
        raise RuntimeError(
            f"scheduler victim() returned rid {rid}, which is not a live "
            f"request (live rids: {live}); victim() must return the rid "
            f"of one of the RequestViews it was passed "
            f"(serving/scheduler.py)")

    # -- single-prompt helpers (used by tests/examples) ---------------------
    def generate(self, prompts: np.ndarray, n_tokens: int,
                 key: Optional[jax.Array] = None) -> np.ndarray:
        """prompts: (B, S) int32 — B must equal batch_slots. Returns
        (B, n_tokens) generated ids. In paged mode the pool is reset (all
        in-flight submit() requests dropped, the prefix cache cleared) and
        every row gets pages for its full S + n_tokens horizon up front."""
        B, S = prompts.shape
        if B != self.sc.batch_slots:
            raise ValueError(
                f"generate() got prompts shaped {tuple(prompts.shape)} "
                f"(batch {B}), but this engine was built with "
                f"ServeConfig.batch_slots={self.sc.batch_slots}; the "
                f"batched path needs one prompt per slot")
        bt = None
        if self.paged:
            if S + n_tokens > self.sc.max_len:
                raise ValueError(
                    f"generate() horizon S+n_tokens = {S + n_tokens} "
                    f"exceeds max_len={self.sc.max_len}")
            self._reset_paged_state()
            need = self.pool.pages_needed(S + n_tokens)
            if not self.pool.can_alloc(need * B):
                raise ValueError(
                    f"batched generate needs {need * B} pages "
                    f"({need}/row), pool holds {self.pool.n_pages}; raise "
                    f"cache_pages or use submit()/step() admission")
            for s in range(B):
                tbl = BlockTable(self.pool)
                tbl.ensure(S + n_tokens)
                self.slot_tables[s] = tbl
                tbl.as_row(self.n_blocks, out=self.block_tables[s])
            bt = self._bt_device()
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        batch = {"tokens": self._dev(prompts),
                 "positions": self._dev(positions)}
        if bt is not None:
            batch["block_tables"] = bt
        logits, self.caches = self.prefill(self.params, batch, self.caches)
        out = []
        key, sub = (jax.random.split(key) if key is not None
                    else (None, None))
        tok = self._sample(logits, sub)[:, None].astype(jnp.int32)
        for i in range(n_tokens):
            out.append(np.asarray(tok)[:, 0])
            pos = self._dev(jnp.full((B, 1), S + i, jnp.int32))
            logits, self.caches = self.decode(self.params, tok, pos,
                                              self.caches, bt)
            key, sub = (jax.random.split(key) if key is not None
                        else (None, None))
            tok = self._sample(logits, sub)[:, None].astype(jnp.int32)
        if self.paged:
            # the generated tokens are complete and no slot is live — the
            # horizon pages are dead; returning them keeps a later
            # submit() from inheriting (and silently dropping) ownership
            self._reset_paged_state()
        return np.stack(out, axis=1)

    def _reset_paged_state(self):
        """Drop every in-flight request and return all pages to the pool
        (batched generate() owns the whole engine). The prefix cache is
        cleared too — its retained pages would otherwise pin pool capacity
        a full-batch generate() is entitled to."""
        if self.prefix is not None:
            self.prefix.clear()
        for s in range(self.sc.batch_slots):
            if self.slot_tables[s] is not None:
                self.slot_tables[s].free()
                self.slot_tables[s] = None
            if self.slot_live[s]:       # dropped mid-flight: stream is dead
                self.request_out.pop(int(self.slot_rid[s]), None)
        for w in self.wait:
            self.request_out.pop(w.rid, None)
        # Zero every row's valid length unconditionally: generate() writes
        # caches without advancing slot_pos, so per-slot reset heuristics
        # would let `len` accumulate across generate() calls (inflating
        # kv_valid_len past the block-table-backed range — garbage keys
        # under non-causal attention, dead block-skip under causal).
        def rec(node):
            if isinstance(node, dict):
                return {k: (jnp.zeros_like(v) if k == "len" else rec(v))
                        for k, v in node.items()}
            if isinstance(node, (list, tuple)):
                return type(node)(rec(v) for v in node)
            return node
        self.caches = rec(self.caches)
        # in-flight lifecycle records die with their requests (their open
        # async spans are auto-closed at export time)
        self.request_traces.clear()
        self.block_tables[:] = 0
        self.slot_rid[:] = -1
        self.slot_live[:] = False
        self.slot_drain[:] = False
        self.slot_pos[:] = 0
        self.slot_prefilling[:] = False
        self.slot_pf_tokens = [None] * self.sc.batch_slots
        self.slot_pf_restore = [None] * self.sc.batch_slots
        self.slot_pf_key = [None] * self.sc.batch_slots
        self.wait.clear()

    # -- continuous batching -------------------------------------------------
    def submit(self, prompt: List[int],
               key: Optional[jax.Array] = None, *,
               priority: int = 0,
               deadline: Optional[float] = None) -> Optional[int]:
        """Admit a request; returns its handle (paged: request id,
        contiguous: slot id) or None when it cannot be admitted now.

        Masked single-slot prefill: the prompt runs as prefill calls in
        which every *other* batch row carries position -1 — the attention
        cache update skips those rows entirely (no K/V write, no
        valid-length bump), so concurrent slots' caches are untouched.
        (The old per-token full-batch decode wrote zero-token K/V into every
        other live slot's cache and inflated their lengths — the
        interleaved-submit corruption regression in tests/test_serving.py.)

        **Bucketed prefill**: each prefill call is right-padded to the next
        power-of-two length with position −1 columns (dropped from the
        cache write, zero rows in attention), so at most log2(max_len)
        prefill programs ever compile instead of one per distinct prompt
        length; the logits seeding the first token are read from the last
        *real* column, leaving the token stream bit-identical to an
        unpadded prefill (the regression test in tests/test_serving.py).

        **Chunked prefill** (scheduler.prefill_chunk = N): submit runs only
        the first N prompt tokens; step() advances one chunk per iteration,
        interleaved with decode, bounding decode-latency jitter under long
        prompts. The default (None) prefills the whole prompt here — the
        PR 4/5 behavior.

        The prefill's last-position logits seed the slot's pending greedy
        token, so the first decode step is conditioned on the real prompt,
        not a pseudo-BOS; step() reports that token first — no token of the
        stream is lost. Recycled slots restart from position 0 with their
        valid lengths zeroed.

        Paged admission is page-bound: a free slot AND enough free pages to
        cover the prompt (decode growth allocates on demand; the padding
        columns cost nothing — pages back real tokens only). With the
        prefix cache, cached full prompt pages are *borrowed* instead of
        allocated (the first divergent page is forked copy-on-write), so
        only the uncached tail needs free pages — and prefills. ``priority``
        (0 = most urgent) and ``deadline`` feed the scheduler: an incoming
        request may preempt a strictly less urgent live one
        (scheduler.should_preempt) instead of returning None.
        """
        if self.cfg.family in ("ssm", "hybrid") and self.sc.batch_slots > 1:
            raise NotImplementedError(
                "slot-based submit() requires position-masked cache updates; "
                "SSD/conv recurrent states carry no positions, so a masked "
                "single-slot prefill cannot leave other slots' SSM state "
                "untouched. Use generate(), or batch_slots=1 where no other "
                "slot exists.")
        if not 0 < len(prompt) < self.sc.max_len:
            raise ValueError(
                f"prompt length {len(prompt)} out of range for "
                f"max_len={self.sc.max_len} (need 1 <= len < max_len)")
        prompt = [int(t) for t in prompt]
        self.tick += 1
        arrival = self.tick
        obs = self.obs
        t0 = time.perf_counter() if obs.enabled else 0.0
        if not self.paged:
            free = np.where(~self.slot_live)[0]
            if free.size == 0:
                return None
            slot = int(free[0])
            self.slot_priority[slot] = priority
            self.slot_deadline[slot] = deadline
            self.slot_arrival[slot] = arrival
            self.slot_prompt[slot] = prompt
            self._begin_admit(slot, prompt, key=key)
            if obs.enabled:
                obs.trace.complete("admit", f"admit {slot}", t0,
                                   args={"handle": slot,
                                         "prompt_len": len(prompt)})
            return slot
        incoming = RequestView(rid=self._next_rid, priority=priority,
                               deadline=deadline, arrival=arrival,
                               n_tokens=len(prompt))
        while True:
            free = np.where(~self.slot_live)[0]
            if free.size and self._paged_admit(
                    int(free[0]), self._next_rid, prompt, prompt,
                    key=key, priority=priority, deadline=deadline,
                    arrival=arrival):
                rid = self._next_rid
                self._next_rid += 1
                if obs.enabled:
                    obs.trace.complete("admit", f"admit rid={rid}", t0,
                                       args={"rid": rid,
                                             "prompt_len": len(prompt)})
                return rid
            # no slot, or not enough pages even after cold-cache eviction:
            # ask the policy whether this request may displace a live one
            live = [s for s in range(self.sc.batch_slots)
                    if self.slot_live[s]]
            if not live:
                return None
            vrid = self.scheduler.victim([self._view(s) for s in live])
            vslot = self._slot_of_rid(vrid)
            if not self.scheduler.should_preempt(incoming,
                                                 self._view(vslot)):
                return None          # page/slot-bound, not worth churning
            self._preempt(vslot)

    def _begin_admit(self, slot: int, tokens: List[int], *,
                     start: int = 0,
                     restore: Optional[_Waiting] = None,
                     key: Optional[jax.Array] = None):
        """Stage ``tokens`` into ``slot`` and run the first prefill chunk
        (the whole remainder unless the scheduler chunks). ``start`` > 0
        marks a prefix-cache hit: positions [0, start) are already resident
        in shared/forked pages, so the slot's valid length is preloaded and
        prefill begins mid-prompt. With ``restore`` (resume after
        preemption) the pending token and output stream are carried over
        instead of re-sampled, so the resumed stream is identical to an
        uninterrupted one under any sampling."""
        if self.slot_pos[slot]:        # recycled slot: restart from pos 0
            self._reset_slot_caches(slot)
            self.slot_pos[slot] = 0
        if start:
            self._set_slot_len(slot, start)
            self.slot_pos[slot] = start
        self.slot_live[slot] = True
        self.slot_drain[slot] = False
        self.slot_prefilling[slot] = True
        self.slot_pf_tokens[slot] = tokens
        self.slot_pf_restore[slot] = restore
        self.slot_pf_key[slot] = key
        self.slot_out[slot] = restore.out if restore is not None else []
        obs = self.obs
        if obs.enabled:
            h = self._handle(slot)
            now = time.perf_counter()
            if restore is None:
                rt = RequestTrace(
                    rid=h, prompt_len=len(tokens),
                    priority=int(self.slot_priority[slot]),
                    deadline=self.slot_deadline[slot], submit_s=now,
                    prefix_hit_tokens=start)
                self.request_traces[h] = rt
                self._m_admissions.inc()
                obs.trace.async_begin(h, {"prompt_len": len(tokens),
                                          "priority": rt.priority})
                if start:
                    obs.trace.async_instant(h, "prefix-hit",
                                            {"tokens": start})
            else:
                self._m_resumes.inc()
                obs.trace.async_instant(h, "resume",
                                        {"restart_tokens": len(tokens)})
                rt = self.request_traces.get(h)
                if rt is not None and rt.preempted_at_s is not None:
                    rt.wait_s += now - rt.preempted_at_s
                    rt.preempted_at_s = None
            self._m_live.set(int(self.slot_live.sum()))
        self._prefill_slot_chunk(slot)

    def _prefill_slot_chunk(self, slot: int) -> bool:
        """Run one masked, bucketed prefill chunk for ``slot``; returns
        True when the prompt is fully prefilled and the slot became
        decodable (pending token seeded, prompt pages indexed in the
        prefix cache)."""
        tokens = self.slot_pf_tokens[slot]
        L = len(tokens)
        p0 = int(self.slot_pos[slot])
        obs = self.obs
        t0 = time.perf_counter() if obs.enabled else 0.0
        budget = self.scheduler.prefill_chunk or (L - p0)
        n = min(budget, L - p0)
        B = self.sc.batch_slots
        # Bucket padding relies on the position −1 masking contract, which
        # SSD/conv recurrent state is outside of (it carries no positions):
        # pad columns would enter the recurrence as real tokens. Those
        # families (admitted only with batch_slots == 1) prefill unpadded.
        if self.cfg.family in ("ssm", "hybrid"):
            Sb = n
        else:
            Sb = min(_next_pow2(n), max(self.sc.max_len, n))
        tok = np.zeros((B, Sb), np.int32)
        tok[slot, :n] = tokens[p0:p0 + n]
        pos = np.full((B, Sb), -1, np.int32)
        pos[slot, :n] = np.arange(p0, p0 + n)
        batch = {"tokens": self._dev(tok), "positions": self._dev(pos),
                 "last_cols": self._dev(jnp.full((B,), n - 1, jnp.int32))}
        if self.paged:
            batch["block_tables"] = self._bt_device()
        logits, self.caches = self.prefill(self.params, batch, self.caches)
        self.prefill_tokens += n
        self.slot_pos[slot] = p0 + n
        if obs.enabled:
            # timing covers host assembly + dispatch (the device call is
            # async; nothing here forces a sync the uninstrumented engine
            # wouldn't do)
            t1 = time.perf_counter()
            h = self._handle(slot)
            self._m_prefill_tokens.inc(n)
            self._h_prefill.observe(t1 - t0)
            obs.trace.complete("prefill-chunk",
                               f"prefill rid={h} [{p0}:{p0 + n})", t0, t1,
                               args={"rid": h, "start": p0, "tokens": n})
            rt = self.request_traces.get(h)
            if rt is not None:
                rt.prefill_chunks.append(
                    {"start_pos": p0, "tokens": n,
                     "dt_s": round(t1 - t0, 6)})
        if p0 + n < L:
            return False               # more chunks on later steps
        self.slot_prefilling[slot] = False
        self.slot_drain[slot] = L >= self.sc.max_len
        restore = self.slot_pf_restore[slot]
        if restore is not None and restore.next_tok is not None:
            self.slot_next[slot] = restore.next_tok
        else:
            # fresh admission — or a resume preempted before its first
            # sample existed: the stored key re-seeds it identically
            self.slot_next[slot] = int(self._sample(
                logits[slot][None], self.slot_pf_key[slot])[0])
        if self.prefix is not None:
            # index the ORIGINAL prompt's full pages (never the generated
            # tail: decode writes positions >= len(prompt), so these pages
            # are write-free from here on — safe to share)
            prompt = self.slot_prompt[slot]
            if len(prompt) >= self.pool.page_size:
                n_full = len(prompt) // self.pool.page_size
                self.prefix.insert(prompt,
                                   self.slot_tables[slot].pages[:n_full])
        self.slot_pf_tokens[slot] = None
        self.slot_pf_restore[slot] = None
        self.slot_pf_key[slot] = None
        return True

    # -- paged scheduling ---------------------------------------------------
    def _ensure_free(self, n: int) -> bool:
        """True once the pool can cover ``n`` fresh pages, evicting cold
        prefix-cache entries on demand to get there."""
        if self.pool.can_alloc(n):
            return True
        if self.prefix is not None:
            obs = self.obs
            t0 = time.perf_counter() if obs.enabled else 0.0
            short = n - self.pool.free_pages
            freed = self.prefix.evict(short)
            if obs.enabled:
                obs.trace.complete("evict", f"evict {freed}p on-demand",
                                   t0, args={"requested": short,
                                             "freed": freed})
        return self.pool.can_alloc(n)

    def _paged_admit(self, slot: int, rid: int, prompt: List[int],
                     tokens: List[int], *,
                     restore: Optional[_Waiting] = None,
                     key: Optional[jax.Array] = None,
                     priority: int = 0, deadline: Optional[float] = None,
                     arrival: int = 0) -> bool:
        """Admit ``tokens`` into ``slot``: prefix lookup, page budget
        (evicting cold cache entries when short), COW fork of the first
        divergent page, block-table assembly, then masked prefill of the
        uncached tail. Returns False — with no side effects beyond the
        lookup's released holds — when pages cannot cover it."""
        hit = self.prefix.lookup(tokens) if self.prefix is not None else None
        n_covered = len(hit.pages) if hit is not None else 0
        need = self.pool.pages_needed(len(tokens)) - n_covered
        if not self._ensure_free(need):
            if hit is not None:
                hit.release(self.pool)
            return False
        assert self.slot_tables[slot] is None, \
            f"free slot {slot} still owns a block table (page leak)"
        start, pages = 0, []
        if hit is not None:
            self.prefix.record(hit, len(tokens))
            pages = hit.pages          # lookup's holds become the table's
            hit.pages = []
            start = hit.n_tokens
            if hit.cow_page is not None:
                # fork: private copy of the partially-matching page; its
                # leading cow_tokens rows are valid, the rest is overwritten
                # by the prefill (or masked by the valid length)
                dst = self.pool.fork(hit.cow_page)
                self._copy_page(hit.cow_page, dst)
                self.pool.release([hit.cow_page])   # drop lookup's hold
                hit.cow_page = None
                pages.append(dst)
                start += hit.cow_tokens
                self.prefix.note_cow_fork()
        tbl = BlockTable(self.pool, pages=pages)
        tbl.ensure(len(tokens))
        self.slot_tables[slot] = tbl
        tbl.as_row(self.n_blocks, out=self.block_tables[slot])
        self.slot_rid[slot] = rid
        self.slot_prompt[slot] = prompt
        self.slot_priority[slot] = priority
        self.slot_deadline[slot] = deadline
        self.slot_arrival[slot] = arrival
        self._begin_admit(slot, tokens, start=start, restore=restore,
                          key=key)
        if restore is None:
            self.request_out[rid] = self.slot_out[slot]
        return True

    def _preempt(self, slot: int):
        """Spill ``slot``'s request to the wait queue: free its pages, park
        prompt/stream/pending-token host-side. Its cache pages are
        recycled; resume re-prefills prompt+out — through the prefix cache
        when enabled, so a preempted request's shared prefix re-admits
        without re-prefilling (docs/serving.md)."""
        obs = self.obs
        if obs.enabled:
            t0 = time.perf_counter()
            h = self._handle(slot)   # before slot_rid resets below
        if self.slot_prefilling[slot]:
            # mid-chunked-prefill: no pending token was sampled yet; park
            # the sampling key (and any carried token from an earlier
            # preemption) so resume reproduces the stream exactly
            restore = self.slot_pf_restore[slot]
            next_tok = None if restore is None else restore.next_tok
            key = self.slot_pf_key[slot]
        else:
            next_tok = int(self.slot_next[slot])
            key = None
        self.wait.append(_Waiting(
            rid=int(self.slot_rid[slot]), prompt=self.slot_prompt[slot],
            out=self.slot_out[slot], next_tok=next_tok, key=key,
            priority=int(self.slot_priority[slot]),
            deadline=self.slot_deadline[slot],
            arrival=int(self.slot_arrival[slot])))
        self.n_preemptions += 1
        self.slot_tables[slot].free()
        self.slot_tables[slot] = None
        self.block_tables[slot] = 0
        self.slot_rid[slot] = -1
        self.slot_live[slot] = False
        self.slot_drain[slot] = False
        self.slot_prefilling[slot] = False
        self.slot_pf_tokens[slot] = None
        self.slot_pf_restore[slot] = None
        self.slot_pf_key[slot] = None
        # slot_pos stays nonzero → the next admission resets this slot's lens
        if obs.enabled:
            self._m_preemptions.inc()
            self._m_live.set(int(self.slot_live.sum()))
            self._m_waiting.set(len(self.wait))
            obs.trace.complete("preempt", f"preempt rid={h}", t0,
                               args={"rid": h})
            obs.trace.async_instant(h, "preempt")
            rt = self.request_traces.get(h)
            if rt is not None:
                rt.n_preemptions += 1
                rt.preempted_at_s = time.perf_counter()

    def _try_resume(self):
        """Re-admit waiting requests into free slots in the scheduler's
        order (default: FIFO within priority). A waiter that doesn't fit
        is *skipped*, not a barrier — the old strict-FIFO resume bailed on
        the first non-fitting request, head-of-line-blocking a small later
        one a free slot and pages existed for."""
        if not self.wait:
            return
        views = [RequestView(rid=w.rid, priority=w.priority,
                             deadline=w.deadline, arrival=w.arrival,
                             n_tokens=len(w.prompt) + len(w.out))
                 for w in self.wait]
        admitted = []
        obs = self.obs
        for i in self.scheduler.resume_order(views):
            free = np.where(~self.slot_live)[0]
            if free.size == 0:
                break
            w = self.wait[i]
            t0 = time.perf_counter() if obs.enabled else 0.0
            if self._paged_admit(int(free[0]), w.rid, w.prompt,
                                 w.prompt + w.out, restore=w, key=w.key,
                                 priority=w.priority, deadline=w.deadline,
                                 arrival=w.arrival):
                admitted.append(i)
                if obs.enabled:
                    obs.trace.complete("resume", f"resume rid={w.rid}", t0,
                                       args={"rid": w.rid})
        for i in sorted(admitted, reverse=True):
            self.wait.pop(i)
        if obs.enabled and admitted:
            self._m_waiting.set(len(self.wait))

    def _grow_pages_for_decode(self, drafts: Optional[Dict[int, List[int]]]
                               = None):
        """Back every decodable slot's next position with a page, oldest
        request first; when the pool is dry — after cold prefix entries
        are evicted — preempt the scheduler's victim (possibly the
        requester itself) until it isn't.

        ``drafts`` (speculative decoding) adds each slot's drafted
        positions to its page budget: the verify pass writes 1 + k
        tokens, so all of them must be page-backed up front. Speculated
        growth is strictly opportunistic — it never preempts (churning a
        live request for tokens that may be rejected is pure loss);
        instead the slot's draft list is trimmed in place to the
        positions the pool can actually back, degrading toward plain
        one-token decode under pressure."""
        order = sorted(
            (s for s in range(self.sc.batch_slots)
             if self.slot_live[s] and not self.slot_drain[s]
             and not self.slot_prefilling[s]),
            key=lambda s: self.slot_rid[s])
        for s in order:
            if not self.slot_live[s]:
                continue               # preempted by an older slot's growth
            pos = int(self.slot_pos[s])
            if pos >= self.slot_tables[s].capacity():
                while not self._ensure_free(1):
                    vrid = self.scheduler.victim(
                        [self._view(t) for t in range(self.sc.batch_slots)
                         if self.slot_live[t]])
                    victim = self._slot_of_rid(vrid)
                    self._preempt(victim)
                    if victim == s:
                        break          # self-preempted: wait queue, no grow
                if not self.slot_live[s]:
                    continue
                self.slot_tables[s].ensure(pos + 1)
            tbl = self.slot_tables[s]
            if drafts and drafts.get(s):
                m = len(drafts[s])
                need = self.pool.pages_needed(pos + 1 + m) - tbl.n_pages
                if need > 0:
                    if not self._ensure_free(need):
                        # trim to what the pool backs right now (never
                        # preempt for speculation); capacity() already
                        # covers pos + 1, so fit >= 0
                        fit = (tbl.capacity() + self.pool.free_pages
                               * self.pool.page_size) - (pos + 1)
                        drafts[s] = drafts[s][:max(fit, 0)]
                        need = (self.pool.pages_needed(
                            pos + 1 + len(drafts[s])) - tbl.n_pages)
                    if need > 0:
                        tbl.ensure(pos + 1 + len(drafts[s]))
            tbl.as_row(self.n_blocks, out=self.block_tables[s])

    def _retire(self, slot: int, *, cancelled: bool = False):
        """Release ``slot``. ``cancelled`` marks a caller-initiated abort
        (cancel() of a live request): the trace's async span then closes
        with ``{"cancelled": true}`` — matching the wait-queue cancel
        branch — and the cancelled counter moves instead of the retired
        one, so traces and slo_report() can tell an abort from a natural
        completion."""
        obs = self.obs
        if obs.enabled:
            h = self._handle(slot)   # before slot_rid resets below
            if cancelled:
                self._m_cancelled.inc()
                obs.trace.async_end(
                    h, {"cancelled": True,
                        "n_tokens": len(self.slot_out[slot])})
            else:
                self._m_retired.inc()
                obs.trace.async_end(h,
                                    {"n_tokens": len(self.slot_out[slot])})
            rt = self.request_traces.get(h)
            if rt is not None and rt.retire_s is None:
                rt.retire_s = time.perf_counter()
        self.slot_live[slot] = False
        self.slot_drain[slot] = False
        self.slot_prefilling[slot] = False
        self.slot_pf_tokens[slot] = None
        self.slot_pf_restore[slot] = None
        self.slot_pf_key[slot] = None
        if self.paged:
            self.slot_tables[slot].free()
            self.slot_tables[slot] = None
            self.block_tables[slot] = 0
            self.slot_rid[slot] = -1
        if obs.enabled:
            self._m_live.set(int(self.slot_live.sum()))

    def cancel(self, handle: int) -> bool:
        """Abort a request by the handle submit() returned (request id in
        paged mode, slot id else), releasing its slot — and, when paged,
        its pages (or its wait-queue entry). Returns True if found."""
        if not self.paged:
            if 0 <= handle < self.sc.batch_slots and self.slot_live[handle]:
                self._retire(handle, cancelled=True)
                return True
            return False
        for s in range(self.sc.batch_slots):
            if self.slot_live[s] and self.slot_rid[s] == handle:
                self._retire(s, cancelled=True)
                self.request_out.pop(handle, None)
                return True
        for i, w in enumerate(self.wait):
            if w.rid == handle:
                self.wait.pop(i)
                self.request_out.pop(handle, None)
                if self.obs.enabled:
                    self._m_cancelled.inc()
                    self._m_waiting.set(len(self.wait))
                    self.obs.trace.async_end(handle, {"cancelled": True})
                    rt = self.request_traces.get(handle)
                    if rt is not None and rt.retire_s is None:
                        rt.retire_s = time.perf_counter()
                return True
        return False

    def step(self, key: Optional[jax.Array] = None) -> Dict[int, int]:
        """One decode iteration across all live slots; non-live, draining
        and still-prefilling slots are masked out (position -1 → no cache
        write, no length bump). Returns {handle: token} — handles are
        request ids in paged mode, slot ids else.

        Reports each slot's *pending* token (decoded last round, or by the
        submit prefill) and pipelines the decode of the one after — the
        same order generate() uses, so slot streams match the batched path
        token for token. Sampling honors ServeConfig.temperature when a
        PRNG ``key`` is supplied (the same _sample rule as generate()).

        Paged mode first restores the prefix-cache watermark (evicting
        cold entries until ServeConfig.prefix_watermark pages are free),
        then resumes waiting requests in the scheduler's order, advances
        at most one chunked prefill (most urgent first), backs each
        decodable slot's next position with a page — evicting cold cache
        entries, then preempting the scheduler's victim when the pool is
        dry — and only then decodes. Retirement returns pages to the pool.

        A slot whose cache fills (slot_pos reaches max_len — every cache
        index written) enters a one-round *drain*: its final pending token
        — freshly decoded last round — is still reported before the slot
        retires, so no token of the stream is ever dropped at retirement.

        With ``ServeConfig.spec`` the iteration is speculative
        (:meth:`_spec_step`) and the result is ``{handle: [tokens]}`` —
        a burst of accepted tokens per request — instead of one token
        each; concatenated bursts equal the non-speculative stream
        exactly (docs/serving.md#speculative-decoding).
        """
        self.tick += 1
        obs = self.obs
        if self.paged:
            if self.prefix is not None and self.sc.prefix_watermark > 0:
                short = self.sc.prefix_watermark - self.pool.free_pages
                if short > 0:
                    t0 = time.perf_counter() if obs.enabled else 0.0
                    freed = self.prefix.evict(short)
                    if obs.enabled:
                        obs.trace.complete(
                            "evict", f"evict {freed}p watermark", t0,
                            args={"requested": short, "freed": freed})
            self._try_resume()
        if not self.slot_live.any():
            return {}
        # one chunked-prefill advance per step: bounded prefill work keeps
        # decode latency jitter bounded (the whole point of chunking);
        # unchunked admissions never appear here — submit() finishes them
        pf = [s for s in range(self.sc.batch_slots)
              if self.slot_prefilling[s]]
        if pf:
            s = min(pf, key=lambda t: (self.slot_priority[t],
                                       self.slot_arrival[t], t))
            self._prefill_slot_chunk(s)
        if self.spec is not None:
            return self._spec_step()
        if self.paged:
            self._grow_pages_for_decode()
        decodable = (self.slot_live & ~self.slot_drain
                     & ~self.slot_prefilling)
        nxt = None
        if decodable.any():
            t0 = time.perf_counter() if obs.enabled else 0.0
            tok = self._dev(np.asarray(self.slot_next)[:, None])
            pos = self._dev(np.where(decodable, self.slot_pos,
                                     -1).astype(np.int32)[:, None])
            bt = self._bt_device() if self.paged else None
            logits, self.caches = self.decode(self.params, tok, pos,
                                              self.caches, bt)
            nxt = np.asarray(self._sample(logits, key))
            n_dec = int(decodable.sum())
            self.decode_tokens += n_dec
            if obs.enabled:
                # np.asarray above synced the sampled ids, so this span is
                # honest wall time for the whole batched decode
                t1 = time.perf_counter()
                self._m_decode_tokens.inc(n_dec)
                self._h_decode.observe(t1 - t0)
                obs.trace.complete("decode-step", f"decode x{n_dec}",
                                   t0, t1,
                                   args={"slots": n_dec, "tick": self.tick})
        out = {}
        for s in range(self.sc.batch_slots):
            if not self.slot_live[s] or self.slot_prefilling[s]:
                continue
            t = int(self.slot_next[s])
            self.slot_out[s].append(t)
            h = self._handle(s)
            out[h] = t
            if obs.enabled:
                self._obs_token(s, h, t)
            if self.slot_drain[s]:      # final pending token flushed above
                self._retire(s)
                continue
            self.slot_next[s] = int(nxt[s])
            self.slot_pos[s] += 1
            if self.slot_pos[s] >= self.sc.max_len:
                self.slot_drain[s] = True   # flush slot_next next round
        return out

    def _spec_step(self) -> Dict[int, List[int]]:
        """One speculative iteration: draft → verify → accept → rollback.

        Per decodable slot the drafter proposes up to ``spec.k`` tokens
        (capped to the ``max_len`` horizon, then — paged — to the pages
        the pool can back without preempting anyone). ONE verify forward
        runs every slot's row ``[pending] + drafts`` at positions
        ``pos..pos+m`` (fixed Sq = 1 + k, position −1 padded: a single
        compiled shape regardless of per-slot draft counts); column ``i``
        of its argmax is the target's greedy choice after consuming the
        row's tokens ``[0..i]``. Acceptance keeps the longest prefix of
        drafts the argmax agrees with, the column after the last accepted
        draft becomes the new pending (the "bonus" token — exactly what
        non-speculative decode would have sampled there), and rejected
        suffixes roll back: valid lengths reset to the accepted count
        (the jitted forward's len update is additive and counted every
        non-masked row) and wholly-rejected tail pages return to the pool
        (:meth:`BlockTable.truncate`). Every reported token is therefore
        the target's argmax given exactly the tokens before it — greedy
        streams are token-identical to ``spec=None`` by construction.

        Draining slots flush their pending final token as a one-token
        burst and retire, mirroring the non-speculative drain round.
        """
        obs = self.obs
        k = int(self.spec.k)
        decodable = (self.slot_live & ~self.slot_drain
                     & ~self.slot_prefilling)
        drafts: Dict[int, List[int]] = {}
        if decodable.any():
            t0 = time.perf_counter() if obs.enabled else 0.0
            for s in np.nonzero(decodable)[0]:
                s = int(s)
                pos = int(self.slot_pos[s])
                # verify writes positions pos..pos+m; the last writable
                # cache index is max_len - 1, so m <= max_len - 1 - pos
                cap = min(k, self.sc.max_len - 1 - pos)
                d: List[int] = []
                if cap >= 1:
                    ctx = (self.slot_prompt[s] + self.slot_out[s]
                           + [int(self.slot_next[s])])
                    d = [int(t) for t in self.spec.draft(ctx, cap)][:cap]
                drafts[s] = d
            if obs.enabled:
                obs.trace.complete(
                    "draft", f"draft x{len(drafts)}", t0,
                    args={"slots": len(drafts),
                          "tokens": sum(len(d) for d in drafts.values()),
                          "tick": self.tick})
        if self.paged:
            # may preempt for the base pos+1 page and TRIM drafts in
            # place when speculation alone would exhaust the pool
            self._grow_pages_for_decode(drafts)
            decodable = (self.slot_live & ~self.slot_drain
                         & ~self.slot_prefilling)
        nxt = None
        if decodable.any():
            t0 = time.perf_counter() if obs.enabled else 0.0
            B = self.sc.batch_slots
            tok = np.zeros((B, 1 + k), np.int32)
            pos2 = np.full((B, 1 + k), -1, np.int32)
            for s in np.nonzero(decodable)[0]:
                s = int(s)
                d = drafts.get(s, [])
                m = len(d)
                p = int(self.slot_pos[s])
                tok[s, 0] = int(self.slot_next[s])
                tok[s, 1:1 + m] = d
                pos2[s, :1 + m] = np.arange(p, p + 1 + m)
            batch = {"tokens": self._dev(tok),
                     "positions": self._dev(pos2)}
            if self.paged:
                batch["block_tables"] = self._bt_device()
            greedy, self.caches = self.verify(self.params, batch,
                                              self.caches)
            nxt = np.asarray(greedy)
            if obs.enabled:
                t1 = time.perf_counter()
                self._h_decode.observe(t1 - t0)
                obs.trace.complete(
                    "verify", f"verify x{int(decodable.sum())}", t0, t1,
                    args={"slots": int(decodable.sum()),
                          "tick": self.tick})
        out: Dict[int, List[int]] = {}
        len_resets: Dict[int, int] = {}
        for s in range(self.sc.batch_slots):
            if not self.slot_live[s] or self.slot_prefilling[s]:
                continue
            h = self._handle(s)
            if self.slot_drain[s]:      # flush the final pending token
                t = int(self.slot_next[s])
                self.slot_out[s].append(t)
                out[h] = [t]
                if obs.enabled:
                    self._obs_token(s, h, t)
                self._retire(s)
                continue
            p = int(self.slot_pos[s])
            d = drafts.get(s, [])
            m = len(d)
            g = nxt[s]
            j = 0
            while j < m and d[j] == int(g[j]):
                j += 1
            burst = [int(self.slot_next[s])] + d[:j]
            for t in burst:
                self.slot_out[s].append(t)
                if obs.enabled:
                    self._obs_token(s, h, t)
            out[h] = burst
            self.spec_accepted += j
            self.spec_rejected += m - j
            self.decode_tokens += 1 + j
            new_pos = p + 1 + j
            if j < m:
                # rejected suffix: the verify pass wrote (and len-counted)
                # positions new_pos..p+m — shrink the valid length back
                # and return wholly-rejected tail pages to the pool
                len_resets[s] = new_pos
                if self.paged:
                    dropped = self.slot_tables[s].truncate(new_pos)
                    if dropped:
                        self.spec_rollback_pages += len(dropped)
                        if obs.enabled:
                            self._m_spec_rollback.inc(len(dropped))
                        self.slot_tables[s].as_row(
                            self.n_blocks, out=self.block_tables[s])
            if obs.enabled:
                self._m_decode_tokens.inc(1 + j)
                self._m_spec_accepted.inc(j)
                self._m_spec_rejected.inc(m - j)
                if m:
                    self._h_spec_accept.observe(j / m)
            self.slot_next[s] = int(g[j])
            self.slot_pos[s] = new_pos
            if new_pos >= self.sc.max_len:
                self.slot_drain[s] = True   # flush slot_next next round
        self._set_slot_lens(len_resets)
        return out

    # -- observability -------------------------------------------------------
    def _obs_token(self, slot: int, h: int, tok: int):
        """Per-reported-token trace/metrics. Called only when observability
        is enabled — the disabled step() loop pays one branch per token and
        never enters here."""
        now = time.perf_counter()
        rt = self.request_traces.get(h)
        if rt is not None:
            if rt.first_token_s is None:
                rt.first_token_s = now
                self._h_ttft.observe(now - rt.submit_s)
                self.obs.trace.async_instant(h, "first-token")
            else:
                gap = now - rt.token_s[-1]
                rt.itl.observe(gap)
                self._h_itl.observe(gap)
            rt.tokens.append(tok)
            rt.token_s.append(now)
            if self.paged:
                tbl = self.slot_tables[slot]
                pages = len(tbl.pages) if tbl is not None else 0
                tl = rt.pages_timeline
                if not tl or tl[-1][1] != pages:
                    tl.append((self.tick, pages))

    def request_trace(self, handle: int, pop: bool = False
                      ) -> Optional[RequestTrace]:
        """The lifecycle record for ``handle`` (repro.obs.RequestTrace):
        queue/preemption waits, prefill chunks, TTFT, the exact reported
        token stream with per-token timestamps, the inter-token-latency
        histogram, and the pages-held timeline. None when observability is
        disabled or the handle is unknown. Records persist past
        retirement; ``pop=True`` removes the record after returning it (a
        long-running server's analogue of ``request_out.pop``)."""
        if pop:
            return self.request_traces.pop(handle, None)
        return self.request_traces.get(handle)

    def stats(self) -> Dict[str, object]:
        """One flat observability snapshot: scheduling churn, prefill vs
        decode token split, pool pressure, and (when enabled) the prefix
        cache's hit/miss/eviction counters. Printed by launch/serve.py and
        recorded per-row in benchmarks/serving_sweep.py JSONL — every
        value is coerced to a plain JSON type (json_scalars), so the dict
        round-trips through json.dumps unchanged (tests/test_obs.py pins
        the schema)."""
        d: Dict[str, object] = {
            "tick": self.tick,
            "live_requests": int(self.slot_live.sum()),
            "waiting_requests": len(self.wait) if self.paged else 0,
            "n_preemptions": self.n_preemptions,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
        }
        if self.spec is not None:
            seen = self.spec_accepted + self.spec_rejected
            d["spec_accepted_tokens"] = self.spec_accepted
            d["spec_rejected_tokens"] = self.spec_rejected
            d["spec_rollback_pages"] = self.spec_rollback_pages
            d["spec_acceptance_rate"] = (
                self.spec_accepted / seen if seen else 0.0)
        if self.paged:
            d["pool_pages"] = self.pool.n_pages
            d["pool_free_pages"] = self.pool.free_pages
            d["pool_pages_in_use"] = self.pool.pages_in_use
            d["pool_high_water"] = self.pool.high_water
            page_bytes = self.kv_page_bytes()
            d["kv_dtype"] = self.sc.kv_dtype or str(self.sc.cache_dtype)
            d["kv_page_bytes"] = page_bytes
            d["kv_pool_bytes"] = page_bytes * self.pool.n_pages
            d["kv_bytes_in_use"] = page_bytes * self.pool.pages_in_use
            if self.prefix is not None:
                d.update(self.prefix.stats())
        return json_scalars(d)
