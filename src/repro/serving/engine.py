"""Batched serving engine: prefill + decode steps over the model's caches.

``prefill_step``/``decode_step`` are the functions the dry-run lowers for the
``prefill_*`` / ``decode_*`` / ``long_*`` shape cells. The engine adds a
simple continuous-batching front end: a slot-based scheduler that admits
queued requests into free batch slots between decode iterations (the
vLLM-style pattern, reduced to its core).

GEMM execution is governed by a GemmPolicy (ServeConfig.gemm); with
``pack_weights=True`` every projection weight is laid out block-major once
at engine construction (api.pack_model_weights) and stays resident — the
paper's Fig. 5 deployment shape, where serving never re-lays-out a weight.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api
from repro.core.plan import GemmPolicy
from repro.models import transformer as T
from repro.models.config import ModelConfig


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int = 8
    max_len: int = 1024
    temperature: float = 0.0     # 0 → greedy
    cache_dtype: str = "bfloat16"
    gemm: Optional[GemmPolicy] = None   # None → the ambient/default policy
    pack_weights: bool = False          # resident block-major weights


def _policy_scope(policy: Optional[GemmPolicy]):
    return api.use_policy(policy) if policy is not None \
        else contextlib.nullcontext()


def make_prefill_step(cfg: ModelConfig, policy: Optional[GemmPolicy] = None):
    """(params, batch, caches) → (last_logits, caches). Processes the full
    prompt with causal self-attention while writing the caches."""
    def prefill_step(params, batch, caches):
        with _policy_scope(policy):
            logits, caches, _ = T.forward(params, cfg, batch, caches=caches,
                                          remat=False)
        return logits[:, -1], caches
    return prefill_step


def make_decode_step(cfg: ModelConfig, policy: Optional[GemmPolicy] = None):
    """(params, tokens(B,1), positions(B,1), caches) → (logits, caches)."""
    def decode_step(params, tokens, positions, caches):
        batch = {"tokens": tokens, "positions": positions}
        with _policy_scope(policy):
            logits, caches, _ = T.forward(params, cfg, batch, caches=caches,
                                          remat=False)
        return logits[:, -1], caches
    return decode_step


class ServingEngine:
    """Greedy/temperature sampling with slot-based continuous batching."""

    def __init__(self, cfg: ModelConfig, params, sc: ServeConfig):
        if sc.pack_weights:
            params = api.pack_model_weights(params, sc.gemm)
        self.cfg, self.params, self.sc = cfg, params, sc
        self.decode = jax.jit(make_decode_step(cfg, sc.gemm))
        self.prefill = jax.jit(make_prefill_step(cfg, sc.gemm))
        self.caches = T.init_caches(cfg, sc.batch_slots, sc.max_len,
                                    jnp.dtype(sc.cache_dtype))
        self.slot_pos = np.zeros(sc.batch_slots, np.int32)
        self.slot_live = np.zeros(sc.batch_slots, bool)
        self.slot_out: List[List[int]] = [[] for _ in range(sc.batch_slots)]

    # -- single-prompt helpers (used by tests/examples) ---------------------
    def generate(self, prompts: np.ndarray, n_tokens: int,
                 key: Optional[jax.Array] = None) -> np.ndarray:
        """prompts: (B, S) int32 — B must equal batch_slots. Returns
        (B, n_tokens) generated ids."""
        B, S = prompts.shape
        assert B == self.sc.batch_slots
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        logits, self.caches = self.prefill(
            self.params, {"tokens": jnp.asarray(prompts),
                          "positions": positions}, self.caches)
        out = []
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        for i in range(n_tokens):
            out.append(np.asarray(tok)[:, 0])
            pos = jnp.full((B, 1), S + i, jnp.int32)
            logits, self.caches = self.decode(self.params, tok, pos,
                                              self.caches)
            if self.sc.temperature > 0 and key is not None:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, logits / self.sc.temperature)[:, None]
            else:
                tok = jnp.argmax(logits, axis=-1)[:, None]
            tok = tok.astype(jnp.int32)
        return np.stack(out, axis=1)

    # -- continuous batching -------------------------------------------------
    def submit(self, prompt: List[int]) -> Optional[int]:
        """Admit a request into a free slot; returns slot id or None."""
        free = np.where(~self.slot_live)[0]
        if free.size == 0:
            return None
        slot = int(free[0])
        # per-slot prefill: run the prompt through decode one token at a
        # time (slot-local; batch-level prefill happens in generate())
        for i, t in enumerate(prompt):
            tok = jnp.zeros((self.sc.batch_slots, 1), jnp.int32)
            tok = tok.at[slot, 0].set(t)
            pos = jnp.asarray(self.slot_pos)[:, None]
            _, self.caches = self.decode(self.params, tok, pos, self.caches)
            self.slot_pos[slot] += 1
        self.slot_live[slot] = True
        self.slot_out[slot] = []
        return slot

    def step(self) -> Dict[int, int]:
        """One decode iteration across all live slots."""
        if not self.slot_live.any():
            return {}
        last = np.array([o[-1] if o else 0 for o in self.slot_out], np.int32)
        tok = jnp.asarray(last)[:, None]
        pos = jnp.asarray(self.slot_pos)[:, None]
        logits, self.caches = self.decode(self.params, tok, pos, self.caches)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        out = {}
        for s in range(self.sc.batch_slots):
            if self.slot_live[s]:
                self.slot_out[s].append(int(nxt[s]))
                self.slot_pos[s] += 1
                out[s] = int(nxt[s])
                if self.slot_pos[s] >= self.sc.max_len - 1:
                    self.slot_live[s] = False   # retire full slots
        return out
