from repro.serving.engine import ServeConfig, ServingEngine  # noqa: F401
from repro.serving.frontend import AsyncServingEngine  # noqa: F401
from repro.serving.kv_pool import (BlockTable, PagePool,  # noqa: F401
                                   PoolExhausted)
from repro.serving.prefix_cache import PrefixCache, PrefixHit  # noqa: F401
from repro.serving.scheduler import (RequestView, Scheduler,  # noqa: F401
                                     SLOScheduler)
from repro.serving.spec_decode import (DraftModelDrafter,  # noqa: F401
                                       Drafter, NGramDrafter, make_drafter)
