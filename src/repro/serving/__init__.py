from repro.serving.engine import ServeConfig, ServingEngine  # noqa: F401
