"""Pluggable serving schedulers: admission, resume, preemption, chunking.

PR 4 buried three scheduling decisions inside ``ServingEngine``: resume
was strict FIFO (and bailed on the first waiter that didn't fit — the
head-of-line block), the preemption victim was always the youngest live
request, and prefill ran unbounded in one shot (a long prompt stalls every
concurrent decode for its whole prefill — decode-latency jitter).

This module lifts the policy out. The engine owns *mechanism* (slots,
pages, masked prefill, the wait queue); a :class:`Scheduler` owns
*policy*, consulted at four points:

==================  ====================================================
``resume_order``    which waiters to try re-admitting, in what order; the
                    engine *skips* (not bails on) entries that don't fit,
                    so a small later request no longer starves behind a
                    large earlier one
``victim``          which live request to preempt when the pool runs dry
``should_preempt``  whether an incoming request may evict a live one at
                    admission (priority ladder; default: only a strictly
                    more urgent request may)
``prefill_chunk``   tokens of prefill allowed per engine step (None →
                    whole prompt in one call, the PR 4 behavior); chunked
                    prefill interleaves with decode, bounding jitter
==================  ====================================================

The default :class:`Scheduler` is **FIFO within priority** (priority 0 is
most urgent; ties resolve by arrival order). With every request at the
default priority it reproduces the PR 4/5 choreography exactly — oldest
resumes first, youngest preempts first — which is what keeps the golden
stream-equivalence gates green. :class:`SLOScheduler` layers deadlines on
top: earliest-deadline-first resume, farthest-deadline-first victims.

Deadlines are caller-defined floats on a clock the caller also defines
(the engine only ever *compares* them — steps, seconds, anything
monotonic works).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

__all__ = ["RequestView", "Scheduler", "SLOScheduler"]


@dataclasses.dataclass(frozen=True)
class RequestView:
    """A read-only snapshot of one request, as the engine shows it to the
    scheduler: identity, class, progress. ``prefilling`` marks a request
    whose chunked prefill hasn't finished (preempting one mid-prefill is
    legal but wasteful — default policies avoid it while any decoded
    request is available)."""

    rid: int
    priority: int = 0                 # 0 = most urgent; larger = later
    deadline: Optional[float] = None  # caller's clock; None = unconstrained
    arrival: int = 0                  # engine tick at submit
    n_tokens: int = 0                 # prompt + generated so far
    prefilling: bool = False
    # speculative decoding: tokens this request may *additionally* write
    # next step (the drafter's budget). Policies costing page pressure
    # should treat the request as n_tokens + lookahead deep — speculated
    # positions need page backing before the verify pass runs.
    lookahead: int = 0


class Scheduler:
    """FIFO-within-priority default policy (see module docstring)."""

    def __init__(self, prefill_chunk: Optional[int] = None):
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, "
                             f"got {prefill_chunk}")
        self.prefill_chunk = prefill_chunk
        # observability (bind_metrics): decision counters, None → unbound
        self._m_victims = None
        self._m_preempt_granted = None
        self._m_preempt_denied = None

    def bind_metrics(self, metrics) -> None:
        """Count this policy's decisions on ``metrics`` (a repro.obs
        Metrics registry, duck-typed): victim picks when the pool runs
        dry, and admission-preemption verdicts either way. The engine
        binds this automatically when built with observability enabled."""
        self._m_victims = metrics.counter("scheduler_victim_picks_total")
        self._m_preempt_granted = metrics.counter(
            "scheduler_admission_preempts_total", verdict="granted")
        self._m_preempt_denied = metrics.counter(
            "scheduler_admission_preempts_total", verdict="denied")

    # -- resume / admission --------------------------------------------------
    def resume_order(self, waiting: Sequence[RequestView]) -> List[int]:
        """Indices into ``waiting`` in re-admission order. The engine
        tries each and *skips* those that don't fit, so order here is
        preference, not a barrier."""
        return sorted(range(len(waiting)),
                      key=lambda i: self._urgency(waiting[i]))

    def should_preempt(self, incoming: RequestView,
                       victim: RequestView) -> bool:
        """May ``incoming`` evict ``victim`` at admission time? Default:
        only strictly more urgent classes jump the pool — equal-priority
        traffic never churns pages preempting itself."""
        verdict = incoming.priority < victim.priority
        if self._m_victims is not None:
            (self._m_preempt_granted if verdict
             else self._m_preempt_denied).inc()
        return verdict

    # -- preemption ----------------------------------------------------------
    def victim(self, live: Sequence[RequestView]) -> int:
        """rid of the request to spill when the pool runs dry. Default:
        among the least-urgent priority class, the youngest (max rid) —
        arrival order is seniority; within a class, requests
        mid-chunked-prefill are spared while a decoded candidate exists
        (their prefill work would be pure loss)."""
        if self._m_victims is not None:
            self._m_victims.inc()
        return max(live, key=lambda r: (r.priority, not r.prefilling,
                                        self._victim_tiebreak(r), r.rid)).rid

    # -- knobs subclasses override -------------------------------------------
    def _urgency(self, r: RequestView):
        """Sort key for resume order: smaller = sooner."""
        return (r.priority, r.arrival, r.rid)

    def _victim_tiebreak(self, r: RequestView):
        """Secondary victim key within a priority class: larger = spilled
        first. The base policy defers entirely to youth (rid)."""
        return 0


class SLOScheduler(Scheduler):
    """Deadline-aware variant: within a priority class, resume runs
    earliest-deadline-first and preemption spills the request with the
    most slack (farthest deadline; no deadline = infinite slack). A
    request that would clearly miss anyway still follows the same order —
    the engine has no cost model to know, and determinism beats cleverness
    for stream-equivalence testing."""

    def _urgency(self, r: RequestView):
        d = math.inf if r.deadline is None else r.deadline
        return (r.priority, d, r.arrival, r.rid)

    def _victim_tiebreak(self, r: RequestView):
        return math.inf if r.deadline is None else r.deadline
