"""Prefix cache: copy-on-write sharing of prompt-prefix KV pages.

At production traffic shapes the same system prompt / few-shot template
heads almost every request, yet the paged engine (PR 4/5) re-prefills each
one from scratch — pure wasted HBM traffic and compute, exactly the
memory-overhead class the MatrixFlow dataflow exists to remove. This
module makes the ``PagePool``'s ref counts earn their keep:

* **Index** — a radix tree over *page-granular* token spans. Every node
  covers one full page (``page_size`` prompt tokens) and is keyed by a
  chained content hash ``h_j = hash((h_{j-1}, tokens_j))`` (a rolling
  hash over page spans, so a prefix's identity folds in everything before
  it); the node also stores its raw token span, which is compared exactly
  on every walk — a hash collision degrades to a miss, never to sharing
  the wrong KV.
* **Lookup** (:meth:`PrefixCache.lookup`) walks the tree over a prompt
  and returns the longest cached chain of full pages, each **retained**
  on behalf of the requester, plus — when the walk dies *inside* a cached
  page — the copy-on-write candidate: the first divergent page and how
  many of its leading rows match. The engine forks that page
  (``PagePool.fork`` + a device copy), so even a partially-matching page
  skips prefill for its matching rows while writes only ever touch the
  private copy.
* **Insert** (:meth:`PrefixCache.insert`) registers a finished prefill's
  full prompt pages. The cache itself retains each page — a retired
  request's prefix stays resident (a *cold* entry, refcount 1) until
  evicted.
* **Eviction** (:meth:`PrefixCache.evict`) walks leaves in LRU order and
  drops the cache's reference when the pool runs low (the engine calls it
  when ``free_pages`` falls under its watermark and on-demand before
  giving up on an admission). Evicting an entry other requests still hold
  merely makes it undiscoverable; their references keep the page alive.

At most ``len(prompt) - 1`` tokens are ever served from cache: the last
prompt token must run through the model so its logits can seed sampling.

Everything here is host-side bookkeeping over token ids and page ids; the
device only ever sees the block tables the engine assembles from it
(serving/engine.py) — which is also why tensor-parallel serving needs no
changes: one host-side cache drives every shard's identical page slice
(docs/serving.md).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs.metrics import Counter
from repro.serving.kv_pool import PagePool

__all__ = ["PrefixCache", "PrefixHit"]


class _Node:
    """One cached page span: ``tokens`` (exactly ``page_size`` ids), the
    physical ``page`` holding its K/V, and the chained content hash that
    indexes it among its parent's children."""

    __slots__ = ("tokens", "page", "chain_hash", "parent", "children",
                 "tick")

    def __init__(self, tokens: Tuple[int, ...], page: int, chain_hash: int,
                 parent: "_Node"):
        self.tokens = tokens
        self.page = page
        self.chain_hash = chain_hash
        self.parent = parent
        self.children: Dict[int, _Node] = {}
        self.tick = 0


@dataclasses.dataclass
class PrefixHit:
    """What :meth:`PrefixCache.lookup` hands the engine.

    ``pages`` are fully-matching pages, already retained for this holder
    (the engine appends them to the request's block table verbatim).
    ``cow_page``/``cow_tokens`` describe the first divergent page: its
    leading ``cow_tokens`` rows match the prompt, so the engine may fork
    it — copy the device contents into a private page — and start prefill
    at ``n_tokens + cow_tokens`` instead of ``n_tokens``. The COW source
    is retained too (eviction between lookup and copy must not free it);
    the engine releases it after the copy, or via :meth:`release` when
    admission falls through.
    """

    pages: List[int]
    n_tokens: int
    cow_page: Optional[int] = None
    cow_tokens: int = 0

    @property
    def tokens_reusable(self) -> int:
        return self.n_tokens + self.cow_tokens

    def release(self, pool: PagePool) -> None:
        """Drop the holder references lookup took (admission failed)."""
        if self.pages:
            pool.release(self.pages)
            self.pages = []
        if self.cow_page is not None:
            pool.release([self.cow_page])
            self.cow_page = None
            self.cow_tokens = 0


class PrefixCache:
    """Radix tree of cached prompt-prefix pages over one :class:`PagePool`.

    The cache holds one pool reference per indexed page; requests that hit
    add their own. LRU recency is a logical ``tick`` bumped on every
    lookup/insert touch — leaves with the stalest tick evict first (a
    parent is only evictable once its children are gone, keeping every
    cached chain walkable from the root).
    """

    def __init__(self, pool: PagePool, page_size: Optional[int] = None,
                 metrics=None):
        self.pool = pool
        self.page_size = int(page_size or pool.page_size)
        if self.page_size != pool.page_size:
            raise ValueError(
                f"prefix cache page_size={page_size} must equal the pool's "
                f"page_size={pool.page_size} (pages are shared verbatim)")
        self._root = _Node((), -1, hash(("prefix-root",)), None)
        self._tick = 0
        self.n_nodes = 0
        # Counters (surfaced by ServingEngine.stats() and, with ``metrics``
        # — a repro.obs.Metrics registry — in its snapshot()). First-class
        # Counter instruments either way; the int-valued properties below
        # keep the historical ``cache.hits == 1`` comparisons working.
        reg = metrics.counter if metrics is not None \
            else (lambda name: Counter(name))
        self._hits = reg("prefix_hits_total")
        self._misses = reg("prefix_misses_total")
        self._evictions = reg("prefix_evictions_total")
        self._cow_forks = reg("prefix_cow_forks_total")
        self._hit_tokens = reg("prefix_hit_tokens_total")
        self._lookup_tokens = reg("prefix_lookup_tokens_total")

    # -- internals ----------------------------------------------------------
    def _child_matching(self, node: _Node, span: Tuple[int, ...]
                        ) -> Optional[_Node]:
        """The child holding exactly ``span``, found via the chained hash
        and verified token-exact (collision → miss)."""
        child = node.children.get(hash((node.chain_hash, span)))
        if child is not None and child.tokens == span:
            return child
        return None

    def _touch(self, node: _Node) -> None:
        self._tick += 1
        while node is not None and node is not self._root:
            node.tick = self._tick
            node = node.parent

    # -- queries ------------------------------------------------------------
    def lookup(self, tokens: Sequence[int]) -> PrefixHit:
        """Longest cached prefix of ``tokens``, capped at ``len - 1`` (the
        final token always prefills — its logits seed the first sample).
        Full-page matches come back retained in ``pages``; a partial match
        of the next page comes back as the COW candidate."""
        tokens = [int(t) for t in tokens]
        limit = len(tokens) - 1
        ps = self.page_size
        node, m = self._root, 0
        pages: List[int] = []
        while m + ps <= limit:
            child = self._child_matching(node, tuple(tokens[m:m + ps]))
            if child is None:
                break
            pages.append(child.page)
            node = child
            m += ps
        # first divergent page: the child sharing the longest leading run
        # with what remains of the prompt (< one page) is worth forking
        cow_node, cow_len = None, 0
        rem = tokens[m:limit]
        if rem:
            for child in node.children.values():
                r = 0
                for a, b in zip(child.tokens, rem):
                    if a != b:
                        break
                    r += 1
                if r > cow_len:
                    cow_node, cow_len = child, r
        if pages:
            self.pool.retain(pages)
        if cow_node is not None:
            self.pool.retain([cow_node.page])
            self._touch(cow_node)
        elif node is not self._root:
            self._touch(node)
        return PrefixHit(pages=pages, n_tokens=m,
                         cow_page=None if cow_node is None
                         else cow_node.page,
                         cow_tokens=cow_len)

    def record(self, hit: PrefixHit, n_tokens: int) -> None:
        """Fold one *committed* admission into the hit-rate counters. The
        engine calls this once per successful admit; lookups whose admission
        falls through (pool full, preempt-retry loops) count nothing, so
        the reported rate reflects tokens actually served from cache."""
        self._lookup_tokens.inc(n_tokens)
        self._hit_tokens.inc(hit.tokens_reusable)
        if hit.tokens_reusable:
            self._hits.inc()
        else:
            self._misses.inc()

    def note_cow_fork(self) -> None:
        """Count one committed copy-on-write fork. The engine calls this
        after the fork + device copy succeed (the pool's own fork counter
        fires at allocation; this one counts prefix-cache-driven forks)."""
        self._cow_forks.inc()

    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> int:
        """Index every *full* page of ``tokens`` (``pages[j]`` backing
        span ``[j*ps, (j+1)*ps)`` — the head of a request's block table
        after its prompt prefill completes). Spans already cached keep
        their existing page; new spans retain theirs on behalf of the
        cache. Returns the number of newly indexed pages."""
        tokens = [int(t) for t in tokens]
        ps = self.page_size
        node, added = self._root, 0
        for j in range(len(tokens) // ps):
            span = tuple(tokens[j * ps:(j + 1) * ps])
            child = self._child_matching(node, span)
            if child is None:
                key = hash((node.chain_hash, span))
                if key in node.children:
                    # hash collision with a different span: leave the
                    # incumbent indexed; deeper spans of this prompt would
                    # dangle off an unshareable chain, so stop here
                    break
                child = _Node(span, int(pages[j]), key, node)
                node.children[key] = child
                self.pool.retain([child.page])
                self.n_nodes += 1
                added += 1
            node = child
        if node is not self._root:
            self._touch(node)
        return added

    # -- eviction -----------------------------------------------------------
    def _leaves(self) -> List[_Node]:
        out, stack = [], list(self._root.children.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            else:
                out.append(n)
        return out

    def reclaimable(self) -> int:
        """Pages eviction could return to the free list *right now*: cached
        pages no live request holds (refcount exactly 1 — the cache's)."""
        stack = list(self._root.children.values())
        n = 0
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            if self.pool.refcount[node.page] == 1:
                n += 1
        return n

    def evict(self, n_pages: int) -> int:
        """Drop cache references, coldest leaves first, until ``n_pages``
        have actually been freed (refcount hit 0) or nothing evictable
        remains. Returns the number of pages freed to the pool. Entries
        whose pages live requests still hold are uncached too when their
        turn comes — they stop being discoverable but free nothing yet."""
        freed = 0
        while freed < n_pages:
            leaves = self._leaves()
            if not leaves:
                break
            # coldest first; among equals prefer deeper nodes (suffix pages
            # are less shareable than the system-prompt head)
            victim = min(leaves, key=lambda n: (n.tick, -self._depth(n)))
            freed += self._drop(victim)
        return freed

    def _depth(self, node: _Node) -> int:
        d = 0
        while node.parent is not None:
            d += 1
            node = node.parent
        return d

    def _drop(self, node: _Node) -> int:
        """Unlink one leaf and release the cache's reference; returns 1 if
        the page actually went free (no other holders)."""
        assert not node.children, "evict only detaches leaves"
        del node.parent.children[node.chain_hash]
        self.n_nodes -= 1
        self._evictions.inc()
        was_last = self.pool.refcount[node.page] == 1
        self.pool.release([node.page])
        return int(was_last)

    def clear(self) -> int:
        """Release every cached page (engine reset, e.g. batched
        generate() taking over the whole pool). Returns pages freed."""
        freed = 0
        while self._root.children:
            freed += self.evict(self.n_nodes)
        return freed

    # -- stats / invariants -------------------------------------------------
    @property
    def hits(self) -> int:
        """Committed admissions reusing >= 1 cached token."""
        return self._hits.value

    @property
    def misses(self) -> int:
        return self._misses.value

    @property
    def evictions(self) -> int:
        return self._evictions.value

    @property
    def cow_forks(self) -> int:
        return self._cow_forks.value

    @property
    def hit_tokens(self) -> int:
        """Tokens served from cache across committed admissions."""
        return self._hit_tokens.value

    @property
    def lookup_tokens(self) -> int:
        """Tokens presented across committed admissions."""
        return self._lookup_tokens.value

    @property
    def cached_pages(self) -> int:
        return self.n_nodes

    def hit_rate(self) -> float:
        """Fraction of looked-up tokens served from cache."""
        return self.hit_tokens / self.lookup_tokens if self.lookup_tokens \
            else 0.0

    def stats(self) -> Dict[str, float]:
        return {
            "prefix_hits": self.hits, "prefix_misses": self.misses,
            "prefix_evictions": self.evictions,
            "prefix_cow_forks": self.cow_forks,
            "prefix_cached_pages": self.n_nodes,
            "prefix_hit_tokens": self.hit_tokens,
            "prefix_lookup_tokens": self.lookup_tokens,
            "prefix_hit_rate": round(self.hit_rate(), 4),
        }

    def check(self) -> None:
        """Structural invariants (tests): every node's page is allocated,
        chain hashes match their recomputation, node count agrees."""
        n, stack = 0, [(self._root, self._root.chain_hash)]
        while stack:
            node, h = stack.pop()
            for child in node.children.values():
                assert child.parent is node
                assert len(child.tokens) == self.page_size
                assert child.chain_hash == hash((h, child.tokens))
                assert self.pool.refcount[child.page] >= 1, \
                    f"cached page {child.page} not allocated"
                n += 1
                stack.append((child, child.chain_hash))
        assert n == self.n_nodes, (n, self.n_nodes)
