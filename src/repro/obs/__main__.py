"""Validator CLI for emitted observability artifacts.

    PYTHONPATH=src python -m repro.obs --trace trace.json \
        --metrics metrics.json

Checks a Chrome/Perfetto trace file (schema, async b/e balance, X-span
nesting — repro.obs.tracing.validate_trace) and/or a metrics snapshot
(section shapes, histogram invariants, JSON round-trip —
repro.obs.metrics.validate_metrics_snapshot). Exit 0 iff every checked
file is clean; the CI ``observability`` job runs this over the serve
demo's artifacts.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List

from repro.obs import validate_metrics_snapshot, validate_trace


def main(argv: List[str] | None = None) -> int:
    p = argparse.ArgumentParser(
        prog="repro.obs", description="validate trace/metrics artifacts")
    p.add_argument("--trace", action="append", default=[],
                   help="Chrome/Perfetto trace JSON to validate "
                        "(repeatable)")
    p.add_argument("--metrics", action="append", default=[],
                   help="metrics snapshot JSON to validate (repeatable)")
    args = p.parse_args(argv)
    if not args.trace and not args.metrics:
        p.error("nothing to do: pass --trace and/or --metrics")

    failures = 0
    for path in args.trace:
        with open(path) as f:
            trace = json.load(f)
        problems = validate_trace(trace)
        n = len(trace.get("traceEvents", []))
        if problems:
            failures += 1
            print(f"[obs] TRACE {path}: {len(problems)} problem(s) "
                  f"in {n} events")
            for msg in problems:
                print(f"  - {msg}")
        else:
            print(f"[obs] trace ok: {path} ({n} events)")
    for path in args.metrics:
        with open(path) as f:
            snap = json.load(f)
        problems = validate_metrics_snapshot(snap)
        if problems:
            failures += 1
            print(f"[obs] METRICS {path}: {len(problems)} problem(s)")
            for msg in problems:
                print(f"  - {msg}")
        else:
            n = sum(len(snap.get(k, {}))
                    for k in ("counters", "gauges", "histograms"))
            print(f"[obs] metrics ok: {path} ({n} series)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
