"""Metrics registry: counters, gauges, fixed-bucket histograms, timers.

The serving hot loop (one ``ServingEngine.step()`` per generated token
per live batch) cannot afford a metrics layer that hashes label dicts or
allocates per observation. The design here is the classic two-phase
split: **registration** (``Metrics.counter(...)``) happens once, at
engine construction, and may be as slow as it likes; the returned
*instrument* is then a tiny ``__slots__`` object whose hot method is one
attribute add (``Counter.inc``), one store (``Gauge.set``), or one bisect
plus two adds (``Histogram.observe``). Call sites hold direct instrument
references — the registry is never consulted per token.

Everything is host-side and stdlib-only (no jax import): instrumentation
must live strictly outside the jitted prefill/decode closures
(docs/observability.md), and this module makes that structurally easy —
there is nothing here a trace could capture.

``Histogram`` uses *fixed* buckets so that histograms from different
sources (per-request inter-token latencies, per-engine step times,
shards of a sweep) **merge associatively**: ``merge`` adds counts
bucket-by-bucket, so ``(a ⊕ b) ⊕ c == a ⊕ (b ⊕ c)`` exactly — the
property tests/test_obs.py pins. Quantiles from a histogram are
estimates (linear interpolation inside the winning bucket); exact
percentiles over raw samples use :func:`quantile`.

``Timer`` replaces the hand-rolled ``t0 = time.perf_counter() … dt``
pairs in launch/serve.py, launch/train.py and train/loop.py::

    with Timer() as tm:
        out = engine.generate(prompts, n)
    print(f"done in {tm.dt:.2f}s")

Optionally it feeds a histogram on exit (``timed(hist)``).
"""
from __future__ import annotations

import bisect
import json
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "Metrics", "NULL_METRICS", "Timer",
    "timed", "quantile", "TIME_BUCKETS_S", "json_scalars",
    "validate_metrics_snapshot", "merge_histograms",
]

# Default latency buckets (seconds): 100 µs … 10 s, roughly 1-2.5-5 per
# decade — wide enough for interpret-mode CPU runs and compiled TPU steps
# to land in informative buckets of the SAME edges (merge-compatible).
TIME_BUCKETS_S: Tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)


def _label_key(labels: Dict[str, object]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _render(name: str, labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonic counter. Hot method: :meth:`inc` (one int add)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = ()):
        self.name = name
        self.labels = labels
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def __repr__(self) -> str:
        return f"Counter({_render(self.name, self.labels)}={self.value})"


class Gauge:
    """Last-value gauge with a max-tracking helper for high-water marks."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = ()):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def set_max(self, v: float) -> None:
        if v > self.value:
            self.value = v

    def __repr__(self) -> str:
        return f"Gauge({_render(self.name, self.labels)}={self.value})"


class Histogram:
    """Fixed-bucket histogram: ``buckets`` are ascending upper bounds, an
    implicit +inf bucket catches the overflow. ``counts`` has
    ``len(buckets) + 1`` cells. Merging is element-wise addition —
    associative and commutative by construction (same bucket edges
    required; anything else raises)."""

    __slots__ = ("name", "labels", "buckets", "counts", "total", "count")

    def __init__(self, name: str,
                 buckets: Sequence[float] = TIME_BUCKETS_S,
                 labels: Tuple[Tuple[str, str], ...] = ()):
        b = tuple(float(x) for x in buckets)
        if not b or any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError(
                f"histogram buckets must be non-empty strictly ascending "
                f"upper bounds, got {b}")
        self.name = name
        self.labels = labels
        self.buckets = b
        self.counts: List[int] = [0] * (len(b) + 1)
        self.total = 0.0          # sum of observations
        self.count = 0

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.total += v
        self.count += 1

    def merge(self, other: "Histogram") -> "Histogram":
        """Return a NEW histogram holding ``self ⊕ other``; operands are
        untouched, so merging is safe mid-collection."""
        if self.buckets != other.buckets:
            raise ValueError(
                f"cannot merge histograms with different buckets: "
                f"{self.buckets} vs {other.buckets}")
        out = Histogram(self.name, self.buckets, self.labels)
        out.counts = [a + b for a, b in zip(self.counts, other.counts)]
        out.total = self.total + other.total
        out.count = self.count + other.count
        return out

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Estimated q-quantile (0..1): linear interpolation inside the
        winning bucket; the overflow bucket reports its lower edge."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum = 0.0
        for i, c in enumerate(self.counts):
            if cum + c >= target and c > 0:
                lo = self.buckets[i - 1] if i > 0 else 0.0
                if i >= len(self.buckets):
                    return lo              # overflow bucket: unbounded above
                hi = self.buckets[i]
                frac = (target - cum) / c
                return lo + frac * (hi - lo)
            cum += c
        return self.buckets[-1]

    def snapshot(self) -> Dict[str, object]:
        return {"buckets": list(self.buckets), "counts": list(self.counts),
                "sum": self.total, "count": self.count}

    def __repr__(self) -> str:
        return (f"Histogram({_render(self.name, self.labels)} "
                f"count={self.count} mean={self.mean():.6g})")


class Metrics:
    """Instrument registry. ``counter``/``gauge``/``histogram`` memoize by
    (kind, name, labels): asking twice returns the same instrument, so
    components can bind by name without coordinating instances. A name
    registered as one kind cannot be re-registered as another."""

    def __init__(self) -> None:
        self._instruments: Dict[Tuple[str, str, Tuple], object] = {}
        self._kinds: Dict[str, str] = {}

    def _get(self, kind: str, name: str, labels: Dict[str, object],
             factory):
        prior = self._kinds.setdefault(name, kind)
        if prior != kind:
            raise ValueError(
                f"metric {name!r} already registered as a {prior}, "
                f"cannot re-register as a {kind}")
        key = (kind, name, _label_key(labels))
        inst = self._instruments.get(key)
        if inst is None:
            inst = factory(name, key[2])
            self._instruments[key] = inst
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str,
                  buckets: Sequence[float] = TIME_BUCKETS_S,
                  **labels) -> Histogram:
        return self._get("histogram", name, labels,
                         lambda n, ls: Histogram(n, buckets, ls))

    def snapshot(self) -> Dict[str, object]:
        """One plain-JSON dict of every instrument's current state:
        ``{"counters": {...}, "gauges": {...}, "histograms": {...}}``.
        Keys are ``name`` or ``name{k=v,...}`` when labeled."""
        out: Dict[str, Dict[str, object]] = {
            "counters": {}, "gauges": {}, "histograms": {}}
        for (kind, name, labels), inst in sorted(
                self._instruments.items(), key=lambda kv: kv[0][:2]):
            key = _render(name, labels)
            if kind == "counter":
                out["counters"][key] = inst.value
            elif kind == "gauge":
                out["gauges"][key] = json_scalars({"v": inst.value})["v"]
            else:
                out["histograms"][key] = inst.snapshot()
        return out


class _NullInstrument:
    """Shared no-op instrument: the disabled path's counter, gauge AND
    histogram. Every method is a no-op; ``value`` stays 0."""

    __slots__ = ()
    value = 0
    count = 0
    total = 0.0

    def inc(self, n: int = 1) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def set_max(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass


_NULL_INSTRUMENT = _NullInstrument()


class _NullMetrics:
    """Registry stand-in for disabled observability: every registration
    returns the one shared no-op instrument — nothing is ever recorded
    and nothing per-call is allocated."""

    def counter(self, name: str, **labels) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def gauge(self, name: str, **labels) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def histogram(self, name: str, buckets=TIME_BUCKETS_S,
                  **labels) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def snapshot(self) -> Dict[str, object]:
        return {"counters": {}, "gauges": {}, "histograms": {}}


NULL_METRICS = _NullMetrics()


class Timer:
    """Context-manager stopwatch over ``time.perf_counter``.

    ``tm.dt`` is the elapsed seconds — final once the block exits, running
    while still inside it (so progress prints mid-block work too).
    ``tm.ms`` is the same in milliseconds. With ``histogram`` the duration
    is observed on exit (the ``timed(hist)`` spelling)."""

    __slots__ = ("_t0", "_dt", "_hist")

    def __init__(self, histogram: Optional[Histogram] = None):
        self._t0 = 0.0
        self._dt: Optional[float] = None
        self._hist = histogram

    def __enter__(self) -> "Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self._dt = time.perf_counter() - self._t0
        if self._hist is not None:
            self._hist.observe(self._dt)

    @property
    def dt(self) -> float:
        return (time.perf_counter() - self._t0 if self._dt is None
                else self._dt)

    @property
    def ms(self) -> float:
        return self.dt * 1e3


def timed(histogram: Optional[Histogram]) -> Timer:
    """``with timed(hist): ...`` — a Timer that records into ``hist``."""
    return Timer(histogram)


def quantile(values: Sequence[float], q: float) -> float:
    """Exact q-quantile (0..1) of raw samples, linear interpolation
    between order statistics (numpy's default method, stdlib-only so the
    frontend needs no numpy). Empty input returns 0.0."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile {q} outside [0, 1]")
    xs = sorted(values)
    if not xs:
        return 0.0
    if len(xs) == 1:
        return float(xs[0])
    pos = q * (len(xs) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    frac = pos - lo
    return float(xs[lo] + frac * (xs[hi] - xs[lo]))


def json_scalars(d: Dict[str, object]) -> Dict[str, object]:
    """Coerce a flat dict's values to plain JSON types: numpy scalars
    (``np.int64`` from ``.sum()``, ``np.float32`` means, ``np.bool_``)
    become native int/float/bool via their ``item()``. Used by
    ``ServingEngine.stats()`` so the dict the benchmarks JSONL-serialize
    round-trips through ``json.dumps`` unchanged (tests/test_obs.py)."""
    out: Dict[str, object] = {}
    for k, v in d.items():
        item = getattr(v, "item", None)
        if item is not None and not isinstance(v, (int, float, bool, str)):
            v = item()
        out[k] = v
    return out


def validate_metrics_snapshot(snap: object) -> List[str]:
    """Schema check for :meth:`Metrics.snapshot` output (the CI
    observability job runs this over the file launch/serve.py writes).
    Returns a list of problems — empty means valid."""
    problems: List[str] = []
    if not isinstance(snap, dict):
        return [f"snapshot is {type(snap).__name__}, expected dict"]
    for section in ("counters", "gauges", "histograms"):
        if section not in snap:
            problems.append(f"missing section {section!r}")
    for key, v in snap.get("counters", {}).items():
        if not isinstance(v, int) or isinstance(v, bool):
            problems.append(f"counter {key!r} value {v!r} is not an int")
    for key, v in snap.get("gauges", {}).items():
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            problems.append(f"gauge {key!r} value {v!r} is not numeric")
    for key, h in snap.get("histograms", {}).items():
        if not isinstance(h, dict):
            problems.append(f"histogram {key!r} is not a dict")
            continue
        buckets = h.get("buckets")
        counts = h.get("counts")
        if (not isinstance(buckets, list) or not isinstance(counts, list)
                or len(counts) != len(buckets) + 1):
            problems.append(
                f"histogram {key!r} needs len(counts) == len(buckets)+1")
            continue
        if any(buckets[i] >= buckets[i + 1]
               for i in range(len(buckets) - 1)):
            problems.append(f"histogram {key!r} buckets not ascending")
        if sum(counts) != h.get("count"):
            problems.append(
                f"histogram {key!r} count {h.get('count')} != "
                f"sum(counts) {sum(counts)}")
    try:
        json.dumps(snap)
    except (TypeError, ValueError) as e:
        problems.append(f"snapshot does not json-serialize: {e}")
    return problems


def merge_histograms(hists: Iterable[Histogram]) -> Optional[Histogram]:
    """Fold any number of same-bucket histograms into one (associative —
    any grouping yields identical counts). None for an empty iterable."""
    out: Optional[Histogram] = None
    for h in hists:
        out = h if out is None else out.merge(h)
    return out
