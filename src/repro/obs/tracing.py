"""Span/event recorder with a ring buffer and a Perfetto trace exporter.

The recorder collects host-side timing events from the serving engine —
**never** from inside a jitted closure (repro.analysis.trace_lint proves
the traced prefill/decode programs stay callback-free) — into a bounded
ring buffer (``collections.deque(maxlen=...)``: a long-running server
keeps the most recent window, oldest events drop first, ``dropped``
counts them).

Two families of events (docs/observability.md#span-taxonomy):

* **Phase tracks** — one named track per engine phase (``admit``,
  ``prefill-chunk``, ``decode-step``, ``preempt``, ``resume``,
  ``evict``): complete spans (Chrome ``ph: "X"``) recorded by the engine
  around each phase's host+device work.
* **Request tracks** — one async track per request id (Chrome
  ``ph: "b"/"n"/"e"`` with ``cat: "request"``), spanning submit →
  first-token → retire with instants for prefix hits, preemptions and
  resumes. Perfetto groups them by id under the engine process.

:meth:`TraceRecorder.export` renders the ring into the Chrome trace
JSON object format (``{"traceEvents": [...]}``) that Perfetto
(https://ui.perfetto.dev) and ``chrome://tracing`` load directly.
Timestamps are microseconds from the recorder's construction. Async
spans still open at export time are closed with a synthetic ``"e"``
carrying ``args.truncated: true`` — the exported file is always
balanced (``validate_trace`` checks it, along with X-span nesting).

:class:`RequestTrace` is the per-request lifecycle record the engine
builds alongside the trace events and serves via
``ServingEngine.request_trace(handle)``: queue wait, prefill chunks,
TTFT, per-token inter-arrival histogram + raw timestamps, preemption
count, prefix-cache hit span, and pages held over time.
:func:`aggregate_request_traces` folds many of them into the SLO
percentile summary (p50/p95/p99 TTFT and ITL).

Everything is stdlib-only; the disabled path is :data:`NULL_RECORDER`,
whose methods are no-ops and which never allocates per call.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import time
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.obs.metrics import Histogram, TIME_BUCKETS_S, quantile

__all__ = [
    "TraceRecorder", "NullRecorder", "NULL_RECORDER", "PHASE_TRACKS",
    "RequestTrace", "aggregate_request_traces", "validate_trace",
]

# The engine's phase tracks, in display order (exporter assigns tids and
# thread_sort_index in this order; unknown tracks append after).
PHASE_TRACKS: Tuple[str, ...] = (
    "admit", "prefill-chunk", "decode-step", "preempt", "resume", "evict",
    "draft", "verify",   # speculative decoding (serving/spec_decode.py)
)

_ENGINE_PID = 1


class TraceRecorder:
    """Bounded host-side event recorder (see module docstring).

    Events live as tuples ``(ph, track, name, ts_us, dur_us, rid, args)``
    in a deque ring — appending is O(1) and allocation-light; rendering
    to Chrome JSON happens only at :meth:`export`.
    """

    enabled = True

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.epoch = time.perf_counter()
        self._events: Deque[Tuple] = collections.deque(maxlen=self.capacity)
        self.dropped = 0

    # -- recording ----------------------------------------------------------
    def _push(self, ev: Tuple) -> None:
        if len(self._events) == self.capacity:
            self.dropped += 1
        self._events.append(ev)

    def _us(self, t: float) -> float:
        return (t - self.epoch) * 1e6

    def complete(self, track: str, name: str, t0: float,
                 t1: Optional[float] = None,
                 args: Optional[Dict] = None) -> None:
        """One complete span on a phase track: began at perf_counter time
        ``t0``, ended at ``t1`` (now when omitted)."""
        if t1 is None:
            t1 = time.perf_counter()
        self._push(("X", track, name, self._us(t0),
                    max(self._us(t1) - self._us(t0), 0.0), None, args))

    def instant(self, track: str, name: str,
                args: Optional[Dict] = None) -> None:
        self._push(("i", track, name, self._us(time.perf_counter()),
                    0.0, None, args))

    def async_begin(self, rid: int, args: Optional[Dict] = None) -> None:
        """Open request ``rid``'s async span (at submit/admission)."""
        self._push(("b", None, f"req {rid}",
                    self._us(time.perf_counter()), 0.0, rid, args))

    def async_instant(self, rid: int, name: str,
                      args: Optional[Dict] = None) -> None:
        """A point event on request ``rid``'s async track (first-token,
        preempt, resume, prefix-hit)."""
        self._push(("n", None, name, self._us(time.perf_counter()),
                    0.0, rid, args))

    def async_end(self, rid: int, args: Optional[Dict] = None) -> None:
        """Close request ``rid``'s async span (retire/cancel)."""
        self._push(("e", None, f"req {rid}",
                    self._us(time.perf_counter()), 0.0, rid, args))

    # -- introspection / export --------------------------------------------
    def __len__(self) -> int:
        return len(self._events)

    def clear(self) -> None:
        self._events.clear()
        self.dropped = 0

    def export(self) -> Dict[str, object]:
        """Render the ring into a Perfetto-loadable Chrome trace dict."""
        tids: Dict[str, int] = {t: i + 1 for i, t in enumerate(PHASE_TRACKS)}
        events: List[Dict[str, object]] = [{
            "ph": "M", "pid": _ENGINE_PID, "tid": 0, "ts": 0,
            "name": "process_name",
            "args": {"name": "serving-engine"},
        }]
        body: List[Dict[str, object]] = []
        open_async: Dict[Tuple[str, str], List[float]] = {}
        last_ts = 0.0
        for ph, track, name, ts, dur, rid, args in self._events:
            last_ts = max(last_ts, ts + dur)
            ev: Dict[str, object] = {
                "ph": ph, "pid": _ENGINE_PID, "name": name, "ts": ts,
            }
            if args:
                ev["args"] = args
            if ph in ("X", "i"):
                tid = tids.setdefault(track, len(tids) + 1)
                ev["tid"] = tid
                ev["cat"] = "engine"
                if ph == "X":
                    ev["dur"] = dur
                else:
                    ev["s"] = "t"          # instant scope: thread
            else:                          # async b/n/e
                ev["tid"] = 0
                ev["cat"] = "request"
                ev["id"] = str(rid)
                key = (str(rid), f"req {rid}")
                if ph == "b":
                    open_async.setdefault(key, []).append(ts)
                elif ph == "e":
                    stack = open_async.get(key)
                    if stack:
                        stack.pop()
            body.append(ev)
        # synthesize ends for spans still open (engine stopped mid-flight
        # or the caller exported a live trace): the file stays balanced
        for (rid, name), stack in sorted(open_async.items()):
            for _ in stack:
                body.append({
                    "ph": "e", "pid": _ENGINE_PID, "tid": 0, "name": name,
                    "cat": "request", "id": rid, "ts": last_ts,
                    "args": {"truncated": True},
                })
        for track, tid in sorted(tids.items(), key=lambda kv: kv[1]):
            events.append({
                "ph": "M", "pid": _ENGINE_PID, "tid": tid, "ts": 0,
                "name": "thread_name", "args": {"name": track},
            })
            events.append({
                "ph": "M", "pid": _ENGINE_PID, "tid": tid, "ts": 0,
                "name": "thread_sort_index", "args": {"sort_index": tid},
            })
        events.extend(body)
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {
                "recorder": "repro.obs.tracing",
                "dropped_events": self.dropped,
            },
        }

    def write(self, path: str) -> int:
        """Write the Chrome trace JSON to ``path``; returns the number of
        trace events written."""
        trace = self.export()
        with open(path, "w") as f:
            json.dump(trace, f)
        return len(trace["traceEvents"])


class NullRecorder:
    """The disabled recorder: every method is a no-op, nothing is ever
    stored, nothing per call is allocated. Shared as NULL_RECORDER."""

    enabled = False
    dropped = 0
    capacity = 0

    def complete(self, track, name, t0, t1=None, args=None) -> None:
        pass

    def instant(self, track, name, args=None) -> None:
        pass

    def async_begin(self, rid, args=None) -> None:
        pass

    def async_instant(self, rid, name, args=None) -> None:
        pass

    def async_end(self, rid, args=None) -> None:
        pass

    def __len__(self) -> int:
        return 0

    def clear(self) -> None:
        pass

    def export(self) -> Dict[str, object]:
        return {"traceEvents": [], "displayTimeUnit": "ms"}


NULL_RECORDER = NullRecorder()


@dataclasses.dataclass
class RequestTrace:
    """Per-request lifecycle record (engine-side wall clock, seconds on
    ``time.perf_counter``). Built only when observability is enabled;
    ``ServingEngine.request_trace(handle)`` serves it, persisting past
    retirement so a finished stream's record stays readable.

    Token-exactness contract (tests/test_obs.py): ``tokens`` is exactly
    the stream the engine reported for this request — a preempted/resumed
    request's trace differs from an uninterrupted run's only in
    ``n_preemptions``/``wait_s``/``prefill_chunks`` (the preemption
    span), never in the tokens themselves.
    """

    rid: int
    prompt_len: int
    priority: int = 0
    deadline: Optional[float] = None
    submit_s: float = 0.0                # perf_counter at submit
    first_token_s: Optional[float] = None
    retire_s: Optional[float] = None
    queue_wait_s: float = 0.0            # pre-admission (frontend) wait
    wait_s: float = 0.0                  # parked preempted, total
    n_preemptions: int = 0
    prefix_hit_tokens: int = 0           # tokens served from the prefix
    #                                      cache at first admission
    tokens: List[int] = dataclasses.field(default_factory=list)
    token_s: List[float] = dataclasses.field(default_factory=list)
    prefill_chunks: List[Dict[str, float]] = dataclasses.field(
        default_factory=list)            # {start_pos, tokens, dt_s}
    pages_timeline: List[Tuple[int, int]] = dataclasses.field(
        default_factory=list)            # (engine tick, pages held)
    itl: Histogram = dataclasses.field(
        default_factory=lambda: Histogram("request_itl_s", TIME_BUCKETS_S))
    deadline_missed: Optional[bool] = None
    # transient: set while parked in the wait queue (preempt → resume)
    preempted_at_s: Optional[float] = None

    @property
    def n_tokens(self) -> int:
        return len(self.tokens)

    def ttft_s(self) -> Optional[float]:
        """Submit → first token, queue wait included (None pre-token)."""
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.submit_s

    def itl_list(self) -> List[float]:
        """Raw inter-token gaps (exact; ``itl`` holds the same data
        bucketed for cheap merging)."""
        return [b - a for a, b in zip(self.token_s, self.token_s[1:])]

    def to_json(self) -> Dict[str, object]:
        """Plain-JSON rendering (json.dumps-safe)."""
        ttft = self.ttft_s()
        return {
            "rid": self.rid,
            "prompt_len": self.prompt_len,
            "priority": self.priority,
            "deadline": self.deadline,
            "submit_s": self.submit_s,
            "first_token_s": self.first_token_s,
            "retire_s": self.retire_s,
            "ttft_s": ttft,
            "queue_wait_s": self.queue_wait_s,
            "wait_s": self.wait_s,
            "n_preemptions": self.n_preemptions,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "n_tokens": self.n_tokens,
            "tokens": list(self.tokens),
            "prefill_chunks": list(self.prefill_chunks),
            "pages_timeline": [[int(t), int(p)]
                               for t, p in self.pages_timeline],
            "itl": self.itl.snapshot(),
            "deadline_missed": self.deadline_missed,
        }


def aggregate_request_traces(traces: Sequence[RequestTrace]
                             ) -> Dict[str, object]:
    """SLO summary over finished (or at least first-tokened) traces:
    exact p50/p95/p99 TTFT and ITL from the raw per-trace samples, plus
    preemption/deadline accounting. All values plain JSON."""
    ttfts = [t.ttft_s() for t in traces if t.first_token_s is not None]
    itls = [g for t in traces for g in t.itl_list()]

    def pcts(xs: List[float]) -> Dict[str, Optional[float]]:
        if not xs:
            return {"p50": None, "p95": None, "p99": None}
        return {"p50": round(quantile(xs, 0.50), 6),
                "p95": round(quantile(xs, 0.95), 6),
                "p99": round(quantile(xs, 0.99), 6)}

    return {
        "n_requests": len(traces),
        "n_first_tokens": len(ttfts),
        "total_tokens": sum(t.n_tokens for t in traces),
        "ttft_s": pcts(ttfts),
        "itl_s": pcts(itls),
        "preemptions": sum(t.n_preemptions for t in traces),
        "deadline_misses": sum(1 for t in traces if t.deadline_missed),
    }


def validate_trace(trace: object) -> List[str]:
    """Schema + structure check for an exported Chrome trace dict:
    required keys per phase type, b/e balance per async (cat, id), and
    proper nesting of X spans within each (pid, tid). Returns a list of
    problems; empty means Perfetto-loadable (tests/test_obs.py and the
    CI observability job both gate on it)."""
    problems: List[str] = []
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        return ["trace must be a dict with a 'traceEvents' list"]
    events = trace["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' is not a list"]
    async_depth: Dict[Tuple[str, str], int] = {}
    by_thread: Dict[Tuple[object, object], List[Tuple[float, float]]] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i} is not a dict")
            continue
        ph = ev.get("ph")
        name = ev.get("name")
        ts = ev.get("ts")
        if ph is None or name is None:
            problems.append(f"event {i} missing ph/name: {ev}")
            continue
        if ph != "M" and not isinstance(ts, (int, float)):
            problems.append(f"event {i} ({name!r}) has non-numeric ts")
            continue
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i} ({name!r}) X needs dur >= 0")
                continue
            by_thread.setdefault((ev.get("pid"), ev.get("tid")),
                                 []).append((float(ts), float(dur)))
        elif ph in ("b", "n", "e"):
            if "id" not in ev or "cat" not in ev:
                problems.append(f"event {i} ({name!r}) async needs id+cat")
                continue
            key = (str(ev["cat"]), str(ev["id"]))
            if ph == "b":
                async_depth[key] = async_depth.get(key, 0) + 1
            elif ph == "e":
                depth = async_depth.get(key, 0)
                if depth <= 0:
                    problems.append(
                        f"event {i}: async end for {key} without a begin")
                else:
                    async_depth[key] = depth - 1
    for key, depth in sorted(async_depth.items()):
        if depth != 0:
            problems.append(f"async span {key} left open ({depth} begins "
                            f"unmatched)")
    # X spans on one thread must nest: sorted by start (ties: longer
    # first), each span lies fully inside or fully outside the previous
    for tkey, spans in sorted(by_thread.items(), key=lambda kv: str(kv[0])):
        spans.sort(key=lambda sd: (sd[0], -sd[1]))
        stack: List[float] = []
        for ts, dur in spans:
            while stack and ts >= stack[-1]:
                stack.pop()
            if stack and ts + dur > stack[-1]:
                problems.append(
                    f"thread {tkey}: span [{ts}, {ts + dur}] partially "
                    f"overlaps its enclosing span (ends {stack[-1]})")
            stack.append(ts + dur)
    try:
        json.dumps(trace)
    except (TypeError, ValueError) as e:
        problems.append(f"trace does not json-serialize: {e}")
    return problems
