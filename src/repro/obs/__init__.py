"""Serving/training observability: metrics registry, span tracing,
per-request lifecycle records, Perfetto export (docs/observability.md).

The single object the rest of the stack threads around is
:class:`Observability` — a facade bundling a :class:`~repro.obs.metrics.Metrics`
registry and a :class:`~repro.obs.tracing.TraceRecorder`:

    from repro.obs import Observability
    obs = Observability()                       # enabled
    eng = ServingEngine(cfg, params, ServeConfig(..., obs=obs))
    ...
    obs.trace.write("trace.json")               # open in ui.perfetto.dev
    print(json.dumps(obs.metrics.snapshot()))

The default everywhere is :data:`NULL_OBS` — ``enabled=False``, null
metrics, null recorder. Every per-token call site in the engine is
guarded by ``if obs.enabled:`` so the disabled path costs one attribute
load + branch and allocates nothing (tests/test_obs.py pins this).
"""
from __future__ import annotations

from repro.obs.metrics import (
    NULL_METRICS,
    TIME_BUCKETS_S,
    Counter,
    Gauge,
    Histogram,
    Metrics,
    Timer,
    json_scalars,
    merge_histograms,
    quantile,
    timed,
    validate_metrics_snapshot,
)
from repro.obs.tracing import (
    NULL_RECORDER,
    PHASE_TRACKS,
    NullRecorder,
    RequestTrace,
    TraceRecorder,
    aggregate_request_traces,
    validate_trace,
)

__all__ = [
    "Observability", "NULL_OBS",
    # metrics
    "Metrics", "NULL_METRICS", "Counter", "Gauge", "Histogram",
    "Timer", "timed", "quantile", "json_scalars", "merge_histograms",
    "validate_metrics_snapshot", "TIME_BUCKETS_S",
    # tracing
    "TraceRecorder", "NullRecorder", "NULL_RECORDER", "PHASE_TRACKS",
    "RequestTrace", "aggregate_request_traces", "validate_trace",
]


class Observability:
    """Bundle of one metrics registry + one trace recorder.

    ``Observability()`` is live; ``Observability(enabled=False)`` (or the
    shared :data:`NULL_OBS`) swaps both members for their null twins, so
    holders never branch on construction — only hot paths check
    ``obs.enabled`` to skip building args dicts.
    """

    __slots__ = ("enabled", "metrics", "trace")

    def __init__(self, enabled: bool = True, trace_capacity: int = 65536):
        self.enabled = bool(enabled)
        if self.enabled:
            self.metrics = Metrics()
            self.trace = TraceRecorder(capacity=trace_capacity)
        else:
            self.metrics = NULL_METRICS
            self.trace = NULL_RECORDER

    def __repr__(self) -> str:
        return (f"Observability(enabled={self.enabled}, "
                f"trace_events={len(self.trace)})")


NULL_OBS = Observability(enabled=False)
