"""Transformer layer zoo: norms, RoPE, GQA/MQA/MLA attention, MLP, MoE.

Every projection GEMM routes through repro.core.api under the active
GemmPolicy; projection weights may be PackedWeights (resident block-major,
packed once at model build — api.pack_model_weights), realizing the paper's
Fig. 5 reuse. Attention routes through api.attention under the active
AttentionPolicy: the fused offset-aware flash kernel (score tile stays in
VMEM — the beyond-paper fusion), or the unfused baseline mirroring the
paper's split where the accelerator takes all GEMMs and the host keeps
softmax/norm/transpose (§4.4). See docs/attention.md.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import api
from repro.distributed import tp as TP
from repro.distributed.sharding import shard
from repro.models.config import ModelConfig
from repro.models.module import ax, dense_init, fold, norm_init

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def apply_norm(cfg: ModelConfig, p, x):
    return rmsnorm(p, x) if cfg.norm == "rmsnorm" else layernorm(p, x)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D) with even D; positions: (B, S)."""
    d = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention core (shared by GQA and MLA): api.attention under the active
# AttentionPolicy — fused flash kernel or the unfused einsum baseline.
# _attn_core is kept as a thin alias for downstream callers.
# ---------------------------------------------------------------------------

def _attn_core(q, k, v, *, q_positions, kv_valid_len, causal, scale,
               soft_cap: Optional[float] = None, block_tables=None,
               kv_scales=None):
    """q: (B,Sq,H,Dk); k: (B,T,Hkv,Dk); v: (B,T,Hkv,Dv); GQA via Hkv | H.

    q_positions: (B,Sq) absolute positions of the queries (−1 → masked row).
    kv_valid_len: number of populated cache slots (T for pure prefill).
    block_tables: (B, n_blocks) — paged caches only, where k/v are page
    pools (P, page_size, Hkv, D); see docs/serving.md.
    kv_scales: ((P, Hkv), (P, Hkv)) fp32 — int8 paged pools only, the
    per-page-per-head dequant scales (docs/quant.md#kv-pages).

    Routed through repro.distributed.tp: under an active TP context the
    heads shard over the model mesh axis (shard_map'd, so the Pallas
    fused/paged kernels run unmodified per shard); otherwise this is
    api.attention verbatim.
    """
    return TP.attention(q, k, v, q_positions=q_positions,
                        kv_valid_len=kv_valid_len, causal=causal,
                        scale=scale, soft_cap=soft_cap,
                        block_tables=block_tables, kv_scales=kv_scales)


# ---------------------------------------------------------------------------
# GQA / MQA attention with KV cache
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype):
    d, H, Hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p, a = {}, {}
    p["wq"], a["wq"] = dense_init(fold(key, 1), d, H * dh, dtype,
                                  ("embed", "heads"))
    p["wk"], a["wk"] = dense_init(fold(key, 2), d, Hkv * dh, dtype,
                                  ("embed", "kv_heads"))
    p["wv"], a["wv"] = dense_init(fold(key, 3), d, Hkv * dh, dtype,
                                  ("embed", "kv_heads"))
    p["wo"], a["wo"] = dense_init(fold(key, 4), H * dh, d, dtype,
                                  ("heads", "embed"))
    if cfg.qkv_bias:
        for nm, width in (("bq", H * dh), ("bk", Hkv * dh), ("bv", Hkv * dh)):
            p[nm] = jnp.zeros((width,), dtype)
            a[nm] = ax("heads" if nm == "bq" else "kv_heads")
    if cfg.qk_norm:
        p["q_norm"], a["q_norm"] = norm_init(dh, dtype)
        p["k_norm"], a["k_norm"] = norm_init(dh, dtype)
    return p, a


def _written_per_row(positions, len_dtype):
    """Tokens actually written per batch row: positions < 0 — masked rows
    AND bucket-padding columns (docs/serving.md) — don't count."""
    return (positions >= 0).sum(axis=1).astype(len_dtype)


def _paged_cache_update(cache, k, v, positions, block_tables):
    """Scatter new K/V into the page pools through the block tables.

    cache: {"kp","vp": (P, page_size, Hkv, dh), "len": (B,)}. Token (b, s)
    at position p lands in page ``block_tables[b, p // page_size]`` at
    offset ``p % page_size``; positions < 0 (masked rows, bucket padding)
    are routed out of range and dropped. One scatter covers paged prefill,
    chunked prefill, and decode — the page indirection replaces both the
    dynamic-slice and the one-hot contiguous paths.

    With an int8 pool (``"k_scale" in cache`` — docs/quant.md#kv-pages) the
    write path quantizes: a page's per-head scale is FROZEN when its first
    row (position % page_size == 0) is written (core/quant.py::
    kv_write_scale), and every row — including the first — quantizes
    against the frozen scale. A fresh page's first chronological write
    always carries its first row (the engine writes positions in order;
    decode growth allocates the page exactly at the page boundary; resume
    re-prefills from 0), so a reused page's stale scale is always
    overwritten before any row depends on it. Freezing makes the int8
    payload a pure function of the page's logical content — bitwise
    identical whether written token-at-a-time or in bulk — which keeps
    token streams exactly reproducible across preempt/resume and
    prefix-COW (tests/test_serving.py).
    """
    B, S = positions.shape
    P, ps, Hkv, dh = cache["kp"].shape
    pos = jnp.clip(positions, 0)
    page = jnp.take_along_axis(block_tables, pos // ps, axis=1)   # (B,S)
    flat = jnp.where(positions >= 0, page * ps + pos % ps, P * ps)
    flat = flat.reshape(-1)

    def scatter(pool, new):
        pooled = pool.reshape(P * ps, Hkv, dh)
        pooled = pooled.at[flat].set(new.reshape(B * S, Hkv, dh),
                                     mode="drop")
        return pooled.reshape(P, ps, Hkv, dh)

    out = {"len": cache["len"] + _written_per_row(positions,
                                                  cache["len"].dtype)}
    if "k_scale" in cache:
        from repro.core import quant as Q  # lazy: avoid import cycles
        # First-row writes establish their page's frozen scale. Block
        # tables of live rows are disjoint, and at most one position per
        # page is ≡ 0 (mod ps) per call, so the scatter targets are unique.
        est = ((positions >= 0) & (pos % ps == 0)).reshape(-1)
        est_page = jnp.where(est, page.reshape(-1), P)          # OOB → drop

        def establish(scales, new):
            fresh = Q.kv_write_scale(new.reshape(B * S, Hkv, dh))
            return scales.at[est_page].set(fresh, mode="drop")

        def quantize(scales, new):
            row_scale = scales[page]                            # (B,S,Hkv)
            return Q.quantize_kv_rows(new, row_scale)

        k_scale = establish(cache["k_scale"], k)
        v_scale = establish(cache["v_scale"], v)
        out["k_scale"], out["v_scale"] = k_scale, v_scale
        k, v = quantize(k_scale, k), quantize(v_scale, v)
    out["kp"] = scatter(cache["kp"], k)
    out["vp"] = scatter(cache["vp"], v)
    return out


def attention(p, cfg: ModelConfig, x, *, positions, cache=None,
              block_tables=None):
    """x: (B,S,D). cache: {"k","v": (B,Smax,Hkv,dh), "len": (B,)}, a paged
    {"kp","vp": (P,page_size,Hkv,dh), "len": (B,)} pool (then
    ``block_tables`` (B, n_blocks) is required), or None.

    Returns (y, new_cache). Without a cache, self-attention over x
    (causal per cfg). With a cache, writes K/V at ``positions`` then
    attends over the cache (prefill chunks and single-token decode).
    """
    B, S, D = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    # Column-parallel under TP: output heads shard over the model axis
    # (units bound the split to whole heads); no-op without a TP context.
    q = TP.linear(x, p["wq"], p.get("bq"), axes=("embed", "heads"),
                  units=H).reshape(B, S, H, dh)
    k = TP.linear(x, p["wk"], p.get("bk"), axes=("embed", "kv_heads"),
                  units=Hkv).reshape(B, S, Hkv, dh)
    v = TP.linear(x, p["wv"], p.get("bv"), axes=("embed", "kv_heads"),
                  units=Hkv).reshape(B, S, Hkv, dh)
    if cfg.qk_norm:
        q, k = rmsnorm(p["q_norm"], q), rmsnorm(p["k_norm"], k)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = shard(q, "act_batch", "act_seq", "act_heads", None)
    k = shard(k, "act_batch", "act_seq", "act_kv_heads", None)
    v = shard(v, "act_batch", "act_seq", "act_kv_heads", None)

    bt = None
    kv_scales = None
    if cache is None:
        kv_k, kv_v = k, v
        kv_valid = jnp.full((B,), S)
    elif "kp" in cache:
        # Paged pool: writes and reads both go through the block table.
        if block_tables is None:
            raise ValueError("paged KV cache requires block_tables "
                             "(batch['block_tables'] — docs/serving.md)")
        cache = _paged_cache_update(cache, k, v, positions, block_tables)
        kv_k, kv_v, kv_valid = cache["kp"], cache["vp"], cache["len"]
        bt = block_tables
        if "k_scale" in cache:   # int8 pool: kernel dequantizes per page
            kv_scales = (cache["k_scale"], cache["v_scale"])
    else:
        # Rows whose position is negative are masked out: they neither
        # write K/V nor advance their valid length. The serving engine uses
        # this for single-slot prefill/decode — other live slots' caches
        # must stay untouched (the submit-corruption regression). The same
        # contract holds per *column* for bucketed prefill padding
        # (position −1 columns — docs/serving.md).
        if S > 1:  # prefill chunk: per-(row, column) masked scatter.
            # (A scatter, unlike the old shared-offset dynamic slice, keeps
            # bucket-padding columns out of the cache and cannot clamp-
            # shift near max_len; under seq sharding it costs the §Perf H2
            # collective, which prefill amortizes over S columns.)
            T = cache["k"].shape[1]
            bi = jnp.broadcast_to(jnp.arange(B)[:, None], (B, S))
            pos_safe = jnp.where(positions >= 0, positions, T)  # OOB → drop
            kv_k = cache["k"].at[bi, pos_safe].set(k, mode="drop")
            kv_v = cache["v"].at[bi, pos_safe].set(v, mode="drop")
        else:      # decode: per-row offsets (continuous batching slots).
            # One-hot masked update, NOT a scatter: a (B,·) scatter makes
            # GSPMD replicate-then-repartition the whole cache when its seq
            # dim is sharded (§Perf H2); the mask-select keeps every shard
            # local — two cache passes, no collective.
            T = cache["k"].shape[1]
            at_pos = (jnp.arange(T)[None, :] == positions)[..., None, None]
            kv_k = jnp.where(at_pos, k[:, 0][:, None], cache["k"])
            kv_v = jnp.where(at_pos, v[:, 0][:, None], cache["v"])
        written = _written_per_row(positions, cache["len"].dtype)
        cache = {"k": kv_k, "v": kv_v, "len": cache["len"] + written}
        kv_valid = cache["len"]

    out = _attn_core(q, kv_k, kv_v, q_positions=positions,
                     kv_valid_len=kv_valid, causal=cfg.causal,
                     scale=1.0 / math.sqrt(dh), block_tables=bt,
                     kv_scales=kv_scales)
    # Row-parallel under TP: contraction over the sharded heads, psum'd.
    y = TP.linear(out.reshape(B, S, H * dh), p["wo"],
                  axes=("heads", "embed"), units=H)
    return shard(y, "act_batch", "act_seq", "act_embed"), cache


def init_attention_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    Hkv, dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, Hkv, dh), dtype),
        "v": jnp.zeros((batch, max_len, Hkv, dh), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def init_paged_attention_cache(cfg: ModelConfig, batch: int, n_pages: int,
                               page_size: int, dtype, kv_dtype=None):
    """Paged variant of :func:`init_attention_cache`: K/V live in a pool of
    ``n_pages`` fixed-size pages shared by every batch row; per-request
    block tables (serving/kv_pool.py) map logical blocks to pages. ``len``
    stays per-row — the kernel masks logical positions, exactly as the
    contiguous cache does.

    ``kv_dtype="int8"`` (AttentionPolicy.kv_dtype) stores the pools int8
    with per-page-per-head fp32 scale side-tensors, quantized at write time
    and dequantized in the paged kernel (docs/quant.md#kv-pages)."""
    Hkv, dh = cfg.n_kv_heads, cfg.head_dim
    cache = {
        "kp": jnp.zeros((n_pages, page_size, Hkv, dh), dtype),
        "vp": jnp.zeros((n_pages, page_size, Hkv, dh), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }
    if kv_dtype is not None:
        if kv_dtype != "int8":
            raise ValueError(f"unsupported kv_dtype {kv_dtype!r}")
        cache["kp"] = jnp.zeros((n_pages, page_size, Hkv, dh), jnp.int8)
        cache["vp"] = jnp.zeros((n_pages, page_size, Hkv, dh), jnp.int8)
        # scale 1.0 init: an unwritten page dequantizes to exact zeros
        cache["k_scale"] = jnp.ones((n_pages, Hkv), jnp.float32)
        cache["v_scale"] = jnp.ones((n_pages, Hkv), jnp.float32)
    return cache


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2, arXiv:2405.04434)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, dtype):
    d, H = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r, rq = cfg.kv_lora_rank, cfg.q_lora_rank
    p, a = {}, {}
    p["wq_a"], a["wq_a"] = dense_init(fold(key, 1), d, rq, dtype,
                                      ("embed", "kv_lora"))
    p["q_norm"], a["q_norm"] = norm_init(rq, dtype)
    p["wq_b"], a["wq_b"] = dense_init(fold(key, 2), rq, H * (dn + dr), dtype,
                                      ("kv_lora", "heads"))
    p["wkv_a"], a["wkv_a"] = dense_init(fold(key, 3), d, r + dr, dtype,
                                        ("embed", "kv_lora"))
    p["kv_norm"], a["kv_norm"] = norm_init(r, dtype)
    p["wkv_b"], a["wkv_b"] = dense_init(fold(key, 4), r, H * (dn + dv), dtype,
                                        ("kv_lora", "heads"))
    p["wo"], a["wo"] = dense_init(fold(key, 5), H * dv, d, dtype,
                                  ("heads", "embed"))
    return p, a


def mla_attention(p, cfg: ModelConfig, x, *, positions, cache=None,
                  block_tables=None):
    """MLA with latent KV cache. cache: {"ckv": (B,Smax,r), "krope":
    (B,Smax,dr), "len": (B,)}. Prefill materializes K/V per head; the cache
    itself stays compressed (the MLA memory saving)."""
    if block_tables is not None:
        raise NotImplementedError(
            "paged KV caches cover GQA attention only; the MLA latent cache "
            "stays contiguous (docs/serving.md)")
    B, S, D = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank

    q = api.linear(x, p["wq_a"])
    q = rmsnorm(p["q_norm"], q)
    q = TP.linear(q, p["wq_b"], axes=("kv_lora", "heads"),
                  units=H).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    ckv = api.linear(x, p["wkv_a"])                       # (B,S,r+dr)
    c_kv, k_rope = ckv[..., :r], ckv[..., r:]
    c_kv = rmsnorm(p["kv_norm"], c_kv)
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    if cache is not None:
        # negative positions mask a row — or, for bucketed prefill padding,
        # a single column — out of the update entirely (same contract as
        # the GQA path, docs/serving.md)
        if S > 1:
            # per-(row, column) masked scatter (see the GQA path's note on
            # bucket padding vs the old shared-offset dynamic slice)
            T = cache["ckv"].shape[1]
            bi = jnp.broadcast_to(jnp.arange(B)[:, None], (B, S))
            pos_safe = jnp.where(positions >= 0, positions, T)  # OOB → drop

            def up(buf, new):
                return buf.at[bi, pos_safe].set(new, mode="drop")
        else:
            # masked update, not scatter — shard-local under seq sharding
            # (same rationale as the GQA path, §Perf H2)
            T = cache["ckv"].shape[1]
            at_pos = (jnp.arange(T)[None, :] == positions)[..., None]

            def up(buf, new):
                return jnp.where(at_pos, new[:, 0][:, None], buf)
        written = _written_per_row(positions, cache["len"].dtype)
        cache = {"ckv": up(cache["ckv"], c_kv),
                 "krope": up(cache["krope"], k_rope),
                 "len": cache["len"] + written}
        c_all, kr_all, kv_valid = cache["ckv"], cache["krope"], cache["len"]
    else:
        c_all, kr_all, kv_valid = c_kv, k_rope, jnp.full((B,), S)

    # Up-project the latent cache to per-head K (nope) and V. (The fully
    # "absorbed" decode path is a §Perf optimization — see serving/engine.)
    # Under TP the up-projection is column-parallel per head slice: the
    # latent cache stays replicated, each shard materializes only its own
    # heads' K/V (the MLA-TP memory shape).
    kv = TP.linear(c_all, p["wkv_b"], axes=("kv_lora", "heads"),
                   units=H).reshape(B, -1, H, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr_all[:, :, None, :],
                                  (*k_nope.shape[:3], dr))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = _attn_core(q_full, k, v, q_positions=positions,
                     kv_valid_len=kv_valid, causal=True,
                     scale=1.0 / math.sqrt(dn + dr))
    y = TP.linear(out.reshape(B, S, H * dv), p["wo"],
                  axes=("heads", "embed"), units=H)
    return shard(y, "act_batch", "act_seq", "act_embed"), cache


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, dtype, d_ff: Optional[int] = None,
             d_in: Optional[int] = None):
    d = d_in or cfg.d_model
    f = d_ff or cfg.d_ff
    p, a = {}, {}
    if cfg.mlp_act == "swiglu":
        p["wi"], a["wi"] = dense_init(fold(key, 1), d, 2 * f, dtype,
                                      ("embed", "mlp"))
    else:
        p["wi"], a["wi"] = dense_init(fold(key, 1), d, f, dtype,
                                      ("embed", "mlp"))
        p["bi"] = jnp.zeros((f,), dtype); a["bi"] = ax("mlp")
    p["wo"], a["wo"] = dense_init(fold(key, 2), f, d, dtype,
                                  ("mlp", "embed"))
    if cfg.mlp_act != "swiglu":
        p["bo"] = jnp.zeros((d,), dtype); a["bo"] = ax("embed")
    return p, a


def mlp(p, cfg: ModelConfig, x):
    # Up/gate column-parallel, down row-parallel under TP (no-op without a
    # context). The swiglu gate‖up split happens on the *global* array, so
    # the activation stays correct for any shard count; GSPMD reconciles
    # the layouts between the two shard_map'd GEMMs.
    if cfg.mlp_act == "swiglu":
        h = TP.linear(x, p["wi"], axes=("embed", "mlp"))
        gate, up = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(gate) * up
    else:
        h = TP.linear(x, p["wi"], p.get("bi"), axes=("embed", "mlp"))
        h = jax.nn.gelu(h)
    h = shard(h, "act_batch", "act_seq", "act_mlp")
    return TP.linear(h, p["wo"], p.get("bo"), axes=("mlp", "embed"))


# ---------------------------------------------------------------------------
# MoE — capacity-based sort/scatter dispatch (EP over the "experts" axis)
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig, dtype):
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff or cfg.d_ff
    p, a = {}, {}
    p["router"], a["router"] = dense_init(fold(key, 1), d, E, dtype,
                                          ("embed", None), scale=0.02)
    def expert_bank(k2, d_in, d_out):
        w = (jax.random.normal(k2, (E, d_in, d_out), jnp.float32)
             / math.sqrt(d_in)).astype(dtype)
        return w, ax("experts", "embed" if d_in == d else None,
                     None if d_out == d else None)
    p["wi"], a["wi"] = expert_bank(fold(key, 2), d, 2 * f)
    p["wo"], a["wo"] = expert_bank(fold(key, 3), f, d)
    if cfg.n_shared_experts:
        sh, sha = init_mlp(fold(key, 4), cfg, dtype,
                           d_ff=cfg.n_shared_experts * f)
        p["shared"], a["shared"] = sh, sha
    return p, a


def _moe_groups(T: int, target: int = 32) -> int:
    """Token groups for local dispatch — the largest divisor of T ≤ target.
    Groups align with data shards so sort/scatter stay shard-local and the
    (group, expert) buffer resharding is the canonical MoE all-to-all."""
    g = min(target, T)
    while T % g:
        g -= 1
    return max(g, 1)


def moe(p, cfg: ModelConfig, x):
    """x: (B,S,D) → (B,S,D), plus load-balance aux loss.

    Grouped sort-based capacity dispatch (GShard-style dropping):
      1. tokens reshaped to (G, t, D) groups; G is sharded over data —
         per-group argsort/scatter are local (vmapped, batch dim sharded);
      2. dispatch buffer (G, E, C, D): constraint (data, model) 2-D sharding
         ⇒ GSPMD inserts the expert-parallel all-to-all here;
      3. experts run as one grouped GEMM bank einsum (E model-sharded);
      4. combine gathers back per group (local) and weights by router probs.
    All shapes static ⇒ compiles on any mesh.
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.n_experts_active
    T = B * S
    G = _moe_groups(T)
    t = T // G
    C = max(int(t * k / E * cfg.capacity_factor), 1)
    C = min(C, t * k)
    xt = x.reshape(G, t, D)
    xt = shard(xt, "act_batch", None, "act_embed")

    logits = api.matmul(xt, p["router"]).astype(jnp.float32)    # (G,t,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, k)                         # (G,t,k)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)   # renorm

    # aux load-balancing loss (Switch-style), over all tokens
    density = jnp.mean(jax.nn.one_hot(ids[..., 0], E), axis=(0, 1))
    aux = E * jnp.mean(density * jnp.mean(probs, axis=(0, 1)))

    def dispatch_one(xg, idg):
        """Per-group local dispatch. xg: (t,D); idg: (t,k) →
        (buffer (E*C+1, D), slot_for_flat (t*k,), tok_for_slot (E*C+1,))."""
        flat_e = idg.reshape(-1)                                # (t*k,)
        flat_t = jnp.repeat(jnp.arange(t), k)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        start = jnp.searchsorted(sorted_e, jnp.arange(E))
        pos_in_e = jnp.arange(t * k) - start[sorted_e]
        keep = pos_in_e < C
        slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)  # E*C = trash
        buf = jnp.zeros((E * C + 1, D), xg.dtype)
        buf = buf.at[slot].set(xg[flat_t[order]])
        slot_for_flat = jnp.zeros((t * k,), jnp.int32).at[order].set(slot)
        tok_for_slot = (jnp.zeros((E * C + 1,), jnp.int32)
                        .at[slot].set(flat_t[order]))
        return buf, slot_for_flat, tok_for_slot

    buf, slot_for_flat, tok_for_slot = jax.vmap(dispatch_one)(xt, ids)
    h = buf[:, :-1].reshape(G, E, C, D)
    # EP boundary: (data × model) 2-D sharding → all-to-all inserted here
    h = shard(h, "act_batch", "act_experts", None, None)

    # NB: no explicit preferred_element_type — XLA:TPU accumulates bf16
    # MXU dots in fp32 natively, and XLA:CPU lacks the mixed thunk.
    gi = jnp.einsum("gecd,edf->gecf", h, p["wi"]).astype(x.dtype)
    g_, u = jnp.split(gi, 2, axis=-1)
    hh = jax.nn.silu(g_) * u
    hh = shard(hh, "act_batch", "act_experts", None, None)
    out = jnp.einsum("gecf,efd->gecd", hh, p["wo"]).astype(x.dtype)
    out = out.reshape(G, E * C, D)
    out = jnp.concatenate([out, jnp.zeros((G, 1, D), x.dtype)], axis=1)

    if cfg.moe_combine == "local":
        # §Perf H4: combine WITHOUT re-replicating the expert buffer.
        # Scale slots by their gates, scatter-add into (G,t,D) token rows —
        # the update operand stays expert-sharded, so GSPMD keeps the
        # scatter local per shard and all-reduces only the (G,t,D) result
        # (~GBs → ~1 GB per layer on deepseek-v2).
        def gate_map(slotg, gateg):
            gs = (jnp.zeros((E * C + 1,), jnp.float32)
                  .at[slotg].set(gateg.reshape(-1)))
            return gs.at[E * C].set(0.0)       # dropped tokens contribute 0

        gate_slot = jax.vmap(gate_map)(slot_for_flat,
                                       gate.astype(jnp.float32))
        upd = out * gate_slot[..., None].astype(out.dtype)

        def comb(updg, tokg):
            return jnp.zeros((t, D), updg.dtype).at[tokg].add(updg)

        y = jax.vmap(comb)(upd, tok_for_slot)
    else:
        out = shard(out, "act_batch", None, None)  # replicated combine
        contrib = jnp.take_along_axis(
            out, slot_for_flat[..., None], axis=1).reshape(G, t, k, D)
        y = jnp.sum(contrib * gate[..., None].astype(x.dtype), axis=2)

    if cfg.n_shared_experts:
        y = y + mlp(p["shared"], cfg, xt)
    return y.reshape(B, S, D), aux
