"""Model assembly: decoder LMs (dense/GQA/MLA/MoE/SSM/hybrid), BERT, ViT.

Layers are stacked along a leading axis and executed with jax.lax.scan —
compile time stays flat in depth (essential for the 512-device dry-run of
80-layer models) and remat policies apply per layer.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import tp as TP
from repro.distributed.sharding import shard, stack_axes
from repro.models import layers as Lyr
from repro.models import ssm as SSM
from repro.models.config import ModelConfig
from repro.models.module import ax, dense_init, embed_init, fold, norm_init

# ---------------------------------------------------------------------------
# Per-layer block init/apply
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, dtype, moe_layer: bool,
                kind: Optional[str] = None):
    kind = kind or ("ssd" if cfg.family in ("ssm", "hybrid") else "attn")
    p, a = {}, {}
    if kind == "ssd":
        p["mix_norm"], a["mix_norm"] = norm_init(cfg.d_model, dtype)
        p["ssd"], a["ssd"] = SSM.init_ssd(fold(key, 1), cfg, dtype)
        return p, a
    p["attn_norm"], a["attn_norm"] = norm_init(
        cfg.d_model, dtype, with_bias=cfg.norm == "layernorm")
    if cfg.is_mla:
        p["attn"], a["attn"] = Lyr.init_mla(fold(key, 1), cfg, dtype)
    else:
        p["attn"], a["attn"] = Lyr.init_attention(fold(key, 1), cfg, dtype)
    p["mlp_norm"], a["mlp_norm"] = norm_init(
        cfg.d_model, dtype, with_bias=cfg.norm == "layernorm")
    if moe_layer:
        p["moe"], a["moe"] = Lyr.init_moe(fold(key, 2), cfg, dtype)
    else:
        p["mlp"], a["mlp"] = Lyr.init_mlp(fold(key, 2), cfg, dtype)
    return p, a


def _apply_block(p, cfg: ModelConfig, x, *, positions, cache=None,
                 block_tables=None):
    """Returns (y, new_cache, aux_loss). ``block_tables`` (B, n_blocks)
    accompanies paged KV caches (docs/serving.md); None otherwise."""
    aux = jnp.zeros((), jnp.float32)
    if "ssd" in p:
        h, cache = SSM.ssd_block(p["ssd"], cfg,
                                 Lyr.rmsnorm(p["mix_norm"], x), cache=cache)
        return x + h, cache, aux
    h, cache = (Lyr.mla_attention if cfg.is_mla else Lyr.attention)(
        p["attn"], cfg, Lyr.apply_norm(cfg, p["attn_norm"], x),
        positions=positions, cache=cache, block_tables=block_tables)
    x = x + h
    h2 = Lyr.apply_norm(cfg, p["mlp_norm"], x)
    if "moe" in p:
        h2, aux = Lyr.moe(p["moe"], cfg, h2)
    else:
        h2 = Lyr.mlp(p["mlp"], cfg, h2)
    return x + h2, cache, aux


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------

def model_axes(cfg: ModelConfig) -> Dict:
    """The logical-axis tree of :func:`init_model`'s params, without
    materializing a single weight: the init is traced abstractly
    (jax.eval_shape — no allocation, no RNG work) and the axes tree, which
    is plain Python metadata, is captured on the side. Used by TP serving
    to place params when the caller didn't keep init_model's second
    return (serving/engine.py)."""
    box = {}

    def capture(key):
        _, box["axes"] = init_model(key, cfg)
        return 0.0

    jax.eval_shape(capture, jax.random.PRNGKey(0))
    return box["axes"]


def init_model(key, cfg: ModelConfig) -> Tuple[Dict, Dict]:
    dtype = cfg.param_dtype
    p, a = {}, {}
    p["embed"], a["embed"] = embed_init(fold(key, 0), cfg.vocab, cfg.d_model,
                                        dtype)
    if cfg.n_codebooks:  # musicgen: one embedding table per codebook
        cb = jax.vmap(lambda k: embed_init(k, cfg.vocab, cfg.d_model,
                                           dtype)[0])(
            jax.random.split(fold(key, 9), cfg.n_codebooks))
        p["embed_cb"] = cb
        a["embed_cb"] = ax(None, "vocab", "embed")

    n_scan = cfg.n_layers - cfg.first_dense_layers
    # deepseek-style leading dense layers (own, unstacked params)
    for i in range(cfg.first_dense_layers):
        dense_cfg = dataclasses.replace(cfg, n_experts=0)
        p[f"dense_layer{i}"], a[f"dense_layer{i}"] = _init_block(
            fold(key, 100 + i), dense_cfg, dtype, moe_layer=False)

    if cfg.attn_every:  # zamba-style hybrid: scan groups + shared attn block
        assert n_scan % cfg.attn_every == 0, (n_scan, cfg.attn_every)
        p["shared_attn"], a["shared_attn"] = _init_block(
            fold(key, 7), cfg, dtype, moe_layer=False, kind="attn")

    def one_layer(k):
        return _init_block(k, cfg, dtype, moe_layer=cfg.is_moe)[0]

    keys = jax.random.split(fold(key, 1), n_scan)
    p["layers"] = jax.vmap(one_layer)(keys)
    _, layer_axes = _init_block(fold(key, 1), cfg, dtype, moe_layer=cfg.is_moe)
    a["layers"] = stack_axes(layer_axes)

    p["final_norm"], a["final_norm"] = norm_init(
        cfg.d_model, dtype, with_bias=cfg.norm == "layernorm")
    head_vocab = cfg.vocab * max(cfg.n_codebooks, 1)
    p["head"], a["head"] = dense_init(fold(key, 2), cfg.d_model, head_vocab,
                                      dtype, ("embed", "vocab"), scale=0.02)
    return p, a


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def embed_tokens(p, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    if "embeds" in batch and batch["embeds"] is not None:
        x = batch["embeds"]                      # stubbed modality frontend
        if "tokens" in batch and batch["tokens"] is not None:
            t = jnp.take(p["embed"], batch["tokens"], axis=0)
            x = jnp.concatenate([x.astype(t.dtype), t], axis=1)  # vlm: img ⊕ text
        return x
    tokens = batch["tokens"]
    if cfg.n_codebooks:                          # (B,S,n_codebooks) token ids
        # p["embed_cb"]: (CB, vocab, D) — per-codebook tables, summed
        x = sum(jnp.take(p["embed_cb"][c], tokens[..., c], axis=0)
                for c in range(cfg.n_codebooks))
        return x
    return jnp.take(p["embed"], tokens, axis=0)


def _remat(fn, cfg: ModelConfig):
    """jax.checkpoint with the config's remat policy."""
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _index_tree(tree, i: int):
    return jax.tree_util.tree_map(lambda t: t[i], tree)


def _stack_tree(trees):
    return jax.tree_util.tree_map(lambda *ts: jnp.stack(ts), *trees)


def _loop_layers(p, cfg: ModelConfig, x, positions, caches, remat: bool,
                 block_tables=None):
    """Unrolled (Python-loop) layer stack — numerically identical to
    _scan_layers; used by the dry-run for exact cost accounting (XLA's
    cost_analysis counts scan bodies once) and available for short models
    where unrolling compiles fine and pipelines marginally better."""
    n_scan = cfg.n_layers - cfg.first_dense_layers
    aux = jnp.zeros((), jnp.float32)

    def block(lp, x, lc):
        return _apply_block(lp, cfg, x, positions=positions, cache=lc,
                            block_tables=block_tables)

    block_fn = _remat(block, cfg) if remat else block

    if cfg.attn_every:
        g = cfg.attn_every
        ssm_caches, attn_caches = caches if caches is not None else (None,
                                                                     None)
        new_ssm, new_attn = [], []
        for gi in range(n_scan // g):
            grp_ssm = []
            for li in range(g):
                idx = gi * g + li
                lc = (_index_tree(_index_tree(ssm_caches, gi), li)
                      if caches is not None else None)
                x, c_new, aux_i = block_fn(_index_tree(p["layers"], idx), x,
                                           lc)
                aux += aux_i
                grp_ssm.append(c_new)
            sc = (_index_tree(attn_caches, gi)
                  if caches is not None else None)
            x, sc_new, _ = block_fn(p["shared_attn"], x, sc)
            if caches is not None:
                new_ssm.append(_stack_tree(grp_ssm))
                new_attn.append(sc_new)
        if caches is not None:
            return x, (_stack_tree(new_ssm), _stack_tree(new_attn)), aux
        return x, None, aux

    new_caches = []
    for i in range(n_scan):
        lc = _index_tree(caches, i) if caches is not None else None
        x, c_new, aux_i = block_fn(_index_tree(p["layers"], i), x, lc)
        aux += aux_i
        new_caches.append(c_new)
    out_caches = _stack_tree(new_caches) if caches is not None else None
    return x, out_caches, aux


def _scan_layers(p, cfg: ModelConfig, x, positions, caches, remat: bool,
                 block_tables=None):
    """Scan the stacked layer params (+ optional stacked caches) over x."""
    if not cfg.scan_layers:
        return _loop_layers(p, cfg, x, positions, caches, remat, block_tables)
    n_scan = cfg.n_layers - cfg.first_dense_layers
    zero = jnp.zeros((), jnp.float32)

    def body(carry, inp):
        x, aux = carry
        lp, lc = inp if caches is not None else (inp, None)
        y, new_c, aux_i = _apply_block(lp, cfg, x, positions=positions,
                                       cache=lc, block_tables=block_tables)
        return (y, aux + aux_i), new_c

    body_fn = _remat(body, cfg) if remat else body

    if cfg.attn_every:
        g = cfg.attn_every
        grouped = jax.tree_util.tree_map(
            lambda t: t.reshape((n_scan // g, g) + t.shape[1:]), p["layers"])

        def group_body(carry, inp):
            (x, aux) = carry
            if caches is not None:
                gp, gc, sc = inp   # group params, group ssm caches, attn cache
                (x, aux), gc_new = jax.lax.scan(body_fn, (x, aux), (gp, gc))
            else:
                gp, gc_new, sc = inp, None, None
                (x, aux), _ = jax.lax.scan(body_fn, (x, aux), gp)
            y, sc_new, _ = _apply_block(p["shared_attn"], cfg, x,
                                        positions=positions, cache=sc)
            out = (gc_new, sc_new) if caches is not None else None
            return (y, aux), out

        if caches is not None:
            ssm_caches, attn_caches = caches
            (x, aux), (ssm_new, attn_new) = jax.lax.scan(
                group_body, (x, zero), (grouped, ssm_caches, attn_caches))
            return x, (ssm_new, attn_new), aux
        (x, aux), _ = jax.lax.scan(group_body, (x, zero), grouped)
        return x, None, aux

    xs = (p["layers"], caches) if caches is not None else p["layers"]
    (x, aux), new_caches = jax.lax.scan(body_fn, (x, zero), xs)
    return x, new_caches, aux


def forward(params, cfg: ModelConfig, batch, *, caches=None,
            remat: Optional[bool] = None):
    """Returns (logits, new_caches, aux). batch: tokens (B,S) [+ embeds,
    positions, block_tables]. caches=None → full self-attention
    (training/scoring). ``block_tables`` (B, n_blocks) int32 accompanies
    paged KV caches (init_paged_caches): every layer's attention reads and
    writes its page pool through the same table (docs/serving.md)."""
    remat = cfg.remat if remat is None else remat
    if cfg.remat_policy == "none":
        remat = False
    x = embed_tokens(params, cfg, batch)
    B, S = x.shape[:2]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    block_tables = batch.get("block_tables")
    x = shard(x, "act_batch", "act_seq", "act_embed")
    aux_total = jnp.zeros((), jnp.float32)

    dense_caches = None
    if caches is not None and cfg.first_dense_layers:
        caches, dense_caches = caches["scan"], caches["dense"]
    elif caches is not None and not cfg.first_dense_layers:
        caches = caches["scan"]

    new_dense = []
    for i in range(cfg.first_dense_layers):
        dense_cfg = dataclasses.replace(cfg, n_experts=0)
        c_i = dense_caches[i] if dense_caches is not None else None
        x, c_i, aux_i = _apply_block(params[f"dense_layer{i}"], dense_cfg, x,
                                     positions=positions, cache=c_i,
                                     block_tables=block_tables)
        new_dense.append(c_i)
        aux_total += aux_i

    x, new_scan, aux = _scan_layers(params, cfg, x, positions, caches, remat,
                                    block_tables)
    aux_total += aux
    x = Lyr.apply_norm(cfg, params["final_norm"], x)
    # vocab-column-parallel under TP (each shard computes its logit slice;
    # sampling consumes the global array) — api.linear without a context
    logits = TP.linear(x, params["head"], axes=("embed", "vocab"))
    logits = shard(logits, "act_batch", "act_seq", "act_vocab")
    if cfg.n_codebooks:
        logits = logits.reshape(B, S, cfg.n_codebooks, cfg.vocab)
    new_caches = {"scan": new_scan}
    if cfg.first_dense_layers:
        new_caches["dense"] = new_dense
    return logits, new_caches, aux_total


def _place_caches(cfg: ModelConfig, caches, tpctx):
    """Shard fresh caches onto a TP mesh: K/V leaves split on the KV-head
    dim exactly when tp.attention will shard them (tp.head_sharding), the
    rest replicated. No-op without a context."""
    if tpctx is None:
        return caches
    _, shard_kv = TP.head_sharding(tpctx, cfg.n_heads, cfg.n_kv_heads)
    return TP.shard_caches(caches, tpctx, shard_kv=shard_kv)


def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype,
                tpctx=None):
    """Stacked per-layer decode caches matching the scan structure.
    ``tpctx`` (a :class:`repro.distributed.tp.TPContext`) places the caches
    mesh-sharded for TP serving."""
    n_scan = cfg.n_layers - cfg.first_dense_layers

    def one_cache():
        if cfg.family == "ssm":
            return SSM.init_ssd_cache(cfg, batch, dtype)
        if cfg.is_mla:
            return Lyr.init_mla_cache(cfg, batch, max_len, dtype)
        return Lyr.init_attention_cache(cfg, batch, max_len, dtype)

    def stack(n, tree):
        return jax.tree_util.tree_map(
            lambda t: jnp.broadcast_to(t[None], (n,) + t.shape).copy(), tree)

    if cfg.attn_every:
        g = cfg.attn_every
        ssm = jax.tree_util.tree_map(
            lambda t: jnp.broadcast_to(t[None, None],
                                       (n_scan // g, g) + t.shape).copy(),
            SSM.init_ssd_cache(cfg, batch, dtype))
        attn = stack(n_scan // g,
                     Lyr.init_attention_cache(cfg, batch, max_len, dtype))
        caches = {"scan": (ssm, attn)}
    else:
        caches = {"scan": stack(n_scan, one_cache())}
    if cfg.first_dense_layers:
        dense_cfg = dataclasses.replace(cfg, n_experts=0)
        caches["dense"] = [
            (Lyr.init_mla_cache(dense_cfg, batch, max_len, dtype)
             if cfg.is_mla else
             Lyr.init_attention_cache(dense_cfg, batch, max_len, dtype))
            for _ in range(cfg.first_dense_layers)]
    return _place_caches(cfg, caches, tpctx)


def init_paged_caches(cfg: ModelConfig, batch: int, n_pages: int,
                      page_size: int, dtype, tpctx=None, kv_dtype=None):
    """Paged variant of :func:`init_caches`: every layer's KV cache is a
    pool of ``n_pages`` fixed-size pages instead of a contiguous
    ``(batch, max_len)`` slab, so cache memory scales with resident tokens,
    not worst-case length (docs/serving.md). One ``(batch, n_blocks)``
    block table — passed per call via ``batch["block_tables"]`` — addresses
    every layer's pool identically (each layer writes the same logical
    positions), the vLLM layout.

    Covers the GQA/MQA attention families only: SSD/conv recurrent state
    has no positions to page, and the MLA latent cache stays contiguous.

    With ``tpctx`` each model shard owns its slice of every page pool —
    the (P, page_size, Hkv, dh) tensors shard on the KV-head dim, so the
    paged kernel reads/writes only its own heads' pages per shard while
    the host-side PagePool accounting (logical pages, identical on every
    shard) stays unchanged (docs/serving.md).

    ``kv_dtype="int8"`` stores every pool int8 with per-page-per-head fp32
    scale side-tensors (docs/quant.md#kv-pages); under ``tpctx`` the
    scales shard on their KV-head dim alongside the pools.
    """
    if cfg.family in ("ssm", "hybrid") or cfg.attn_every:
        raise NotImplementedError(
            f"paged KV caches require pure-attention layer stacks; family="
            f"{cfg.family!r} attn_every={cfg.attn_every} carries SSD "
            f"recurrent state (docs/serving.md)")
    if cfg.is_mla:
        raise NotImplementedError(
            "paged KV caches cover GQA attention; the MLA latent cache "
            "stays contiguous (docs/serving.md)")
    n_scan = cfg.n_layers - cfg.first_dense_layers

    def stack(n, tree):
        return jax.tree_util.tree_map(
            lambda t: jnp.broadcast_to(t[None], (n,) + t.shape).copy(), tree)

    caches = {"scan": stack(n_scan, Lyr.init_paged_attention_cache(
        cfg, batch, n_pages, page_size, dtype, kv_dtype=kv_dtype))}
    if cfg.first_dense_layers:
        dense_cfg = dataclasses.replace(cfg, n_experts=0)
        caches["dense"] = [
            Lyr.init_paged_attention_cache(dense_cfg, batch, n_pages,
                                           page_size, dtype,
                                           kv_dtype=kv_dtype)
            for _ in range(cfg.first_dense_layers)]
    return _place_caches(cfg, caches, tpctx)


# ---------------------------------------------------------------------------
# Losses / steps (pure functions; launch/ wraps them in pjit)
# ---------------------------------------------------------------------------

def lm_loss(params, cfg: ModelConfig, batch, *, aux_weight: float = 0.01):
    logits, _, aux = forward(params, cfg, batch, caches=None)
    tokens = batch["tokens"]
    if cfg.n_codebooks:
        labels = tokens[:, 1:, :]
        lg = logits[:, :-1]
        logp = jax.nn.log_softmax(lg.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)
        loss = jnp.mean(nll)
    else:
        labels = batch.get("labels")
        # vlm: image embeds occupy the first positions; only text predicts
        n_img = (batch["embeds"].shape[1]
                 if batch.get("embeds") is not None else 0)
        if labels is None:
            labels = tokens[:, 1:]
            lg = logits[:, n_img:-1]
        else:
            lg = logits[:, n_img:]
        logp = jax.nn.log_softmax(lg.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        loss = jnp.mean(nll)
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}


# ---------------------------------------------------------------------------
# BERT / ViT (the paper's own evaluation models)
# ---------------------------------------------------------------------------

def bert_config(variant: str) -> ModelConfig:
    dims = {"medium": (8, 512, 8), "base": (12, 768, 12),
            "large": (24, 1024, 16)}[variant]
    L, d, h = dims
    return ModelConfig(
        name=f"bert-{variant}", family="bert", n_layers=L, d_model=d,
        n_heads=h, n_kv_heads=h, d_ff=4 * d, vocab=30522, causal=False,
        mlp_act="gelu", norm="layernorm", source="arXiv:1810.04805")


def vit_config(variant: str) -> ModelConfig:
    dims = {"base": (12, 768, 12, 197), "large": (24, 1024, 16, 197),
            "huge": (32, 1280, 16, 257)}[variant]
    L, d, h, seq = dims
    return ModelConfig(
        name=f"vit-{variant}", family="vit", n_layers=L, d_model=d,
        n_heads=h, n_kv_heads=h, d_ff=4 * d, vocab=1000, causal=False,
        mlp_act="gelu", norm="layernorm", source="arXiv:2010.11929")


def encoder_forward(params, cfg: ModelConfig, batch):
    """BERT/ViT: bidirectional encoder; ViT consumes stubbed patch embeds."""
    logits, _, _ = forward(params, cfg, batch, caches=None, remat=False)
    return logits
