"""Minimal pure-JAX module substrate (no flax dependency).

Parameters are nested dicts of jax.Arrays. Every ``init_*`` function returns
``(params, axes)`` where ``axes`` is a pytree of the same structure whose
leaves are tuples of *logical axis names* — the sharding engine
(distributed/sharding.py) maps those to mesh PartitionSpecs. Keeping the
axis metadata structurally parallel to the params makes resharding (elastic
restarts, mesh changes) a pure tree_map.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]
Axes = Dict[str, Any]


class AxisLeaf(tuple):
    """Tuple of logical axis names; subclass so tree libs treat it as a leaf."""
    pass


def ax(*names: Optional[str]) -> AxisLeaf:
    return AxisLeaf(names)


def is_axis_leaf(x) -> bool:
    return isinstance(x, AxisLeaf)


def axes_tree_map(fn, axes: Axes):
    return jax.tree_util.tree_map(fn, axes, is_leaf=is_axis_leaf)


def dense_init(key, d_in: int, d_out: int, dtype, axes_names=("embed", "mlp"),
               scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale
    return w.astype(dtype), ax(*axes_names)


def embed_init(key, vocab: int, d: int, dtype):
    w = jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02
    return w.astype(dtype), ax("vocab", "embed")


def norm_init(d: int, dtype, with_bias: bool = False):
    p = {"scale": jnp.ones((d,), dtype)}
    a = {"scale": ax("embed")}
    if with_bias:
        p["bias"] = jnp.zeros((d,), dtype)
        a["bias"] = ax("embed")
    return p, a


def count_params(params: Params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


def fold(key, *data: int):
    for d in data:
        key = jax.random.fold_in(key, d)
    return key
