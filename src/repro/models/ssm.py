"""Mamba-2 SSD mixer (arXiv:2405.21060), chunked matmul formulation.

The SSD "state-space duality" decomposition is itself a block-matrix
algorithm — structurally the closest relative of the paper's Algorithm 1 —
so the chunked train path is deliberately expressed as batched GEMMs
(intra-chunk C·Bᵀ∘L and state updates), which route onto the MXU /
MatrixFlow path. Decode keeps the O(1) recurrent state.

Shapes: x (B,S,H,P) heads×head-dim; B/C projections shared across heads
(n_groups=1): (B,S,N); A scalar per head; dt per head.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import api
from repro.distributed.sharding import shard
from repro.models.config import ModelConfig
from repro.models.module import ax, dense_init, fold, norm_init


def init_ssd(key, cfg: ModelConfig, dtype):
    """Separate z/x/B/C/dt projections (not one fused w_in) so each output
    keeps a clean TP sharding — the fused layout splits at non-shard-aligned
    offsets and would force all-gathers under GSPMD."""
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H, P, K = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_conv
    p, a = {}, {}
    p["w_z"], a["w_z"] = dense_init(fold(key, 1), d, di, dtype, ("embed", "mlp"))
    p["w_x"], a["w_x"] = dense_init(fold(key, 2), d, di, dtype, ("embed", "mlp"))
    p["w_B"], a["w_B"] = dense_init(fold(key, 3), d, N, dtype, ("embed", None))
    p["w_C"], a["w_C"] = dense_init(fold(key, 4), d, N, dtype, ("embed", None))
    p["w_dt"], a["w_dt"] = dense_init(fold(key, 5), d, H, dtype, ("embed", None))
    p["conv_x"] = (jax.random.normal(fold(key, 6), (K, di), jnp.float32)
                   / math.sqrt(K)).astype(dtype)
    a["conv_x"] = ax("conv", "mlp")
    p["conv_b_x"] = jnp.zeros((di,), dtype); a["conv_b_x"] = ax("mlp")
    p["conv_B"] = (jax.random.normal(fold(key, 7), (K, N), jnp.float32)
                   / math.sqrt(K)).astype(dtype)
    a["conv_B"] = ax("conv", None)
    p["conv_b_B"] = jnp.zeros((N,), dtype); a["conv_b_B"] = ax(None)
    p["conv_C"] = (jax.random.normal(fold(key, 8), (K, N), jnp.float32)
                   / math.sqrt(K)).astype(dtype)
    a["conv_C"] = ax("conv", None)
    p["conv_b_C"] = jnp.zeros((N,), dtype); a["conv_b_C"] = ax(None)
    p["A_log"] = jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32))
    a["A_log"] = ax(None)
    p["D"] = jnp.ones((H,), jnp.float32); a["D"] = ax(None)
    p["dt_bias"] = jnp.full((H,), math.log(math.e - 1), jnp.float32)
    a["dt_bias"] = ax(None)
    p["norm"], a["norm"] = norm_init(di, dtype)
    p["w_out"], a["w_out"] = dense_init(fold(key, 9), di, d, dtype,
                                        ("mlp", "embed"))
    return p, a


def _causal_conv(xbc, w, b, conv_state=None):
    """Depthwise causal conv. xbc: (B,S,C); w: (K,C). Returns (y, new_state)
    where state is the last K-1 inputs (for decode)."""
    K = w.shape[0]
    if conv_state is not None:
        ctx = jnp.concatenate([conv_state, xbc], axis=1)   # (B, K-1+S, C)
        new_state = ctx[:, -(K - 1):]
    else:
        ctx = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
        new_state = ctx[:, -(K - 1):]
    # windowed sum: y_t = Σ_k w_k · x_{t-K+1+k}
    S = xbc.shape[1]
    y = sum(ctx[:, k:k + S] * w[k][None, None, :] for k in range(K))
    return jax.nn.silu(y + b[None, None, :]), new_state


def _segsum_decay(a_chunk):
    """a_chunk: (..., Q) per-step log-decays → (..., Q, Q) lower-tri decay
    matrix L[i,j] = exp(Σ_{j<m≤i} a_m), 0 above diagonal.

    The mask is applied to the *exponent* (−inf → exp 0), not the output:
    masked-out entries have positive exponents that overflow to inf, and
    ``where(mask, inf, 0)`` poisons the backward pass with inf·0 = NaN.
    """
    Q = a_chunk.shape[-1]
    cs = jnp.cumsum(a_chunk, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # Σ_{j<m≤i}
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.exp(jnp.where(mask, diff, -jnp.inf))


def ssd_chunked(x, dt, A, Bc, Cc, chunk: int = 128):
    """Chunked SSD scan. x:(B,S,H,P) dt:(B,S,H) A:(H,) Bc/Cc:(B,S,N).
    fp32 internals; returns (y, final_state (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    N = Bc.shape[-1]
    Q = min(chunk, S)
    while S % Q:          # largest divisor of S ≤ chunk (static shapes)
        Q -= 1
    nc = S // Q
    f32 = jnp.float32
    xq = x.astype(f32).reshape(Bsz, nc, Q, H, P)
    dtq = dt.astype(f32).reshape(Bsz, nc, Q, H)
    bq = Bc.astype(f32).reshape(Bsz, nc, Q, N)
    cq = Cc.astype(f32).reshape(Bsz, nc, Q, N)
    a = dtq * A[None, None, None, :]                     # (B,nc,Q,H) log-decay
    a_h = jnp.moveaxis(a, -1, -2)                        # (B,nc,H,Q)
    L = _segsum_decay(a_h)                               # (B,nc,H,Q,Q)

    # intra-chunk: Y_i = Σ_j (C_i·B_j) L_ij dt_j x_j
    cb = jnp.einsum("bnqs,bnks->bnqk", cq, bq)           # (B,nc,Q,Q)
    dtx = xq * dtq[..., None]                            # (B,nc,Q,H,P)
    y_intra = jnp.einsum("bnhqk,bnqk,bnkhp->bnqhp",
                         L, cb, dtx)

    # chunk-final states: S_n = Σ_j decay_{end←j} B_j (dt_j x_j)
    cum = jnp.cumsum(a_h, axis=-1)                       # (B,nc,H,Q)
    decay_end = jnp.exp(cum[..., -1:] - cum)             # (B,nc,H,Q)
    states = jnp.einsum("bnhq,bnqs,bnqhp->bnhps",
                        decay_end, bq, dtx)              # (B,nc,H,P,N)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(jnp.sum(a_h, axis=-1))         # (B,nc,H)

    def scan_fn(h, inp):
        s_n, g_n = inp                                   # (B,H,P,N), (B,H)
        h_new = h * g_n[..., None, None] + s_n
        return h_new, h                                  # emit state BEFORE chunk

    h0 = jnp.zeros((Bsz, H, P, N), f32)
    hT, h_prev = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                  # (B,nc,H,P,N)

    # inter-chunk output: C_i decay_{i←start} h_prev
    decay_in = jnp.exp(cum)                              # (B,nc,H,Q)
    y_inter = jnp.einsum("bnqs,bnhq,bnhps->bnqhp",
                         cq, decay_in, h_prev)
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y.astype(x.dtype), hT


def ssd_decode_step(x, dt, A, Bc, Cc, state):
    """One-token recurrence. x:(B,1,H,P) dt:(B,1,H) Bc/Cc:(B,1,N);
    state:(B,H,P,N) fp32."""
    f32 = jnp.float32
    xt = x[:, 0].astype(f32)
    dtt = dt[:, 0].astype(f32)
    bt, ct = Bc[:, 0].astype(f32), Cc[:, 0].astype(f32)
    decay = jnp.exp(dtt * A[None, :])[..., None, None]      # (B,H,1,1)
    dBx = jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], bt)
    new_state = decay * state + dBx
    y = jnp.einsum("bhpn,bn->bhp", new_state, ct)
    return y[:, None].astype(x.dtype), new_state


def ssd_block(p, cfg: ModelConfig, x, *, cache=None, chunk: int = 128):
    """Full Mamba-2 block: in_proj → conv → SSD → gated norm → out_proj.

    cache (decode): {"conv": (B,K-1,conv_ch), "state": (B,H,P,N)}.
    """
    B, S, D = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z = api.linear(x, p["w_z"])
    xc = api.linear(x, p["w_x"])
    bc = api.linear(x, p["w_B"])
    cc = api.linear(x, p["w_C"])
    dt = api.linear(x, p["w_dt"])
    xc = shard(xc, "act_batch", "act_seq", "act_mlp")
    cs = cache["conv"] if cache is not None else {"x": None, "B": None,
                                                  "C": None}
    xc, ncx = _causal_conv(xc, p["conv_x"], p["conv_b_x"], cs["x"])
    bc, ncb = _causal_conv(bc, p["conv_B"], p["conv_b_B"], cs["B"])
    cc, ncc = _causal_conv(cc, p["conv_C"], p["conv_b_C"], cs["C"])
    new_conv = {"x": ncx, "B": ncb, "C": ncc}
    xc = xc.reshape(B, S, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])

    if cache is not None and S == 1:
        y, new_state = ssd_decode_step(xc, dt, A, bc, cc, cache["state"])
        cache = {"conv": new_conv, "state": new_state}
    elif cache is not None:
        # prefill with cache: chunked scan, then store the final state.
        # (Assumes a fresh cache — prefill-continuation would need an
        # initial-state term in ssd_chunked; the serving engine always
        # prefills whole prompts.)
        y, hT = ssd_chunked(xc, dt, A, bc, cc, chunk=min(chunk, S))
        cache = {"conv": new_conv, "state": hT}
    else:
        y, _ = ssd_chunked(xc, dt, A, bc, cc, chunk=chunk)
    y = y + xc * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, di)
    y = y * jax.nn.silu(z)
    from repro.models.layers import rmsnorm  # local import (cycle-free)
    y = rmsnorm(p["norm"], y)
    y = shard(y, "act_batch", "act_seq", "act_mlp")
    return api.linear(y, p["w_out"]), cache


def init_ssd_cache(cfg: ModelConfig, batch: int, dtype):
    K = cfg.ssm_conv
    return {
        "conv": {
            "x": jnp.zeros((batch, K - 1, cfg.d_inner), dtype),
            "B": jnp.zeros((batch, K - 1, cfg.ssm_state), dtype),
            "C": jnp.zeros((batch, K - 1, cfg.ssm_state), dtype),
        },
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                            cfg.ssm_state), jnp.float32),
    }
