"""Architecture config schema. One instance per assigned architecture
(src/repro/configs/<id>.py) plus the paper's own BERT/ViT models."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm | bert | vit
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None          # defaults to d_model // n_heads
    # attention flavor
    qk_norm: bool = False                 # qwen3
    qkv_bias: bool = False                # qwen2
    rope_theta: float = 1e4
    causal: bool = True                   # False → encoder (BERT/ViT)
    # MLA (deepseek-v2)
    kv_lora_rank: int = 0                 # >0 enables MLA
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # MoE
    n_experts: int = 0
    n_experts_active: int = 0             # top-k
    n_shared_experts: int = 0
    moe_d_ff: int = 0                     # per-expert hidden dim
    first_dense_layers: int = 0           # deepseek-v2: layer 0 is dense
    capacity_factor: float = 1.25
    # combine strategy: "gather" re-replicates the expert output buffer
    # over the model axis before the slot gather (simple, collective-heavy);
    # "local" masks the slot gather per expert shard and all-reduces the
    # (G,t,D)-sized result instead — §Perf H4
    moe_combine: str = "gather"
    # SSM (mamba2 / zamba2)
    ssm_state: int = 0                    # >0 enables SSD mixer
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    # hybrid (zamba2): shared attention block applied every k SSM blocks
    attn_every: int = 0
    # frontends (audio/vlm are stubs providing precomputed embeddings)
    n_codebooks: int = 0                  # musicgen EnCodec streams
    mlp_act: str = "swiglu"               # swiglu | gelu | gelu_glu
    norm: str = "rmsnorm"                 # rmsnorm | layernorm
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # distribution
    sharding_overrides: Tuple[Tuple[str, Optional[str]], ...] = ()
    remat: bool = True
    # remat policy: "full" (recompute everything), "dots" (save MXU dot
    # outputs, recompute elementwise — trades a little memory for a lot of
    # recompute traffic; §Perf hillclimb H3), "none" ≡ remat=False
    remat_policy: str = "full"
    # scan-over-layers keeps compile time flat in depth (production default).
    # The dry-run sets False: XLA's cost_analysis counts a while-loop body
    # ONCE regardless of trip count, so exact roofline accounting requires
    # unrolled layers (see DESIGN.md §Roofline-methodology).
    scan_layers: bool = True
    # provenance
    source: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def is_mla(self) -> bool:
        return self.kv_lora_rank > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def d_inner(self) -> int:             # SSD inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def overrides_dict(self) -> Dict[str, Optional[str]]:
        return dict(self.sharding_overrides)


def reduced(cfg: ModelConfig, **kw) -> ModelConfig:
    """Smoke-test shrink: same family/topology, tiny dims."""
    shrink = dict(
        n_layers=4 if cfg.attn_every else min(cfg.n_layers, 2),
        d_model=128,
        n_heads=max(min(cfg.n_heads, 4), 1),
        n_kv_heads=max(min(cfg.n_kv_heads, 2), 1),
        d_ff=256,
        vocab=512,
        d_head=32,
    )
    if cfg.is_mla:
        shrink.update(kv_lora_rank=64, q_lora_rank=96, qk_nope_head_dim=32,
                      qk_rope_head_dim=16, v_head_dim=32, d_head=None)
    if cfg.is_moe:
        shrink.update(n_experts=min(cfg.n_experts, 8),
                      n_experts_active=min(cfg.n_experts_active, 2),
                      moe_d_ff=64,
                      n_shared_experts=min(cfg.n_shared_experts, 1))
    if cfg.ssm_state:
        shrink.update(ssm_state=16, ssm_head_dim=16)
    if cfg.attn_every:
        shrink.update(attn_every=2)
    if cfg.n_kv_heads == cfg.n_heads:  # keep MHA archs MHA
        shrink["n_kv_heads"] = shrink["n_heads"]
    shrink.update(kw)
    return dataclasses.replace(cfg, **shrink)
