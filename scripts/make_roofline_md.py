"""Render EXPERIMENTS.md §Roofline tables from the merged dry-run jsonl."""
import json
import sys


def main(path="dryrun_final.jsonl"):
    rows = [json.loads(line) for line in open(path) if line.strip()]
    for mesh in ("16x16", "2x16x16"):
        sel = [r for r in rows if r.get("mesh") == mesh and "roofline" in r]
        print(f"\n### Mesh {mesh} ({sel[0]['n_chips'] if sel else '?'} chips)\n")
        print("| arch | shape | t_compute (s) | t_memory (s) | t_collective"
              " (s) | bottleneck | MODEL/HLO flops | GB/dev | one-line fix |")
        print("|---|---|---|---|---|---|---|---|---|")
        fixes = {
            "compute": "more chips / lower precision",
            "memory": "fuse attention (flash) + cut remat re-reads",
            "collective": "shard KV seq (kvseq) / EP all-to-all overlap",
        }
        for r in sel:
            t = r["roofline"]
            fix = fixes[t["bottleneck"]]
            if r["arch"] == "smollm-135m" and r["shape"] == "train_4k":
                fix = "seqpar: attention idle on model axis (H1)"
            if r["shape"] == "decode_32k" and t["bottleneck"] == "collective":
                fix = "kvseq partial-softmax decode (H2)"
            print(f"| {r['arch']} | {r['shape']} | {t['t_compute_s']:.2e} "
                  f"| {t['t_memory_s']:.2e} | {t['t_collective_s']:.2e} "
                  f"| **{t['bottleneck']}** "
                  f"| {t.get('useful_flops_ratio', 0):.2f} "
                  f"| {r['memory'].get('total_gb_per_device', '?')} "
                  f"| {fix} |")


if __name__ == "__main__":
    main(*sys.argv[1:])
