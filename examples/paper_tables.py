"""Reproduce the paper's headline artifacts from the calibrated system
model — Table 3, Fig. 6, Fig. 7, Fig. 9 — side by side with the paper's
reported numbers.

Run:  PYTHONPATH=src python examples/paper_tables.py
"""
from repro.core import sysmodel as SM
from repro.core.workloads import PAPER_TABLE3, paper_workload


def main():
    print("=== Table 3: transformer speedups vs single-thread CPU ===")
    hdr = f"{'model':14s} {'omp':>8s} {'ticsat':>8s} {'mf(ours)':>9s} {'mf(paper)':>9s}"
    print(hdr)
    for m, ref in PAPER_TABLE3.items():
        t = SM.speedup_table(paper_workload(m), "int32")
        print(f"{m:14s} {t['omp']:8.1f} {t['ticsat']:8.1f} "
              f"{t['mf_dc']:9.1f} {ref['mf_dc']:9.1f}")

    print("\n=== Fig. 7: GEMM speedup vs size (int8, incl. re-layout) ===")
    for n in (256, 512, 1024, 2048):
        wl = ((SM.Gemm(n, n, n),), ())
        t = SM.speedup_table(wl, "int8", include_layout_cost=True)
        print(f"  {n:5d}³: DC {t['mf_dc']:6.0f}x   DM {t['mf_dm']:6.0f}x"
              f"   OMP {t['omp']:5.1f}x   Neon {t['neon']:4.1f}x")
    print("  (paper: 'up to a 400x' at 1024, DC slightly ahead of DM)")

    print("\n=== Fig. 6: dtype sweep at 512³ ===")
    for dt in ("int8", "int16", "int32", "fp16", "fp32"):
        t = SM.speedup_table(((SM.Gemm(512, 512, 512),), ()), dt)
        print(f"  {dt:5s}: accel(DC) {t['mf_dc']:6.0f}x   neon {t['neon']:4.1f}x")
    print("  (paper: fp16 best on the accelerator; int8 best for Neon)")

    print("\n=== Fig. 9: PCIe sensitivity (GEMM 1024³ int32, DC) ===")
    base = None
    for label, gbps in (("16 lanes-64Gbps", 64.0), ("4 lanes-16Gbps", 16.0),
                        ("4 lanes-5Gbps", 5.0)):
        sys = SM.SystemConfig(pcie_total_gbps=gbps)
        t = SM.workload_time(((SM.Gemm(1024, 1024, 1024),), ()),
                             "int32", "mf_dc", sys)["total"]
        base = base or t
        print(f"  {label:16s}: {t * 1e3:7.2f} ms  ({t / base:4.2f}x)")
    print("  (paper: best config ~130% better than worst)")


if __name__ == "__main__":
    main()
