"""End-to-end training driver: train a ~100M-class LM for a few hundred
steps on the synthetic pipeline and show the loss trace.

By default trains the REDUCED smollm config (CPU-friendly); pass
--full-135m to train the real SmolLM-135M config (slow on CPU; sized for a
single TPU host).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse

from repro.configs.registry import get_config, get_smoke_config
from repro.data.pipeline import DataConfig
from repro.train.loop import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--full-135m", action="store_true")
    args = ap.parse_args()

    cfg = (get_config("smollm-135m") if args.full_135m
           else get_smoke_config("smollm-135m", n_layers=4, d_model=256,
                                 d_ff=1024, vocab=2048))
    print(f"[example] training {cfg.name} ({cfg.n_layers}L d={cfg.d_model}) "
          f"for {args.steps} steps")
    tc = TrainConfig(steps=args.steps, log_every=20, ckpt_every=100,
                     ckpt_dir=args.ckpt_dir, base_lr=args.lr,
                     warmup=max(args.steps // 10, 5))
    dc = DataConfig(seq_len=args.seq_len, global_batch=args.global_batch,
                    vocab=cfg.vocab, seed=0)
    out = Trainer(cfg, dc, tc).run()
    first, last = out["history"][0], out["history"][-1]
    print(f"[example] loss {first[1]:.3f} (step {first[0]}) → "
          f"{last[1]:.3f} (step {last[0]})")
    assert last[1] < first[1], "training did not reduce loss"
    print("[example] OK — loss decreased; checkpoint in", args.ckpt_dir)


if __name__ == "__main__":
    main()
