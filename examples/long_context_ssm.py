"""Long-context decode with an O(1)-state SSM — the long_500k cell's story.

A Mamba-2 model decodes with *constant* memory per step regardless of how
long the context is: the SSD recurrence carries a (H, P, N) state instead
of a growing KV cache. This script decodes at three context lengths and
shows the state size (and step cost) staying flat, versus the KV cache a
transformer would need.

Run:  PYTHONPATH=src python examples/long_context_ssm.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, get_smoke_config
from repro.models import transformer as T


def tree_bytes(tree):
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def main():
    cfg = get_smoke_config("mamba2-1.3b", n_layers=2, vocab=256)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)

    from repro.serving.engine import make_decode_step
    decode = jax.jit(make_decode_step(cfg))

    B = 2
    caches = T.init_caches(cfg, B, max_len=8, dtype=cfg.param_dtype)
    state_bytes = tree_bytes(caches)
    print(f"[ssm] recurrent state: {state_bytes / 1024:.1f} KiB "
          f"(constant — no KV cache)")

    tok = jnp.zeros((B, 1), jnp.int32)
    for ctx in (1_000, 100_000, 500_000):
        pos = jnp.full((B, 1), ctx, jnp.int32)
        logits, caches = decode(params, tok, pos, caches)   # warm
        t0 = time.perf_counter()
        for _ in range(5):
            logits, caches = decode(params, tok, pos, caches)
        jax.block_until_ready(logits)
        dt = (time.perf_counter() - t0) / 5
        print(f"[ssm] decode @ context {ctx:>7,d}: {dt * 1e3:6.1f} ms/step, "
              f"state still {tree_bytes(caches) / 1024:.1f} KiB")

    # what a full-attention model would need at 500k (per layer, per seq):
    full = get_config("qwen2-1.5b")
    kv_bytes = (2 * full.n_kv_heads * full.head_dim * 524_288 * 2
                * full.n_layers)
    print(f"[ref] qwen2-1.5b KV cache at 500k context: "
          f"{kv_bytes / 2**30:.1f} GiB per sequence — why long_500k is an "
          f"SSM/hybrid-only cell (DESIGN.md §3)")


if __name__ == "__main__":
    main()
