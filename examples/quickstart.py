"""Quickstart: the MatrixFlow public API in five minutes.

  1. a GEMM through the paper's block-major layout + Algorithm 1,
  2. the same GEMM through the Pallas TPU kernel (interpret mode on CPU),
  3. the analytic system model reproducing a paper headline number,
  4. the ExecutionPlan API: GemmPolicy, plan resolution, resident
     PackedWeights, and a tiny transformer forward with every GEMM on
     the MatrixFlow path.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api
from repro.core import layout as L
from repro.core import sysmodel as SM
from repro.core.blockflow import block_matmul
from repro.core.workloads import PAPER_TABLE3, paper_workload
from repro.kernels.matrixflow_gemm import matrixflow_gemm


def main():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((256, 512), np.float32))
    b = jnp.asarray(rng.standard_normal((512, 384), np.float32))

    # -- 1. the paper's data structure -------------------------------------
    blk = L.choose_layout(256, 384, 512, jnp.float32, mode="dc")
    print(f"block layout: {blk}  (grid {blk.grid(256, 384, 512)}, "
          f"VMEM claim {blk.vmem_bytes(4) / 1024:.0f} KiB)")
    a_bm = L.to_block_major_a(a, blk.bm, blk.bk)
    print(f"A row-major {a.shape} → block-major {a_bm.shape} "
          f"(each block one contiguous transfer)")

    # -- 2. Algorithm 1, two substrates ------------------------------------
    c_lax = block_matmul(a, b, blk=blk)
    c_pallas = matrixflow_gemm(a, b, blk=blk, interpret=True)
    err = float(jnp.abs(c_lax - c_pallas).max())
    print(f"Algorithm 1 via lax vs Pallas kernel: max |Δ| = {err:.2e}")

    # -- 3. paper headline from the system model ---------------------------
    table = SM.speedup_table(paper_workload("bert-large"), "int32")
    print(f"BERT-large speedup vs 1-core CPU: model {table['mf_dc']:.0f}x, "
          f"paper {PAPER_TABLE3['bert-large']['mf_dc']}x")

    # -- 4. the ExecutionPlan API ------------------------------------------
    # A GemmPolicy is a frozen description of HOW GEMMs execute; plan()
    # resolves it per shape (memoized), consulting the sysmodel for DC/DM.
    policy = api.GemmPolicy(backend="pallas_interpret", mode="auto")
    pln = api.plan(256, 384, 512, jnp.float32, policy)
    print(f"plan(256,384,512): backend={pln.backend} mode={pln.mode} "
          f"layout={pln.layout}  (cache: {api.plan_cache_info()})")

    # Weights pack block-major ONCE (the paper's offline arrangement);
    # linear consumes the resident blocks — no per-call re-layout.
    w_packed = api.pack_weight(b, policy)
    y = api.linear(a, w_packed, policy=policy)
    y_row = api.linear(a, b, policy=policy)
    print(f"resident PackedWeight linear: bitwise equal to row-major: "
          f"{bool(jnp.all(y == y_row))}")

    # A model with every GEMM on the MatrixFlow path, weights resident.
    from repro.configs.registry import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("smollm-135m", n_layers=2)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.zeros((1, 16), jnp.int32)}
    mf_policy = api.GemmPolicy(backend="blockflow")
    packed_params = api.pack_model_weights(params, mf_policy)
    with api.use_policy(mf_policy):
        t0 = time.perf_counter()
        logits, _, _ = T.forward(packed_params, cfg, batch)
        dt = time.perf_counter() - t0
    print(f"smollm (reduced) forward on the MatrixFlow path: "
          f"logits {logits.shape} in {dt * 1e3:.0f} ms")


if __name__ == "__main__":
    main()
