"""Batched serving example: prefill + decode with a KV cache and
continuous batching over slots (the decode_* shape cells' code path).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.models import transformer as T
from repro.serving.engine import ServeConfig, ServingEngine


def main():
    cfg = get_smoke_config("qwen2-1.5b", n_layers=2, vocab=512)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params,
                           ServeConfig(batch_slots=4, max_len=64))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (4, 12)).astype(np.int32)

    t0 = time.perf_counter()
    out = engine.generate(prompts, n_tokens=16)
    dt = time.perf_counter() - t0
    print(f"[serve] generated {out.shape[0]}×{out.shape[1]} tokens "
          f"in {dt:.2f}s ({out.size / dt:.1f} tok/s)")
    print("[serve] first sequence:", out[0].tolist())

    # continuous batching: requests trickle in, slots recycle
    engine2 = ServingEngine(cfg, params,
                            ServeConfig(batch_slots=2, max_len=32))
    reqs = [rng.integers(0, cfg.vocab, n).tolist() for n in (5, 7, 4)]
    s0 = engine2.submit(reqs[0])
    engine2.submit(reqs[1])
    assert engine2.submit(reqs[2]) is None      # full → queued by caller
    for _ in range(6):
        engine2.step()
    engine2.cancel(s0)                          # request 0 finishes
    s2 = engine2.submit(reqs[2])                # slot recycled
    assert s2 == s0
    for _ in range(4):
        engine2.step()
    print("[serve] continuous batching OK — slot", s0, "recycled for req 2")

    # paged KV cache: page-bound admission (docs/serving.md) — a pool half
    # the contiguous budget still serves all 4 requests concurrently
    from repro.core.plan import AttentionPolicy
    engine3 = ServingEngine(cfg, params, ServeConfig(
        batch_slots=4, max_len=32,
        attention=AttentionPolicy(backend="paged_interpret", page_size=8,
                                  block_q=8),
        cache_pages=8))
    rids = [engine3.submit(rng.integers(0, cfg.vocab, 3).tolist())
            for _ in range(4)]
    assert all(r is not None for r in rids)
    for _ in range(8):
        engine3.step()
    print(f"[serve] paged: 4 live requests on a pool of "
          f"{engine3.pool.n_pages} pages "
          f"({engine3.pool.pages_in_use} in use, "
          f"{engine3.n_preemptions} preemptions)")


if __name__ == "__main__":
    main()
