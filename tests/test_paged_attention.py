"""Paged attention kernel: block-table indirection vs the mha_ref oracle.

The parity grid (tests/parity.py) covers the backend-level contract; these
tests hit kernels/paged_attention.py directly for the properties only the
paged layout can break: shuffled physical assignment, garbage distractor
pages, unallocated-tail block-table entries, partial last pages, and the
page-gather inverse.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from parity import make_paged_operands

from repro.kernels.paged_attention import gather_pages, paged_attention
from repro.kernels.ref import mha_ref


def build_paged(rng, B, T, Hkv, D, ps, garbage=100.0):
    """Dense K/V plus an equivalent shuffled, distractor-laden pool —
    pool construction shared with the parity harness (one layout helper,
    tests/parity.py::make_paged_operands)."""
    k = jnp.asarray(rng.standard_normal((B, T, Hkv, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, T, Hkv, D)).astype(np.float32))
    kp, vp, bt = make_paged_operands(k, v, page_size=ps,
                                     seed=int(rng.integers(1 << 16)),
                                     garbage=garbage)
    return k, v, kp, vp, bt


@pytest.mark.parametrize("case", [
    # name, B, Sq, T, H, Hkv, ps, causal, q_offsets, kv_lens
    ("prefill", 2, 32, 32, 4, 4, 8, True, None, None),
    ("prefill_gqa_ragged", 2, 33, 33, 4, 2, 8, True, None, None),
    ("decode_offsets", 3, 1, 96, 4, 2, 16, True, (5, 80, 37), (6, 81, 38)),
    ("decode_masked_row", 3, 1, 64, 2, 1, 16, True, (12, -1, 3), (13, 0, 4)),
    ("chunked_prefill", 2, 8, 64, 2, 2, 16, True, (24, 40), (32, 48)),
    ("noncausal_ragged", 2, 17, 45, 2, 1, 16, False, None, (45, 29)),
], ids=lambda c: c[0])
def test_paged_kernel_matches_ref(case):
    name, B, Sq, T, H, Hkv, ps, causal, q_off, kv_lens = case
    D = 16
    rng = np.random.default_rng(hash(name) % 2**32)
    k, v, kp, vp, bt = build_paged(rng, B, T, Hkv, D, ps)
    q = jnp.asarray(rng.standard_normal((B, Sq, H, D)).astype(np.float32))
    if q_off is None:
        qpos = jnp.broadcast_to(
            jnp.arange(Sq, dtype=jnp.int32) + (T - Sq), (B, Sq))
    else:
        offs = np.asarray(q_off, np.int32)[:, None]
        qpos = jnp.asarray(np.where(
            offs < 0, -1, offs + np.arange(Sq)[None]).astype(np.int32))
    kvl = jnp.asarray(np.asarray(kv_lens, np.int32) if kv_lens is not None
                      else np.full((B,), T, np.int32))
    out = paged_attention(q, kp, vp, bt, qpos, kvl, causal=causal,
                          block_q=8, interpret=True)
    ref = mha_ref(q, k, v, causal=causal, q_positions=qpos,
                  kv_valid_len=kvl)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=3e-5, rtol=3e-5)
    masked = np.asarray(qpos)[:, 0] < 0
    if masked.any():
        assert np.abs(np.asarray(out, np.float32)[masked]).max() == 0.0


def test_unallocated_tail_entries_are_dead():
    """Block-table entries past kv_valid_len may point anywhere valid (the
    engine leaves them at 0): they must contribute nothing."""
    rng = np.random.default_rng(7)
    B, T, H, D, ps = 2, 24, 2, 8, 8
    k, v, kp, vp, bt = build_paged(rng, B, T, H, D, ps)
    q = jnp.asarray(rng.standard_normal((B, 1, H, D)).astype(np.float32))
    qpos = jnp.asarray([[4], [9]], jnp.int32)
    kvl = jnp.asarray([5, 10], jnp.int32)
    base = paged_attention(q, kp, vp, bt, qpos, kvl, block_q=8,
                           interpret=True)
    # rewrite every tail entry (blocks past the valid prefix) to page 0
    bt_n = np.asarray(bt).copy()
    for b in range(B):
        first_dead = -(-int(np.asarray(kvl)[b]) // ps)
        bt_n[b, first_dead:] = 0
    redirected = paged_attention(q, kp, vp, jnp.asarray(bt_n), qpos, kvl,
                                 block_q=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(redirected))


def test_gather_pages_inverts_layout():
    rng = np.random.default_rng(3)
    B, T, H, D, ps = 3, 40, 2, 8, 8
    k, v, kp, vp, bt = build_paged(rng, B, T, H, D, ps)
    np.testing.assert_array_equal(
        np.asarray(gather_pages(kp, bt, T)), np.asarray(k))
    np.testing.assert_array_equal(
        np.asarray(gather_pages(vp, bt, T)), np.asarray(v))


def test_empty_block_table_returns_zeros():
    """Regression: n_blocks == 0 (a zero-token probe) used to build a
    grid=(B, H, nq, 0) whose flush step never ran, returning uninitialized
    output. With no key block visible, the masked-row contract demands
    exactly zeros."""
    rng = np.random.default_rng(5)
    B, H, D, ps, P = 2, 2, 8, 8, 4
    q = jnp.asarray(rng.standard_normal((B, 3, H, D)).astype(np.float32))
    kp = jnp.asarray(rng.standard_normal((P, ps, H, D)).astype(np.float32))
    vp = jnp.asarray(rng.standard_normal((P, ps, H, D)).astype(np.float32))
    bt = jnp.zeros((B, 0), jnp.int32)
    out = paged_attention(q, kp, vp, bt, block_q=8, interpret=True)
    assert out.shape == (B, 3, H, D) and out.dtype == q.dtype
    assert np.abs(np.asarray(out, np.float32)).max() == 0.0
    # same contract on the quantized path
    from repro.core.quant import quantize_kv_pages
    qk, ks = quantize_kv_pages(kp)
    qv, vs = quantize_kv_pages(vp)
    out_q = paged_attention(q, qk, qv, bt, kv_scales=(ks, vs), block_q=8,
                            interpret=True)
    assert np.abs(np.asarray(out_q, np.float32)).max() == 0.0


def test_int8_pages_dequantize_in_kernel():
    """int8 pools + per-page-per-head scales must match the fp oracle run
    on the DEQUANTIZED pool exactly (up to fp tolerance): the kernel's
    in-fetch dequant is the only thing under test, not the quantization
    error itself."""
    from repro.core.quant import dequantize_kv_pages, quantize_kv_pages
    rng = np.random.default_rng(13)
    B, T, H, Hkv, D, ps = 2, 48, 4, 2, 16, 16
    k, v, kp, vp, bt = build_paged(rng, B, T, Hkv, D, ps)
    q = jnp.asarray(rng.standard_normal((B, 4, H, D)).astype(np.float32))
    qpos = jnp.asarray(np.stack([np.arange(4) + 30, np.arange(4) + 11])
                       .astype(np.int32))
    kvl = jnp.asarray([34, 15], jnp.int32)
    qk, ks = quantize_kv_pages(kp)
    qv, vs = quantize_kv_pages(vp)
    out = paged_attention(q, qk, qv, bt, qpos, kvl, kv_scales=(ks, vs),
                          block_q=8, interpret=True)
    ref = mha_ref(q, gather_pages(dequantize_kv_pages(qk, ks), bt, T),
                  gather_pages(dequantize_kv_pages(qv, vs), bt, T),
                  q_positions=qpos, kv_valid_len=kvl)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=3e-5, rtol=3e-5)


def test_int8_pages_validation():
    """int8 pools without scales, wrong-shape scales, fp pools WITH
    scales, and mixed int8/fp pools must all be rejected loudly."""
    from repro.core.quant import quantize_kv_pages
    rng = np.random.default_rng(17)
    B, T, Hkv, D, ps = 1, 16, 2, 8, 8
    k, v, kp, vp, bt = build_paged(rng, B, T, Hkv, D, ps)
    q = jnp.asarray(rng.standard_normal((B, 2, Hkv, D)).astype(np.float32))
    qk, ks = quantize_kv_pages(kp)
    qv, vs = quantize_kv_pages(vp)
    with pytest.raises(ValueError, match="kv_scales"):
        paged_attention(q, qk, qv, bt, interpret=True)
    with pytest.raises(ValueError, match="shape"):
        paged_attention(q, qk, qv, bt, kv_scales=(ks, vs[:, :1]),
                        interpret=True)
    with pytest.raises(ValueError, match="not int8"):
        paged_attention(q, kp, vp, bt, kv_scales=(ks, vs), interpret=True)
    with pytest.raises(ValueError, match="dtype mismatch"):
        paged_attention(q, qk, vp, bt, kv_scales=(ks, vs), interpret=True)


def test_soft_cap_and_bf16():
    rng = np.random.default_rng(11)
    B, T, H, D, ps = 1, 32, 2, 16, 16
    k, v, kp, vp, bt = build_paged(rng, B, T, H, D, ps, garbage=3.0)
    q = jnp.asarray(rng.standard_normal((B, 16, H, D)).astype(np.float32))
    for dt, tol in ((jnp.float32, 3e-5), (jnp.bfloat16, 3e-2)):
        out = paged_attention(q.astype(dt), kp.astype(dt), vp.astype(dt),
                              bt, causal=True, soft_cap=5.0, block_q=8,
                              interpret=True,
                              q_positions=jnp.broadcast_to(
                                  jnp.arange(16, dtype=jnp.int32) + 16,
                                  (B, 16)),
                              kv_valid_len=jnp.full((B,), T, jnp.int32))
        ref = mha_ref(q.astype(dt), k.astype(dt), v.astype(dt), causal=True,
                      soft_cap=5.0,
                      q_positions=jnp.broadcast_to(
                          jnp.arange(16, dtype=jnp.int32) + 16, (B, 16)),
                      kv_valid_len=jnp.full((B,), T, jnp.int32))
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=tol, rtol=tol)
