"""core/quant.py: symmetric per-channel INT8 quantization.

Property tests (via hypcompat) bound the quantize→dequantize error by half
a scale step per element; golden-value tests pin a fixed-seed quantized
transformer forward against committed reference outputs so quantization
regressions are caught without a TPU.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.core import api
from repro.core import quant as Q
from repro.core.plan import GemmPolicy, PackedWeight, QuantizedPackedWeight

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "int8_forward.npz")


# ---------------------------------------------------------------------------
# Quantize → dequantize error bounds (property)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), k=st.integers(1, 96),
       n=st.integers(1, 96), scale_pow=st.integers(-8, 8))
def test_weight_roundtrip_error_half_step(seed, k, n, scale_pow):
    """|w - dequant(quantize(w))| ≤ scale/2 per element, any magnitude."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32)
                    * 2.0 ** scale_pow)
    q, scales = Q.quantize_weight(w)
    assert q.dtype == jnp.int8 and scales.shape == (n,)
    assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= Q.QMAX
    deq = Q.dequantize_weight(q, scales)
    # half a quantization step, plus fp32 rounding slop in scale/divide/mult
    err = np.abs(np.asarray(deq) - np.asarray(w))
    bound = np.asarray(scales)[None, :] * (0.5 + 1e-4) + 1e-30
    np.testing.assert_array_less(err, np.broadcast_to(bound, err.shape))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), m=st.integers(1, 64),
       k=st.integers(1, 64))
def test_activation_roundtrip_error_half_step(seed, m, k):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    q, scales = Q.quantize_activations(x)
    assert q.dtype == jnp.int8 and scales.shape == (m,)
    deq = np.asarray(q, np.float32) * np.asarray(scales)[:, None]
    err = np.abs(deq - np.asarray(x))
    bound = np.asarray(scales)[:, None] * (0.5 + 1e-4) + 1e-30
    np.testing.assert_array_less(err, np.broadcast_to(bound, err.shape))


def test_zero_channels_are_safe():
    """All-zero columns/rows quantize to exact zeros with scale 1 — no NaN
    or division blow-up."""
    w = jnp.zeros((16, 4), jnp.float32)
    q, s = Q.quantize_weight(w)
    np.testing.assert_array_equal(np.asarray(q), 0)
    np.testing.assert_array_equal(np.asarray(s), 1.0)
    x = jnp.zeros((3, 16), jnp.float32)
    qa, sa = Q.quantize_activations(x)
    np.testing.assert_array_equal(np.asarray(qa), 0)
    np.testing.assert_array_equal(np.asarray(sa), 1.0)


def test_per_channel_scales_isolate_columns():
    """A huge outlier in one column must not degrade the others (the point
    of per-channel granularity)."""
    rng = np.random.default_rng(0)
    w = rng.standard_normal((64, 8)).astype(np.float32)
    w[:, 3] *= 1e4
    q, s = Q.quantize_weight(jnp.asarray(w))
    deq, s = np.asarray(Q.dequantize_weight(q, s)), np.asarray(s)
    small = [c for c in range(8) if c != 3]
    assert np.abs(deq[:, small] - w[:, small]).max() < 0.5 * s[small].max()


# ---------------------------------------------------------------------------
# QuantizedPackedWeight (block-major residency)
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(k=st.integers(1, 130), n=st.integers(1, 140))
def test_quantized_pack_roundtrip_non_divisible(k, n):
    """Pack → unpack recovers the quantized weight exactly on any geometry,
    including shapes that don't divide the block dims."""
    rng = np.random.default_rng(k * 1000 + n)
    w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    qw = api.pack_weight(w, GemmPolicy(), quantize="int8")
    assert isinstance(qw, QuantizedPackedWeight)
    assert qw.shape == (k, n) and qw.dtype == jnp.int8
    q_ref, s_ref = Q.quantize_weight(w)
    np.testing.assert_array_equal(np.asarray(qw.unpack_quantized()),
                                  np.asarray(q_ref))
    np.testing.assert_array_equal(np.asarray(qw.scales), np.asarray(s_ref))


def test_quantized_packed_is_pytree():
    """jit/tree_map must trace through data+scales and keep geometry static."""
    w = jnp.asarray(np.random.default_rng(0)
                    .standard_normal((32, 16)).astype(np.float32))
    qw = api.pack_weight(w, GemmPolicy(), quantize="int8")
    leaves, treedef = jax.tree_util.tree_flatten(qw)
    assert len(leaves) == 2
    qw2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert (qw2.k, qw2.n, qw2.bk, qw2.bn) == (qw.k, qw.n, qw.bk, qw.bn)
    x = jnp.ones((4, 32), jnp.float32)
    y = jax.jit(lambda xx, ww: api.linear(xx, ww))(x, qw)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(api.linear(x, qw)),
                               atol=1e-6)


def test_pack_model_weights_quantize():
    """quantize="int8" turns every projection weight into a
    QuantizedPackedWeight; non-GEMM params pass through."""
    from repro.configs.registry import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("smollm-135m", n_layers=1, vocab=32)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    packed = api.pack_model_weights(params, quantize="int8")
    assert isinstance(packed["head"], QuantizedPackedWeight)
    assert isinstance(packed["layers"]["attn"]["wq"], QuantizedPackedWeight)
    assert not isinstance(packed["embed"], (PackedWeight,
                                            QuantizedPackedWeight))
    # weight_dtype on the policy is the equivalent spelling
    packed2 = api.pack_model_weights(params,
                                     GemmPolicy(weight_dtype="int8"))
    assert isinstance(packed2["head"], QuantizedPackedWeight)


def test_policy_rejects_unknown_weight_dtype():
    with pytest.raises(ValueError, match="weight_dtype"):
        GemmPolicy(weight_dtype="int4")
    with pytest.raises(ValueError, match="quantize"):
        api.pack_weight(jnp.ones((8, 8)), quantize="fp8")


def test_policy_rejects_acc_override_on_quantized_route():
    """int8×int8 accumulates in int32 by construction; an acc_dtype
    override would be silently ignored, so the policy refuses it."""
    with pytest.raises(ValueError, match="acc_dtype"):
        GemmPolicy(weight_dtype="int8", acc_dtype="float32")


# ---------------------------------------------------------------------------
# Golden values: fixed-seed quantized transformer forward
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def golden():
    return np.load(GOLDEN)


def _golden_forward(weight_dtype):
    from repro.configs.registry import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=32)
    params, _ = T.init_model(jax.random.PRNGKey(1234), cfg)
    tokens = np.asarray(
        np.random.default_rng(42).integers(0, 32, (2, 6)), np.int32)
    batch = {"tokens": jnp.asarray(tokens)}
    pol = GemmPolicy(weight_dtype=weight_dtype)
    with api.use_policy(pol):
        logits, _, _ = T.forward(params, cfg, batch)
    return tokens, np.asarray(logits, np.float32)


def test_golden_int8_forward(golden):
    """The quantized forward must reproduce the committed logits within a
    small drift budget (bf16 ulp-level differences across XLA versions),
    and sit within the committed quantization-error budget of the fp run."""
    tokens, q = _golden_forward("int8")
    np.testing.assert_array_equal(tokens, golden["tokens"])
    assert np.abs(q - golden["int8_logits"]).max() <= 1e-2
    # quantization error vs the fp32-path logits stays bounded
    assert np.abs(q - golden["fp_logits"]).max() <= 8e-2


def test_golden_fp_forward_unchanged(golden):
    """The unquantized forward pins the same committed reference — separates
    'quantization regressed' from 'the model itself changed'."""
    _, fp = _golden_forward(None)
    assert np.abs(fp - golden["fp_logits"]).max() <= 1e-2
