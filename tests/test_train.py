"""Training substrate: optimizer math, schedules, checkpoint fault tolerance,
data pipeline determinism, loss-goes-down integration."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.optim.adamw import quantize_int8
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import TrainConfig, Trainer


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    """AdamW must drive a toy quadratic to its minimum."""
    params = {"w": jnp.asarray([5.0, -3.0])}
    target = jnp.asarray([1.0, 2.0])
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    state = adamw_init(params)
    for _ in range(300):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _, _ = adamw_update(params, grads, state, cfg,
                                           jnp.asarray(0.05))
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_grad_clip_caps_update():
    params = {"w": jnp.zeros((4,))}
    cfg = AdamWConfig(grad_clip=1.0, weight_decay=0.0)
    state = adamw_init(params)
    huge = {"w": jnp.full((4,), 1e6)}
    _, _, metrics, _ = adamw_update(params, huge, state, cfg,
                                    jnp.asarray(1e-3))
    assert float(metrics["grad_norm"]) == pytest.approx(2e6, rel=1e-3)
    # all fine: the clipped update is (lr * mhat/...) bounded; just no NaN
    assert np.isfinite(float(metrics["grad_norm"]))


def test_int8_quantize_error_feedback():
    g = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                    jnp.float32)
    err = jnp.zeros_like(g)
    total_deq = jnp.zeros_like(g)
    # error feedback: accumulated dequantized grads converge to the truth
    for _ in range(64):
        deq, err = quantize_int8(g, err)
        total_deq = total_deq + deq
    avg = total_deq / 64
    np.testing.assert_allclose(np.asarray(avg), np.asarray(g), atol=2e-2)


def test_cosine_schedule_shape():
    lr0 = float(cosine_schedule(jnp.asarray(0), base_lr=1.0, warmup=10,
                                total=100))
    lr_w = float(cosine_schedule(jnp.asarray(10), base_lr=1.0, warmup=10,
                                 total=100))
    lr_end = float(cosine_schedule(jnp.asarray(100), base_lr=1.0, warmup=10,
                                   total=100))
    assert lr0 == pytest.approx(0.1)    # non-zero at step 0 (first batch counts)
    assert lr_w == pytest.approx(1.0)
    assert lr_end == pytest.approx(0.1, abs=1e-6)   # min_frac floor


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def _state():
    return {"params": {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                       "nested": {"b": jnp.ones((4,), jnp.bfloat16)}},
            "opt": {"step": jnp.asarray(7, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_save=False)
    state = _state()
    cm.save(3, state, extra={"data": {"cursor": 11, "seed": 0}})
    out = cm.restore(state)
    np.testing.assert_array_equal(np.asarray(out["params"]["a"]),
                                  np.asarray(state["params"]["a"]))
    assert out["params"]["nested"]["b"].dtype == jnp.bfloat16
    assert cm.meta()["extra"]["data"]["cursor"] == 11


def test_checkpoint_keep_n_gc(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        cm.save(s, _state())
    assert cm.all_steps() == [3, 4]


def test_checkpoint_atomic_no_partial(tmp_path):
    """A crash mid-save must never corrupt the published checkpoints: temp
    dirs are invisible to all_steps()/latest_step()."""
    cm = CheckpointManager(str(tmp_path), async_save=False)
    cm.save(1, _state())
    os.makedirs(os.path.join(str(tmp_path), ".tmp_step_2"), exist_ok=True)
    with open(os.path.join(str(tmp_path), ".tmp_step_2", "arrays.npz"),
              "wb") as f:
        f.write(b"partial garbage")
    assert cm.all_steps() == [1]
    assert cm.latest_step() == 1


def test_checkpoint_async_save(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_save=True)
    cm.save(5, _state())
    cm.wait()
    assert cm.latest_step() == 5


def test_restore_specific_step(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=5, async_save=False)
    s = _state()
    cm.save(1, s)
    s2 = {"params": {"a": s["params"]["a"] + 100,
                     "nested": s["params"]["nested"]},
          "opt": s["opt"]}
    cm.save(2, s2)
    out1 = cm.restore(s, step=1)
    out2 = cm.restore(s, step=2)
    assert float(out2["params"]["a"][0, 1] - out1["params"]["a"][0, 1]) == 100


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_restartable():
    cfg = DataConfig(seq_len=16, global_batch=4, vocab=100, seed=3)
    p1 = TokenPipeline(cfg)
    batches = [p1.next_batch()["tokens"] for _ in range(3)]
    # restore from cursor=1 → identical batch #2
    p2 = TokenPipeline(cfg)
    p2.load_state_dict({"cursor": 1, "seed": 3})
    np.testing.assert_array_equal(p2.next_batch()["tokens"], batches[1])


def test_pipeline_host_sharding_partitions_batch():
    cfg = DataConfig(seq_len=8, global_batch=8, vocab=50, seed=0)
    full = TokenPipeline(cfg).next_batch()["tokens"]
    shard0 = TokenPipeline(cfg, host_id=0, n_hosts=2).next_batch()["tokens"]
    shard1 = TokenPipeline(cfg, host_id=1, n_hosts=2).next_batch()["tokens"]
    np.testing.assert_array_equal(np.concatenate([shard0, shard1]), full)


def test_pipeline_codebook_shape():
    cfg = DataConfig(seq_len=8, global_batch=2, vocab=50, n_codebooks=4)
    t = TokenPipeline(cfg).next_batch()["tokens"]
    assert t.shape == (2, 8, 4)


def test_pipeline_tokens_in_vocab():
    cfg = DataConfig(seq_len=64, global_batch=4, vocab=37)
    t = TokenPipeline(cfg).next_batch()["tokens"]
    assert t.min() >= 0 and t.max() < 37


# ---------------------------------------------------------------------------
# Trainer integration: loss decreases + resume mid-run
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_trainer_loss_decreases(tmp_path):
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=128)
    dc = DataConfig(seq_len=32, global_batch=8, vocab=cfg.vocab, seed=0)
    tc = TrainConfig(steps=30, log_every=10, ckpt_every=0,
                     ckpt_dir=None, base_lr=3e-3, warmup=5)
    out = Trainer(cfg, dc, tc).run()
    (s0, l0), (s1, l1) = out["history"][0], out["history"][-1]
    assert l1 < l0 - 0.2, f"loss did not decrease: {l0} → {l1}"


@pytest.mark.slow
def test_trainer_resume_exact(tmp_path):
    """Train 10 steps, checkpoint at 5; resume-from-5 path must produce the
    same final params as the uninterrupted run (bitwise, CPU determinism)."""
    cfg = get_smoke_config("qwen2-1.5b", n_layers=2, vocab=128)
    dc = DataConfig(seq_len=16, global_batch=4, vocab=cfg.vocab, seed=1)

    tc_full = TrainConfig(steps=10, log_every=100, ckpt_every=5,
                          ckpt_dir=str(tmp_path / "full"), base_lr=1e-3)
    full = Trainer(cfg, dc, tc_full).run()

    # simulate preemption: run 5 steps only
    tc_a = TrainConfig(steps=5, log_every=100, ckpt_every=5,
                       ckpt_dir=str(tmp_path / "resume"), base_lr=1e-3)
    Trainer(cfg, dc, tc_a).run()
    # restart for the remaining 5
    tc_b = TrainConfig(steps=10, log_every=100, ckpt_every=5,
                       ckpt_dir=str(tmp_path / "resume"), base_lr=1e-3)
    resumed = Trainer(cfg, dc, tc_b).run()

    fa = jax.tree_util.tree_leaves(full["params"])
    fb = jax.tree_util.tree_leaves(resumed["params"])
    for a, b in zip(fa, fb):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-6, rtol=1e-6)
