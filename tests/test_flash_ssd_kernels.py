"""Shape/dtype sweeps for the flash-attention and SSD Pallas kernels vs
their pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import mha_ref, ssd_ref
from repro.models.ssm import ssd_chunked

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (B, Sq, H, Hkv, D, bq, bk)
    (1, 64, 4, 4, 32, 32, 32),      # MHA
    (2, 64, 4, 2, 32, 32, 32),      # GQA 2:1
    (1, 128, 8, 1, 64, 64, 64),     # MQA
    (1, 96, 2, 2, 32, 64, 32),      # ragged q blocks (96 = 1.5×64 → pad)
    (2, 33, 2, 1, 16, 32, 32),      # S not block multiple
]


@pytest.mark.parametrize("case", FLASH_CASES,
                         ids=lambda c: "B{}S{}H{}kv{}D{}".format(*c[:5]))
def test_flash_matches_ref_causal(case):
    B, S, H, Hkv, D, bq, bk = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    ref = mha_ref(q, k, v, causal=True)
    out = ops.mha(q, k, v, causal=True, impl="interpret",
                  block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_noncausal():
    B, S, H, D = 1, 64, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    ref = mha_ref(q, k, v, causal=False)
    out = ops.mha(q, k, v, causal=False, impl="interpret",
                  block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_noncausal_ragged_keys():
    """Sk not a block multiple with a per-row KV length mask — the old
    kernel raised ValueError here; padded key blocks must now contribute
    exactly zero weight."""
    B, Sq, Sk, H, D = 2, 17, 45, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Sk, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Sk, H, D), jnp.float32)
    kv_len = jnp.asarray([45, 29], jnp.int32)
    ref = mha_ref(q, k, v, causal=False, kv_valid_len=kv_len)
    out = ops.mha(q, k, v, causal=False, kv_valid_len=kv_len,
                  impl="interpret", block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_decode_offsets_long_cache():
    """Sq=1 against a long, partially populated cache: per-row query
    positions + valid lengths (the serving decode shape)."""
    B, T, H, Hkv, D = 4, 128, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), jnp.float32)
    qpos = jnp.asarray([[0], [17], [63], [127]], jnp.int32)
    kv_len = jnp.asarray([1, 18, 64, 128], jnp.int32)
    ref = mha_ref(q, k, v, causal=True, q_positions=qpos, kv_valid_len=kv_len)
    out = ops.mha(q, k, v, causal=True, q_positions=qpos, kv_valid_len=kv_len,
                  impl="interpret", block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_masked_rows_zero():
    """Serving's position −1 rows: no valid key → exactly-zero output, no
    NaN — and live rows in the same batch are unaffected."""
    B, T, H, D = 3, 64, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, H, D), jnp.float32)
    qpos = jnp.asarray([[9], [-1], [30]], jnp.int32)
    kv_len = jnp.asarray([10, 0, 31], jnp.int32)
    out = np.asarray(ops.mha(q, k, v, causal=True, q_positions=qpos,
                             kv_valid_len=kv_len, impl="interpret",
                             block_q=32, block_k=32))
    assert np.isfinite(out).all()
    assert np.abs(out[1]).max() == 0.0
    ref = np.asarray(mha_ref(q, k, v, causal=True, q_positions=qpos,
                             kv_valid_len=kv_len))
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_flash_chunked_prefill_offset_gqa():
    """A chunk of queries continuing an existing cache (offset > 0) under
    GQA head grouping — the serving prefill-continuation shape."""
    B, Sq, T, H, Hkv, D = 2, 16, 96, 4, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), jnp.float32)
    offs = jnp.asarray([24, 50], jnp.int32)
    qpos = offs[:, None] + jnp.arange(Sq)[None, :]
    kv_len = offs + Sq
    ref = mha_ref(q, k, v, causal=True, q_positions=qpos, kv_valid_len=kv_len)
    out = ops.mha(q, k, v, causal=True, q_positions=qpos, kv_valid_len=kv_len,
                  impl="interpret", block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_bottom_right_aligned_default():
    """Sq < Sk with no explicit positions: the default is bottom-right
    aligned (query i sees keys ≤ i + Sk − Sq), matching mha_ref's tril."""
    B, Sq, Sk, H, D = 1, 16, 64, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Sk, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Sk, H, D), jnp.float32)
    ref = mha_ref(q, k, v, causal=True)
    out = ops.mha(q, k, v, causal=True, impl="interpret",
                  block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_soft_cap():
    """Logit soft-capping (gemma2-style) folds into the fused kernel."""
    B, S, H, D = 1, 32, 2, 16
    ks = jax.random.split(KEY, 3)
    q = 3.0 * jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = 3.0 * jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    ref = mha_ref(q, k, v, causal=True, soft_cap=20.0)
    out = ops.mha(q, k, v, causal=True, soft_cap=20.0, impl="interpret",
                  block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_bf16():
    B, S, H, D = 1, 64, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.bfloat16)
    ref = mha_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                  v.astype(jnp.float32), causal=True)
    out = ops.mha(q, k, v, causal=True, impl="interpret",
                  block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=5e-2, rtol=5e-2)


def test_flash_long_context_streams_blocks():
    """Many K blocks per Q block — exercises the online-softmax recurrence."""
    B, S, H, D = 1, 512, 1, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    ref = mha_ref(q, k, v, causal=True)
    out = ops.mha(q, k, v, causal=True, impl="interpret",
                  block_q=128, block_k=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    # (B, S, H, P, N, chunk)
    (1, 16, 1, 4, 8, 4),
    (2, 32, 3, 8, 16, 8),
    (1, 64, 2, 16, 32, 16),
    (2, 48, 2, 8, 16, 16),       # S not a power of two
    (1, 128, 4, 64, 128, 64),    # production-like head dims
]


@pytest.mark.parametrize("case", SSD_CASES,
                         ids=lambda c: "B{}S{}H{}P{}N{}q{}".format(*c))
def test_ssd_kernel_matches_ref(case):
    B, S, H, P, N, chunk = case
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bc = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cc = jax.random.normal(ks[4], (B, S, N)) * 0.5
    ref = ssd_ref(x, dt, A, Bc, Cc)
    out = ops.ssd(x, dt, A, Bc, Cc, chunk=chunk, impl="interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-4, rtol=5e-4)


def test_ssd_kernel_matches_model_chunked():
    """The Pallas kernel and the model's lax implementation must agree —
    they are the same algorithm on different substrates."""
    B, S, H, P, N = 2, 32, 2, 8, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bc = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cc = jax.random.normal(ks[4], (B, S, N)) * 0.5
    via_lax, _ = ssd_chunked(x, dt, A, Bc, Cc, chunk=8)
    via_pallas = ops.ssd(x, dt, A, Bc, Cc, chunk=8, impl="interpret")
    np.testing.assert_allclose(np.asarray(via_pallas), np.asarray(via_lax),
                               atol=1e-4, rtol=1e-4)


def test_ssd_decay_extremes():
    """Very fast decay (large dt·|A|) must not produce NaN/inf."""
    B, S, H, P, N = 1, 16, 1, 4, 8
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jnp.full((B, S, H), 20.0)          # extreme step size
    A = jnp.asarray([-8.0])
    Bc = jax.random.normal(ks[1], (B, S, N))
    Cc = jax.random.normal(ks[2], (B, S, N))
    out = ops.ssd(x, dt, A, Bc, Cc, chunk=4, impl="interpret")
    assert bool(jnp.isfinite(out).all())
