"""Shape/dtype sweeps for the flash-attention and SSD Pallas kernels vs
their pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import mha_ref, ssd_ref
from repro.models.ssm import ssd_chunked

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (B, Sq, H, Hkv, D, bq, bk)
    (1, 64, 4, 4, 32, 32, 32),      # MHA
    (2, 64, 4, 2, 32, 32, 32),      # GQA 2:1
    (1, 128, 8, 1, 64, 64, 64),     # MQA
    (1, 96, 2, 2, 32, 64, 32),      # ragged q blocks (96 = 1.5×64 → pad)
    (2, 33, 2, 1, 16, 32, 32),      # S not block multiple
]


@pytest.mark.parametrize("case", FLASH_CASES,
                         ids=lambda c: "B{}S{}H{}kv{}D{}".format(*c[:5]))
def test_flash_matches_ref_causal(case):
    B, S, H, Hkv, D, bq, bk = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    ref = mha_ref(q, k, v, causal=True)
    out = ops.mha(q, k, v, causal=True, impl="interpret",
                  block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_noncausal():
    B, S, H, D = 1, 64, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    ref = mha_ref(q, k, v, causal=False)
    out = ops.mha(q, k, v, causal=False, impl="interpret",
                  block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_bf16():
    B, S, H, D = 1, 64, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.bfloat16)
    ref = mha_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                  v.astype(jnp.float32), causal=True)
    out = ops.mha(q, k, v, causal=True, impl="interpret",
                  block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=5e-2, rtol=5e-2)


def test_flash_long_context_streams_blocks():
    """Many K blocks per Q block — exercises the online-softmax recurrence."""
    B, S, H, D = 1, 512, 1, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    ref = mha_ref(q, k, v, causal=True)
    out = ops.mha(q, k, v, causal=True, impl="interpret",
                  block_q=128, block_k=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    # (B, S, H, P, N, chunk)
    (1, 16, 1, 4, 8, 4),
    (2, 32, 3, 8, 16, 8),
    (1, 64, 2, 16, 32, 16),
    (2, 48, 2, 8, 16, 16),       # S not a power of two
    (1, 128, 4, 64, 128, 64),    # production-like head dims
]


@pytest.mark.parametrize("case", SSD_CASES,
                         ids=lambda c: "B{}S{}H{}P{}N{}q{}".format(*c))
def test_ssd_kernel_matches_ref(case):
    B, S, H, P, N, chunk = case
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bc = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cc = jax.random.normal(ks[4], (B, S, N)) * 0.5
    ref = ssd_ref(x, dt, A, Bc, Cc)
    out = ops.ssd(x, dt, A, Bc, Cc, chunk=chunk, impl="interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-4, rtol=5e-4)


def test_ssd_kernel_matches_model_chunked():
    """The Pallas kernel and the model's lax implementation must agree —
    they are the same algorithm on different substrates."""
    B, S, H, P, N = 2, 32, 2, 8, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bc = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cc = jax.random.normal(ks[4], (B, S, N)) * 0.5
    via_lax, _ = ssd_chunked(x, dt, A, Bc, Cc, chunk=8)
    via_pallas = ops.ssd(x, dt, A, Bc, Cc, chunk=8, impl="interpret")
    np.testing.assert_allclose(np.asarray(via_pallas), np.asarray(via_lax),
                               atol=1e-4, rtol=1e-4)


def test_ssd_decay_extremes():
    """Very fast decay (large dt·|A|) must not produce NaN/inf."""
    B, S, H, P, N = 1, 16, 1, 4, 8
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jnp.full((B, S, H), 20.0)          # extreme step size
    A = jnp.asarray([-8.0])
    Bc = jax.random.normal(ks[1], (B, S, N))
    Cc = jax.random.normal(ks[2], (B, S, N))
    out = ops.ssd(x, dt, A, Bc, Cc, chunk=4, impl="interpret")
    assert bool(jnp.isfinite(out).all())
