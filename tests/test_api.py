"""core/api.py backend dispatch: all backends agree; batched shapes route
correctly; backend context manager restores state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api
from repro.kernels.ref import matmul_ref


def test_default_backend_is_xla_on_cpu():
    assert api.current_backend() == "xla"


def test_backend_context_restores():
    with api.gemm_backend("blockflow"):
        assert api.current_backend() == "blockflow"
        with api.gemm_backend("pallas_interpret"):
            assert api.current_backend() == "pallas_interpret"
        assert api.current_backend() == "blockflow"
    assert api.current_backend() == "xla"


@pytest.mark.parametrize("backend", ["xla", "blockflow", "pallas_interpret"])
def test_backends_agree_2d(backend):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((96, 128)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((128, 64)).astype(np.float32))
    ref = matmul_ref(a, b)
    with api.gemm_backend(backend):
        out = api.matmul(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-5)


@pytest.mark.parametrize("backend", ["xla", "blockflow", "pallas_interpret"])
def test_backends_agree_batched_lhs(backend):
    """(B, S, K) @ (K, N) — the layer 'linear' shape."""
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((2, 5, 32)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32))
    ref = jnp.einsum("bsk,kn->bsn", a, w)
    with api.gemm_backend(backend):
        out = api.matmul(a, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("backend", ["blockflow", "pallas_interpret"])
def test_backends_agree_batched_both(backend):
    """(B, M, K) @ (B, K, N) — the attention-scores shape."""
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.standard_normal((3, 8, 16)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((3, 16, 12)).astype(np.float32))
    ref = jnp.einsum("bmk,bkn->bmn", a, b)
    with api.gemm_backend(backend):
        out = api.matmul(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_linear_bias():
    a = jnp.ones((2, 4))
    w = jnp.ones((4, 3))
    bias = jnp.asarray([1.0, 2.0, 3.0])
    out = api.linear(a, w, bias)
    np.testing.assert_allclose(np.asarray(out[0]), [5.0, 6.0, 7.0])


def test_model_forward_through_matrixflow_backend():
    """A small model runs end-to-end with every GEMM on the paper's path
    (blockflow on CPU; the Pallas kernel would serve on TPU)."""
    from repro.configs.registry import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("smollm-135m", n_layers=1, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.zeros((1, 8), jnp.int32)}
    ref_logits, _, _ = T.forward(params, cfg, batch)
    with api.gemm_backend("blockflow"):
        mf_logits, _, _ = T.forward(params, cfg, batch)
    np.testing.assert_allclose(np.asarray(mf_logits, np.float32),
                               np.asarray(ref_logits, np.float32),
                               atol=5e-2, rtol=5e-2)
