"""core/api.py policy dispatch: all backends agree; batched shapes route
correctly; policy context restores; deprecation shims still work."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api
from repro.core.plan import GemmPolicy
from repro.kernels.ref import matmul_ref

BACKENDS = ["xla", "blockflow", "pallas_interpret"]


def test_default_policy_resolves_xla_on_cpu():
    assert api.current_policy() == GemmPolicy()
    assert api.resolved_backend() == "xla"
    assert api.prefers_einsum()


def test_policy_context_restores():
    with api.use_policy(GemmPolicy(backend="blockflow")):
        assert api.resolved_backend() == "blockflow"
        assert not api.prefers_einsum()
        with api.use_policy(GemmPolicy(backend="pallas_interpret")):
            assert api.resolved_backend() == "pallas_interpret"
        assert api.resolved_backend() == "blockflow"
    assert api.resolved_backend() == "xla"


@pytest.mark.parametrize("backend", BACKENDS)
def test_backends_agree_2d(backend):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((96, 128)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((128, 64)).astype(np.float32))
    ref = matmul_ref(a, b)
    out = api.matmul(a, b, policy=GemmPolicy(backend=backend))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_backends_agree_batched_lhs(backend):
    """(B, S, K) @ (K, N) — the layer 'linear' shape."""
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((2, 5, 32)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32))
    ref = jnp.einsum("bsk,kn->bsn", a, w)
    with api.use_policy(GemmPolicy(backend=backend)):
        out = api.matmul(a, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# Batched-rhs dispatch (b.ndim != 2 → vmap recursion over leading dims)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_rhs_one_lead_dim(backend):
    """(B, M, K) @ (B, K, N) — the attention-scores shape."""
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.standard_normal((3, 8, 16)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((3, 16, 12)).astype(np.float32))
    ref = jnp.matmul(a, b)
    out = api.matmul(a, b, policy=GemmPolicy(backend=backend))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_rhs_two_lead_dims(backend):
    """(B, H, M, K) @ (B, H, K, N) — per-head attention batching."""
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.standard_normal((2, 4, 8, 16)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((2, 4, 16, 10)).astype(np.float32))
    ref = jnp.matmul(a, b)
    out = api.matmul(a, b, policy=GemmPolicy(backend=backend))
    assert out.shape == (2, 4, 8, 10)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_batched_rhs_mismatched_lead_dims_raises():
    a = jnp.zeros((2, 8, 16))
    b = jnp.zeros((3, 16, 4))
    with pytest.raises(AssertionError):
        api.matmul(a, b, policy=GemmPolicy(backend="blockflow"))


def test_linear_bias():
    a = jnp.ones((2, 4))
    w = jnp.ones((4, 3))
    bias = jnp.asarray([1.0, 2.0, 3.0])
    out = api.linear(a, w, bias)
    np.testing.assert_allclose(np.asarray(out[0]), [5.0, 6.0, 7.0])


def test_unknown_backend_raises():
    with pytest.raises(ValueError, match="unknown GEMM backend"):
        api.matmul(jnp.ones((4, 4)), jnp.ones((4, 4)),
                   policy=GemmPolicy(backend="nonesuch"))


def test_model_forward_through_matrixflow_backend():
    """A small model runs end-to-end with every GEMM on the paper's path
    (blockflow on CPU; the Pallas kernel would serve on TPU)."""
    from repro.configs.registry import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("smollm-135m", n_layers=1, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.zeros((1, 8), jnp.int32)}
    ref_logits, _, _ = T.forward(params, cfg, batch)
    with api.use_policy(GemmPolicy(backend="blockflow")):
        mf_logits, _, _ = T.forward(params, cfg, batch)
    np.testing.assert_allclose(np.asarray(mf_logits, np.float32),
                               np.asarray(ref_logits, np.float32),
                               atol=5e-2, rtol=5e-2)


# ---------------------------------------------------------------------------
# Deprecation shims (one release)
# ---------------------------------------------------------------------------

def test_gemm_backend_shim_warns_and_pins():
    with pytest.deprecated_call():
        with api.gemm_backend("blockflow"):
            assert api.current_backend() == "blockflow"
    assert api.current_backend() == "xla"


def test_matmul_mode_kw_shim_warns():
    a = jnp.ones((8, 16), jnp.float32)
    b = jnp.ones((16, 8), jnp.float32)
    with pytest.deprecated_call():
        out = api.matmul(a, b, policy=GemmPolicy(backend="blockflow"),
                         mode="dc")
    np.testing.assert_allclose(np.asarray(out), 16.0)
