"""Sharding rule engine: logical→mesh mapping, divisibility fallbacks,
mesh-axis dropping, and param-spec trees."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.registry import get_config
from repro.distributed import sharding as shd
from repro.models.module import ax


def one_device_mesh(axes=("data", "model")):
    dev = np.array(jax.devices()[:1]).reshape((1,) * len(axes))
    return Mesh(dev, axes)


def test_spec_basic_mapping():
    rules = shd.ShardingRules(one_device_mesh())
    assert rules.spec(("embed", "mlp")) == P("data", "model")
    assert rules.spec(("vocab", "embed")) == P("model", "data")
    assert rules.spec((None, "heads")) == P(None, "model")


def test_divisibility_fallback():
    """9 heads on a 16-way model axis must fall back to replicated — but only
    when a shape is provided to check against."""
    mesh = one_device_mesh()
    rules = shd.ShardingRules(mesh)
    # fake a 16-wide model axis by overriding _mesh_size via a fabricated mesh
    class Fake(shd.ShardingRules):
        def _mesh_size(self, axes):
            return 16 if axes == "model" else 1
    rules = Fake(mesh)
    spec = rules.spec(("embed", "heads"), shape=(576, 9 * 64))
    assert spec == P("data", "model")          # 576 % 16 == 0 on dim1
    spec = rules.spec((None, "heads"), shape=(1, 9))
    assert spec == P(None, None)               # 9 % 16 != 0 → replicate


def test_missing_mesh_axis_dropped():
    """'pod' doesn't exist on the single-pod mesh → silently dropped."""
    mesh = one_device_mesh(("data", "model"))
    rules = shd.ShardingRules(mesh)
    assert rules.spec(("act_batch",)) == P("data")   # ("pod","data") → data


def test_overrides_take_precedence():
    rules = shd.ShardingRules(one_device_mesh(), overrides={"heads": None})
    assert rules.spec(("embed", "heads")) == P("data", None)


def test_smollm_overrides_replicate_attention():
    cfg = get_config("smollm-135m")
    rules = shd.ShardingRules(one_device_mesh(), cfg.overrides_dict())
    assert rules.spec(("embed", "heads")) == P("data", None)
    assert rules.spec(("embed", "mlp")) == P("data", "model")  # d_ff still TP


def test_param_specs_tree():
    rules = shd.ShardingRules(one_device_mesh())
    axes = {"w": ax("embed", "mlp"), "b": ax("mlp"),
            "nested": {"v": ax("vocab", "embed")}}
    shapes = {"w": jax.ShapeDtypeStruct((128, 256), jnp.float32),
              "b": jax.ShapeDtypeStruct((256,), jnp.float32),
              "nested": {"v": jax.ShapeDtypeStruct((512, 128), jnp.float32)}}
    specs = shd.param_specs(axes, shapes, rules)
    assert specs["w"] == P("data", "model")
    assert specs["b"] == P("model")
    assert specs["nested"]["v"] == P("model", "data")


def test_shard_noop_without_rules():
    x = jnp.ones((4, 4))
    y = shd.shard(x, "act_batch", None)
    assert y is x


def test_stack_axes_prepends_layers():
    axes = {"w": ax("embed", "mlp")}
    stacked = shd.stack_axes(axes)
    assert tuple(stacked["w"]) == ("layers", "embed", "mlp")


def test_use_rules_context():
    mesh = one_device_mesh()
    rules = shd.ShardingRules(mesh)
    assert shd.current_rules() is None
    with shd.use_rules(rules):
        assert shd.current_rules() is rules
        x = shd.shard(jnp.ones((2, 2)), "act_batch", None)
        assert x.shape == (2, 2)
    assert shd.current_rules() is None


def test_make_host_mesh_model_factor():
    """Regression: make_host_mesh silently pinned the model axis to 1 — a
    caller asking for TP got a mesh that could never shard. It now takes
    the model factor and fails loudly on an impossible split."""
    from repro.launch.mesh import make_host_mesh
    n = len(jax.devices())
    mesh = make_host_mesh()                      # default: all-data, TP=1
    assert dict(mesh.shape) == {"data": n, "model": 1}
    mesh = make_host_mesh(model=n)               # all-model
    assert dict(mesh.shape) == {"data": 1, "model": n}
    with pytest.raises(ValueError, match="model"):
        make_host_mesh(model=0)
    bad = n + 1                                  # never divides n
    with pytest.raises(ValueError, match="divisible"):
        make_host_mesh(model=bad)
