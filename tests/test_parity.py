"""Tier-1 gate over the cross-backend differential harness (tests/parity.py).

Every (backend × dtype × shape) cell must hold: blockflow ≡ Pallas ≡ XLA ≡
reference, exactly for int8, within per-dtype tolerances for fp — plus the
quantized W8A8 route across all backends. New backends registered in
core/api.py extend parity.BACKENDS and inherit this gate.
"""
import pytest

import parity


@pytest.mark.parametrize("backend", parity.BACKENDS)
@pytest.mark.parametrize("dtype", parity.DTYPES)
@pytest.mark.parametrize("shape", parity.SHAPES,
                         ids=lambda s: "x".join(map(str, s)))
def test_backend_dtype_parity(backend, dtype, shape):
    parity.check_cell(backend, dtype, shape)


@pytest.mark.parametrize("backend", parity.BACKENDS)
@pytest.mark.parametrize("shape", parity.SHAPES[:3],
                         ids=lambda s: "x".join(map(str, s)))
def test_quantized_route_parity(backend, shape):
    parity.check_quantized_cell(backend, shape)


@pytest.mark.parametrize("backend", parity.ATTN_BACKENDS)
@pytest.mark.parametrize("dtype", parity.ATTN_DTYPES)
@pytest.mark.parametrize("case", parity.ATTN_CASES, ids=lambda c: c.name)
def test_attention_backend_parity(backend, dtype, case):
    """Every attention backend (fused flash kernel in interpret mode, the
    unfused host-softmax baseline) must match kernels/ref.py::mha_ref on
    prefill, decode-with-offsets, GQA, ragged non-causal keys, and masked
    serving rows — the AttentionPolicy contract (docs/attention.md)."""
    parity.check_attention_cell(backend, dtype, case)


def test_attention_fused_vs_unfused_direct():
    """Fused and unfused must also agree with *each other* (not just each
    within tolerance of the oracle) on the decode case — the cell serving
    exercises every step."""
    import numpy as np
    case = parity.ATTN_CASES[2]          # decode_long_cache
    q, k, v, qp, kl = parity.make_attention_operands(case, "float32")
    from repro.core import api
    from repro.core.plan import AttentionPolicy
    outs = [np.asarray(api.attention(
        q, k, v, q_positions=qp, kv_valid_len=kl, causal=case.causal,
        policy=AttentionPolicy(backend=b, block_q=32, block_k=32)))
        for b in parity.ATTN_BACKENDS]
    np.testing.assert_allclose(outs[0], outs[1], atol=3e-5, rtol=3e-5)


def test_attention_grid_runner_smoke():
    """The CLI sweep CI uses must run the attention grid end-to-end."""
    import io
    results = parity.run_attention_grid(backends=("unfused",),
                                        dtypes=("float32",),
                                        cases=parity.ATTN_CASES[:1],
                                        out=io.StringIO())
    assert all(r.ok for r in results)


def test_int8_blockflow_exactly_matches_reference():
    """Acceptance: int8 blockflow-vs-reference exact integer equality on a
    larger-than-one-block problem (multi K-blocks exercise accumulation)."""
    r = parity.check_cell("blockflow", "int8", (130, 24, 56))
    assert r.detail == "exact"


def test_grid_runner_smoke():
    """The CLI entry CI uses must sweep a small grid end-to-end."""
    import io
    results = parity.run_grid(backends=("xla",), dtypes=("int8",),
                              shapes=((8, 8, 8),), out=io.StringIO())
    assert all(r.ok for r in results)
