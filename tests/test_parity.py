"""Tier-1 gate over the cross-backend differential harness (tests/parity.py).

Every (backend × dtype × shape) cell must hold: blockflow ≡ Pallas ≡ XLA ≡
reference, exactly for int8, within per-dtype tolerances for fp — plus the
quantized W8A8 route across all backends. New backends registered in
core/api.py extend parity.BACKENDS and inherit this gate.
"""
import pytest

import parity


@pytest.mark.parametrize("backend", parity.BACKENDS)
@pytest.mark.parametrize("dtype", parity.DTYPES)
@pytest.mark.parametrize("shape", parity.SHAPES,
                         ids=lambda s: "x".join(map(str, s)))
def test_backend_dtype_parity(backend, dtype, shape):
    parity.check_cell(backend, dtype, shape)


@pytest.mark.parametrize("backend", parity.BACKENDS)
@pytest.mark.parametrize("shape", parity.SHAPES[:3],
                         ids=lambda s: "x".join(map(str, s)))
def test_quantized_route_parity(backend, shape):
    parity.check_quantized_cell(backend, shape)


@pytest.mark.parametrize("backend", parity.ATTN_BACKENDS)
@pytest.mark.parametrize("dtype", parity.ATTN_DTYPES)
@pytest.mark.parametrize("case", parity.ATTN_CASES, ids=lambda c: c.name)
def test_attention_backend_parity(backend, dtype, case):
    """Every attention backend (fused flash kernel in interpret mode, the
    unfused host-softmax baseline) must match kernels/ref.py::mha_ref on
    prefill, decode-with-offsets, GQA, ragged non-causal keys, and masked
    serving rows — the AttentionPolicy contract (docs/attention.md)."""
    parity.check_attention_cell(backend, dtype, case)


@pytest.mark.parametrize("case", parity.ATTN_CASES, ids=lambda c: c.name)
def test_attention_quantized_kv_parity(case):
    """The quantized-KV paged cells (AttentionPolicy(kv_dtype="int8")):
    int8 pages + per-page-per-head scales, dequantized inside the kernel's
    K/V fetch, vs mha_ref on the dequantized pool (docs/quant.md#kv-pages).
    Same case set as the fp grid — offsets, GQA, masked rows included."""
    parity.check_quantized_attention_cell("paged_interpret", case)


def test_attention_fused_vs_unfused_direct():
    """The backends must also agree with *each other* (not just each
    within tolerance of the oracle) on the decode case — the cell serving
    exercises every step. The paged backend reads the same K/V through a
    shuffled block table; with page_size == block_k its blocking (and
    hence accumulation order) is identical to fused, so those two must
    agree *bitwise*."""
    import numpy as np
    case = parity.ATTN_CASES[2]          # decode_long_cache
    q, k, v, qp, kl = parity.make_attention_operands(case, "float32")
    from repro.core import api
    from repro.core.plan import AttentionPolicy
    ps = parity.ATTN_PAGE_SIZE
    kp, vp, bt = parity.make_paged_operands(k, v, page_size=ps)
    outs = {}
    for b in parity.ATTN_BACKENDS:
        pol = AttentionPolicy(backend=b, block_q=32, block_k=ps,
                              page_size=ps)
        kw = (dict(block_tables=bt) if b.startswith("paged") else {})
        operands = (q, kp, vp) if b.startswith("paged") else (q, k, v)
        outs[b] = np.asarray(api.attention(
            *operands, q_positions=qp, kv_valid_len=kl, causal=case.causal,
            policy=pol, **kw))
    np.testing.assert_allclose(outs["unfused"], outs["fused_interpret"],
                               atol=3e-5, rtol=3e-5)
    np.testing.assert_array_equal(outs["paged_interpret"],
                                  outs["fused_interpret"])


def test_dense_backends_reject_block_tables():
    """Handing a paged pool + block table to a dense backend must fail
    loudly (it would silently misread the pool layout otherwise)."""
    import jax.numpy as jnp
    import pytest
    from repro.core import api
    from repro.core.plan import AttentionPolicy
    q = jnp.zeros((1, 1, 2, 8))
    kp = jnp.zeros((4, 16, 1, 8))
    bt = jnp.zeros((1, 2), jnp.int32)
    for b in ("unfused", "fused_interpret"):
        with pytest.raises(ValueError, match="paged"):
            api.attention(q, kp, kp, q_positions=jnp.zeros((1, 1), jnp.int32),
                          kv_valid_len=jnp.ones((1,), jnp.int32),
                          block_tables=bt,
                          policy=AttentionPolicy(backend=b))


def test_attention_grid_runner_smoke():
    """The CLI sweep CI uses must run the attention grid end-to-end."""
    import io
    results = parity.run_attention_grid(backends=("unfused",),
                                        dtypes=("float32",),
                                        cases=parity.ATTN_CASES[:1],
                                        out=io.StringIO())
    assert all(r.ok for r in results)


def test_int8_blockflow_exactly_matches_reference():
    """Acceptance: int8 blockflow-vs-reference exact integer equality on a
    larger-than-one-block problem (multi K-blocks exercise accumulation)."""
    r = parity.check_cell("blockflow", "int8", (130, 24, 56))
    assert r.detail == "exact"


def test_grid_runner_smoke():
    """The CLI entry CI uses must sweep a small grid end-to-end."""
    import io
    results = parity.run_grid(backends=("xla",), dtypes=("int8",),
                              shapes=((8, 8, 8),), out=io.StringIO())
    assert all(r.ok for r in results)
