"""Tier-1 gate over the cross-backend differential harness (tests/parity.py).

Every (backend × dtype × shape) cell must hold: blockflow ≡ Pallas ≡ XLA ≡
reference, exactly for int8, within per-dtype tolerances for fp — plus the
quantized W8A8 route across all backends. New backends registered in
core/api.py extend parity.BACKENDS and inherit this gate.
"""
import pytest

import parity


@pytest.mark.parametrize("backend", parity.BACKENDS)
@pytest.mark.parametrize("dtype", parity.DTYPES)
@pytest.mark.parametrize("shape", parity.SHAPES,
                         ids=lambda s: "x".join(map(str, s)))
def test_backend_dtype_parity(backend, dtype, shape):
    parity.check_cell(backend, dtype, shape)


@pytest.mark.parametrize("backend", parity.BACKENDS)
@pytest.mark.parametrize("shape", parity.SHAPES[:3],
                         ids=lambda s: "x".join(map(str, s)))
def test_quantized_route_parity(backend, shape):
    parity.check_quantized_cell(backend, shape)


def test_int8_blockflow_exactly_matches_reference():
    """Acceptance: int8 blockflow-vs-reference exact integer equality on a
    larger-than-one-block problem (multi K-blocks exercise accumulation)."""
    r = parity.check_cell("blockflow", "int8", (130, 24, 56))
    assert r.detail == "exact"


def test_grid_runner_smoke():
    """The CLI entry CI uses must sweep a small grid end-to-end."""
    import io
    results = parity.run_grid(backends=("xla",), dtypes=("int8",),
                              shapes=((8, 8, 8),), out=io.StringIO())
    assert all(r.ok for r in results)
