"""ExecutionPlan API (core/plan.py): policy resolution + memoized plan
cache, the backend registry, and resident block-major PackedWeights."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api
from repro.core import layout as L
from repro.core import plan as P
from repro.core.plan import ExecutionPlan, GemmPolicy, PackedWeight


# ---------------------------------------------------------------------------
# Plan resolution + cache
# ---------------------------------------------------------------------------

def test_plan_cache_hits_on_repeated_shapes():
    P.plan_cache_clear()
    pol = GemmPolicy(backend="blockflow", mode="dm")
    p1 = P.plan(128, 256, 512, jnp.float32, pol)
    miss_info = P.plan_cache_info()
    p2 = P.plan(128, 256, 512, jnp.float32, pol)
    hit_info = P.plan_cache_info()
    assert miss_info.misses == 1 and miss_info.hits == 0
    assert hit_info.hits == 1 and hit_info.misses == 1
    assert p1 is p2                      # memoized: the same object

    # a different policy is a different cache entry
    P.plan(128, 256, 512, jnp.float32, GemmPolicy(backend="blockflow",
                                                  mode="dc"))
    assert P.plan_cache_info().misses == 2


def test_plan_resolves_layout_and_acc():
    pln = P.plan(64, 384, 256, jnp.bfloat16,
                 GemmPolicy(backend="pallas_interpret", mode="dm"))
    assert isinstance(pln, ExecutionPlan)
    assert pln.backend == "pallas_interpret"
    assert pln.mode == "dm"
    assert pln.layout.mode == "dm"
    assert pln.acc == jnp.dtype(jnp.float32)
    assert pln.layout.vmem_bytes(2) <= GemmPolicy().vmem_budget

    int_pln = P.plan(64, 64, 64, jnp.int8, GemmPolicy(backend="blockflow"))
    assert int_pln.acc == jnp.dtype(jnp.int32)


def test_plan_auto_mode_consults_sysmodel():
    """mode="auto" must resolve to a concrete paper mode per shape, matching
    the sysmodel's own dc-vs-dm comparison."""
    from repro.core import sysmodel as SM
    pol = GemmPolicy(backend="blockflow", mode="auto")
    for M, N, K in [(128, 128, 128), (1024, 1024, 1024), (8192, 512, 512)]:
        pln = P.plan(M, N, K, jnp.float32, pol)
        g = SM.Gemm(M=M, K=K, N=N)
        t_dc = SM.matrixflow_gemm_time(g, "fp32", mode="dc")["total"]
        t_dm = SM.matrixflow_gemm_time(g, "fp32", mode="dm")["total"]
        expect = "dc" if t_dc <= t_dm else "dm"
        assert pln.mode == expect, (M, N, K)


def test_plan_layout_override_skips_choice():
    blk = L.BlockLayout(16, 128, 128, "dc")
    pln = P.plan(999, 999, 999, jnp.float32,
                 GemmPolicy(backend="blockflow", layout=blk))
    assert pln.layout is blk
    assert pln.mode == "dc"


def test_xla_plan_needs_no_layout():
    pln = P.plan(64, 64, 64, jnp.float32, GemmPolicy(backend="xla"))
    assert pln.layout is None and pln.mode is None


def test_acc_dtype_override():
    a = jnp.ones((8, 16), jnp.bfloat16)
    b = jnp.ones((16, 8), jnp.bfloat16)
    pol = GemmPolicy(backend="blockflow", acc_dtype="float32")
    out = api.matmul(a, b, policy=pol, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), 16.0)


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------

def test_register_backend_dispatch():
    calls = []

    def fake_gemm(a2, b, pln, out_dtype):
        calls.append((a2.shape, pln.backend))
        return jnp.zeros((a2.shape[0], b.shape[-1]), out_dtype)

    P.register_backend("fake", fake_gemm, overwrite=True)
    try:
        out = api.matmul(jnp.ones((4, 8)), jnp.ones((8, 6)),
                         policy=GemmPolicy(backend="fake"))
        assert out.shape == (4, 6)
        assert calls == [((4, 8), "fake")]
    finally:
        P.unregister_backend("fake")
    with pytest.raises(ValueError):
        P.get_backend_spec("fake")


def test_register_backend_no_silent_overwrite():
    P.register_backend("dupe", lambda *a: None, overwrite=True)
    try:
        with pytest.raises(ValueError, match="already registered"):
            P.register_backend("dupe", lambda *a: None)
    finally:
        P.unregister_backend("dupe")


def test_builtin_backends_present():
    names = P.registered_backends()
    for expected in ("xla", "pallas", "pallas_interpret", "blockflow"):
        assert expected in names


# ---------------------------------------------------------------------------
# PackedWeight: resident block-major weights
# ---------------------------------------------------------------------------

def test_pack_weight_roundtrip():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((200, 136)).astype(np.float32))
    pw = P.pack_weight(w, GemmPolicy(backend="blockflow", mode="dm"))
    assert pw.shape == (200, 136)
    assert pw.data.shape == (L.cdiv(136, pw.bn), L.cdiv(200, pw.bk),
                             pw.bk, pw.bn)
    np.testing.assert_array_equal(np.asarray(pw.unpack()), np.asarray(w))


def test_packed_linear_bitwise_identical_pallas_interpret():
    """Acceptance: linear with a PackedWeight is bitwise-identical to the
    row-major path under pallas_interpret — same kernel, same blocks, minus
    the per-call re-layout."""
    rng = np.random.default_rng(1)
    pol = GemmPolicy(backend="pallas_interpret", mode="dm")
    x = jnp.asarray(rng.standard_normal((64, 256)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((256, 384)).astype(np.float32))
    y_row = api.linear(x, w, policy=pol)
    y_packed = api.linear(x, P.pack_weight(w, pol), policy=pol)
    np.testing.assert_array_equal(np.asarray(y_row), np.asarray(y_packed))


@pytest.mark.parametrize("backend", ["xla", "blockflow"])
def test_packed_linear_other_backends(backend):
    """Layout-free backends unpack transparently — same numerics."""
    rng = np.random.default_rng(2)
    pol = GemmPolicy(backend=backend, mode="dm")
    x = jnp.asarray(rng.standard_normal((16, 96)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((96, 40)).astype(np.float32))
    y_row = api.linear(x, w, policy=pol)
    y_packed = api.linear(x, P.pack_weight(w, pol), policy=pol)
    np.testing.assert_array_equal(np.asarray(y_row), np.asarray(y_packed))


def test_packed_weight_is_pytree():
    w = jnp.ones((32, 16))
    pw = P.pack_weight(w, GemmPolicy(mode="dm"))
    leaves, treedef = jax.tree_util.tree_flatten(pw)
    assert len(leaves) == 1                      # geometry is static aux
    pw2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert (pw2.k, pw2.n, pw2.bk, pw2.bn) == (pw.k, pw.n, pw.bk, pw.bn)
    # tree_map over the data leaf (what lax.scan / _index_tree do)
    doubled = jax.tree_util.tree_map(lambda t: t * 2, pw)
    np.testing.assert_array_equal(np.asarray(doubled.unpack()),
                                  2 * np.asarray(pw.unpack()))


def test_pack_model_weights_model_equivalence():
    """pack_model_weights packs projections, skips MoE expert banks, and the
    packed model matches the row-major model on the MatrixFlow path."""
    from repro.configs.registry import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    pol = GemmPolicy(backend="blockflow", mode="dm")
    packed = P.pack_model_weights(params, pol)
    assert isinstance(packed["head"], PackedWeight)
    assert isinstance(packed["layers"]["attn"]["wq"], PackedWeight)
    # norm scales and embeddings pass through untouched
    assert not isinstance(packed["embed"], PackedWeight)
    assert not isinstance(packed["final_norm"]["scale"], PackedWeight)

    batch = {"tokens": jnp.zeros((1, 8), jnp.int32)}
    with api.use_policy(pol):
        ref_logits, _, _ = T.forward(params, cfg, batch)
        packed_logits, _, _ = T.forward(packed, cfg, batch)
    np.testing.assert_array_equal(np.asarray(ref_logits),
                                  np.asarray(packed_logits))


def test_pack_model_weights_skips_moe_banks():
    pol = GemmPolicy(backend="blockflow")
    tree = {"moe": {"wi": jnp.ones((4, 8, 16)), "wo": jnp.ones((4, 16, 8)),
                    "router": jnp.ones((8, 4)),
                    "shared": {"wi": jnp.ones((8, 32))}},
            "attn": {"wq": jnp.ones((8, 8))}}
    packed = P.pack_model_weights(tree, pol)
    assert not isinstance(packed["moe"]["wi"], PackedWeight)
    assert not isinstance(packed["moe"]["wo"], PackedWeight)
    assert isinstance(packed["moe"]["router"], PackedWeight)
    assert isinstance(packed["moe"]["shared"]["wi"], PackedWeight)
    assert isinstance(packed["attn"]["wq"], PackedWeight)


def test_layout_for_packed_respects_calling_budget():
    """A weight packed under one policy, consumed under a tighter one: bm
    shrinks to honor the caller's vmem_budget; an impossible fit raises a
    named error instead of silently overflowing VMEM."""
    w = jnp.ones((2048, 512), jnp.float32)
    pw = P.pack_weight(w, GemmPolicy(mode="dm"))     # bk=2048, bn=512
    mid = GemmPolicy(backend="pallas_interpret", mode="dc",
                     vmem_budget=12 * 1024 * 1024)
    blk = P.layout_for_packed(512, pw, jnp.float32, mid)
    assert (blk.bk, blk.bn) == (pw.bk, pw.bn)
    assert blk.vmem_bytes(4) <= mid.vmem_budget
    tight = GemmPolicy(backend="pallas_interpret", mode="dc",
                       vmem_budget=2 * 1024 * 1024)
    with pytest.raises(ValueError, match="cannot fit"):
        P.layout_for_packed(512, pw, jnp.float32, tight)


def test_plan_module_usable_standalone():
    """plan.py must not depend on api.py having been imported first (the
    built-ins lazily register on first lookup)."""
    import os
    import subprocess
    import sys
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = os.path.join(repo_root, "src")
    code = ("from repro.core import plan as P; import jax.numpy as jnp; "
            "assert P.plan(64, 64, 64, jnp.float32).backend == 'xla'")
    r = subprocess.run([sys.executable, "-c", code],
                       env=dict(os.environ, PYTHONPATH=src,
                                JAX_PLATFORMS="cpu"),
                       capture_output=True, text=True, cwd=repo_root)
    assert r.returncode == 0, r.stderr


def test_policy_is_hashable_and_frozen():
    pol = GemmPolicy(backend="blockflow", mode="dc")
    assert hash(pol) == hash(dataclasses.replace(pol))
    with pytest.raises(dataclasses.FrozenInstanceError):
        pol.backend = "xla"


# ---------------------------------------------------------------------------
# AttentionPolicy + attention backend registry
# ---------------------------------------------------------------------------

def test_attention_policy_hashable_and_frozen():
    from repro.core.plan import AttentionPolicy
    pol = AttentionPolicy(backend="fused_interpret", block_q=64)
    assert hash(pol) == hash(dataclasses.replace(pol))
    with pytest.raises(dataclasses.FrozenInstanceError):
        pol.backend = "unfused"


def test_attention_auto_resolution_mirrors_gemm():
    """"auto" resolves per platform like the GEMM registry: fused on TPU,
    unfused elsewhere; explicit names pass through untouched."""
    from repro.core.plan import AttentionPolicy, resolve_attention_backend
    expect = "fused" if jax.default_backend() == "tpu" else "unfused"
    assert resolve_attention_backend("auto") == expect
    assert AttentionPolicy().resolved_backend() == expect
    assert resolve_attention_backend("fused_interpret") == "fused_interpret"


def test_attention_registry_builtins_and_errors():
    assert {"fused", "fused_interpret", "unfused"} <= set(
        P.registered_attention_backends())
    with pytest.raises(ValueError, match="already registered"):
        P.register_attention_backend("unfused", lambda *a, **k: None)
    with pytest.raises(ValueError, match="unknown attention backend"):
        P.get_attention_backend_spec("no-such-attn")


def test_attention_registry_custom_backend_dispatch():
    """A registered backend receives the full offset/length contract and
    its output is returned untouched — downstream paged/sharded attention
    implementations plug in without touching dispatch."""
    from repro.core.plan import AttentionPolicy
    seen = {}

    def fake(q, k, v, *, q_positions, kv_valid_len, causal, scale, soft_cap,
             policy):
        seen.update(causal=causal, scale=scale, policy=policy)
        return jnp.zeros(q.shape[:3] + (v.shape[-1],), q.dtype)

    P.register_attention_backend("fake_attn", fake)
    try:
        q = jnp.ones((1, 4, 2, 8)); kv = jnp.ones((1, 4, 1, 8))
        pol = AttentionPolicy(backend="fake_attn")
        out = api.attention(q, kv, kv,
                            q_positions=jnp.zeros((1, 4), jnp.int32),
                            kv_valid_len=jnp.full((1,), 4, jnp.int32),
                            policy=pol)
        assert out.shape == (1, 4, 2, 8)
        assert seen["causal"] is True and seen["policy"] is pol
        assert seen["scale"] == pytest.approx(8 ** -0.5)
    finally:
        P.unregister_attention_backend("fake_attn")


def test_use_attention_policy_nests_thread_local():
    from repro.core.plan import AttentionPolicy
    base = api.current_attention_policy()
    inner = AttentionPolicy(backend="fused_interpret", block_q=32)
    with api.use_attention_policy(inner):
        assert api.current_attention_policy() is inner
        with api.use_attention_policy(AttentionPolicy(backend="unfused")):
            assert api.resolved_attention_backend() == "unfused"
        assert api.current_attention_policy() is inner
    assert api.current_attention_policy() == base
