"""Algorithm 1 (core/blockflow.py) against the jnp oracle + dtype policy."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.core import layout as L
from repro.core.blockflow import acc_dtype_for, block_matmul, multi_acc
from repro.kernels.ref import matmul_ref


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 128), k=st.integers(1, 128), n=st.integers(1, 128))
def test_block_matmul_matches_dense(m, k, n):
    rng = np.random.default_rng(m + 31 * k + 977 * n)
    a = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(block_matmul(a, b)),
                               np.asarray(matmul_ref(a, b)),
                               atol=1e-4, rtol=1e-5)


def test_acc_dtype_policy():
    assert acc_dtype_for(jnp.int8) == jnp.int32
    assert acc_dtype_for(jnp.int32) == jnp.int32
    assert acc_dtype_for(jnp.bfloat16) == jnp.float32
    assert acc_dtype_for(jnp.float32) == jnp.float32


def test_multi_acc_accumulates():
    a = jnp.ones((4, 8), jnp.float32)
    b = jnp.ones((8, 4), jnp.float32)
    c = jnp.full((4, 4), 2.0, jnp.float32)
    out = multi_acc(a, b, c)
    np.testing.assert_array_equal(np.asarray(out), np.full((4, 4), 10.0))


@pytest.mark.parametrize("blk", [L.BlockLayout(8, 128, 128),
                                 L.BlockLayout(16, 256, 512)])
def test_explicit_block_geometry(blk):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((64, 512)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((512, 256)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(block_matmul(a, b, blk=blk)),
                               np.asarray(matmul_ref(a, b)),
                               atol=1e-4, rtol=1e-5)
