"""Static-analysis tiers: the checker's own unit tests + the mutation suite.

Three tiers:

  1. **Clean pass** — every built-in contract (all five kernels), the full
     CLI sweep, and the serving engine's traced hot path must produce zero
     violations/findings: the acceptance gate ``python -m repro.analysis
     --all-backends`` enforces in CI.
  2. **Mutation suite** — deliberately corrupted contracts (off-by-one
     index maps, dropped reduction axes, out-of-range block-table entries,
     zero-extent grids, mis-declared semantics...) that the checker must
     each flag with the *right* violation kind. The suite spans every kind
     in ``VIOLATION_KINDS`` — ≥ 6 distinct defect classes caught
     statically, per the PR acceptance criteria.
  3. **Drift guards** — the sweep's mirrored shape/dtype grid must equal
     tests/parity.py's, and the runtime ``require`` guards must raise
     ``ValueError`` (not ``AssertionError``: asserts vanish under -O).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl

import parity
from repro.analysis import (ContractViolationError, KernelContract,
                            OperandSpec, Precondition, check_contract,
                            get_contract_builder, lint_jaxpr,
                            registered_contracts, require)
from repro.analysis import sweep as SW
from repro.analysis.kernel_contracts import VIOLATION_KINDS
from repro.core import layout as L
from repro.core.plan import GemmPolicy, plan


def kinds(violations):
    return {v.kind for v in violations}


# ---------------------------------------------------------------------------
# Tier 1: clean pass
# ---------------------------------------------------------------------------

def test_all_five_kernels_register_contracts():
    assert registered_contracts() == (
        "blockflow", "flash_attention", "matrixflow_gemm",
        "paged_attention", "ssd_scan")


GEMM_BLK = L.BlockLayout(bm=8, bn=8, bk=8)


def gemm_contract(**over):
    kw = dict(a_shape=(4, 3, 8, 8), b_shape=(5, 3, 8, 8), blk=GEMM_BLK)
    kw.update(over)
    return get_contract_builder("matrixflow_gemm")(**kw)


def paged_contract(**over):
    kw = dict(B=2, Sq=1, H=4, Hkv=2, D=16, Dv=16, P=8, page_size=16,
              block_tables=np.array([[2, 0, 5], [1, 3, 4]], np.int32),
              block_q=32)
    kw.update(over)
    return get_contract_builder("paged_attention")(**kw)


@pytest.mark.parametrize("name,kwargs", [
    ("matrixflow_gemm", dict(a_shape=(4, 3, 8, 8), b_shape=(5, 3, 8, 8),
                             blk=GEMM_BLK)),
    ("matrixflow_gemm", dict(a_shape=(4, 3, 8, 8), b_shape=(5, 3, 8, 8),
                             blk=GEMM_BLK, fused=True)),
    ("flash_attention", dict(B=2, H=4, Hkv=2, Sq=33, Sk=65, D=16, Dv=16,
                             block_q=32, block_k=32)),
    ("ssd_scan", dict(B=2, S=96, H=3, P=16, N=8, chunk=32)),
    ("blockflow", dict(nbm=3, nbn=4, nbk=2)),
])
def test_builtin_contract_clean(name, kwargs):
    assert check_contract(get_contract_builder(name)(**kwargs)) == []


def test_paged_contract_clean_including_quantized():
    assert check_contract(paged_contract()) == []
    assert check_contract(paged_contract(quantized=True)) == []


def test_full_sweep_zero_violations():
    """The CI gate, in-process: every backend × dtype × shape plus the
    configs/ registry must contract-check clean."""
    _, n_bad = SW.run_sweep(out=open("/dev/null", "w"))
    assert n_bad == 0


def test_plan_validate_accepts_auto_mode_choices():
    for backend in ("blockflow", "pallas_interpret"):
        for (M, K, N) in parity.SHAPES:
            plan(M, N, K, "float32", GemmPolicy(backend=backend),
                 validate=True)       # raises ContractViolationError if bad


# ---------------------------------------------------------------------------
# Tier 2: mutation suite — each seeded defect must be flagged with the
# right violation kind
# ---------------------------------------------------------------------------

def mutate(contract, op_name, **changes):
    """Return the contract with operand ``op_name`` rebuilt with changes."""
    ops = tuple(dataclasses.replace(op, **changes) if op.name == op_name
                else op for op in contract.operands)
    return dataclasses.replace(contract, operands=ops)


def test_mutation_off_by_one_index_map_is_bounds():
    c = mutate(gemm_contract(), "a_bm",
               index_map=lambda i, j, k: (i + 1, k, 0, 0))
    v = check_contract(c)
    assert "bounds" in kinds(v)
    assert any("outside the blocked array" in x.detail for x in v)


def test_mutation_swapped_axes_is_bounds_or_coverage():
    # j has 5 extents but indexes a_bm's 4-block M axis: bounds; and the
    # K stream never advances: coverage.
    c = mutate(gemm_contract(), "a_bm",
               index_map=lambda i, j, k: (j, k, 0, 0))
    assert {"bounds"} <= kinds(check_contract(c))


def test_mutation_missing_reduction_axis_is_write_race():
    c = mutate(gemm_contract(), "c_bm", reduction_axes=())
    v = check_contract(c)
    assert "write_race" in kinds(v)
    assert any("differ along non-reduction axes" in x.detail for x in v)


def test_mutation_dropped_divisibility_guard_is_precondition():
    # b_bm walks a different K stream than a_bm — the guard the kernel
    # used to assert; the checker cites it as a structured precondition.
    c = gemm_contract(b_shape=(5, 2, 8, 8))
    v = check_contract(c)
    assert kinds(v) == {"precondition"}
    assert "K-stream agreement" in v[0].detail


def test_mutation_coverage_hole():
    # the C map pins the N axis to 0: blocks (i, 1..4) are never written.
    c = mutate(gemm_contract(), "c_bm",
               index_map=lambda i, j, k: (i, 0, 0, 0))
    v = check_contract(c)
    assert "coverage" in kinds(v)


def test_mutation_parallel_reduction_axis_is_semantics():
    c = dataclasses.replace(
        gemm_contract(),
        dimension_semantics=("parallel", "parallel", "parallel"))
    v = check_contract(c)
    assert kinds(v) == {"semantics"}
    assert "license to reorder" in v[0].detail


def test_mutation_zero_extent_grid_is_grid():
    """The PR 7 regression class: an empty block table makes the key axis
    zero-extent, the flush step never runs, and the output is returned
    uninitialized. The contract layer refuses it as a precondition (the
    kernel short-circuits nb == 0); the raw grid check catches it too."""
    v = check_contract(paged_contract(
        block_tables=np.zeros((2, 0), np.int32)))
    assert kinds(v) == {"precondition"}
    raw = dataclasses.replace(gemm_contract(), grid=(4, 5, 0))
    assert kinds(check_contract(raw)) == {"grid"}


def test_mutation_out_of_range_block_table_is_bounds():
    """The PR 2 regression class: a block-table entry pointing outside the
    pool (or at another slot's page) is a bad physical fetch the length
    mask cannot save."""
    bt = np.array([[2, 0, 9], [1, 3, 4]], np.int32)       # 9 >= P=8
    v = check_contract(paged_contract(block_tables=bt))
    assert "bounds" in kinds(v)


def test_mutation_non_contiguous_revisit_is_revisit_order():
    # reduction along the OUTERMOST axis: revisits of output block (i, j)
    # are strided by the whole inner grid — flushed, left, re-entered.
    c = KernelContract(
        kernel="mutant", grid=(2, 2, 2),
        operands=(OperandSpec("o", "output", (2, 2), (1, 1),
                              lambda k, i, j: (i, j),
                              reduction_axes=(0,)),),
        dimension_semantics=("arbitrary", "parallel", "parallel"))
    v = check_contract(c)
    assert kinds(v) == {"revisit_order"}


def test_mutation_suite_spans_six_defect_classes():
    """The acceptance criterion: >= 6 distinct defect classes caught."""
    caught = set()
    caught |= kinds(check_contract(mutate(
        gemm_contract(), "a_bm", index_map=lambda i, j, k: (i + 1, k, 0, 0))))
    caught |= kinds(check_contract(mutate(
        gemm_contract(), "c_bm", reduction_axes=())))
    caught |= kinds(check_contract(gemm_contract(b_shape=(5, 2, 8, 8))))
    caught |= kinds(check_contract(mutate(
        gemm_contract(), "c_bm", index_map=lambda i, j, k: (i, 0, 0, 0))))
    caught |= kinds(check_contract(dataclasses.replace(
        gemm_contract(),
        dimension_semantics=("parallel", "parallel", "parallel"))))
    caught |= kinds(check_contract(dataclasses.replace(
        gemm_contract(), grid=(4, 5, 0))))
    caught |= kinds(check_contract(KernelContract(
        kernel="mutant", grid=(2, 2, 2),
        operands=(OperandSpec("o", "output", (2, 2), (1, 1),
                              lambda k, i, j: (i, j), reduction_axes=(0,)),),
        dimension_semantics=("arbitrary", "parallel", "parallel"))))
    assert caught >= set(VIOLATION_KINDS), caught
    assert len(caught) >= 6


# ---------------------------------------------------------------------------
# Tier 3: runtime guards + trace lint + drift guards
# ---------------------------------------------------------------------------

def test_require_raises_value_error_not_assertion():
    with pytest.raises(ValueError, match="broke"):
        require(Precondition.check("x", False, "it broke"),
                Precondition.check("y", True, "fine"))
    require(Precondition.check("y", True, "fine"))        # no raise


def test_kernel_guards_are_value_errors():
    from repro.kernels.flash_attention import flash_attention
    q = jnp.zeros((1, 4, 8, 16))
    kv = jnp.zeros((1, 3, 8, 16))                         # 4 % 3 != 0
    with pytest.raises(ValueError, match="multiple"):
        flash_attention(q, kv, kv, interpret=True)


def test_blockflow_guards_are_value_errors():
    from repro.core.blockflow import block_matmul
    a = jnp.zeros((8, 16))
    b = jnp.zeros((8, 8))                                 # K mismatch
    with pytest.raises(ValueError, match="contraction"):
        block_matmul(a, b)
    b4 = jnp.zeros((1, 2, 8, 8))                          # block-major, no blk
    with pytest.raises(ValueError, match="explicit blk"):
        block_matmul(a, b4)


def test_lint_flags_host_callback():
    def f(x):
        jax.debug.print("x = {}", x)
        return x * 2

    findings = lint_jaxpr(jax.make_jaxpr(f)(jnp.ones(3)))
    assert any(f.rule == "host-callback" for f in findings)


def test_lint_flags_weak_type_input():
    findings = lint_jaxpr(
        jax.make_jaxpr(lambda x, y: x + y)(jnp.ones(3), 1.0))
    assert any(f.rule == "weak-type" for f in findings)


def test_lint_flags_fp64_promotion():
    with jax.experimental.enable_x64():
        jaxpr = jax.make_jaxpr(
            lambda x: x * np.float64(2.0))(jnp.ones(3, jnp.float32))
    findings = lint_jaxpr(jaxpr, check_weak_invars=False)
    assert any(f.rule == "fp64-promotion" for f in findings)


def test_lint_flags_int8_pool_without_scales():
    def bad(pool):
        def copy(p_ref, o_ref):
            o_ref[...] = p_ref[...]
        return pl.pallas_call(
            copy, out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        )(pool)

    pool = jnp.zeros((4, 16, 2, 8), jnp.int8)             # (P, ps, Hkv, D)
    findings = lint_jaxpr(jax.make_jaxpr(bad)(pool),
                          check_weak_invars=False)
    assert any(f.rule == "int8-pool-no-scales" for f in findings)


def test_lint_recurses_into_jitted_subjaxprs():
    @jax.jit
    def inner(x):
        jax.debug.print("{}", x)
        return x

    findings = lint_jaxpr(jax.make_jaxpr(lambda x: inner(x) + 1)(jnp.ones(3)))
    assert any(f.rule == "host-callback" for f in findings)
    assert any("pjit" in f.path for f in findings)


def test_serving_engine_hot_path_lints_clean():
    """The jitted prefill/decode closures — the per-request programs — must
    carry no host syncs, fp64 upcasts, weak-type retrace triggers, or
    scale-less int8 pools."""
    from repro.analysis.trace_lint import lint_engine
    from repro.configs.registry import get_smoke_config
    from repro.models import transformer as T
    from repro.serving.engine import ServeConfig, ServingEngine

    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, ServeConfig(batch_slots=2, max_len=32))
    assert lint_engine(eng) == []


def test_sweep_grid_matches_parity():
    """Drift guard: the static sweep must cover exactly the cells the
    runtime parity harness proves. Extend both together."""
    assert SW.GEMM_SHAPES == parity.SHAPES
    assert SW.GEMM_DTYPES == parity.DTYPES
    assert SW.ATTN_PAGE_SIZE == parity.ATTN_PAGE_SIZE
    mirrored = tuple((c.name, c.B, c.Sq, c.T, c.H, c.Hkv)
                     for c in parity.ATTN_CASES)
    assert SW.ATTN_CASES == mirrored


def test_contract_violation_error_formats_all():
    v = check_contract(gemm_contract(b_shape=(5, 2, 8, 8)))
    err = ContractViolationError(v)
    assert "precondition" in str(err)
    assert err.violations == tuple(v)
