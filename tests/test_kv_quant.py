"""Quantized int8 KV-cache pages (docs/quant.md#kv-pages).

Two quantization regimes share core/quant.py:

  * **one-shot** (``quantize_kv_pages``) — true per-page-per-head amax,
    used by the parity/benchmark harnesses on already-full pools;
  * **write-time** (``kv_write_scale`` + ``quantize_kv_rows``) — the
    serving path: a page's scale is FROZEN from its first row (position %
    page_size == 0, with KV_HEADROOM slack for later rows) and every row
    quantizes against the frozen scale.

The freeze is what makes the int8 payload a pure function of a page's
logical content — the bitwise write-granularity test below is the
invariant the serving engine's preempt/resume and prefix-COW stream
identity rests on (tests/test_serving.py asserts it end to end).
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.core import quant as Q
from repro.core.plan import AttentionPolicy


# ---------------------------------------------------------------------------
# One-shot page quantization: error bounds and shape contract
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), P=st.integers(1, 6),
       ps=st.integers(1, 16), Hkv=st.integers(1, 4),
       scale_pow=st.integers(-8, 8))
def test_kv_pages_roundtrip_error_half_step(seed, P, ps, Hkv, scale_pow):
    """|pool - dequant(quantize(pool))| ≤ scale/2 per element, per page
    per head, at any magnitude (the _safe_scale guard covers zeros)."""
    rng = np.random.default_rng(seed)
    pool = jnp.asarray(rng.standard_normal((P, ps, Hkv, 8))
                       .astype(np.float32) * 2.0 ** scale_pow)
    q, scales = Q.quantize_kv_pages(pool)
    assert q.dtype == jnp.int8 and scales.shape == (P, Hkv)
    assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= Q.QMAX
    deq = np.asarray(Q.dequantize_kv_pages(q, scales))
    err = np.abs(deq - np.asarray(pool))
    bound = np.asarray(scales)[:, None, :, None] * (0.5 + 1e-4) + 1e-30
    np.testing.assert_array_less(err, np.broadcast_to(bound, err.shape))


def test_zero_pages_are_safe():
    """All-zero pages (freshly allocated pools) must quantize to zeros
    with a finite scale and dequantize back to exact zeros."""
    pool = jnp.zeros((3, 8, 2, 16))
    q, scales = Q.quantize_kv_pages(pool)
    assert np.isfinite(np.asarray(scales)).all()
    assert np.abs(np.asarray(Q.dequantize_kv_pages(q, scales))).max() == 0.0


def test_write_scale_headroom_clips_late_outliers():
    """kv_write_scale carries KV_HEADROOM slack so later rows larger than
    the frozen first row still land in range (clipped at QMAX, not
    wrapped); rows within headroom round-trip at half-step error."""
    rng = np.random.default_rng(3)
    first = jnp.asarray(rng.standard_normal((4, 2, 8)).astype(np.float32))
    scales = Q.kv_write_scale(first)
    assert scales.shape == (4, 2)
    late = first * (Q.KV_HEADROOM * 4.0)     # beyond the headroom
    q = Q.quantize_kv_rows(late, scales)
    assert q.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= Q.QMAX
    within = first * (Q.KV_HEADROOM * 0.5)   # inside the headroom
    deq = (np.asarray(Q.quantize_kv_rows(within, scales), np.float32)
           * np.asarray(scales)[..., None])
    err = np.abs(deq - np.asarray(within))
    bound = np.asarray(scales)[..., None] * (0.5 + 1e-4) + 1e-30
    np.testing.assert_array_less(err, np.broadcast_to(bound, err.shape))


# ---------------------------------------------------------------------------
# Policy plumbing
# ---------------------------------------------------------------------------

def test_policy_rejects_unknown_kv_dtype():
    with pytest.raises(ValueError, match="kv_dtype"):
        AttentionPolicy(kv_dtype="int4")
    assert AttentionPolicy(kv_dtype="int8").kv_dtype == "int8"


# ---------------------------------------------------------------------------
# Write-granularity bitwise determinism (the frozen-scale invariant)
# ---------------------------------------------------------------------------

def test_paged_int8_write_granularity_bitwise():
    """Writing a sequence token-at-a-time (decode), in chunks (chunked
    prefill), or all at once (bulk prefill / preempt-resume re-prefill)
    must produce byte-identical int8 pools AND scales: the page scale is
    frozen by the pos%page_size==0 row regardless of which write carried
    it, so the payload depends only on the page's logical content."""
    from repro.configs.registry import get_smoke_config
    from repro.models import layers as L

    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    B, T, ps, P = 1, 12, 8, 4
    rng = np.random.default_rng(11)
    k = jnp.asarray(rng.standard_normal(
        (B, T, cfg.n_kv_heads, cfg.head_dim)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal(
        (B, T, cfg.n_kv_heads, cfg.head_dim)).astype(np.float32))
    bt = jnp.asarray([[2, 0]], jnp.int32)    # shuffled page assignment

    def write(chunks):
        cache = L.init_paged_attention_cache(cfg, B, P, ps, jnp.float32,
                                             kv_dtype="int8")
        t0 = 0
        for n in chunks:
            pos = jnp.arange(t0, t0 + n, dtype=jnp.int32)[None, :]
            cache = L._paged_cache_update(
                cache, k[:, t0:t0 + n], v[:, t0:t0 + n], pos, bt)
            t0 += n
        return cache

    bulk = write([T])
    for chunks in ([1] * T, [5, 7], [8, 4], [3, 3, 3, 3]):
        got = write(chunks)
        for leaf in ("kp", "vp", "k_scale", "v_scale"):
            np.testing.assert_array_equal(
                np.asarray(got[leaf]), np.asarray(bulk[leaf]),
                err_msg=f"{leaf} diverged for chunks={chunks}")
        np.testing.assert_array_equal(np.asarray(got["len"]),
                                      np.asarray(bulk["len"]))

    # untouched pages keep their ones-scales and zero payloads
    untouched = [p for p in range(P) if p not in (0, 2)]
    for leaf in ("k_scale", "v_scale"):
        assert (np.asarray(bulk[leaf])[untouched] == 1.0).all()
    for leaf in ("kp", "vp"):
        assert (np.asarray(bulk[leaf])[untouched] == 0).all()


def test_init_paged_cache_rejects_unknown_kv_dtype():
    from repro.configs.registry import get_smoke_config
    from repro.models import layers as L

    cfg = get_smoke_config("smollm-135m", n_layers=1, vocab=64)
    with pytest.raises(ValueError, match="kv_dtype"):
        L.init_paged_attention_cache(cfg, 1, 4, 8, jnp.float32,
                                     kv_dtype="fp8")
