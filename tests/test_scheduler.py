"""Scheduler policy units + engine-level scheduling regressions.

Covers the policy/mechanism split (serving/scheduler.py): the default
FIFO-within-priority Scheduler reproduces the pre-scheduler engine
choreography, SLOScheduler layers deadlines on top, and the engine's
consultation points behave — most importantly the head-of-line resume
regression: a waiter that doesn't fit is *skipped*, not a barrier, while
stream order within a priority class is still preserved when everything
fits.
"""
import jax
import pytest

from repro.configs.registry import get_smoke_config
from repro.core.plan import AttentionPolicy
from repro.models import transformer as T
from repro.serving.engine import ServeConfig, ServingEngine, _Waiting
from repro.serving.kv_pool import BlockTable
from repro.serving.scheduler import RequestView, Scheduler, SLOScheduler

PAGED8 = AttentionPolicy(backend="paged_interpret", page_size=8, block_q=8)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# Policy units (no engine)
# ---------------------------------------------------------------------------

def test_default_resume_is_fifo_within_priority():
    sched = Scheduler()
    waiting = [RequestView(rid=3, priority=1, arrival=5),
               RequestView(rid=1, priority=0, arrival=9),
               RequestView(rid=2, priority=0, arrival=2)]
    # priority first (0 beats 1), then arrival order within the class
    assert sched.resume_order(waiting) == [2, 1, 0]


def test_default_victim_is_youngest_of_least_urgent():
    sched = Scheduler()
    live = [RequestView(rid=0, priority=0), RequestView(rid=1, priority=2),
            RequestView(rid=2, priority=2), RequestView(rid=3, priority=1)]
    assert sched.victim(live) == 2       # least urgent class, then youngest


def test_default_victim_spares_prefilling_requests():
    """Preempting mid-chunked-prefill throws away its prefill work; the
    default spares it while a decoded candidate exists in the class."""
    sched = Scheduler()
    live = [RequestView(rid=0, priority=0),
            RequestView(rid=1, priority=0, prefilling=True)]
    assert sched.victim(live) == 0
    # ... but an urgency gap still dominates
    live = [RequestView(rid=0, priority=0),
            RequestView(rid=1, priority=1, prefilling=True)]
    assert sched.victim(live) == 1


def test_should_preempt_is_strict():
    sched = Scheduler()
    lo, hi = RequestView(rid=0, priority=1), RequestView(rid=1, priority=0)
    assert sched.should_preempt(hi, lo)
    assert not sched.should_preempt(lo, hi)
    assert not sched.should_preempt(lo, lo)   # equal class never churns


def test_slo_scheduler_orders_by_deadline():
    sched = SLOScheduler()
    waiting = [RequestView(rid=0, deadline=30.0, arrival=1),
               RequestView(rid=1, deadline=10.0, arrival=2),
               RequestView(rid=2, deadline=None, arrival=0)]
    assert sched.resume_order(waiting) == [1, 0, 2]   # EDF; None = last
    # victim: most slack first — no deadline spills before any deadline
    assert sched.victim(waiting) == 2
    assert sched.victim(waiting[:2]) == 0
    # priority still dominates deadline
    waiting = [RequestView(rid=0, priority=1, deadline=1.0),
               RequestView(rid=1, priority=0, deadline=99.0)]
    assert sched.resume_order(waiting) == [1, 0]


def test_prefill_chunk_validation():
    with pytest.raises(ValueError, match="prefill_chunk"):
        Scheduler(prefill_chunk=0)
    assert Scheduler(prefill_chunk=4).prefill_chunk == 4
    assert Scheduler().prefill_chunk is None


# ---------------------------------------------------------------------------
# Engine: head-of-line resume regression (satellite 1)
# ---------------------------------------------------------------------------

def test_resume_skips_nonfitting_waiter(setup):
    """The HOL regression: a big waiter at the head of the queue must not
    block a small one behind it that a free slot and pages exist for —
    the engine skips it and keeps it queued for when pages free up."""
    cfg, params = setup
    sc = ServeConfig(batch_slots=2, max_len=32, attention=PAGED8,
                     cache_pages=4)
    eng = ServingEngine(cfg, params, sc)
    # pin one page so only 3 of 4 are free: big (25 tok → 4 pages) cannot
    # fit, small (2 tok → 1 page) can
    pin = BlockTable(eng.pool)
    pin.ensure(1)
    big = _Waiting(rid=100, prompt=list(range(1, 26)), out=[], next_tok=7,
                   arrival=1)
    small = _Waiting(rid=101, prompt=[9, 9], out=[], next_tok=3, arrival=2)
    eng.wait.extend([big, small])
    eng.request_out[100] = big.out
    eng.request_out[101] = small.out
    out = eng.step()
    assert 101 in out                    # small admitted and decoding
    assert [w.rid for w in eng.wait] == [100]   # big skipped, still queued
    # pages return → the big one resumes on a later step
    pin.free()
    eng.cancel(101)
    eng.step()
    assert not eng.wait
    assert 100 in eng.step()
    eng.pool.check()


def test_resume_preserves_order_within_priority_class(setup):
    """When every waiter fits, re-admission runs in arrival order within a
    priority class — the skip rule must not reorder streams that never
    needed skipping."""
    cfg, params = setup
    sc = ServeConfig(batch_slots=4, max_len=32, attention=PAGED8,
                     cache_pages=16)
    eng = ServingEngine(cfg, params, sc)
    waiters = [_Waiting(rid=200 + i, prompt=[i + 1, i + 2], out=[],
                        next_tok=i, arrival=10 + i) for i in range(3)]
    eng.wait.extend(waiters)             # arrival order 200, 201, 202
    for w in waiters:
        eng.request_out[w.rid] = w.out
    eng.step()
    assert not eng.wait
    # slots are taken first-free-first in resume order → rid ascends
    admitted = [int(r) for r in eng.slot_rid if r >= 0]
    assert admitted == [200, 201, 202]


# ---------------------------------------------------------------------------
# Engine: priority admission-preemption + chunked prefill equivalence
# ---------------------------------------------------------------------------

def test_urgent_submit_preempts_lower_priority(setup):
    cfg, params = setup
    sc = ServeConfig(batch_slots=2, max_len=32, attention=PAGED8,
                     cache_pages=4)
    eng = ServingEngine(cfg, params, sc)
    r0 = eng.submit([1, 2, 3], priority=1)
    r1 = eng.submit([4, 5, 6], priority=1)
    assert r0 is not None and r1 is not None
    # equal priority: no slots free → refused, never churns
    assert eng.submit([7, 8, 9], priority=1) is None
    assert eng.n_preemptions == 0
    # strictly more urgent: the youngest lower-priority request spills
    r2 = eng.submit([7, 8, 9], priority=0)
    assert r2 is not None and eng.n_preemptions == 1
    assert any(w.rid == r1 for w in eng.wait)   # youngest spilled
    # its stream continues after the urgent one retires
    eng.cancel(r2)
    for _ in range(3):
        eng.step()
    assert not eng.wait and eng.request_out[r1]
    eng.pool.check()


def test_chunked_prefill_streams_identical(setup):
    """Golden gate: chunked prefill (any chunk size) must not change a
    single token of any stream — paged and contiguous engines both."""
    cfg, params = setup
    prompts = [[3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5], [8, 9, 7, 9]]

    def streams(sc):
        eng = ServingEngine(cfg, params, sc)
        hs = [eng.submit(p) for p in prompts]
        assert all(h is not None for h in hs)
        got = {h: [] for h in hs}
        # chunk=1 serializes prefills one token per step (one prefilling
        # slot advances per step) — give the slow case room to produce
        for _ in range(40):
            for h, t in eng.step().items():
                got[h].append(t)
            if all(len(v) >= 6 for v in got.values()):
                break
        return [got[h][:6] for h in hs]

    for base in (dict(batch_slots=2, max_len=32, attention=PAGED8,
                      cache_pages=8),
                 dict(batch_slots=2, max_len=32)):
        want = streams(ServeConfig(**base))
        for chunk in (1, 3, 4):
            got = streams(ServeConfig(
                **base, scheduler=Scheduler(prefill_chunk=chunk)))
            assert got == want, (base.get("cache_pages"), chunk)


def test_chunked_prefill_bounds_per_step_work(setup):
    """The point of chunking: a long prompt's prefill spreads over steps
    (prefill_tokens advances by at most the chunk per step) while a
    concurrent decoded request keeps producing every step."""
    cfg, params = setup
    sc = ServeConfig(batch_slots=2, max_len=64, attention=PAGED8,
                     cache_pages=16, scheduler=Scheduler(prefill_chunk=8))
    eng = ServingEngine(cfg, params, sc)
    r0 = eng.submit([1, 2, 3])               # short: prefills in one chunk
    for _ in range(2):
        eng.step()
    r1 = eng.submit(list(range(1, 41)))      # 40 tokens → 5 chunks
    assert eng.slot_prefilling.any()
    seen_r0 = 0
    before = eng.prefill_tokens
    while eng.slot_prefilling.any():
        out = eng.step()
        assert eng.prefill_tokens - before <= 8   # bounded per step
        before = eng.prefill_tokens
        if eng.slot_prefilling.any():        # mid-prefill: no r1 tokens yet
            assert r1 not in out             # (its final chunk's step may
        seen_r0 += int(r0 in out)            # legally report the first one)
    assert seen_r0 >= 4                      # decode interleaved throughout
    assert r1 in eng.step()
    eng.pool.check()


def test_slo_deadline_resume_order(setup):
    """SLOScheduler end-to-end: two preempted waiters resume earliest-
    deadline-first even against arrival order."""
    cfg, params = setup
    sc = ServeConfig(batch_slots=2, max_len=32, attention=PAGED8,
                     cache_pages=8, scheduler=SLOScheduler())
    eng = ServingEngine(cfg, params, sc)
    late = _Waiting(rid=300, prompt=[1, 2], out=[], next_tok=5,
                    arrival=1, deadline=50.0)
    soon = _Waiting(rid=301, prompt=[3, 4], out=[], next_tok=6,
                    arrival=2, deadline=5.0)
    eng.wait.extend([late, soon])
    eng.request_out[300] = late.out
    eng.request_out[301] = soon.out
    eng.step()
    admitted = [int(r) for r in eng.slot_rid if r >= 0]
    assert admitted == [301, 300]            # EDF beat arrival order
