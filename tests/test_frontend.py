"""AsyncServingEngine: per-request streams over the batched engine.

Plain asyncio.run() inside sync tests (no pytest-asyncio dependency —
the [test] extra stays jax+pytest+hypothesis). The golden property: the
streamed tokens are exactly the engine's submit()/step() streams, under
any number of concurrent consumers.
"""
import asyncio

import jax
import pytest

from repro.configs.registry import get_smoke_config
from repro.core.plan import AttentionPolicy
from repro.models import transformer as T
from repro.serving.engine import ServeConfig, ServingEngine
from repro.serving.frontend import AsyncServingEngine

PAGED8 = AttentionPolicy(backend="paged_interpret", page_size=8, block_q=8)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def sync_stream(cfg, params, sc, prompt, n):
    eng = ServingEngine(cfg, params, sc)
    h = eng.submit(prompt)
    out = []
    while len(out) < n and (eng.slot_live.any() or eng.wait):
        for hh, t in eng.step().items():
            if hh == h:
                out.append(t)
    return out[:n]


def test_stream_matches_engine(setup):
    cfg, params = setup
    sc = ServeConfig(batch_slots=2, max_len=32)
    want = sync_stream(cfg, params, sc, [3, 1, 4, 1, 5], 6)
    aeng = AsyncServingEngine(ServingEngine(cfg, params, sc))
    got = asyncio.run(aeng.complete([3, 1, 4, 1, 5], 6))
    assert got == want
    assert aeng.in_flight == 0


def test_concurrent_streams_match_solo_runs(setup):
    """N concurrent consumers through one pump: every stream equals its
    solo engine run — batching is invisible to each consumer."""
    cfg, params = setup
    sc = ServeConfig(batch_slots=2, max_len=32, attention=PAGED8,
                     cache_pages=8)
    prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [2, 4]]

    async def run_all(aeng):
        return await asyncio.gather(
            *(aeng.complete(p, 5, priority=i % 2)
              for i, p in enumerate(prompts)))

    aeng = AsyncServingEngine(ServingEngine(cfg, params, sc))
    got = asyncio.run(run_all(aeng))
    for p, stream in zip(prompts, got):
        assert stream == sync_stream(cfg, params, ServeConfig(
            batch_slots=2, max_len=32, attention=PAGED8, cache_pages=8),
            p, 5), p
    assert aeng.engine.pool.free_pages == aeng.engine.pool.n_pages


def test_breaking_out_cancels_request(setup):
    cfg, params = setup
    sc = ServeConfig(batch_slots=1, max_len=32, attention=PAGED8)
    eng = ServingEngine(cfg, params, sc)
    aeng = AsyncServingEngine(eng)

    async def take_two():
        got = []
        async for tok in aeng.stream([1, 2, 3], 10):
            got.append(tok)
            if len(got) == 2:
                break                    # consumer walks away
        return got

    got = asyncio.run(take_two())
    assert len(got) == 2
    assert aeng.in_flight == 0
    assert eng.pool.free_pages == eng.pool.n_pages   # pages released


def test_stream_closes_at_engine_horizon(setup):
    """A request retiring at max_len stops producing; its stream must end
    rather than hang, even while other requests keep running."""
    cfg, params = setup
    sc = ServeConfig(batch_slots=2, max_len=8)
    aeng = AsyncServingEngine(ServingEngine(cfg, params, sc))

    async def run():
        return await asyncio.gather(aeng.complete([1, 2, 3], 50),
                                    aeng.complete([4, 5, 6], 4))

    long, short = asyncio.run(run())
    assert len(short) == 4
    assert 0 < len(long) < 50            # horizon-bounded, not hung
    assert aeng.in_flight == 0


def test_queued_overflow_is_served_after_capacity_frees(setup):
    """More concurrent streams than slots: the surplus queues in the
    frontend and is admitted as capacity frees — every stream completes."""
    cfg, params = setup
    sc = ServeConfig(batch_slots=2, max_len=32, attention=PAGED8,
                     cache_pages=8)
    aeng = AsyncServingEngine(ServingEngine(cfg, params, sc))

    async def run():
        return await asyncio.gather(
            *(aeng.complete([10 + i, 20 + i], 3) for i in range(5)))

    streams = asyncio.run(run())
    assert all(len(s) == 3 for s in streams)
    assert aeng.in_flight == 0


def test_stream_rejects_nonpositive_budget(setup):
    cfg, params = setup
    aeng = AsyncServingEngine(ServingEngine(
        cfg, params, ServeConfig(batch_slots=1, max_len=16)))
    with pytest.raises(ValueError, match="n_tokens"):
        asyncio.run(aeng.complete([1, 2], 0))
