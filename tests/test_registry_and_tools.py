"""Registry cell enumeration, dry-run helpers, data memmap source,
pipeline stacking helpers — the long tail of framework coverage."""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, SHAPES, all_cells, get_config
from repro.data.pipeline import DataConfig, TokenPipeline


def test_cell_grid_counts():
    """40 assigned cells; 8 long_500k cells excluded for full-attention
    archs → 34 runnable? No: 10 archs × 4 shapes = 40; long_500k applies
    to 2 archs → 32 runnable cells."""
    cells = list(all_cells())
    assert len(cells) == 32
    long_archs = {a for a, s in cells if s.name == "long_500k"}
    assert long_archs == {"mamba2-1.3b", "zamba2-2.7b"}


def test_all_archs_have_source_provenance():
    for arch in ARCHS:
        cfg = get_config(arch)
        assert cfg.source, arch
        assert cfg.n_layers > 0 and cfg.d_model > 0


def test_shape_cells_match_assignment():
    assert SHAPES["train_4k"].seq == 4096 and SHAPES["train_4k"].batch == 256
    assert SHAPES["prefill_32k"].seq == 32768
    assert SHAPES["prefill_32k"].batch == 32
    assert SHAPES["decode_32k"].batch == 128
    assert SHAPES["long_500k"].seq == 524288
    assert SHAPES["long_500k"].batch == 1


def test_decode_shapes_lower_serve_step_not_train():
    assert SHAPES["decode_32k"].kind == "decode"
    assert SHAPES["long_500k"].kind == "decode"
    assert SHAPES["train_4k"].kind == "train"


def test_active_params_moe_discount():
    from repro.launch.dryrun import active_params, count_params_abstract_cfg
    cfg = get_config("deepseek-v2-236b")
    n = count_params_abstract_cfg(cfg)
    act = active_params(cfg, n)
    assert act < n * 0.25          # top-6 of 160 experts → mostly inactive
    dense = get_config("qwen3-8b")
    nd = count_params_abstract_cfg(dense)
    assert active_params(dense, nd) == float(nd)


def test_memmap_data_source(tmp_path):
    data = np.arange(10_000, dtype=np.uint16) % 97
    path = tmp_path / "tokens.bin"
    data.tofile(path)
    cfg = DataConfig(seq_len=32, global_batch=2, vocab=97, source="memmap",
                     path=str(path))
    p = TokenPipeline(cfg)
    b1 = p.next_batch()["tokens"]
    assert b1.shape == (2, 32)
    assert b1.max() < 97
    # restartability holds for memmap too
    p2 = TokenPipeline(cfg)
    np.testing.assert_array_equal(p2.next_batch()["tokens"], b1)


def test_stack_for_stages_roundtrip():
    from repro.distributed.pipeline import stack_for_stages
    t = {"w": jnp.arange(24).reshape(8, 3)}
    s = stack_for_stages(t, 4)
    assert s["w"].shape == (4, 2, 3)
    np.testing.assert_array_equal(np.asarray(s["w"].reshape(8, 3)),
                                  np.asarray(t["w"]))


def test_hillclimb_variant_parsing():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.hillclimb import VARIANTS
    assert "baseline" in VARIANTS and "seqpar" in VARIANTS


@pytest.mark.slow
def test_dryrun_cell_subprocess_smoke():
    """One real 256-device dry-run cell end-to-end in a subprocess (the
    pytest process keeps its 1-device platform)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    script = (
        "from repro.launch.dryrun import dryrun_cell;"
        "r = dryrun_cell('mamba2-1.3b', 'long_500k', verbose=False);"
        "import json; print('RESULT ' + json.dumps(r['roofline']['bottleneck']))"
    )
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=560)
    assert "RESULT " in r.stdout, (r.stdout[-1000:], r.stderr[-3000:])
