"""Property tests for distributed/sharding.py::ShardingRules.

The rule engine is now load-bearing for TP serving (repro/distributed/tp.py
derives its column/row/head sharding decisions from it), so its contracts
get the hypothesis treatment (tests/hypcompat.py shim — skips without
hypothesis, the CI test job installs it):

  * resolved specs never over-partition: every dim's assigned mesh-axis
    product divides the dim (the divisibility fallback to replicated), and
    no mesh axis is assigned twice;
  * spec() is deterministic — same inputs, same spec, across calls and
    across equally-configured instances;
  * overrides round-trip: construction-time overrides are visible in
    ``rules``, don't leak into DEFAULT_RULES, and govern the spec.

The explicit example tests at the bottom pin the same invariants without
hypothesis, so a bare environment still exercises the checkers.
"""
import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from hypcompat import given, settings, st
from repro.distributed import sharding as shd

LOGICALS = sorted(shd.DEFAULT_RULES)
AXIS_VALUES = (None, "data", "model", "pod", ("pod", "data"))


def sized_rules(data: int = 1, model: int = 1, overrides=None,
                pod: int = 1) -> shd.ShardingRules:
    """ShardingRules over a fabricated (data, model) mesh whose axis sizes
    are reported as given — the same trick tests/test_sharding.py uses, so
    over-partition checks run without multi-device hosts."""
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    sizes = {"data": data, "model": model, "pod": pod}

    class Sized(shd.ShardingRules):
        def _mesh_size(self, axes):
            if axes is None:
                return 1
            axes = (axes,) if isinstance(axes, str) else axes
            n = 1
            for a in axes:
                n *= sizes.get(a, 1)
            return n

    return Sized(mesh, overrides)


def axes_of(entry):
    return (entry,) if isinstance(entry, str) else tuple(entry or ())


def assert_spec_well_formed(rules: shd.ShardingRules, logical, shape):
    """The two structural invariants every resolved spec must satisfy."""
    spec = rules.spec(logical, shape)
    entries = tuple(spec)
    assert len(entries) == len(logical), (spec, logical)
    used = []
    for dim, entry in zip(shape, entries):
        size = rules._mesh_size(entry)
        assert dim % max(size, 1) == 0, (
            f"over-partitioned: dim {dim} split {size}-way in {spec} "
            f"for logical={logical} shape={shape}")
        used.extend(axes_of(entry))
    assert len(used) == len(set(used)), (
        f"mesh axis assigned twice in {spec} for logical={logical}")
    return spec


@settings(max_examples=200, deadline=None)
@given(names=st.lists(st.sampled_from(LOGICALS + [None]), min_size=1,
                      max_size=4),
       dims=st.lists(st.integers(min_value=1, max_value=96), min_size=4,
                     max_size=4),
       data=st.sampled_from([1, 2, 3, 4, 16]),
       model=st.sampled_from([1, 2, 3, 4, 16]))
def test_spec_never_overpartitions(names, dims, data, model):
    rules = sized_rules(data=data, model=model)
    assert_spec_well_formed(rules, tuple(names), tuple(dims[:len(names)]))


@settings(max_examples=100, deadline=None)
@given(names=st.lists(st.sampled_from(LOGICALS + [None]), min_size=1,
                      max_size=4),
       dims=st.lists(st.integers(min_value=1, max_value=96), min_size=4,
                     max_size=4),
       model=st.sampled_from([1, 2, 4]))
def test_spec_is_deterministic(names, dims, model):
    logical, shape = tuple(names), tuple(dims[:len(names)])
    a = sized_rules(model=model)
    b = sized_rules(model=model)
    assert a.spec(logical, shape) == a.spec(logical, shape)
    assert a.spec(logical, shape) == b.spec(logical, shape)
    # shape-less resolution is deterministic too
    assert a.spec(logical) == b.spec(logical)


@settings(max_examples=100, deadline=None)
@given(key=st.sampled_from(LOGICALS),
       value=st.sampled_from(AXIS_VALUES))
def test_overrides_round_trip(key, value):
    before = dict(shd.DEFAULT_RULES)
    rules = sized_rules(data=2, model=2, overrides={key: value})
    assert rules.rules[key] == value                 # override lands
    assert shd.DEFAULT_RULES == before               # and doesn't leak
    for other in LOGICALS:
        if other != key:
            assert rules.rules[other] == shd.DEFAULT_RULES[other]
    # and it governs resolution: a divisible dim follows the override
    spec = rules.spec((key,), (16,))
    resolved = rules._resolve(value)
    assert tuple(spec) == (resolved,), (spec, value)


# --- explicit examples: the same invariants without hypothesis ------------

def test_overpartition_fallback_example():
    rules = sized_rules(data=4, model=16)
    spec = assert_spec_well_formed(rules, ("embed", "heads"), (576, 9 * 64))
    assert spec == P("data", "model")
    spec = assert_spec_well_formed(rules, (None, "heads"), (1, 9))
    assert spec == P(None, None)                     # 9 % 16 → replicate


def test_duplicate_axis_resolution_example():
    """act_seq flipped to model (sequence parallelism) collides with a TP
    feature dim: the feature dim must win, the sequence dim replicate."""
    rules = sized_rules(data=2, model=2, overrides={"act_seq": "model"})
    spec = assert_spec_well_formed(
        rules, ("act_batch", "act_seq", "act_mlp"), (4, 8, 8))
    assert spec == P("data", None, "model")


def test_overrides_do_not_mutate_defaults_example():
    before = dict(shd.DEFAULT_RULES)
    sized_rules(overrides={"heads": None, "mlp": "data"})
    assert shd.DEFAULT_RULES == before
