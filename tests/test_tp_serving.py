"""Golden stream-equivalence for tensor-parallel serving (docs/serving.md).

The acceptance gate of the TP tier: a TP=2 paged serving engine
(ServeConfig.mesh over a (data, model) host mesh, per-shard KV pools,
shard_map'd GEMM + paged attention — repro/distributed/tp.py) must produce
token streams **identical** to the single-device engine — greedy,
seeded-temperature, and across a forced preempt/resume cycle.

Multi-device CPU hosts require XLA_FLAGS before jax initializes, and
conftest.py must stay 1-device (its own warning), so the scenarios run in
a subprocess: tests/tp_serving_runner.py holds the actual assertions; this
file owns process isolation and failure surfacing.
"""
import os
import subprocess
import sys

import pytest

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(TESTS_DIR)
RUNNER = os.path.join(TESTS_DIR, "tp_serving_runner.py")


def run_tp_subprocess(script, args, timeout=900):
    """Run a tests/ script on a forced 4-device CPU host; returns stdout.
    Fails with the child's full output on a nonzero exit."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.run([sys.executable, script, *args], env=env,
                          capture_output=True, text=True, timeout=timeout,
                          cwd=REPO)
    assert proc.returncode == 0, (
        f"{os.path.basename(script)} {' '.join(args)} failed "
        f"(exit {proc.returncode})\n--- stdout ---\n{proc.stdout}\n"
        f"--- stderr ---\n{proc.stderr}")
    return proc.stdout


def test_tp2_paged_stream_equivalence():
    """TP=2 vs single-device: batched greedy generate, greedy submit/step
    streams, seeded-temperature sampling, preempt/resume, and prefix-cache
    COW sharing in lockstep (same streams AND same per-step page
    accounting on the sharded pool) — all token-identical (one subprocess;
    the runner prints a PASS marker per scenario so a partial run cannot
    pass silently)."""
    out = run_tp_subprocess(RUNNER, [])
    for marker in ("TP-EQUIV PASS greedy", "TP-EQUIV PASS temperature",
                   "TP-EQUIV PASS preempt-resume", "TP-EQUIV PASS prefix",
                   "TP-EQUIV PASS kv-int8", "TP-EQUIV PASS all"):
        assert marker in out, f"missing {marker!r} in runner output:\n{out}"


def test_tp_engine_rejects_packed_weights():
    """Resident block-major packed weights are not TP-shardable yet; the
    combination must refuse at construction, not misplace silently.
    (In-process: a 1-device mesh is enough to trip the check.)"""
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.configs.registry import get_smoke_config
    from repro.models import transformer as T
    from repro.serving.engine import ServeConfig, ServingEngine

    cfg = get_smoke_config("smollm-135m", n_layers=1, vocab=64)
    params, axes = T.init_model(jax.random.PRNGKey(0), cfg)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    with pytest.raises(NotImplementedError, match="packed"):
        ServingEngine(cfg, params, ServeConfig(
            batch_slots=1, max_len=16, pack_weights=True, mesh=mesh),
            axes=axes)


def test_tp_context_noop_on_trivial_model_axis():
    """A (N,1) mesh — model axis 1 — must leave every wrapper on the plain
    api path: same arrays, no shard_map, token streams trivially equal."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.core import api
    from repro.distributed import tp

    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    ctx = tp.make_context(mesh)
    assert ctx.model_size == 1
    assert tp.head_sharding(ctx, 4, 2) == (False, False)
    x = jnp.ones((2, 8))
    w = jnp.ones((8, 4))
    with tp.use_tp(ctx):
        got = tp.linear(x, w, axes=("embed", "mlp"))
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(api.linear(x, w)))
