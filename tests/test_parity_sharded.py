"""Tier-1 gate over the SHARDED parity grid (tests/parity.py --sharded).

Every (mesh × backend × dtype) cell — shard_map'd column/row-parallel GEMM
and head-sharded fused/paged attention (repro/distributed/tp.py) — must
match its unsharded twin to the same per-dtype tolerances as the existing
backend grid, on meshes (1,1)/(2,1)/(1,2)/(2,2).

Multi-device CPU hosts need XLA_FLAGS set before jax initializes and
conftest.py must stay 1-device (its own warning), so the grid runs in a
subprocess — the same CLI CI's ``parity-sharded`` job invokes per dtype.
"""
import os

from test_tp_serving import run_tp_subprocess

PARITY = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "parity.py")


def test_sharded_parity_grid_float32():
    """The full mesh grid at float32 (CI's dtype matrix adds bfloat16 and
    int8): GEMM column+row parallel and fused/paged sharded attention all
    agree with their unsharded cells."""
    out = run_tp_subprocess(PARITY, ["--sharded", "--dtypes", "float32"])
    assert "parity[sharded]:" in out and "cells OK" in out, out


def test_sharded_parity_grid_int8_exact():
    """int8 GEMM cells must stay integer-exact under sharding: the
    row-parallel path psums int32 partial accumulators, which is
    associative — any deviation means the TP layer re-quantized or
    re-ordered through a lossy dtype. One mesh suffices (the others are
    covered by the float32 grid + CI)."""
    out = run_tp_subprocess(
        PARITY, ["--sharded", "--dtypes", "int8", "--mesh-shapes", "2x2"])
    assert "parity[sharded]:" in out and "cells OK" in out, out
