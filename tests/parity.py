"""Cross-backend differential GEMM harness (backend × dtype × shape grid).

Every registered GEMM backend must be provably equivalent on every dtype the
paper's MAC units cover (Table 2): the blockflow oracle (faithful Algorithm
1), the Pallas kernel (interpret mode on CPU), and XLA einsum must agree
with the pure-jnp reference within per-dtype tolerances — and *exactly* (in
integers) for int8, where accumulation in int32 is associative.

The grid also sweeps the quantized W8A8 route (``GemmPolicy(weight_dtype=
"int8")``): all backends share the same quantization functions and the same
rank-1 dequant, so their fp32 outputs must agree bitwise-tight with the
unfused reference formula.

Used three ways:
  * ``tests/test_parity.py`` parametrizes pytest over the grid (tier-1 gate);
  * CI's dtype-matrix job runs ``python tests/parity.py --dtypes <dt>``;
  * new backends/dtypes extend BACKENDS / DTYPES / SHAPES and inherit the
    whole gate.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import api
from repro.core import quant as Q
from repro.core.plan import GemmPolicy

BACKENDS = ("xla", "blockflow", "pallas_interpret")
DTYPES = ("float32", "bfloat16", "int8")

# (M, K, N): MXU-aligned, multi-block, ragged/odd (padding paths), and the
# decode-like skinny-M GEMV.
SHAPES = (
    (8, 8, 8),
    (64, 96, 48),
    (33, 17, 65),
    (1, 64, 128),
    (130, 24, 56),
)

# (atol, rtol) per dtype; int8 demands exact integer equality.
TOLS = {
    "float32": (1e-4, 1e-5),
    "bfloat16": (5e-2, 5e-2),
    "int8": (0.0, 0.0),
}


@dataclasses.dataclass
class ParityResult:
    backend: str
    dtype: str
    shape: Tuple[int, int, int]
    max_err: float
    ok: bool
    detail: str = ""


def make_operands(dtype: str, M: int, K: int, N: int, seed: int = 0):
    """Deterministic operands per (dtype, shape) cell."""
    rng = np.random.default_rng((seed * 7919 + M * 1000003 + K * 1009 + N)
                                % 2**32)
    if dtype == "int8":
        a = rng.integers(-127, 128, (M, K)).astype(np.int8)
        b = rng.integers(-127, 128, (K, N)).astype(np.int8)
        return jnp.asarray(a), jnp.asarray(b)
    a = rng.standard_normal((M, K)).astype(np.float32)
    b = rng.standard_normal((K, N)).astype(np.float32)
    return (jnp.asarray(a).astype(jnp.dtype(dtype)),
            jnp.asarray(b).astype(jnp.dtype(dtype)))


def reference(a, b) -> np.ndarray:
    """Ground truth: int64 exact for integer inputs, fp32 accumulation else."""
    if jnp.issubdtype(a.dtype, jnp.integer):
        return np.asarray(a, np.int64) @ np.asarray(b, np.int64)
    return (np.asarray(a, np.float32) @ np.asarray(b, np.float32))


def check_cell(backend: str, dtype: str,
               shape: Tuple[int, int, int]) -> ParityResult:
    """One grid cell: backend output vs reference. Raises AssertionError
    with full context on disagreement; returns the passing ParityResult."""
    M, K, N = shape
    a, b = make_operands(dtype, M, K, N)
    ref = reference(a, b)
    out = api.matmul(a, b, policy=GemmPolicy(backend=backend))
    assert out.shape == (M, N), (out.shape, shape)
    ctx = f"backend={backend} dtype={dtype} shape={shape}"
    if dtype == "int8":
        assert out.dtype == jnp.int32, f"{ctx}: got {out.dtype}, want int32"
        got = np.asarray(out, np.int64)
        np.testing.assert_array_equal(
            got, ref, err_msg=f"{ctx}: int8 GEMM must be integer-exact")
        return ParityResult(backend, dtype, shape, 0.0, True, "exact")
    atol, rtol = TOLS[dtype]
    got = np.asarray(out, np.float32)
    err = float(np.abs(got - ref).max()) if got.size else 0.0
    np.testing.assert_allclose(got, ref, atol=atol, rtol=rtol, err_msg=ctx)
    return ParityResult(backend, dtype, shape, err, True)


def check_quantized_cell(backend: str,
                         shape: Tuple[int, int, int]) -> ParityResult:
    """The W8A8 route under GemmPolicy(weight_dtype="int8") vs the unfused
    dequant reference — same int8 operands, same scales, so every backend
    must land within fp32 noise of the rank-1 rescaled int32 GEMM."""
    M, K, N = shape
    a, w = make_operands("float32", M, K, N, seed=1)
    aq, sa = Q.quantize_activations(a)
    wq, sw = Q.quantize_weight(w)
    c_int = np.asarray(aq, np.int64) @ np.asarray(wq, np.int64)
    ref = np.asarray(Q.dequantize_gemm(jnp.asarray(c_int, jnp.int32),
                                       sa, sw), np.float32)
    pol = GemmPolicy(backend=backend, weight_dtype="int8")
    out = np.asarray(api.linear(a, w, policy=pol), np.float32)
    ctx = f"quantized backend={backend} shape={shape}"
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-6, err_msg=ctx)
    # and the quantization error vs the fp product stays bounded:
    fp = reference(a, w)
    budget = np.abs(fp).max() * 0.05 + 1e-3
    err = float(np.abs(out - fp).max())
    assert err <= budget, f"{ctx}: quant error {err} > budget {budget}"
    return ParityResult(backend, "int8(w8a8)", shape, err, True)


def run_grid(backends: Sequence[str] = BACKENDS,
             dtypes: Sequence[str] = DTYPES,
             shapes: Sequence[Tuple[int, int, int]] = SHAPES,
             *, quantized: bool = True,
             out=sys.stdout) -> list:
    """Sweep the full grid; returns results, raising on first failure."""
    results = []
    for dtype in dtypes:
        for backend in backends:
            for shape in shapes:
                r = check_cell(backend, dtype, shape)
                results.append(r)
                print(f"parity {backend:17s} {dtype:9s} "
                      f"{'x'.join(map(str, shape)):12s} "
                      f"max_err={r.max_err:.2e} {r.detail}", file=out)
    if quantized and "int8" in dtypes:
        for backend in backends:
            for shape in shapes[:3]:
                r = check_quantized_cell(backend, shape)
                results.append(r)
                print(f"parity {backend:17s} w8a8      "
                      f"{'x'.join(map(str, shape)):12s} "
                      f"max_err={r.max_err:.2e}", file=out)
    return results


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dtypes", nargs="+", default=list(DTYPES),
                    choices=list(DTYPES))
    ap.add_argument("--backends", nargs="+", default=list(BACKENDS))
    ap.add_argument("--no-quantized", action="store_true",
                    help="skip the W8A8 weight_dtype route cells")
    args = ap.parse_args(argv)
    results = run_grid(args.backends, args.dtypes,
                       quantized=not args.no_quantized)
    print(f"parity: {len(results)} cells OK "
          f"(backends={args.backends}, dtypes={args.dtypes})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
