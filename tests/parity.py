"""Cross-backend differential harness (GEMM and attention grids).

Every registered GEMM backend must be provably equivalent on every dtype the
paper's MAC units cover (Table 2): the blockflow oracle (faithful Algorithm
1), the Pallas kernel (interpret mode on CPU), and XLA einsum must agree
with the pure-jnp reference within per-dtype tolerances — and *exactly* (in
integers) for int8, where accumulation in int32 is associative.

The grid also sweeps the quantized W8A8 route (``GemmPolicy(weight_dtype=
"int8")``): all backends share the same quantization functions and the same
rank-1 dequant, so their fp32 outputs must agree bitwise-tight with the
unfused reference formula.

The **attention grid** applies the same discipline to the AttentionPolicy
registry (docs/attention.md): every attention backend — the offset-aware
fused flash kernel (interpret mode on CPU), the unfused einsum +
host-softmax baseline, and the block-table **paged** kernel
(kernels/paged_attention.py, docs/serving.md) — must match
``kernels/ref.py::mha_ref`` on cases covering prefill, single-token decode
against a long ragged cache, GQA head grouping, non-causal ragged keys,
and serving's masked position −1 rows. Paged cells scatter the dense K/V
into a page pool under a *shuffled* page assignment with garbage-filled
distractor pages, so any fetch outside the block table, any masking slip
past ``kv_valid_len``, or any logical/physical confusion diverges loudly.

The **sharded grid** extends both disciplines across device meshes: every
GEMM cell re-runs column- and row-parallel through the shard_map'd TP layer
(repro/distributed/tp.py) and every attention cell re-runs with heads (and
KV pools) sharded over the model axis, on meshes of shape
(1,1)/(2,1)/(1,2)/(2,2) — asserting sharded ≡ unsharded to the same
per-dtype tolerances. These cells need a multi-device host
(``XLA_FLAGS=--xla_force_host_platform_device_count=4``): CI's
``parity-sharded`` job sets it, and the tier-1 gate runs them through the
subprocess-isolated ``tests/test_parity_sharded.py`` (conftest.py must stay
1-device per its own warning).

Used three ways:
  * ``tests/test_parity.py`` parametrizes pytest over the grids (tier-1
    gate); ``tests/test_parity_sharded.py`` adds the mesh axis via a
    subprocess;
  * CI's dtype-matrix job runs ``python tests/parity.py --dtypes <dt>``
    (GEMM cells for every dtype, attention cells for the fp dtypes;
    ``int8`` additionally selects the quantized-KV paged cells —
    ``AttentionPolicy(kv_dtype="int8")``, oracle on the dequantized
    pool); the
    ``parity-sharded`` job runs ``--sharded --dtypes <dt>`` on a forced
    4-device host;
  * new backends/dtypes/cases/mesh shapes extend BACKENDS / DTYPES /
    SHAPES / ATTN_BACKENDS / ATTN_CASES / MESH_SHAPES and inherit the
    whole gate.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import api
from repro.core import quant as Q
from repro.core.plan import AttentionPolicy, GemmPolicy
from repro.kernels.ref import mha_ref

BACKENDS = ("xla", "blockflow", "pallas_interpret")
DTYPES = ("float32", "bfloat16", "int8")

# (M, K, N): MXU-aligned, multi-block, ragged/odd (padding paths), and the
# decode-like skinny-M GEMV.
SHAPES = (
    (8, 8, 8),
    (64, 96, 48),
    (33, 17, 65),
    (1, 64, 128),
    (130, 24, 56),
)

# (atol, rtol) per dtype; int8 demands exact integer equality.
TOLS = {
    "float32": (1e-4, 1e-5),
    "bfloat16": (5e-2, 5e-2),
    "int8": (0.0, 0.0),
}


@dataclasses.dataclass
class ParityResult:
    backend: str
    dtype: str
    shape: Tuple[int, int, int]
    max_err: float
    ok: bool
    detail: str = ""


def make_operands(dtype: str, M: int, K: int, N: int, seed: int = 0):
    """Deterministic operands per (dtype, shape) cell."""
    rng = np.random.default_rng((seed * 7919 + M * 1000003 + K * 1009 + N)
                                % 2**32)
    if dtype == "int8":
        a = rng.integers(-127, 128, (M, K)).astype(np.int8)
        b = rng.integers(-127, 128, (K, N)).astype(np.int8)
        return jnp.asarray(a), jnp.asarray(b)
    a = rng.standard_normal((M, K)).astype(np.float32)
    b = rng.standard_normal((K, N)).astype(np.float32)
    return (jnp.asarray(a).astype(jnp.dtype(dtype)),
            jnp.asarray(b).astype(jnp.dtype(dtype)))


def reference(a, b) -> np.ndarray:
    """Ground truth: int64 exact for integer inputs, fp32 accumulation else."""
    if jnp.issubdtype(a.dtype, jnp.integer):
        return np.asarray(a, np.int64) @ np.asarray(b, np.int64)
    return (np.asarray(a, np.float32) @ np.asarray(b, np.float32))


def check_cell(backend: str, dtype: str,
               shape: Tuple[int, int, int]) -> ParityResult:
    """One grid cell: backend output vs reference. Raises AssertionError
    with full context on disagreement; returns the passing ParityResult."""
    M, K, N = shape
    a, b = make_operands(dtype, M, K, N)
    ref = reference(a, b)
    out = api.matmul(a, b, policy=GemmPolicy(backend=backend))
    assert out.shape == (M, N), (out.shape, shape)
    ctx = f"backend={backend} dtype={dtype} shape={shape}"
    if dtype == "int8":
        assert out.dtype == jnp.int32, f"{ctx}: got {out.dtype}, want int32"
        got = np.asarray(out, np.int64)
        np.testing.assert_array_equal(
            got, ref, err_msg=f"{ctx}: int8 GEMM must be integer-exact")
        return ParityResult(backend, dtype, shape, 0.0, True, "exact")
    atol, rtol = TOLS[dtype]
    got = np.asarray(out, np.float32)
    err = float(np.abs(got - ref).max()) if got.size else 0.0
    np.testing.assert_allclose(got, ref, atol=atol, rtol=rtol, err_msg=ctx)
    return ParityResult(backend, dtype, shape, err, True)


def check_quantized_cell(backend: str,
                         shape: Tuple[int, int, int]) -> ParityResult:
    """The W8A8 route under GemmPolicy(weight_dtype="int8") vs the unfused
    dequant reference — same int8 operands, same scales, so every backend
    must land within fp32 noise of the rank-1 rescaled int32 GEMM."""
    M, K, N = shape
    a, w = make_operands("float32", M, K, N, seed=1)
    aq, sa = Q.quantize_activations(a)
    wq, sw = Q.quantize_weight(w)
    c_int = np.asarray(aq, np.int64) @ np.asarray(wq, np.int64)
    ref = np.asarray(Q.dequantize_gemm(jnp.asarray(c_int, jnp.int32),
                                       sa, sw), np.float32)
    pol = GemmPolicy(backend=backend, weight_dtype="int8")
    out = np.asarray(api.linear(a, w, policy=pol), np.float32)
    ctx = f"quantized backend={backend} shape={shape}"
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-6, err_msg=ctx)
    # and the quantization error vs the fp product stays bounded:
    fp = reference(a, w)
    budget = np.abs(fp).max() * 0.05 + 1e-3
    err = float(np.abs(out - fp).max())
    assert err <= budget, f"{ctx}: quant error {err} > budget {budget}"
    return ParityResult(backend, "int8(w8a8)", shape, err, True)


# ---------------------------------------------------------------------------
# Attention grid (backend × dtype × case)
# ---------------------------------------------------------------------------

ATTN_BACKENDS = ("unfused", "fused_interpret", "paged_interpret")
ATTN_DTYPES = ("float32", "bfloat16")       # fp only: scores are fp32 always
ATTN_PAGE_SIZE = 16                          # key-block of the paged cells

# (atol, rtol) per dtype for attention outputs (post-softmax, O(1) scale).
ATTN_TOLS = {"float32": (3e-5, 3e-5), "bfloat16": (3e-2, 3e-2)}


@dataclasses.dataclass(frozen=True)
class AttnCase:
    """One attention-grid cell: shapes plus the offset/length semantics.

    q_offsets: per-batch-row position of the first query (−1 → the whole
    row is masked, serving's position −1 contract); None → the default
    bottom-right alignment. kv_lens: per-row valid key count; None → T.
    """

    name: str
    B: int
    Sq: int
    T: int
    H: int
    Hkv: int
    causal: bool = True
    q_offsets: Optional[Tuple[int, ...]] = None
    kv_lens: Optional[Tuple[int, ...]] = None


ATTN_CASES = (
    # pure prefill, MHA, block-aligned
    AttnCase("prefill_mha", B=2, Sq=32, T=32, H=4, Hkv=4),
    # prefill with GQA grouping and a ragged (non-block-multiple) length
    AttnCase("prefill_gqa_ragged", B=2, Sq=33, T=33, H=4, Hkv=2),
    # single-token decode against a long, partially filled cache (per-row
    # offsets — the continuous-batching slots)
    AttnCase("decode_long_cache", B=3, Sq=1, T=96, H=4, Hkv=2,
             q_offsets=(5, 80, 37), kv_lens=(6, 81, 38)),
    # decode batch containing masked (position −1) serving rows
    AttnCase("decode_masked_rows", B=3, Sq=1, T=64, H=2, Hkv=1,
             q_offsets=(12, -1, 3), kv_lens=(13, 0, 4)),
    # chunked prefill: a short query block continuing a long cache
    AttnCase("prefill_chunk_offset", B=2, Sq=8, T=64, H=2, Hkv=2,
             q_offsets=(24, 40), kv_lens=(32, 48)),
    # non-causal ragged keys (the old kernel raised ValueError here)
    AttnCase("noncausal_ragged", B=2, Sq=17, T=45, H=2, Hkv=1, causal=False,
             kv_lens=(45, 29)),
)


def make_attention_operands(case: AttnCase, dtype: str, seed: int = 0):
    """Deterministic (q, k, v, q_positions, kv_valid_len) per cell."""
    rng = np.random.default_rng(
        (seed * 7919 + case.B * 1000003 + case.Sq * 1009 + case.T) % 2**32)
    dt = jnp.dtype(dtype)
    q = jnp.asarray(rng.standard_normal(
        (case.B, case.Sq, case.H, 16), np.float32)).astype(dt)
    k = jnp.asarray(rng.standard_normal(
        (case.B, case.T, case.Hkv, 16), np.float32)).astype(dt)
    v = jnp.asarray(rng.standard_normal(
        (case.B, case.T, case.Hkv, 16), np.float32)).astype(dt)
    if case.q_offsets is None:
        q_positions = jnp.broadcast_to(
            jnp.arange(case.Sq, dtype=jnp.int32) + (case.T - case.Sq),
            (case.B, case.Sq))
    else:
        offs = np.asarray(case.q_offsets, np.int32)[:, None]
        q_positions = jnp.asarray(
            np.where(offs < 0, -1, offs + np.arange(case.Sq)[None, :])
            .astype(np.int32))
    kv_valid_len = jnp.asarray(
        np.full((case.B,), case.T, np.int32) if case.kv_lens is None
        else np.asarray(case.kv_lens, np.int32))
    return q, k, v, q_positions, kv_valid_len


def make_paged_operands(k, v, page_size: int = ATTN_PAGE_SIZE,
                        seed: int = 0, n_distractors: int = 3,
                        garbage: float = 100.0):
    """Scatter dense (B, T, Hkv, D) K/V into page pools under a shuffled
    page assignment. Returns (k_pages, v_pages, block_tables); distractor
    pages and every unwritten slot are filled with large garbage so an
    out-of-table fetch cannot silently agree with the oracle. (Also the
    single pool-construction helper for tests/test_paged_attention.py.)"""
    B, T, Hkv, D = k.shape
    nb = -(-T // page_size)
    P = B * nb + n_distractors                  # garbage distractor pages
    rng = np.random.default_rng(seed * 31 + B * 101 + T)
    kp = (rng.standard_normal((P, page_size, Hkv, D)) * garbage).astype(
        np.float32)
    vp = (rng.standard_normal((P, page_size, Hkv, D)) * garbage).astype(
        np.float32)
    assign = rng.permutation(P)[:B * nb].reshape(B, nb)
    kn, vn = np.asarray(k, np.float32), np.asarray(v, np.float32)
    for b in range(B):
        for t in range(T):
            page = assign[b, t // page_size]
            kp[page, t % page_size] = kn[b, t]
            vp[page, t % page_size] = vn[b, t]
    dt = jnp.dtype(k.dtype)
    return (jnp.asarray(kp).astype(dt), jnp.asarray(vp).astype(dt),
            jnp.asarray(assign.astype(np.int32)))


def check_attention_cell(backend: str, dtype: str,
                         case: AttnCase) -> ParityResult:
    """One attention cell: backend output vs the mha_ref oracle, plus the
    masked-row zero contract. Raises AssertionError with context. Paged
    backends read K/V through a shuffled block table over a distractor-
    laden pool; the oracle still sees the dense cache."""
    q, k, v, q_positions, kv_valid_len = make_attention_operands(case, dtype)
    ref = np.asarray(mha_ref(q, k, v, causal=case.causal,
                             q_positions=q_positions,
                             kv_valid_len=kv_valid_len), np.float32)
    pol = AttentionPolicy(backend=backend, block_q=32, block_k=32,
                          page_size=ATTN_PAGE_SIZE)
    if backend.startswith("paged"):
        kp, vp, bt = make_paged_operands(k, v)
        out = api.attention(q, kp, vp, q_positions=q_positions,
                            kv_valid_len=kv_valid_len, causal=case.causal,
                            block_tables=bt, policy=pol)
    else:
        out = api.attention(q, k, v, q_positions=q_positions,
                            kv_valid_len=kv_valid_len, causal=case.causal,
                            policy=pol)
    ctx = f"attention backend={backend} dtype={dtype} case={case.name}"
    assert out.shape == q.shape[:3] + (v.shape[-1],), (ctx, out.shape)
    got = np.asarray(out, np.float32)
    atol, rtol = ATTN_TOLS[dtype]
    np.testing.assert_allclose(got, ref, atol=atol, rtol=rtol, err_msg=ctx)
    masked = np.asarray(q_positions)[:, 0] < 0
    if masked.any():
        assert np.abs(got[masked]).max() == 0.0, \
            f"{ctx}: masked rows must be exactly zero"
    err = float(np.abs(got - ref).max()) if got.size else 0.0
    return ParityResult(backend, dtype, (case.B, case.Sq, case.T), err, True,
                        case.name)


def check_quantized_attention_cell(backend: str,
                                   case: AttnCase) -> ParityResult:
    """One quantized-KV cell (AttentionPolicy(kv_dtype="int8")): the paged
    backend reads int8 pages + (P, Hkv) per-page-per-head scales and
    dequantizes inside the key/value fetch. The oracle is mha_ref on the
    DEQUANTIZED pool — the in-kernel dequant is what is under test here,
    not the quantization error (core/quant.py owns that bound) — so the
    fp32 attention tolerances apply unchanged."""
    from repro.kernels.paged_attention import gather_pages

    q, k, v, q_positions, kv_valid_len = make_attention_operands(
        case, "float32")
    kp, vp, bt = make_paged_operands(k, v)
    qk, ks = Q.quantize_kv_pages(kp)
    qv, vs = Q.quantize_kv_pages(vp)
    ref = np.asarray(mha_ref(
        q, gather_pages(Q.dequantize_kv_pages(qk, ks), bt, case.T),
        gather_pages(Q.dequantize_kv_pages(qv, vs), bt, case.T),
        causal=case.causal, q_positions=q_positions,
        kv_valid_len=kv_valid_len), np.float32)
    pol = AttentionPolicy(backend=backend, block_q=32, block_k=32,
                          page_size=ATTN_PAGE_SIZE, kv_dtype="int8")
    out = api.attention(q, qk, qv, q_positions=q_positions,
                        kv_valid_len=kv_valid_len, causal=case.causal,
                        block_tables=bt, kv_scales=(ks, vs), policy=pol)
    ctx = f"attention backend={backend} kv_dtype=int8 case={case.name}"
    got = np.asarray(out, np.float32)
    atol, rtol = ATTN_TOLS["float32"]
    np.testing.assert_allclose(got, ref, atol=atol, rtol=rtol, err_msg=ctx)
    masked = np.asarray(q_positions)[:, 0] < 0
    if masked.any():
        assert np.abs(got[masked]).max() == 0.0, \
            f"{ctx}: masked rows must be exactly zero"
    err = float(np.abs(got - ref).max()) if got.size else 0.0
    return ParityResult(backend, "int8(kv)", (case.B, case.Sq, case.T),
                        err, True, case.name)


def run_attention_grid(backends: Sequence[str] = ATTN_BACKENDS,
                       dtypes: Sequence[str] = ATTN_DTYPES,
                       cases: Sequence[AttnCase] = ATTN_CASES,
                       out=sys.stdout) -> list:
    """Sweep the attention grid; raises on first divergence. "int8" in
    ``dtypes`` selects the quantized-KV cells (paged backends only — the
    policy layer rejects kv_dtype elsewhere), not an int8 compute dtype."""
    results = []
    for dtype in dtypes:
        if dtype == "int8":
            for backend in backends:
                if not backend.startswith("paged"):
                    continue            # kv_dtype is a paged-only policy
                for case in cases:
                    r = check_quantized_attention_cell(backend, case)
                    results.append(r)
                    print(f"parity {backend:17s} int8(kv)  "
                          f"attn:{case.name:22s} max_err={r.max_err:.2e}",
                          file=out)
            continue
        if dtype not in ATTN_TOLS:
            continue                    # integer dtypes: GEMM-only
        for backend in backends:
            for case in cases:
                r = check_attention_cell(backend, dtype, case)
                results.append(r)
                print(f"parity {backend:17s} {dtype:9s} "
                      f"attn:{case.name:22s} max_err={r.max_err:.2e}",
                      file=out)
    return results


# ---------------------------------------------------------------------------
# Sharded grid (mesh × backend × dtype): shard_map'd TP ≡ unsharded
# ---------------------------------------------------------------------------

# (data, model) mesh shapes; (2,2) needs the forced 4-device host.
MESH_SHAPES = ((1, 1), (2, 1), (1, 2), (2, 2))

SHARDED_GEMM_BACKENDS = ("xla", "blockflow")
SHARDED_ATTN_BACKENDS = ("fused_interpret", "paged_interpret")


def make_tp_mesh(shape: Tuple[int, int]):
    """A (data, model) mesh over the first shape[0]*shape[1] local devices."""
    import jax
    from jax.sharding import Mesh
    need = shape[0] * shape[1]
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, host has {len(devs)}; "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count=4 "
            f"before jax initializes (tests/test_parity_sharded.py does)")
    return Mesh(np.asarray(devs[:need]).reshape(shape), ("data", "model"))


def check_sharded_gemm_cell(mesh_shape: Tuple[int, int], backend: str,
                            dtype: str,
                            shape: Tuple[int, int, int]) -> ParityResult:
    """One sharded GEMM cell: the TP layer's column-parallel AND
    row-parallel (psum) paths vs the unsharded backend, exact for int8,
    per-dtype tolerances else. Non-divisible shapes exercise the
    replicated fallback (trivially equal — still asserted)."""
    from repro.distributed import tp
    M, K, N = shape
    a, b = make_operands(dtype, M, K, N)
    pol = GemmPolicy(backend=backend)
    ref = np.asarray(api.matmul(a, b, policy=pol))
    ctx = tp.make_context(make_tp_mesh(mesh_shape))
    ctx_desc = f"mesh={mesh_shape}"
    bias = (None if dtype == "int8"
            else jnp.asarray(np.arange(N, dtype=np.float32) * 0.25,
                             b.dtype))
    with tp.use_tp(ctx):
        # "mlp" → model axis: second position = column-parallel (N split),
        # first position = row-parallel (K split, fp32/int32 psum).
        col = np.asarray(tp.matmul(a, b, axes=("embed", "mlp"), policy=pol))
        row = np.asarray(tp.matmul(a, b, axes=("mlp", "embed"), policy=pol))
        colb = (None if bias is None else np.asarray(
            tp.linear(a, b, bias, axes=("embed", "mlp"), policy=pol)))
    checks = [("column", col, ref), ("row", row, ref)]
    if bias is not None:
        # sharded-bias path: the (N,) bias splits with its output columns
        refb = np.asarray(api.linear(a, b, bias, policy=pol))
        checks.append(("column+bias", colb, refb))
    err = 0.0
    for name, got, ref in checks:
        cx = (f"sharded {ctx_desc} {name}-parallel backend={backend} "
              f"dtype={dtype} shape={shape}")
        if dtype == "int8":
            np.testing.assert_array_equal(got, ref, err_msg=cx)
        else:
            atol, rtol = TOLS[dtype]
            np.testing.assert_allclose(got, ref, atol=atol, rtol=rtol,
                                       err_msg=cx)
            err = max(err, float(np.abs(got.astype(np.float32)
                                        - ref.astype(np.float32)).max()))
    return ParityResult(backend, dtype, shape, err, True,
                        f"mesh{mesh_shape[0]}x{mesh_shape[1]}")


def check_sharded_attention_cell(mesh_shape: Tuple[int, int], backend: str,
                                 dtype: str, case: AttnCase) -> ParityResult:
    """One sharded attention cell: heads (and the paged pool's KV heads)
    sharded over the model axis through tp.attention vs the unsharded
    backend and the mha_ref oracle. MQA cases (Hkv=1) exercise the
    KV-replication fallback; the masked-row zero contract must survive
    sharding."""
    from repro.distributed import tp
    q, k, v, q_positions, kv_valid_len = make_attention_operands(case, dtype)
    pol = AttentionPolicy(backend=backend, block_q=32, block_k=32,
                          page_size=ATTN_PAGE_SIZE)
    ref = np.asarray(mha_ref(q, k, v, causal=case.causal,
                             q_positions=q_positions,
                             kv_valid_len=kv_valid_len), np.float32)
    if backend.startswith("paged"):
        kop, vop, bt = make_paged_operands(k, v)
    else:
        kop, vop, bt = k, v, None
    unsharded = np.asarray(api.attention(
        q, kop, vop, q_positions=q_positions, kv_valid_len=kv_valid_len,
        causal=case.causal, block_tables=bt, policy=pol), np.float32)
    ctx = tp.make_context(make_tp_mesh(mesh_shape))
    with tp.use_tp(ctx):
        out = tp.attention(q, kop, vop, q_positions=q_positions,
                           kv_valid_len=kv_valid_len, causal=case.causal,
                           block_tables=bt, policy=pol)
    got = np.asarray(out, np.float32)
    cx = (f"sharded mesh={mesh_shape} attention backend={backend} "
          f"dtype={dtype} case={case.name}")
    atol, rtol = ATTN_TOLS[dtype]
    np.testing.assert_allclose(got, unsharded, atol=atol, rtol=rtol,
                               err_msg=f"{cx}: sharded vs unsharded")
    np.testing.assert_allclose(got, ref, atol=atol, rtol=rtol,
                               err_msg=f"{cx}: sharded vs oracle")
    masked = np.asarray(q_positions)[:, 0] < 0
    if masked.any():
        assert np.abs(got[masked]).max() == 0.0, \
            f"{cx}: masked rows must stay exactly zero under sharding"
    err = float(np.abs(got - ref).max()) if got.size else 0.0
    return ParityResult(backend, dtype, (case.B, case.Sq, case.T), err, True,
                        f"{case.name}@mesh{mesh_shape[0]}x{mesh_shape[1]}")


def run_sharded_grid(mesh_shapes: Sequence[Tuple[int, int]] = MESH_SHAPES,
                     dtypes: Sequence[str] = DTYPES,
                     gemm_backends: Sequence[str] = SHARDED_GEMM_BACKENDS,
                     attn_backends: Sequence[str] = SHARDED_ATTN_BACKENDS,
                     shapes: Sequence[Tuple[int, int, int]] = SHAPES,
                     cases: Sequence[AttnCase] = ATTN_CASES,
                     out=sys.stdout) -> list:
    """Sweep the sharded grids; raises on first divergence."""
    results = []
    for ms in mesh_shapes:
        for dtype in dtypes:
            for backend in gemm_backends:
                for shape in shapes:
                    r = check_sharded_gemm_cell(ms, backend, dtype, shape)
                    results.append(r)
                    print(f"parity {backend:17s} {dtype:9s} "
                          f"{'x'.join(map(str, shape)):12s} "
                          f"max_err={r.max_err:.2e} {r.detail}", file=out)
            if dtype not in ATTN_TOLS:
                continue                # integer dtypes: GEMM-only
            for backend in attn_backends:
                for case in cases:
                    r = check_sharded_attention_cell(ms, backend, dtype,
                                                     case)
                    results.append(r)
                    print(f"parity {backend:17s} {dtype:9s} "
                          f"attn:{r.detail:34s} max_err={r.max_err:.2e}",
                          file=out)
    return results


def run_grid(backends: Sequence[str] = BACKENDS,
             dtypes: Sequence[str] = DTYPES,
             shapes: Sequence[Tuple[int, int, int]] = SHAPES,
             *, quantized: bool = True,
             out=sys.stdout) -> list:
    """Sweep the full grid; returns results, raising on first failure."""
    results = []
    for dtype in dtypes:
        for backend in backends:
            for shape in shapes:
                r = check_cell(backend, dtype, shape)
                results.append(r)
                print(f"parity {backend:17s} {dtype:9s} "
                      f"{'x'.join(map(str, shape)):12s} "
                      f"max_err={r.max_err:.2e} {r.detail}", file=out)
    if quantized and "int8" in dtypes:
        for backend in backends:
            for shape in shapes[:3]:
                r = check_quantized_cell(backend, shape)
                results.append(r)
                print(f"parity {backend:17s} w8a8      "
                      f"{'x'.join(map(str, shape)):12s} "
                      f"max_err={r.max_err:.2e}", file=out)
    return results


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dtypes", nargs="+", default=list(DTYPES),
                    choices=list(DTYPES))
    ap.add_argument("--backends", nargs="+", default=list(BACKENDS))
    ap.add_argument("--no-quantized", action="store_true",
                    help="skip the W8A8 weight_dtype route cells")
    ap.add_argument("--no-attention", action="store_true",
                    help="skip the attention backend grid (runs for the fp "
                         "dtypes in --dtypes)")
    ap.add_argument("--attn-backends", nargs="+",
                    default=list(ATTN_BACKENDS),
                    help="attention grid backends; paged_interpret cells "
                         "read K/V through shuffled block tables over a "
                         "distractor-laden page pool")
    ap.add_argument("--sharded", action="store_true",
                    help="run the SHARDED grids instead (mesh × backend × "
                         "dtype): shard_map'd TP GEMM (column+row) and "
                         "head-sharded attention vs unsharded, over "
                         "(1,1)/(2,1)/(1,2)/(2,2) meshes. Needs "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=4 set before jax initializes")
    ap.add_argument("--mesh-shapes", nargs="+", default=None,
                    help="sharded grid mesh shapes as DxM (e.g. 2x2)")
    args = ap.parse_args(argv)
    if args.sharded:
        shapes = (tuple(tuple(int(x) for x in m.split("x"))
                        for m in args.mesh_shapes)
                  if args.mesh_shapes else MESH_SHAPES)
        results = run_sharded_grid(mesh_shapes=shapes, dtypes=args.dtypes)
        print(f"parity[sharded]: {len(results)} cells OK "
              f"(meshes={list(shapes)}, dtypes={args.dtypes})")
        return 0
    results = run_grid(args.backends, args.dtypes,
                       quantized=not args.no_quantized)
    if not args.no_attention:
        results += run_attention_grid(
            backends=args.attn_backends,
            dtypes=[d for d in args.dtypes
                    if d in ATTN_TOLS or d == "int8"])
    print(f"parity: {len(results)} cells OK "
          f"(backends={args.backends}, dtypes={args.dtypes})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
