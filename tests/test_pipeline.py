"""GPipe pipeline-parallel engine: pipelined == sequential, forward and
backward. Needs >1 device → runs itself in a subprocess with 8 forced host
devices (the main pytest process keeps the real 1-device platform)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.distributed.pipeline import pipeline_apply, stack_for_stages

    S, LperS, mu, mb, d = 4, 2, 6, 3, 16
    L = S * LperS
    mesh = jax.make_mesh((S, 2), ("stage", "model"))
    key = jax.random.PRNGKey(0)
    Ws = jax.random.normal(key, (L, d, d), jnp.float32) * (1.0 / d ** 0.5)
    x = jax.random.normal(jax.random.fold_in(key, 1), (mu, mb, d))

    def layer(w, h):
        return jnp.tanh(h @ w)

    # sequential reference
    def seq_apply(Ws, x):
        h = x
        for i in range(L):
            h = layer(Ws[i], h)
        return h
    ref = jax.vmap(seq_apply, in_axes=(None, 0))(Ws, x)

    def stage_fn(wslice, h):      # wslice: (L/S, d, d)
        def body(h, w):
            return layer(w, h), None
        h, _ = jax.lax.scan(body, h, wslice)
        return h

    staged = stack_for_stages(Ws, S)
    staged = jax.device_put(staged, NamedSharding(mesh, P("stage")))
    out = pipeline_apply(stage_fn, staged, x, mesh=mesh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    print("FWD-OK")

    # backward: grads of a scalar loss w.r.t. stage params match sequential
    def loss_pipe(Ws_staged):
        return jnp.sum(pipeline_apply(stage_fn, Ws_staged, x, mesh=mesh) ** 2)

    def loss_seq(Ws_flat):
        return jnp.sum(jax.vmap(seq_apply, in_axes=(None, 0))(Ws_flat, x) ** 2)

    g_pipe = jax.grad(loss_pipe)(staged).reshape(L, d, d)
    g_seq = jax.grad(loss_seq)(Ws)
    np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_seq),
                               atol=1e-4, rtol=1e-4)
    print("BWD-OK")
""")


@pytest.mark.slow
def test_pipeline_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=420)
    assert "FWD-OK" in r.stdout and "BWD-OK" in r.stdout, (
        r.stdout[-2000:], r.stderr[-4000:])
