"""PrefixCache unit tests: page-granular radix lookup/insert, the
len-1 reuse cap, COW candidates, LRU eviction, holder-safe reclamation,
and the committed-admission stats contract (docs/serving.md#prefix-cache).

Pure host-side bookkeeping — no model, no device. The engine-level
golden gates (prefix-on streams identical to prefix-off) live in
tests/test_serving.py; pool/cache interleaving properties in
tests/test_kv_pool.py.
"""
import pytest

from repro.serving.kv_pool import PagePool
from repro.serving.prefix_cache import PrefixCache

PS = 4  # page size used throughout


def make(n_pages=16):
    pool = PagePool(n_pages, PS)
    return pool, PrefixCache(pool)


def prefill(pool, cache, tokens):
    """Simulate a finished prefill + insert: allocate the prompt's full
    pages and index them; returns the request's pages (holder refs)."""
    pages = pool.alloc(pool.pages_needed(len(tokens)))
    cache.insert(tokens, pages[:len(tokens) // PS])
    return pages


def test_page_size_must_match_pool():
    pool = PagePool(4, 8)
    with pytest.raises(ValueError, match="page_size"):
        PrefixCache(pool, page_size=4)


def test_empty_cache_misses_cleanly():
    pool, cache = make()
    hit = cache.lookup([1, 2, 3, 4, 5])
    assert hit.pages == [] and hit.cow_page is None
    assert hit.tokens_reusable == 0
    assert pool.pages_in_use == 0            # lookup retained nothing
    cache.record(hit, 5)
    assert cache.misses == 1 and cache.hits == 0
    assert cache.hit_rate() == 0.0


def test_insert_then_lookup_shares_full_pages():
    pool, cache = make()
    prompt = list(range(10))                 # 2 full pages + 2-token tail
    mine = prefill(pool, cache, prompt)
    assert cache.cached_pages == 2
    assert (pool.refcount[mine[:2]] == 2).all()   # holder + cache

    hit = cache.lookup(prompt[:8] + [40, 41])     # same head, new tail
    assert hit.pages == mine[:2] and hit.n_tokens == 8
    assert (pool.refcount[mine[:2]] == 3).all()   # + the new requester
    cache.record(hit, 10)
    assert cache.hits == 1 and cache.hit_tokens == 8
    hit.release(pool)
    assert (pool.refcount[mine[:2]] == 2).all()


def test_last_token_never_served_from_cache():
    """The final prompt token must prefill (its logits seed sampling):
    a prompt that is an exact multiple of the page size reuses its last
    page only as a COW candidate, never as a full page."""
    pool, cache = make()
    prompt = list(range(8))                  # exactly 2 pages
    mine = prefill(pool, cache, prompt)
    hit = cache.lookup(prompt)               # identical prompt resubmitted
    assert hit.pages == mine[:1]             # page 2 would cover token 8
    assert hit.cow_page == mine[1] and hit.cow_tokens == 3
    assert hit.tokens_reusable == 7          # == len(prompt) - 1
    hit.release(pool)


def test_cow_candidate_on_partial_page_match():
    pool, cache = make()
    prompt = list(range(12))                 # 3 full pages
    mine = prefill(pool, cache, prompt)
    # diverges 2 tokens into the third page
    other = prompt[:10] + [90, 91, 92]
    hit = cache.lookup(other)
    assert hit.pages == mine[:2] and hit.n_tokens == 8
    assert hit.cow_page == mine[2] and hit.cow_tokens == 2
    assert hit.tokens_reusable == 10
    assert pool.refcount[mine[2]] == 3       # holder + cache + cow retain
    hit.release(pool)
    assert pool.refcount[mine[2]] == 2


def test_divergent_tokens_do_not_share():
    pool, cache = make()
    a = prefill(pool, cache, [1, 2, 3, 4, 5, 6, 7, 8, 9])
    hit = cache.lookup([9, 9, 9, 9, 5, 6, 7, 8, 1])   # first page differs
    assert hit.pages == [] and hit.cow_page is None
    assert a  # silence unused


def test_reinsert_keeps_incumbent_pages():
    pool, cache = make()
    prompt = list(range(9))
    first = prefill(pool, cache, prompt)
    second = pool.alloc(3)
    added = cache.insert(prompt, second[:2])
    assert added == 0 and cache.cached_pages == 2
    hit = cache.lookup(prompt + [50])
    assert hit.pages == first[:2]            # the incumbent won
    hit.release(pool)
    pool.release(second)


def test_lru_eviction_prefers_cold_chains():
    pool, cache = make()
    cold = prefill(pool, cache, [1, 2, 3, 4, 5])
    hot = prefill(pool, cache, [6, 7, 8, 9, 10])
    pool.release(cold)                       # both requests retire
    pool.release(hot)
    cache.lookup([6, 7, 8, 9, 99]).release(pool)   # touch the hot chain
    assert cache.evict(1) == 1               # the cold page goes first
    assert cache.evictions == 1
    hit = cache.lookup([6, 7, 8, 9, 99])
    assert hit.pages == hot[:1]              # hot chain survived
    hit.release(pool)
    miss = cache.lookup([1, 2, 3, 4, 99])
    assert miss.pages == [] and miss.cow_page is None


def test_evicting_held_pages_frees_nothing_but_uncaches():
    pool, cache = make(n_pages=4)
    mine = prefill(pool, cache, list(range(9)))   # request still holds
    assert cache.reclaimable() == 0
    freed = cache.evict(4)
    assert freed == 0                        # holder keeps the pages alive
    assert cache.cached_pages == 0           # but they left the index
    assert (pool.refcount[mine[:2]] == 1).all()
    pool.release(mine)
    pool.check()
    assert pool.free_pages == 4


def test_reclaimable_counts_only_cache_held_pages():
    pool, cache = make()
    mine = prefill(pool, cache, list(range(9)))
    assert cache.reclaimable() == 0          # request holds both
    pool.release(mine)                       # retire
    assert cache.reclaimable() == 2
    assert cache.evict(2) == 2
    pool.check()
    assert pool.free_pages == pool.n_pages


def test_clear_releases_everything():
    pool, cache = make()
    for base in (0, 100, 200):
        pages = prefill(pool, cache, [base + i for i in range(9)])
        pool.release(pages)
    assert cache.cached_pages == 6
    assert cache.clear() == 6
    assert cache.cached_pages == 0
    pool.check()
    assert pool.free_pages == pool.n_pages


def test_record_only_counts_committed_admissions():
    """Admission retry loops call lookup repeatedly; only the final
    committed admit calls record() — the hit rate reflects tokens actually
    served, not lookup traffic (the stat-inflation regression)."""
    pool, cache = make()
    mine = prefill(pool, cache, list(range(9)))
    for _ in range(5):                       # retries: lookup, no record
        cache.lookup(list(range(9)) + [77]).release(pool)
    assert cache.hits == 0 and cache.lookup_tokens == 0
    hit = cache.lookup(list(range(9)) + [77])
    cache.record(hit, 10)
    hit.release(pool)
    assert cache.hits == 1 and cache.hit_tokens == 8
    assert cache.lookup_tokens == 10
    assert cache.hit_rate() == 0.8
    stats = cache.stats()
    assert stats["prefix_hits"] == 1 and stats["prefix_hit_rate"] == 0.8
    pool.release(mine)


def test_check_validates_structure():
    pool, cache = make()
    mine = prefill(pool, cache, list(range(13)))
    cache.check()
    pool.release(mine)
    cache.check()
    cache.clear()
    cache.check()
