"""PagePool / BlockTable invariants: unit tests + hypothesis properties.

The properties the paged serving engine's correctness rests on
(docs/serving.md):

  * no page is ever referenced by two live block tables;
  * free-list accounting balances across arbitrary admit / grow / retire /
    preempt cycles (free + in-use == n_pages, no page both free and used);
  * allocation hands out each page at most once until released.

The third pillar — a preempted-then-resumed request's token stream being
identical to an uninterrupted run — needs a real model and lives in
tests/test_serving.py.
"""
import numpy as np
import pytest

from hypcompat import given, settings, st

from repro.serving.kv_pool import (BlockTable, PagePool, PoolExhausted,
                                   pages_needed)


# ---------------------------------------------------------------------------
# Unit tests
# ---------------------------------------------------------------------------

def test_pages_needed():
    assert pages_needed(0, 8) == 0
    assert pages_needed(1, 8) == 1
    assert pages_needed(8, 8) == 1
    assert pages_needed(9, 8) == 2


def test_alloc_release_roundtrip():
    pool = PagePool(4, 8)
    a = pool.alloc(3)
    assert len(set(a)) == 3 and pool.free_pages == 1
    pool.release(a)
    assert pool.free_pages == 4
    pool.check()


def test_exhaustion_raises_without_side_effects():
    pool = PagePool(2, 8)
    pool.alloc(1)
    with pytest.raises(PoolExhausted):
        pool.alloc(2)
    assert pool.free_pages == 1          # the failed alloc took nothing
    pool.check()


def test_double_free_raises():
    pool = PagePool(2, 8)
    a = pool.alloc(1)
    pool.release(a)
    with pytest.raises(ValueError, match="double free"):
        pool.release(a)


def test_retain_release_refcount():
    pool = PagePool(2, 8)
    a = pool.alloc(1)
    pool.retain(a)
    pool.release(a)
    assert pool.free_pages == 1          # still one reference out
    pool.release(a)
    assert pool.free_pages == 2
    pool.check()


def test_block_table_grows_and_frees():
    pool = PagePool(8, 4)
    tbl = BlockTable(pool)
    assert tbl.ensure(3) and tbl.n_pages == 1 and tbl.capacity() == 4
    assert tbl.ensure(4) == []           # already covered
    assert tbl.ensure(9) and tbl.n_pages == 3
    row = tbl.as_row(6)
    assert row.dtype == np.int32 and (row[3:] == 0).all()
    assert list(row[:3]) == tbl.pages
    tbl.free()
    assert tbl.n_pages == 0 and pool.free_pages == 8
    pool.check()


def test_as_row_rejects_overflow():
    pool = PagePool(8, 4)
    tbl = BlockTable(pool)
    tbl.ensure(16)
    with pytest.raises(ValueError, match="n_blocks"):
        tbl.as_row(2)


def test_invalid_config_rejected():
    with pytest.raises(ValueError):
        PagePool(0, 8)
    with pytest.raises(ValueError):
        PagePool(8, 0)


# ---------------------------------------------------------------------------
# Property tests: random admit / grow / retire / preempt schedules
# ---------------------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(st.integers(4, 32), st.integers(1, 16),
       st.lists(st.tuples(st.integers(0, 3), st.integers(1, 40)),
                min_size=1, max_size=60))
def test_pool_invariants_under_random_schedules(n_pages, page_size, ops):
    """Ops: (0, n) admit request of n tokens; (1, i) grow request i by one
    token; (2, i) retire request i; (3, i) preempt request i (identical
    accounting to retire — the engine re-admits from scratch). After every
    op: live tables disjoint, accounting balanced."""
    pool = PagePool(n_pages, page_size)
    live = {}                           # rid -> (BlockTable, n_tokens)
    next_rid = 0
    for kind, arg in ops:
        if kind == 0:                   # admit
            need = pool.pages_needed(arg)
            tbl = BlockTable(pool)
            if pool.can_alloc(need):
                tbl.ensure(arg)
                live[next_rid] = [tbl, arg]
                next_rid += 1
            else:
                with pytest.raises(PoolExhausted):
                    pool.alloc(need)
        elif kind in (1, 2, 3) and live:
            rid = sorted(live)[arg % len(live)]
            tbl, n = live[rid]
            if kind == 1:               # grow one token (decode step)
                if pool.can_alloc(pool.pages_needed(n + 1) - tbl.n_pages):
                    tbl.ensure(n + 1)
                    live[rid][1] = n + 1
            else:                       # retire / preempt: free everything
                tbl.free()
                del live[rid]
        # -- invariants ----------------------------------------------------
        pool.check()
        owned = [p for tbl, _ in live.values() for p in tbl.pages]
        assert len(owned) == len(set(owned)), \
            "a page is referenced by two live block tables"
        assert pool.free_pages + len(owned) == pool.n_pages
        for tbl, n in live.values():
            assert tbl.capacity() >= n   # every resident token is backed
    # final drain balances exactly
    for tbl, _ in live.values():
        tbl.free()
    pool.check()
    assert pool.free_pages == pool.n_pages


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 64), st.integers(1, 16))
def test_alloc_is_duplicate_free(n, page_size):
    pool = PagePool(64, page_size)
    pages = pool.alloc(n)
    assert len(set(pages)) == n
    assert (pool.refcount[pages] == 1).all()
    pool.release(pages)
    assert pool.free_pages == 64
