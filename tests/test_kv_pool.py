"""PagePool / BlockTable invariants: unit tests + hypothesis properties.

The properties the paged serving engine's correctness rests on
(docs/serving.md):

  * no page is ever referenced by two live block tables;
  * free-list accounting balances across arbitrary admit / grow / retire /
    preempt cycles (free + in-use == n_pages, no page both free and used);
  * allocation hands out each page at most once until released.

The third pillar — a preempted-then-resumed request's token stream being
identical to an uninterrupted run — needs a real model and lives in
tests/test_serving.py.
"""
import numpy as np
import pytest

from hypcompat import given, settings, st

from repro.serving.kv_pool import (BlockTable, PagePool, PoolExhausted,
                                   pages_needed)


# ---------------------------------------------------------------------------
# Unit tests
# ---------------------------------------------------------------------------

def test_pages_needed():
    assert pages_needed(0, 8) == 0
    assert pages_needed(1, 8) == 1
    assert pages_needed(8, 8) == 1
    assert pages_needed(9, 8) == 2


def test_alloc_release_roundtrip():
    pool = PagePool(4, 8)
    a = pool.alloc(3)
    assert len(set(a)) == 3 and pool.free_pages == 1
    pool.release(a)
    assert pool.free_pages == 4
    pool.check()


def test_exhaustion_raises_without_side_effects():
    pool = PagePool(2, 8)
    pool.alloc(1)
    with pytest.raises(PoolExhausted):
        pool.alloc(2)
    assert pool.free_pages == 1          # the failed alloc took nothing
    pool.check()


def test_double_free_raises():
    pool = PagePool(2, 8)
    a = pool.alloc(1)
    pool.release(a)
    with pytest.raises(ValueError, match="double free"):
        pool.release(a)


def test_release_is_all_or_nothing():
    """Regression: release used to decrement page by page and raise mid-
    loop on a double free, leaving earlier pages already released. A mixed
    valid + already-free sequence must raise with the pool untouched."""
    pool = PagePool(4, 8)
    a = pool.alloc(2)
    b = pool.alloc(1)
    pool.release(b)                      # b is free now
    before_rc = pool.refcount.copy()
    before_free = pool.free_pages
    with pytest.raises(ValueError, match="double free"):
        pool.release(a + b)              # valid pages first, bad one last
    np.testing.assert_array_equal(pool.refcount, before_rc)
    assert pool.free_pages == before_free
    pool.check()


def test_release_duplicates_in_one_call_need_refs():
    """One call releasing the same page twice needs two references — with
    only one, the whole call must fail atomically."""
    pool = PagePool(2, 8)
    (p,) = pool.alloc(1)
    with pytest.raises(ValueError, match="double free"):
        pool.release([p, p])
    assert pool.refcount[p] == 1 and pool.free_pages == 1
    pool.retain([p])
    pool.release([p, p])                 # two refs → fine in one call
    assert pool.free_pages == 2
    pool.check()


def test_release_rejects_unknown_page():
    pool = PagePool(2, 8)
    with pytest.raises(ValueError, match="unknown page"):
        pool.release([5])
    pool.check()


def test_retain_release_refcount():
    pool = PagePool(2, 8)
    a = pool.alloc(1)
    pool.retain(a)
    pool.release(a)
    assert pool.free_pages == 1          # still one reference out
    pool.release(a)
    assert pool.free_pages == 2
    pool.check()


def test_block_table_grows_and_frees():
    pool = PagePool(8, 4)
    tbl = BlockTable(pool)
    assert tbl.ensure(3) and tbl.n_pages == 1 and tbl.capacity() == 4
    assert tbl.ensure(4) == []           # already covered
    assert tbl.ensure(9) and tbl.n_pages == 3
    row = tbl.as_row(6)
    assert row.dtype == np.int32 and (row[3:] == 0).all()
    assert list(row[:3]) == tbl.pages
    tbl.free()
    assert tbl.n_pages == 0 and pool.free_pages == 8
    pool.check()


def test_as_row_rejects_overflow():
    pool = PagePool(8, 4)
    tbl = BlockTable(pool)
    tbl.ensure(16)
    with pytest.raises(ValueError, match="n_blocks"):
        tbl.as_row(2)


def test_as_row_validates_out_buffer():
    """Regression: a wrong-width or wrong-dtype caller buffer used to be
    filled silently, corrupting the device block-table row."""
    pool = PagePool(8, 4)
    tbl = BlockTable(pool)
    tbl.ensure(8)
    with pytest.raises(ValueError, match="shape"):
        tbl.as_row(4, out=np.zeros(3, np.int32))       # too narrow
    with pytest.raises(ValueError, match="shape"):
        tbl.as_row(4, out=np.zeros((4, 1), np.int32))  # wrong rank
    with pytest.raises(ValueError, match="dtype"):
        tbl.as_row(4, out=np.zeros(4, np.int64))       # device wants int32
    out = np.full(4, 99, np.int32)
    row = tbl.as_row(4, out=out)
    assert row is out
    assert list(row[:2]) == tbl.pages and (row[2:] == 0).all()


def test_block_table_free_keeps_pages_on_failure():
    """BlockTable.free() clears ``pages`` only when the release succeeds —
    after an injected double free the table still owns its pages and a
    later free() drains cleanly."""
    pool = PagePool(4, 8)
    tbl = BlockTable(pool)
    tbl.ensure(16)
    pages = list(tbl.pages)
    pool.release([pages[0]])             # sabotage: drop one ref externally
    with pytest.raises(ValueError, match="double free"):
        tbl.free()
    assert tbl.pages == pages            # ownership record intact
    # restore the stolen reference: re-allocate until the page comes back,
    # hand it to the table, drop the bystanders
    grabbed = pool.alloc(pool.free_pages)
    assert pages[0] in grabbed
    pool.release([p for p in grabbed if p != pages[0]])
    tbl.free()
    assert tbl.pages == [] and pool.free_pages == 4
    pool.check()


def test_invalid_config_rejected():
    with pytest.raises(ValueError):
        PagePool(0, 8)
    with pytest.raises(ValueError):
        PagePool(8, 0)


# ---------------------------------------------------------------------------
# Property tests: random admit / grow / retire / preempt schedules
# ---------------------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(st.integers(4, 32), st.integers(1, 16),
       st.lists(st.tuples(st.integers(0, 3), st.integers(1, 40)),
                min_size=1, max_size=60))
def test_pool_invariants_under_random_schedules(n_pages, page_size, ops):
    """Ops: (0, n) admit request of n tokens; (1, i) grow request i by one
    token; (2, i) retire request i; (3, i) preempt request i (identical
    accounting to retire — the engine re-admits from scratch). After every
    op: live tables disjoint, accounting balanced."""
    pool = PagePool(n_pages, page_size)
    live = {}                           # rid -> (BlockTable, n_tokens)
    next_rid = 0
    for kind, arg in ops:
        if kind == 0:                   # admit
            need = pool.pages_needed(arg)
            tbl = BlockTable(pool)
            if pool.can_alloc(need):
                tbl.ensure(arg)
                live[next_rid] = [tbl, arg]
                next_rid += 1
            else:
                with pytest.raises(PoolExhausted):
                    pool.alloc(need)
        elif kind in (1, 2, 3) and live:
            rid = sorted(live)[arg % len(live)]
            tbl, n = live[rid]
            if kind == 1:               # grow one token (decode step)
                if pool.can_alloc(pool.pages_needed(n + 1) - tbl.n_pages):
                    tbl.ensure(n + 1)
                    live[rid][1] = n + 1
            else:                       # retire / preempt: free everything
                tbl.free()
                del live[rid]
        # -- invariants ----------------------------------------------------
        pool.check()
        owned = [p for tbl, _ in live.values() for p in tbl.pages]
        assert len(owned) == len(set(owned)), \
            "a page is referenced by two live block tables"
        assert pool.free_pages + len(owned) == pool.n_pages
        for tbl, n in live.values():
            assert tbl.capacity() >= n   # every resident token is backed
    # final drain balances exactly
    for tbl, _ in live.values():
        tbl.free()
    pool.check()
    assert pool.free_pages == pool.n_pages


@settings(max_examples=200, deadline=None)
@given(st.integers(2, 16), st.integers(0, 8),
       st.lists(st.integers(0, 15), min_size=1, max_size=8),
       st.integers(0, 3))
def test_release_atomic_under_injected_double_frees(n_pages, n_alloc,
                                                    extra, n_dups):
    """Atomicity property: ANY release sequence that raises (injected
    already-free pages, in-call duplicates beyond the refcount, unknown
    ids) leaves refcounts and the free list exactly as they were; any
    sequence that succeeds drains exactly one reference per entry."""
    pool = PagePool(n_pages, 8)
    owned = pool.alloc(min(n_alloc, n_pages))
    seq = list(owned) + [p % (n_pages + 2) for p in extra]  # maybe bad/dup
    seq += owned[:1] * n_dups                               # in-call dups
    before_rc = pool.refcount.copy()
    before_free = list(pool._free)
    from collections import Counter
    drops = Counter(seq)
    legal = all(0 <= p < n_pages and pool.refcount[p] >= c
                for p, c in drops.items())
    if legal:
        pool.release(seq)
        for p, c in drops.items():
            assert pool.refcount[p] == before_rc[p] - c
    else:
        with pytest.raises(ValueError):
            pool.release(seq)
        np.testing.assert_array_equal(pool.refcount, before_rc)
        assert pool._free == before_free, "failed release mutated the pool"
    pool.check()


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 64), st.integers(1, 16))
def test_alloc_is_duplicate_free(n, page_size):
    pool = PagePool(64, page_size)
    pages = pool.alloc(n)
    assert len(set(pages)) == n
    assert (pool.refcount[pages] == 1).all()
    pool.release(pages)
    assert pool.free_pages == 64


# ---------------------------------------------------------------------------
# Sharing properties: the prefix cache's contract with the pool
# (docs/serving.md#prefix-cache)
# ---------------------------------------------------------------------------

def test_fork_accounting():
    pool = PagePool(3, 8)
    (src,) = pool.alloc(1)
    pool.retain([src])                    # a second holder: the page is shared
    dst = pool.fork(src)
    assert dst != src and pool.refcount[dst] == 1
    pool.release([src, dst])
    pool.release([src])
    assert pool.free_pages == 3
    with pytest.raises(ValueError, match="unallocated"):
        pool.fork(src)                    # src went free


def test_fork_exhaustion_is_side_effect_free():
    pool = PagePool(1, 8)
    (src,) = pool.alloc(1)
    with pytest.raises(PoolExhausted):
        pool.fork(src)
    assert pool.refcount[src] == 1 and pool.free_pages == 0
    pool.check()


def test_free_hook_fires_on_last_release_only():
    pool = PagePool(2, 8)
    freed = []
    pool.add_free_hook(freed.append)
    a = pool.alloc(1)
    pool.retain(a)
    pool.release(a)
    assert freed == []                    # one holder remains
    pool.release(a)
    assert freed == a
    assert pool.high_water == 1


@settings(max_examples=150, deadline=None)
@given(st.integers(1, 8), st.integers(2, 6),
       st.lists(st.integers(0, 5), min_size=1, max_size=6))
def test_shared_span_survives_releasing_one_holder(n_span, n_holders, order):
    """A span referenced by k holders stays resident until the LAST holder
    releases it — releasing any proper subset frees nothing."""
    pool = PagePool(16, 8)
    span = pool.alloc(n_span)
    for _ in range(n_holders - 1):
        pool.retain(span)                 # holders 2..k
    for i in range(n_holders - 1):        # all but the last
        pool.release(span)
        assert (pool.refcount[span] == n_holders - 1 - i).all()
        assert pool.pages_in_use == n_span, \
            "shared span freed while holders remain"
        pool.check()
    pool.release(span)
    assert pool.free_pages == 16
    pool.check()


@settings(max_examples=150, deadline=None)
@given(st.integers(1, 16), st.integers(2, 8), st.integers(1, 4))
def test_cow_fork_never_aliases_a_shared_page(n_pages, page_size, n_shared):
    """The page fork() hands out to absorb a write is never one of the
    shared pages (it is freshly allocated, refcount 1) — so writing it
    cannot corrupt any other holder's view."""
    pool = PagePool(max(n_pages, n_shared + 1), page_size)
    shared = pool.alloc(n_shared)
    pool.retain(shared)                   # cache + one request hold them
    dst = pool.fork(shared[-1])
    assert dst not in shared
    assert pool.refcount[dst] == 1       # private: safe to write
    # writer swaps the fork in and drops its hold on the source
    pool.release([shared[-1]])
    assert pool.refcount[shared[-1]] == 1  # the other holder keeps it alive
    pool.release([dst])
    pool.release(shared[:-1])
    pool.release(shared)
    assert pool.free_pages == pool.n_pages
    pool.check()


@settings(max_examples=100, deadline=None)
@given(st.integers(2, 6),
       st.lists(st.tuples(st.integers(0, 3), st.integers(0, 7),
                          st.integers(1, 24)),
                min_size=1, max_size=40))
def test_prefix_cache_invariants_under_interleavings(page_size, ops):
    """Engine-shaped interleavings of admit (lookup + COW fork + insert),
    preempt/retire (release a holder's pages), demand eviction, and
    watermark eviction keep every pool AND cache invariant: accounting
    balances, cached nodes stay allocated, shared pages outlive any single
    holder, and a fork target is never an alias of a still-shared page."""
    from repro.serving.prefix_cache import PrefixCache

    pool = PagePool(12, page_size)
    cache = PrefixCache(pool)
    # two prompt families sharing a long head — token content derived from
    # the op stream, no RNG (hypothesis owns the entropy)
    base = list(range(1, 4 * page_size + 2))
    holders = {}                          # rid -> list of pages it holds
    next_rid = 0
    for kind, sel, size in ops:
        if kind == 0:                     # admit: lookup → fork → fill → insert
            prompt = base[:max(2, min(size, len(base)))]
            prompt = prompt[:-1] + [100 + sel]   # divergent final token
            hit = cache.lookup(prompt)
            owned = list(hit.pages)
            ok = True
            if hit.cow_page is not None:
                if pool.can_alloc(1):
                    dst = pool.fork(hit.cow_page)
                    assert dst not in owned and dst != hit.cow_page
                    assert pool.refcount[dst] == 1   # write target private
                    pool.release([hit.cow_page])     # copy done
                    hit.cow_page = None
                    owned.append(dst)
                else:
                    ok = False
            if ok:
                need = pages_needed(len(prompt), page_size) - len(owned)
                if pool.can_alloc(need):
                    owned += pool.alloc(need)
                else:
                    ok = False
            if ok:
                cache.insert(prompt, owned[:len(prompt) // page_size])
                holders[next_rid] = owned
                next_rid += 1
            else:
                # admission fell through: give back the fork target (if
                # taken) and every hold the lookup put on our behalf
                for p in owned[len(hit.pages):]:
                    pool.release([p])
                hit.release(pool)
        elif kind == 1 and holders:       # preempt / retire one holder
            rid = sorted(holders)[sel % len(holders)]
            pool.release(holders.pop(rid))
        elif kind == 2:                   # demand eviction
            cache.evict(size)
        else:                             # watermark sweep
            cache.evict(cache.reclaimable())
        # -- invariants ----------------------------------------------------
        pool.check()
        cache.check()
        held = [p for pages in holders.values() for p in pages]
        assert (pool.refcount[held] >= 1).all(), \
            "a live holder's page was freed under it"
        assert pool.free_pages + pool.pages_in_use == pool.n_pages
        assert cache.reclaimable() <= cache.cached_pages
    # drain: release every holder, then the cache — accounting must zero
    for pages in holders.values():
        pool.release(pages)
    cache.clear()
    pool.check()
    assert pool.free_pages == pool.n_pages


# ---------------------------------------------------------------------------
# truncate() — the speculative-decoding rollback primitive
# ---------------------------------------------------------------------------

def test_truncate_releases_tail_pages_only():
    pool = PagePool(8, 4)
    tbl = BlockTable(pool)
    tbl.ensure(14)                       # 4 pages back 14 tokens
    assert tbl.n_pages == 4
    dropped = tbl.truncate(6)            # keep 2 pages (positions 0..7)
    assert len(dropped) == 2
    assert tbl.n_pages == 2 and tbl.capacity() == 8
    assert pool.free_pages == 6
    pool.check()


def test_truncate_is_noop_when_already_fits():
    pool = PagePool(4, 4)
    tbl = BlockTable(pool)
    tbl.ensure(7)
    assert tbl.truncate(8) == []         # 2 pages already cover 8
    assert tbl.truncate(7) == []
    assert tbl.truncate(5) == []         # same page count
    assert tbl.n_pages == 2
    dropped = tbl.truncate(4)
    assert len(dropped) == 1
    assert tbl.truncate(4) == []         # repeat truncate: no-op
    pool.check()


def test_truncate_to_zero_frees_everything_and_rejects_negative():
    pool = PagePool(4, 4)
    tbl = BlockTable(pool)
    tbl.ensure(10)
    assert len(tbl.truncate(0)) == 3
    assert tbl.pages == [] and pool.free_pages == 4
    with pytest.raises(ValueError):
        tbl.truncate(-1)
    pool.check()


def test_truncate_spares_shared_pages():
    """COW/refcount safety: truncate drops only THIS table's reference —
    a tail page the prefix cache still retains stays resident for it."""
    pool = PagePool(4, 4)
    tbl = BlockTable(pool)
    tbl.ensure(12)                       # pages for positions 0..11
    shared = tbl.pages[2]
    pool.retain([shared])                # the cache's hold
    dropped = tbl.truncate(5)            # keeps 2 pages, drops index 2
    assert dropped == [shared]
    assert pool.refcount[shared] == 1    # cache hold survives
    assert shared not in pool._free
    pool.release([shared])               # cache lets go → now truly free
    assert pool.free_pages == 2          # table still holds its 2 pages
    pool.check()


def test_truncate_then_regrow_reuses_fresh_pages():
    """Rollback then decode growth: the re-grown table stays disjoint
    from everything else and accounting balances."""
    pool = PagePool(6, 4)
    a, b = BlockTable(pool), BlockTable(pool)
    a.ensure(12)
    b.ensure(8)
    a.truncate(5)
    a.ensure(16)                         # regrow past the old length
    assert not set(a.pages) & set(b.pages)
    pool.check()
    a.free()
    b.free()
    assert pool.free_pages == 6


@settings(max_examples=200, deadline=None)
@given(st.integers(4, 24), st.integers(1, 8),
       st.lists(st.tuples(st.integers(0, 5), st.integers(0, 40)),
                min_size=1, max_size=80))
def test_alloc_fork_truncate_free_interleavings(n_pages, page_size, ops):
    """Satellite property: ANY interleaving of alloc / fork (COW under a
    sharer's retain) / truncate / grow / free returns the pool to its
    baseline free count, never double-frees, and keeps live tables
    disjoint. Ops: (0, n) admit; (1, i) grow one token; (2, x) truncate
    request x to a random smaller length; (3, x) COW-fork request x's
    first page under a cache retain; (4, x) free; (5, x) cache drops one
    of its holds."""
    pool = PagePool(n_pages, page_size)
    live = {}                            # rid -> [BlockTable, n_tokens]
    cache_held = []                      # pages a pseudo prefix-cache retains
    next_rid = 0
    for kind, arg in ops:
        if kind == 0:                    # admit arg%40 + 1 tokens
            n = arg % 40 + 1
            tbl = BlockTable(pool)
            if pool.can_alloc(pool.pages_needed(n)):
                tbl.ensure(n)
                live[next_rid] = [tbl, n]
                next_rid += 1
        elif kind == 1 and live:         # grow one token (decode)
            rid = sorted(live)[arg % len(live)]
            tbl, n = live[rid]
            if pool.can_alloc(pool.pages_needed(n + 1) - tbl.n_pages):
                tbl.ensure(n + 1)
                live[rid][1] = n + 1
        elif kind == 2 and live:         # rollback (truncate)
            rid = sorted(live)[arg % len(live)]
            tbl, n = live[rid]
            keep = arg % (n + 1)
            before = tbl.n_pages
            dropped = tbl.truncate(keep)
            assert tbl.n_pages == before - len(dropped)
            assert tbl.capacity() >= keep
            live[rid][1] = keep
        elif kind == 3 and live:         # COW fork under a cache retain
            rid = sorted(live)[arg % len(live)]
            tbl, _ = live[rid]
            if tbl.pages and pool.can_alloc(1):
                src = tbl.pages[0]
                pool.retain([src])       # the cache becomes a sharer
                cache_held.append(src)
                dst = pool.fork(src)
                tbl.pages[0] = dst       # writer swaps in the private copy
                pool.release([src])      # …and drops its ref on the donor
        elif kind == 4 and live:         # retire
            rid = sorted(live)[arg % len(live)]
            live.pop(rid)[0].free()
        elif kind == 5 and cache_held:   # cache eviction
            pool.release([cache_held.pop(arg % len(cache_held))])
        # -- invariants ----------------------------------------------------
        pool.check()
        owned = [p for tbl, _ in live.values() for p in tbl.pages]
        assert len(owned) == len(set(owned)), \
            "a page is referenced by two live block tables"
    for tbl, _ in live.values():
        tbl.free()
    for p in cache_held:
        pool.release([p])
    pool.check()
    assert pool.free_pages == pool.n_pages   # baseline restored
