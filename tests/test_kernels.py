"""Pallas MatrixFlow GEMM kernel: shape × dtype sweeps vs the pure-jnp
oracle (kernels/ref.py), executed in interpret mode on CPU.

Also cross-checks the three implementations of the paper's Algorithm 1
against each other: Pallas kernel ≡ blockflow (lax) ≡ jnp oracle.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.core import layout as L
from repro.core.blockflow import block_matmul
from repro.kernels.matrixflow_gemm import matrixflow_gemm, matrixflow_gemm_block_major
from repro.kernels.ref import matmul_ref


def _operands(rng, M, K, N, dtype):
    if dtype in (jnp.int8, jnp.int32):
        a = rng.integers(-8, 8, (M, K)).astype(dtype)
        b = rng.integers(-8, 8, (K, N)).astype(dtype)
        tol = 0
    else:
        a = rng.standard_normal((M, K)).astype(np.float32).astype(dtype)
        b = rng.standard_normal((K, N)).astype(np.float32).astype(dtype)
        # fp32 accumulation-order differences grow ~sqrt(K): allow for it
        tol = 2e-2 if dtype == jnp.bfloat16 else 5e-4
    return jnp.asarray(a), jnp.asarray(b), tol


SHAPES = [
    (8, 8, 8),          # single sub-MXU block
    (128, 128, 128),    # one MXU tile
    (256, 512, 384),    # multi-block all dims
    (100, 60, 72),      # ragged (padding path)
    (1, 576, 1536),     # skinny M (decode-like GEMV)
    (512, 64, 512),     # skinny K
]
DTYPES = [jnp.float32, jnp.bfloat16, jnp.int8]


@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: jnp.dtype(d).name)
@pytest.mark.parametrize("shape", SHAPES, ids=lambda s: "x".join(map(str, s)))
def test_kernel_matches_oracle(shape, dtype):
    M, K, N = shape
    rng = np.random.default_rng(hash((M, K, N)) % 2**32)
    a, b, tol = _operands(rng, M, K, N, dtype)
    ref = matmul_ref(a, b)
    out = matrixflow_gemm(a, b, interpret=True)
    assert out.shape == ref.shape
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol)


@pytest.mark.parametrize("mode", ["dc", "dm"])
def test_kernel_modes_agree(mode):
    """DC (fine bk) and DM (burst bk) schedules must produce identical C."""
    rng = np.random.default_rng(7)
    a, b, tol = _operands(rng, 256, 512, 256, jnp.float32)
    blk = L.choose_layout(256, 256, 512, jnp.float32, mode=mode)
    out = matrixflow_gemm(a, b, blk=blk, interpret=True)
    ref = matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-5)


def test_block_major_entry_point():
    """Weights stored block-major once (the deploy path) give the same C."""
    rng = np.random.default_rng(3)
    a, b, _ = _operands(rng, 128, 256, 128, jnp.float32)
    blk = L.BlockLayout(bm=64, bn=128, bk=128)
    a_bm = L.to_block_major_a(a, blk.bm, blk.bk)
    b_bm = L.to_block_major_b(b, blk.bk, blk.bn)
    c_bm = matrixflow_gemm_block_major(a_bm, b_bm, blk=blk, interpret=True)
    c = L.from_block_major_c(c_bm, 128, 128)
    np.testing.assert_allclose(np.asarray(c), np.asarray(matmul_ref(a, b)),
                               atol=1e-4, rtol=1e-5)


@settings(max_examples=12, deadline=None)
@given(m=st.integers(1, 160), k=st.integers(1, 160), n=st.integers(1, 160),
       dtype=st.sampled_from([jnp.float32, jnp.int8]))
def test_kernel_property_sweep(m, k, n, dtype):
    """Hypothesis geometry sweep: any (M,K,N) must round through padding."""
    rng = np.random.default_rng(m * 1000003 + k * 1009 + n)
    a, b, tol = _operands(rng, m, k, n, dtype)
    out = matrixflow_gemm(a, b, interpret=True)
    ref = matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=max(tol, 1e-4), rtol=1e-4)


def test_blockflow_algorithm1_equals_kernel():
    """The faithful lax rendering and the Pallas kernel execute the same
    Algorithm 1 → bitwise-comparable fp32 results on identical blocks."""
    rng = np.random.default_rng(11)
    a, b, _ = _operands(rng, 192, 256, 320, jnp.float32)
    blk = L.BlockLayout(bm=64, bn=128, bk=128)
    via_lax = block_matmul(a, b, blk=blk)
    via_pallas = matrixflow_gemm(a, b, blk=blk, interpret=True)
    np.testing.assert_allclose(np.asarray(via_lax), np.asarray(via_pallas),
                               atol=1e-5, rtol=1e-6)


def test_int8_accumulates_int32_exact():
    """Paper Table 2 int designs: int8 MACs accumulate exactly in int32."""
    rng = np.random.default_rng(5)
    a = jnp.asarray(rng.integers(-127, 127, (64, 512)).astype(np.int8))
    b = jnp.asarray(rng.integers(-127, 127, (512, 64)).astype(np.int8))
    out = matrixflow_gemm(a, b, interpret=True)
    assert out.dtype == jnp.int32
    exact = np.asarray(a, np.int64) @ np.asarray(b, np.int64)
    np.testing.assert_array_equal(np.asarray(out, np.int64), exact)


def test_vmem_claim_within_budget():
    """BlockSpec working set (the paper's 3-buffer analogue) must fit VMEM."""
    for M, K, N in [(4096, 4096, 4096), (32768, 5120, 5120)]:
        for mode in ("dc", "dm"):
            blk = L.choose_layout(M, N, K, jnp.bfloat16, mode=mode)
            assert blk.vmem_bytes(2) <= 96 * 1024 * 1024
