"""End-to-end system behaviour: sharded train step on a real (1-device)
mesh, abstract-spec coherence, dry-run cell lowering on the host mesh.

The 512-device production dry-run lives in launch/dryrun.py (it must own
the process to set XLA_FLAGS); here we prove the same code path lowers and
*runs* on the host mesh, which is what guards refactors.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import SHAPES, ShapeCell, get_smoke_config
from repro.distributed import sharding as shd
from repro.launch import specs as SP
from repro.launch import steps as ST
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.optim import adamw_init

KEY = jax.random.PRNGKey(0)


def host_rules(cfg):
    return ST.make_rules(cfg, make_host_mesh())


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "dbrx-132b", "zamba2-2.7b"])
def test_sharded_train_step_runs(arch):
    """jit with in/out shardings + donation on a real mesh, tiny config."""
    cfg = get_smoke_config(arch)
    rules = host_rules(cfg)
    with shd.use_rules(rules):
        params, axes = T.init_model(KEY, cfg)
        opt = adamw_init(params)
        p_shard = ST.model_shardings(cfg, params, axes, rules)
        o_shard = ST.opt_shardings(p_shard, rules)
        step = ST.make_train_step_fn(cfg)
        B, S = 2, 16
        if cfg.n_codebooks:
            tokens = jax.random.randint(KEY, (B, S, cfg.n_codebooks), 0,
                                        cfg.vocab)
        else:
            tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
        batch = {"tokens": tokens}
        b_shard = ST.batch_shardings(batch, rules)
        jitted = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                         out_shardings=(p_shard, o_shard, None),
                         donate_argnums=(0, 1))
        params2, opt2, metrics = jitted(params, opt, batch)
        assert np.isfinite(float(metrics["loss"]))


def test_abstract_specs_match_concrete_init():
    """abstract_params_and_axes must mirror a real init's tree + shapes."""
    cfg = get_smoke_config("qwen3-8b")
    abs_p, axes = SP.abstract_params_and_axes(cfg)
    concrete, _ = T.init_model(KEY, cfg)
    abs_small = jax.eval_shape(lambda k: T.init_model(k, cfg)[0], KEY)
    at = jax.tree_util.tree_structure(abs_small)
    ct = jax.tree_util.tree_structure(concrete)
    assert at == ct
    for a, c in zip(jax.tree_util.tree_leaves(abs_small),
                    jax.tree_util.tree_leaves(concrete)):
        assert a.shape == c.shape and a.dtype == c.dtype


@pytest.mark.parametrize("shape_name", ["train_4k", "prefill_32k",
                                        "decode_32k"])
def test_input_specs_cover_cells(shape_name):
    for arch in ("qwen2-1.5b", "musicgen-medium", "internvl2-76b",
                 "mamba2-1.3b"):
        from repro.configs.registry import get_config
        cfg = get_config(arch)
        cell = SHAPES[shape_name]
        specs = SP.input_specs(cfg, cell)
        leaves = jax.tree_util.tree_leaves(specs)
        assert all(isinstance(x, jax.ShapeDtypeStruct) for x in leaves)
        if cell.kind == "train":
            toks = specs["batch"]["tokens"]
            assert toks.shape[0] == cell.batch


def test_dryrun_cell_lowers_on_host_mesh():
    """The dry-run path (shardings, donation, lowering) on the 1-device
    host mesh with a reduced config — the structural guard for dryrun.py."""
    cfg = get_smoke_config("qwen2-1.5b")
    mesh = make_host_mesh()
    rules = ST.make_rules(cfg, mesh)
    with shd.use_rules(rules):
        params_abs = jax.eval_shape(lambda k: T.init_model(k, cfg)[0], KEY)
        _, axes = T.init_model(KEY, cfg)
        p_shard = ST.model_shardings(cfg, params_abs, axes, rules)
        o_shard = ST.opt_shardings(p_shard, rules)
        step = ST.make_train_step_fn(cfg)
        opt_abs = jax.eval_shape(adamw_init, params_abs)
        batch = {"tokens": jax.ShapeDtypeStruct((4, 16), jnp.int32)}
        b_shard = ST.batch_shardings(batch, rules)
        lowered = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                          out_shardings=(p_shard, o_shard, None),
                          donate_argnums=(0, 1)).lower(params_abs, opt_abs,
                                                       batch)
        compiled = lowered.compile()
        assert compiled.cost_analysis() is not None


def test_decode_cell_lowers_on_host_mesh():
    cfg = get_smoke_config("zamba2-2.7b")
    mesh = make_host_mesh()
    cell = ShapeCell("decode_small", "decode", 64, 4)
    rules = ST.make_rules(cfg, mesh, cell)
    from repro.serving.engine import make_decode_step
    with shd.use_rules(rules):
        params_abs = jax.eval_shape(lambda k: T.init_model(k, cfg)[0], KEY)
        _, axes = T.init_model(KEY, cfg)
        p_shard = ST.model_shardings(cfg, params_abs, axes, rules)
        caches = SP.cache_specs(cfg, cell.batch, cell.seq)
        c_shard = ST.cache_shardings(caches, rules)
        toks = jax.ShapeDtypeStruct((cell.batch, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((cell.batch, 1), jnp.int32)
        tok_shard = ST.batch_shardings(toks, rules)
        pos_shard = ST.batch_shardings(pos, rules)
        lowered = jax.jit(make_decode_step(cfg),
                          in_shardings=(p_shard, tok_shard, pos_shard,
                                        c_shard),
                          out_shardings=(None, c_shard),
                          donate_argnums=(3,)).lower(params_abs, toks, pos,
                                                     caches)
        assert lowered.compile() is not None


def test_remat_toggle_changes_nothing_numerically():
    cfg = get_smoke_config("qwen2-1.5b", n_layers=2)
    params, _ = T.init_model(KEY, cfg)
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab)}
    l1, _, _ = T.forward(params, cfg, batch, remat=True)
    l2, _, _ = T.forward(params, cfg, batch, remat=False)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), atol=1e-5)
