"""Analytic system model (core/sysmodel.py) vs the paper's own numbers.

The model is the container's stand-in for gem5; these tests pin it to the
paper's reported results (Table 3, Figs 6/7/9) within stated tolerances, so
regressions in the calibration are caught.
"""
import pytest

from repro.core import sysmodel as SM
from repro.core.workloads import PAPER_TABLE3, paper_workload


def gemm_square(n, tag="gemm"):
    return ((SM.Gemm(n, n, n, tag=tag),), ())


# ---------------------------------------------------------------------------
# Table 3 — end-to-end transformer speedups
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", list(PAPER_TABLE3))
def test_table3_matrixflow_speedup(model):
    """MatrixFlow DC speedup within ±40% of the paper's Table 3 value and
    preserving the ordering (≫ TiC-SAT ≫ OMP)."""
    wl = paper_workload(model)
    table = SM.speedup_table(wl, "int32")
    paper = PAPER_TABLE3[model]
    ours = table["mf_dc"]
    assert paper["mf_dc"] * 0.6 <= ours <= paper["mf_dc"] * 1.4, \
        (model, ours, paper["mf_dc"])


@pytest.mark.parametrize("model", list(PAPER_TABLE3))
def test_table3_ordering(model):
    """mf > ticsat > omp > 1 for every model (the paper's qualitative claim)."""
    table = SM.speedup_table(paper_workload(model), "int32")
    assert table["mf_dc"] > table["ticsat"] > table["omp"] > 1.0


def test_table3_scaling_with_model_size():
    """Paper: MatrixFlow speedup *grows* with model size (453.9 → 698.2 on
    BERT medium → large), while OMP stagnates."""
    sp = {m: SM.speedup_table(paper_workload(m), "int32")
          for m in ("bert-medium", "bert-base", "bert-large")}
    assert (sp["bert-medium"]["mf_dc"] < sp["bert-base"]["mf_dc"]
            < sp["bert-large"]["mf_dc"])
    assert sp["bert-large"]["omp"] < 30  # OMP stagnates ~25x


def test_omp_efficiency_matches_paper():
    for model, ref in PAPER_TABLE3.items():
        got = SM.speedup_table(paper_workload(model), "int32")["omp"]
        assert ref["omp"] * 0.7 <= got <= ref["omp"] * 1.3


def test_ticsat_within_band():
    for model in ("bert-medium", "bert-base", "bert-large"):
        ref = PAPER_TABLE3[model]["ticsat"]
        got = SM.speedup_table(paper_workload(model), "int32")["ticsat"]
        assert ref * 0.5 <= got <= ref * 1.6, (model, got, ref)


# ---------------------------------------------------------------------------
# Fig. 7 — GEMM size sweep
# ---------------------------------------------------------------------------

def test_gemm_speedup_grows_with_size():
    """DC speedup increases with matrix size and reaches the paper's
    ~400x order of magnitude at 1024 (int8, layout cost included)."""
    sp = []
    for n in (256, 512, 1024):
        t = SM.speedup_table(gemm_square(n), "int8",
                             include_layout_cost=True)
        sp.append(t["mf_dc"])
    assert sp[0] < sp[1] < sp[2]
    assert 200 <= sp[2] <= 800          # paper: "up to a 400x"


def test_dc_beats_dm_on_gemm():
    """Paper §4.3.1: DC 400x vs DM 385x — DC ahead, both same magnitude."""
    t = SM.speedup_table(gemm_square(1024), "int8", include_layout_cost=True)
    assert t["mf_dc"] >= t["mf_dm"]
    assert t["mf_dm"] / t["mf_dc"] > 0.85


# ---------------------------------------------------------------------------
# Fig. 6 — dtype sweep
# ---------------------------------------------------------------------------

def test_fp16_best_on_accelerator():
    """Paper §4.3.2: fp16 gives the biggest accelerator gain (fp32 baseline
    is slow; fp16 halves traffic); int8 best for Neon."""
    t16 = SM.speedup_table(gemm_square(512), "fp16")
    t32 = SM.speedup_table(gemm_square(512), "fp32")
    assert t16["mf_dc"] > t32["mf_dc"]
    tn8 = SM.speedup_table(gemm_square(512), "int8")["neon"]
    tn32 = SM.speedup_table(gemm_square(512), "int32")["neon"]
    assert tn8 > tn32


# ---------------------------------------------------------------------------
# Fig. 9 — PCIe bandwidth sensitivity
# ---------------------------------------------------------------------------

def test_pcie_bandwidth_sensitivity():
    """16L/64G ≈ 130% better than 4L/5G; 4L/16G in between (paper Fig. 9)."""
    def total(lanes, gbps):
        sys = SM.SystemConfig(pcie_lanes=lanes, pcie_total_gbps=gbps)
        wl = gemm_square(1024)
        return SM.workload_time(wl, "int32", "mf_dc", sys)["total"]

    hi = total(16, 64.0)
    mid = total(4, 16.0)
    lo = total(4, 5.0)
    assert hi < mid < lo
    assert lo / hi >= 1.5              # ≥50% gap hi↔lo (paper: ~130%)
    assert mid / hi <= 2.5             # mid closer to hi than lo


# ---------------------------------------------------------------------------
# Fig. 8 — runtime breakdown
# ---------------------------------------------------------------------------

def test_runtime_breakdown_baseline_gemm_dominates():
    """Baseline: GEMM ≈ 99% of runtime, FF dominates within GEMM (§4.5)."""
    wl = paper_workload("bert-base")
    r = SM.workload_time(wl, "int32", "cpu1")
    assert r["gemm"] / r["total"] > 0.98
    ff = r["parts"]["FF1"] + r["parts"]["FF2"]
    assert ff / r["gemm"] > 0.6


def test_runtime_breakdown_accelerated_nongemm_grows():
    """MatrixFlow: non-GEMM + control become visible shares (paper: 13.3% /
    24.25%)."""
    wl = paper_workload("bert-base")
    r = SM.workload_time(wl, "int32", "mf_dc")
    nongemm_share = r["nongemm"] / r["total"]
    control_share = r["control"] / r["total"]
    assert 0.02 <= nongemm_share <= 0.45
    assert 0.005 <= control_share <= 0.45


# ---------------------------------------------------------------------------
# Descriptor / traffic accounting invariants
# ---------------------------------------------------------------------------

def test_matrixflow_layout_strictly_fewer_descriptors():
    g = SM.Gemm(1024, 1024, 1024)
    mf = SM.matrixflow_gemm_time(g, "int8", "dc")
    conv = SM.matrixflow_gemm_time(g, "int8", "dc", conventional_layout=True)
    assert conv["transfer"] > mf["transfer"]


def test_control_overhead_linear_in_offloads():
    g1 = SM.Gemm(512, 512, 512, count=1)
    g8 = SM.Gemm(512, 512, 512, count=8)
    t1 = SM.matrixflow_gemm_time(g1, "int8", "dc")["control"]
    t8 = SM.matrixflow_gemm_time(g8, "int8", "dc")["control"]
    assert abs(t8 - 8 * t1) < 1e-12
