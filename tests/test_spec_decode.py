"""Speculative decoding: drafter units, token-identity gates (paged,
fused, int8 KV, prefix-COW, forced preempt/resume), rollback accounting,
and the draft-model path's full-acceptance sanity check."""
import asyncio

import jax
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.core.plan import AttentionPolicy
from repro.models import transformer as T
from repro.serving.engine import ServeConfig, ServingEngine
from repro.serving.spec_decode import (DraftModelDrafter, NGramDrafter,
                                       make_drafter)

PAGED8 = AttentionPolicy(backend="paged_interpret", page_size=8, block_q=8)
FUSED8 = AttentionPolicy(backend="fused_interpret", block_q=8, block_k=8)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


# -- NGramDrafter units ------------------------------------------------------
def test_ngram_proposes_most_recent_continuation():
    d = NGramDrafter(k=3, ngram=2)
    # suffix (1, 2) occurred at index 0 (→ 9, 9, 9) and index 5 (→ 7, 8);
    # the most recent match wins
    ctx = [1, 2, 9, 9, 9, 1, 2, 7, 8, 1, 2]
    assert d.draft(ctx, 3) == [7, 8, 1]


def test_ngram_falls_back_to_shorter_ngram():
    d = NGramDrafter(k=2, ngram=3, min_ngram=1)
    # the trailing 3-gram and 2-gram only occur flush against the suffix;
    # the 1-gram [2] occurred earlier with a continuation
    assert d.draft([2, 5, 1, 3, 2], 2) == [5, 1]


def test_ngram_no_match_proposes_nothing():
    d = NGramDrafter(k=4)
    assert d.draft([1, 2, 3, 4, 5], 4) == []
    assert d.draft([7], 4) == []              # too short to self-match
    assert d.draft([3, 3, 3], 0) == []        # engine trimmed budget to 0


def test_ngram_respects_draft_budget():
    d = NGramDrafter(k=8, ngram=1)
    ctx = [5, 1, 2, 3, 4, 5]
    assert d.draft(ctx, 2) == [1, 2]          # per-call cap below k
    assert NGramDrafter(k=2, ngram=1).draft(ctx, 8) == [1, 2]  # instance cap


def test_ngram_validates_arguments():
    with pytest.raises(ValueError, match="ngram"):
        NGramDrafter(k=0)
    with pytest.raises(ValueError, match="ngram"):
        NGramDrafter(ngram=1, min_ngram=2)


def test_make_drafter_rejects_unknown_spec():
    with pytest.raises(ValueError, match="unknown drafter"):
        make_drafter("nope")


# -- engine validation -------------------------------------------------------
def test_spec_requires_greedy(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="greedy"):
        ServingEngine(cfg, params, ServeConfig(
            batch_slots=2, max_len=32, temperature=0.7,
            spec=NGramDrafter()))


def test_spec_rejects_bad_k(setup):
    cfg, params = setup

    class BadDrafter:
        k = 0

    with pytest.raises(ValueError, match="k >= 1"):
        ServingEngine(cfg, params, ServeConfig(
            batch_slots=2, max_len=32, spec=BadDrafter()))


# -- token-identity gates ----------------------------------------------------
def _run_to_retirement(cfg, params, sc, prompts):
    """Serve ``prompts`` to natural retirement (max_len drain); returns
    {i: full stream} keyed by prompt index, plus the engine."""
    eng = ServingEngine(cfg, params, sc)
    outs = {i: [] for i in range(len(prompts))}
    hmap = {}
    pending = list(enumerate(prompts))
    for _ in range(600):
        while pending:
            i, p = pending[0]
            h = eng.submit(list(p))
            if h is None:
                break
            hmap[h] = i
            pending.pop(0)
        stepped = eng.step()
        for h, t in stepped.items():
            outs[hmap[h]].extend(t if isinstance(t, list) else [t])
        if not pending and not eng.slot_live.any() \
                and not (eng.paged and eng.wait):
            break
    assert not pending and not eng.slot_live.any()
    return outs, eng


def _prompts(n, seed=0, lo=3, hi=14):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 64, rng.integers(lo, hi)).tolist()
            for _ in range(n)]


@pytest.mark.parametrize("attn", [PAGED8, FUSED8],
                         ids=["paged", "fused"])
def test_spec_streams_token_identical(setup, attn):
    """The tentpole gate: speculative greedy streams — run all the way
    through the max_len drain — equal non-speculative streams exactly,
    on the paged AND fused (contiguous rollback) backends."""
    cfg, params = setup
    prompts = _prompts(3, seed=2)
    base = dict(batch_slots=3, max_len=32, attention=attn)
    want, _ = _run_to_retirement(cfg, params, ServeConfig(**base), prompts)
    got, eng = _run_to_retirement(
        cfg, params, ServeConfig(**base, spec=NGramDrafter(k=4)), prompts)
    assert got == want
    assert eng.spec_accepted > 0          # speculation actually engaged
    if eng.paged:
        eng.pool.check()
        assert eng.pool.free_pages == eng.pool.n_pages


def test_spec_rollback_returns_pages(setup):
    """Rejected drafts must shed their tail pages: the rollback counter
    moves and the pool ends fully reclaimed with invariants intact."""
    cfg, params = setup
    prompts = _prompts(4, seed=3)
    sc = ServeConfig(batch_slots=4, max_len=64, attention=PAGED8,
                     spec=NGramDrafter(k=4))
    _, eng = _run_to_retirement(cfg, params, sc, prompts)
    assert eng.spec_rejected > 0
    assert eng.spec_rollback_pages > 0
    eng.pool.check()
    assert eng.pool.free_pages == eng.pool.n_pages
    st = eng.stats()
    assert st["spec_rollback_pages"] == eng.spec_rollback_pages
    assert 0.0 <= st["spec_acceptance_rate"] <= 1.0


def test_spec_preempt_resume_streams_identical(setup):
    """Speculation under pool pressure: a pool that forces preemption
    mid-stream must still produce non-speculative streams — draft pages
    never preempt anyone (they trim instead), and resume re-prefills
    through the same masked path."""
    cfg, params = setup
    sc = ServeConfig(batch_slots=2, max_len=16, attention=PAGED8,
                     cache_pages=2, spec=NGramDrafter(k=4))
    prompts = [[1, 2, 3], [4, 5, 6]]
    got, eng = _run_to_retirement(cfg, params, sc, prompts)
    assert eng.n_preemptions > 0                   # pressure actually hit
    eng.pool.check()
    assert eng.pool.free_pages == eng.pool.n_pages
    base = ServeConfig(batch_slots=2, max_len=16, attention=PAGED8)
    for i, p in enumerate(prompts):
        want, _ = _run_to_retirement(cfg, params, base, [p])
        assert got[i] == want[0], (i, p)


def test_spec_prefix_cow_streams_identical(setup):
    """Speculation over prefix-cache-shared prompts: verify writes and
    rollback truncates must never touch a shared page — streams equal the
    uncached engine's for every request."""
    cfg, params = setup
    shared = list(range(1, 13))                    # crosses a page boundary
    prompts = [shared + [20 + i] for i in range(3)]
    base = dict(batch_slots=3, max_len=32, attention=PAGED8)
    want, _ = _run_to_retirement(cfg, params, ServeConfig(**base), prompts)
    got, eng = _run_to_retirement(
        cfg, params,
        ServeConfig(**base, prefix_cache=True, spec=NGramDrafter(k=4)),
        prompts)
    assert got == want
    assert eng.prefix.stats()["prefix_hits"] > 0   # sharing actually hit
    eng.prefix.clear()
    eng.pool.check()
    assert eng.pool.free_pages == eng.pool.n_pages


def test_spec_kv_int8_streams_self_consistent(setup):
    """int8 KV pages under speculation: the spec stream must equal the
    non-spec stream at the same kv_dtype — page scales stay a pure
    function of logical content across rollback."""
    cfg, params = setup
    prompts = _prompts(2, seed=4)
    base = dict(batch_slots=2, max_len=32, attention=PAGED8,
                kv_dtype="int8")
    want, _ = _run_to_retirement(cfg, params, ServeConfig(**base), prompts)
    got, eng = _run_to_retirement(
        cfg, params, ServeConfig(**base, spec=NGramDrafter(k=4)), prompts)
    assert got == want
    eng.pool.check()


def test_draft_model_self_draft_accepts_everything(setup):
    """A draft model that IS the target proposes the target's own greedy
    continuation — every draft must be accepted (the acceptance rule is
    exact argmax agreement, not approximation)."""
    cfg, params = setup
    # matching the target's backend keeps near-tied argmaxes in agreement
    drafter = DraftModelDrafter(cfg, params, k=3, max_len=32,
                                attention=PAGED8)
    prompts = _prompts(2, seed=5, lo=3, hi=8)
    base = dict(batch_slots=2, max_len=24, attention=PAGED8)
    want, _ = _run_to_retirement(cfg, params, ServeConfig(**base), prompts)
    got, eng = _run_to_retirement(
        cfg, params, ServeConfig(**base, spec=drafter), prompts)
    assert got == want
    assert eng.spec_rejected == 0
    assert eng.spec_accepted > 0


def test_spec_step_emits_bursts(setup):
    """With spec enabled step() returns {handle: [tokens]} — the repeated
    self-matching prompt makes the n-gram drafter land multi-token
    bursts."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, ServeConfig(
        batch_slots=1, max_len=64, attention=PAGED8,
        spec=NGramDrafter(k=4)))
    h = eng.submit([7, 7, 7, 7, 7, 7])
    total, bursts = 0, []
    for _ in range(30):
        stepped = eng.step()
        if h in stepped:
            assert isinstance(stepped[h], list)
            bursts.append(len(stepped[h]))
            total += len(stepped[h])
        if total >= 10:
            break
    assert total >= 10
    eng.cancel(h)


def test_spec_async_frontend_streams(setup):
    """The streaming frontend must consume spec bursts token-by-token and
    stop at exactly n_tokens."""
    from repro.serving.frontend import AsyncServingEngine
    cfg, params = setup
    eng = ServingEngine(cfg, params, ServeConfig(
        batch_slots=2, max_len=32, attention=PAGED8,
        spec=NGramDrafter(k=4)))
    aeng = AsyncServingEngine(eng)

    solo, _ = _run_to_retirement(
        cfg, params, ServeConfig(batch_slots=2, max_len=32,
                                 attention=PAGED8),
        [[1, 2, 3, 1, 2]])

    async def demo():
        return await asyncio.gather(
            aeng.complete([1, 2, 3, 1, 2], 8),
            aeng.complete([9, 8, 7], 8))

    got = asyncio.run(demo())
    assert [len(g) for g in got] == [8, 8]
    assert got[0] == solo[0][:8]
    eng.pool.check()
    assert eng.pool.free_pages == eng.pool.n_pages
