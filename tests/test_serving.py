"""Serving engine: batched generate, continuous batching slots, greedy
determinism, fused-attention parity, retirement/temperature regressions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.core.plan import AttentionPolicy
from repro.models import transformer as T
from repro.serving.engine import ServeConfig, ServingEngine


@pytest.fixture(scope="module")
def engine():
    cfg = get_smoke_config("qwen2-1.5b", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    return ServingEngine(cfg, params, ServeConfig(batch_slots=4, max_len=64))


def test_generate_shapes(engine):
    prompts = np.random.default_rng(0).integers(0, 64, (4, 8)).astype(np.int32)
    out = engine.generate(prompts, n_tokens=5)
    assert out.shape == (4, 5)
    assert out.min() >= 0 and out.max() < 64


def test_generate_greedy_deterministic():
    cfg = get_smoke_config("qwen2-1.5b", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    prompts = np.random.default_rng(1).integers(0, 64, (4, 8)).astype(np.int32)
    e1 = ServingEngine(cfg, params, ServeConfig(batch_slots=4, max_len=64))
    e2 = ServingEngine(cfg, params, ServeConfig(batch_slots=4, max_len=64))
    np.testing.assert_array_equal(e1.generate(prompts, 6),
                                  e2.generate(prompts, 6))


def test_continuous_batching_slots():
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, ServeConfig(batch_slots=2, max_len=32))
    s0 = eng.submit([1, 2, 3])
    s1 = eng.submit([4, 5])
    assert {s0, s1} == {0, 1}
    assert eng.submit([9]) is None          # no free slot
    out = eng.step()
    assert set(out) == {0, 1}               # both slots decoded one token
    out2 = eng.step()
    assert set(out2) == {0, 1}


def test_interleaved_submit_leaves_other_slots_uncorrupted():
    """Regression for the submit cache-corruption bug: the old per-slot
    prefill ran full-batch decode with zero tokens, writing garbage K/V
    into every other live slot's cache at its current position and
    inflating its valid length. Admitting slot 1 mid-stream must leave
    slot 0's greedy decode byte-identical to an uninterrupted run."""
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)

    def run(interleave: bool):
        eng = ServingEngine(cfg, params,
                            ServeConfig(batch_slots=2, max_len=32))
        assert eng.submit([1, 2, 3]) == 0
        outs = []
        for i in range(6):
            if interleave and i == 2:
                assert eng.submit([4, 5]) == 1
            outs.append(eng.step()[0])
        return outs

    assert run(False) == run(True)


def test_submit_masked_prefill_matches_generate_cache_state():
    """After submit, the admitted slot's cache length equals its prompt
    length and no other slot's length moved (the masked-prefill contract)."""
    import numpy as _np
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, ServeConfig(batch_slots=3, max_len=32))
    eng.submit([7, 8, 9, 10])
    lens = _np.asarray(eng.caches["scan"]["len"])      # (n_layers, B)
    _np.testing.assert_array_equal(lens, [[4, 0, 0]] * lens.shape[0])
    eng.submit([5])
    lens = _np.asarray(eng.caches["scan"]["len"])
    _np.testing.assert_array_equal(lens, [[4, 1, 0]] * lens.shape[0])


def test_submit_step_matches_batched_generate():
    """Slot-mode decode must equal the batched generate() path on the same
    prompt token for token: submit() seeds the slot's pending token from the
    prefill argmax (no pseudo-BOS conditioning) and step() reports it before
    pipelining the next decode — no token of the stream is lost."""
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    prompt = [3, 1, 4, 1, 5]
    e_batch = ServingEngine(cfg, params, ServeConfig(batch_slots=1,
                                                     max_len=32))
    want = e_batch.generate(np.asarray([prompt], np.int32), 5)[0].tolist()
    e_slot = ServingEngine(cfg, params, ServeConfig(batch_slots=2,
                                                    max_len=32))
    slot = e_slot.submit(prompt)
    got = [e_slot.step()[slot] for _ in range(5)]
    assert got == want
    assert e_slot.slot_out[slot] == want


def test_recycled_slot_restarts_clean():
    """A retired slot must be recycled from position 0 with its valid
    length zeroed — the new request's output equals a fresh engine's."""
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    sc = ServeConfig(batch_slots=1, max_len=16)
    eng = ServingEngine(cfg, params, sc)
    eng.submit([9, 8, 7])
    while eng.slot_live[0]:          # decode to retirement at max_len
        eng.step()
    assert eng.slot_pos[0] >= sc.max_len - 1
    slot = eng.submit([1, 2, 3, 4])  # recycle
    assert slot == 0 and eng.slot_pos[0] == 4
    for _ in range(3):
        eng.step()
    fresh = ServingEngine(cfg, params, sc)
    fresh.submit([1, 2, 3, 4])
    for _ in range(3):
        fresh.step()
    assert eng.slot_out[0] == fresh.slot_out[0]


def test_retirement_flushes_final_token():
    """Regression: step() used to overwrite the freshly decoded slot_next
    when slot_pos hit max_len - 1, silently dropping the last token of
    every retired stream. The slot must drain — report the pending token —
    before retiring, so the slot stream is a strict prefix-match of an
    unbounded generate() stream of the same length."""
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    prompt = [1, 2, 3]
    M = 8
    eng = ServingEngine(cfg, params, ServeConfig(batch_slots=1, max_len=M))
    eng.submit(prompt)
    toks = []
    while eng.slot_live[0]:
        toks.append(eng.step()[0])
    # prefill token + one decode per remaining cache slot (positions
    # S..M-1), the last of which is flushed by the drain round
    assert len(toks) == M - len(prompt) + 1, toks
    big = ServingEngine(cfg, params, ServeConfig(batch_slots=1, max_len=64))
    want = big.generate(np.asarray([prompt], np.int32),
                        len(toks))[0].tolist()
    assert toks == want           # nothing dropped, nothing reordered


def test_step_honors_temperature():
    """Regression: the continuous-batching path always did greedy argmax
    while generate() sampled. step()/submit() take an optional PRNG key and
    share generate()'s sampling rule."""
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    sc = ServeConfig(batch_slots=1, max_len=32, temperature=2.0)

    def run(seed):
        eng = ServingEngine(cfg, params, sc)
        s = eng.submit([1, 2, 3], key=jax.random.PRNGKey(seed))
        return [eng.step(key=jax.random.PRNGKey(100 * seed + i))[s]
                for i in range(8)]

    sampled = run(1)
    assert sampled == run(1)            # deterministic under the same keys
    greedy_eng = ServingEngine(cfg, params,
                               ServeConfig(batch_slots=1, max_len=32))
    s = greedy_eng.submit([1, 2, 3])
    greedy = [greedy_eng.step()[s] for i in range(8)]
    assert sampled != greedy            # temperature actually applied
    # without a key the tempered engine still serves (greedy fallback)
    eng = ServingEngine(cfg, params, sc)
    s = eng.submit([1, 2, 3])
    assert [eng.step()[s] for i in range(8)] == greedy


def test_submit_rejects_multislot_ssm():
    """SSD/conv recurrent state carries no positions, so masked single-slot
    prefill cannot protect concurrent slots — multi-slot submit() must
    refuse rather than corrupt silently. With batch_slots=1 there is no
    other slot to corrupt, so the single-slot case still serves."""
    cfg = get_smoke_config("mamba2-1.3b", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, ServeConfig(batch_slots=2, max_len=32))
    with pytest.raises(NotImplementedError, match="SSM"):
        eng.submit([1, 2, 3])
    solo = ServingEngine(cfg, params, ServeConfig(batch_slots=1, max_len=32))
    assert solo.submit([1, 2, 3]) == 0
    assert set(solo.step()) == {0}


def test_submit_rejects_oversized_prompt():
    cfg = get_smoke_config("smollm-135m", n_layers=1, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, ServeConfig(batch_slots=1, max_len=8))
    with pytest.raises(ValueError, match="prompt length"):
        eng.submit(list(range(8)))
    with pytest.raises(ValueError, match="prompt length"):
        eng.submit([])


def test_weight_dtype_implies_quantize_at_pack():
    """weight_dtype without pack_weights must still quantize once at engine
    build — quantizing inside the jitted decode would redo the O(K·N) work
    per token."""
    from repro.core.plan import QuantizedPackedWeight
    cfg = get_smoke_config("smollm-135m", n_layers=1, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, ServeConfig(
        batch_slots=1, max_len=16, weight_dtype="int8"))
    assert isinstance(eng.params["head"], QuantizedPackedWeight)


def test_quantized_packed_engine_matches_fp_greedy():
    """ServeConfig(pack_weights=True, weight_dtype="int8"): every projection
    weight becomes a resident QuantizedPackedWeight and greedy decode at
    temperature 0 tracks the unquantized engine. Empirically the smoke
    config is token-identical on the reference platform; the asserted
    floor is a 90% top-1 agreement rate so ulp-level drift across
    jax/XLA versions cannot flake the gate (see docs/quant.md)."""
    from repro.core.plan import GemmPolicy, QuantizedPackedWeight
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    prompts = np.random.default_rng(3).integers(0, 64, (2, 6)).astype(np.int32)
    pol = GemmPolicy(backend="blockflow")
    e_fp = ServingEngine(cfg, params, ServeConfig(
        batch_slots=2, max_len=32, gemm=pol))
    e_q = ServingEngine(cfg, params, ServeConfig(
        batch_slots=2, max_len=32, gemm=pol, pack_weights=True,
        weight_dtype="int8"))
    assert isinstance(e_q.params["head"], QuantizedPackedWeight)
    assert isinstance(e_q.params["layers"]["attn"]["wq"],
                      QuantizedPackedWeight)
    o_fp = e_fp.generate(prompts, 8)
    o_q = e_q.generate(prompts, 8)
    agreement = float((o_fp == o_q).mean())
    assert agreement >= 0.9, f"top-1 agreement {agreement} < 0.9"


def test_fused_attention_token_streams_identical():
    """The acceptance gate: ServingEngine token streams — batched generate
    AND submit()/step() slot streams — must be identical under the fused
    flash-attention path and the unfused baseline."""
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    prompt = [3, 1, 4, 1, 5]
    prompts = np.random.default_rng(5).integers(0, 64, (2, 6)).astype(np.int32)
    streams, gens = {}, {}
    for backend in ("unfused", "fused_interpret"):
        attn = AttentionPolicy(backend=backend, block_q=16, block_k=16)
        eng = ServingEngine(cfg, params, ServeConfig(
            batch_slots=2, max_len=32, attention=attn))
        slot = eng.submit(prompt)
        streams[backend] = [eng.step()[slot] for _ in range(6)]
        eng2 = ServingEngine(cfg, params, ServeConfig(
            batch_slots=2, max_len=32, attention=attn))
        gens[backend] = eng2.generate(prompts, 5)
    assert streams["unfused"] == streams["fused_interpret"]
    np.testing.assert_array_equal(gens["unfused"], gens["fused_interpret"])


def test_fused_interleaved_submit_leaves_other_slots_uncorrupted():
    """Interleaved submit()/step() with the fused attention path enabled:
    the masked position −1 rows must not write K/V through the fused
    kernel — admitting slot 1 mid-stream leaves slot 0's decode
    byte-identical to an uninterrupted run."""
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    attn = AttentionPolicy(backend="fused_interpret", block_q=16, block_k=16)

    def run(interleave: bool):
        eng = ServingEngine(cfg, params, ServeConfig(
            batch_slots=2, max_len=32, attention=attn))
        assert eng.submit([1, 2, 3]) == 0
        outs = []
        for i in range(5):
            if interleave and i == 2:
                assert eng.submit([4, 5]) == 1
            outs.append(eng.step()[0])
        return outs

    assert run(False) == run(True)


def test_decode_prefill_logit_parity_fused_vs_unfused():
    """Same tokens through (a) one full prefill and (b) prefill + cached
    decode steps, on both attention backends: all four last-token logit
    vectors must agree within fp tolerance — decode-vs-prefill consistency
    of the offset/length-mask semantics, fused vs unfused."""
    from repro.serving.engine import make_decode_step, make_prefill_step
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    toks = np.asarray([[7, 3, 11, 5, 2, 9]], np.int32)
    B, S = toks.shape
    logits = {}
    for backend in ("unfused", "fused_interpret"):
        attn = AttentionPolicy(backend=backend, block_q=16, block_k=16)
        prefill = make_prefill_step(cfg, attn=attn)
        decode = make_decode_step(cfg, attn=attn)
        # (a) one prefill over the whole sequence
        caches = T.init_caches(cfg, B, 32, jnp.bfloat16)
        full, _ = prefill(params, {
            "tokens": jnp.asarray(toks),
            "positions": jnp.broadcast_to(jnp.arange(S)[None], (B, S))},
            caches)
        # (b) prefill the prefix, then decode the rest token by token
        caches = T.init_caches(cfg, B, 32, jnp.bfloat16)
        cut = 3
        out, caches = prefill(params, {
            "tokens": jnp.asarray(toks[:, :cut]),
            "positions": jnp.broadcast_to(jnp.arange(cut)[None], (B, cut))},
            caches)
        for i in range(cut, S):
            out, caches = decode(params, jnp.asarray(toks[:, i:i + 1]),
                                 jnp.full((B, 1), i, jnp.int32), caches)
        logits[backend] = (np.asarray(full, np.float32),
                           np.asarray(out, np.float32))
    for a in logits["unfused"] + logits["fused_interpret"]:
        np.testing.assert_allclose(a, logits["unfused"][0],
                                   atol=5e-2, rtol=5e-2)


def test_packed_resident_weights_match_row_major():
    """ServeConfig(pack_weights=True) lays every projection weight out
    block-major once at engine build (the paper's Fig. 5 deployment shape);
    generation must match the row-major engine exactly under the same
    policy."""
    from repro.core.plan import GemmPolicy, PackedWeight
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    pol = GemmPolicy(backend="blockflow", mode="dm")
    prompts = np.random.default_rng(2).integers(0, 64, (2, 6)).astype(np.int32)
    e_row = ServingEngine(cfg, params, ServeConfig(
        batch_slots=2, max_len=32, gemm=pol))
    e_packed = ServingEngine(cfg, params, ServeConfig(
        batch_slots=2, max_len=32, gemm=pol, pack_weights=True))
    assert isinstance(e_packed.params["head"], PackedWeight)
    np.testing.assert_array_equal(e_row.generate(prompts, 4),
                                  e_packed.generate(prompts, 4))
