"""Serving engine: batched generate, continuous batching slots, greedy
determinism, fused-attention parity, retirement/temperature regressions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.core.plan import AttentionPolicy
from repro.models import transformer as T
from repro.serving.engine import ServeConfig, ServingEngine


@pytest.fixture(scope="module")
def engine():
    cfg = get_smoke_config("qwen2-1.5b", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    return ServingEngine(cfg, params, ServeConfig(batch_slots=4, max_len=64))


def test_generate_shapes(engine):
    prompts = np.random.default_rng(0).integers(0, 64, (4, 8)).astype(np.int32)
    out = engine.generate(prompts, n_tokens=5)
    assert out.shape == (4, 5)
    assert out.min() >= 0 and out.max() < 64


def test_generate_greedy_deterministic():
    cfg = get_smoke_config("qwen2-1.5b", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    prompts = np.random.default_rng(1).integers(0, 64, (4, 8)).astype(np.int32)
    e1 = ServingEngine(cfg, params, ServeConfig(batch_slots=4, max_len=64))
    e2 = ServingEngine(cfg, params, ServeConfig(batch_slots=4, max_len=64))
    np.testing.assert_array_equal(e1.generate(prompts, 6),
                                  e2.generate(prompts, 6))


def test_continuous_batching_slots():
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, ServeConfig(batch_slots=2, max_len=32))
    s0 = eng.submit([1, 2, 3])
    s1 = eng.submit([4, 5])
    assert {s0, s1} == {0, 1}
    assert eng.submit([9]) is None          # no free slot
    out = eng.step()
    assert set(out) == {0, 1}               # both slots decoded one token
    out2 = eng.step()
    assert set(out2) == {0, 1}


def test_interleaved_submit_leaves_other_slots_uncorrupted():
    """Regression for the submit cache-corruption bug: the old per-slot
    prefill ran full-batch decode with zero tokens, writing garbage K/V
    into every other live slot's cache at its current position and
    inflating its valid length. Admitting slot 1 mid-stream must leave
    slot 0's greedy decode byte-identical to an uninterrupted run."""
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)

    def run(interleave: bool):
        eng = ServingEngine(cfg, params,
                            ServeConfig(batch_slots=2, max_len=32))
        assert eng.submit([1, 2, 3]) == 0
        outs = []
        for i in range(6):
            if interleave and i == 2:
                assert eng.submit([4, 5]) == 1
            outs.append(eng.step()[0])
        return outs

    assert run(False) == run(True)


def test_submit_masked_prefill_matches_generate_cache_state():
    """After submit, the admitted slot's cache length equals its prompt
    length and no other slot's length moved (the masked-prefill contract)."""
    import numpy as _np
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, ServeConfig(batch_slots=3, max_len=32))
    eng.submit([7, 8, 9, 10])
    lens = _np.asarray(eng.caches["scan"]["len"])      # (n_layers, B)
    _np.testing.assert_array_equal(lens, [[4, 0, 0]] * lens.shape[0])
    eng.submit([5])
    lens = _np.asarray(eng.caches["scan"]["len"])
    _np.testing.assert_array_equal(lens, [[4, 1, 0]] * lens.shape[0])


def test_submit_step_matches_batched_generate():
    """Slot-mode decode must equal the batched generate() path on the same
    prompt token for token: submit() seeds the slot's pending token from the
    prefill argmax (no pseudo-BOS conditioning) and step() reports it before
    pipelining the next decode — no token of the stream is lost."""
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    prompt = [3, 1, 4, 1, 5]
    e_batch = ServingEngine(cfg, params, ServeConfig(batch_slots=1,
                                                     max_len=32))
    want = e_batch.generate(np.asarray([prompt], np.int32), 5)[0].tolist()
    e_slot = ServingEngine(cfg, params, ServeConfig(batch_slots=2,
                                                    max_len=32))
    slot = e_slot.submit(prompt)
    got = [e_slot.step()[slot] for _ in range(5)]
    assert got == want
    assert e_slot.slot_out[slot] == want


def test_recycled_slot_restarts_clean():
    """A retired slot must be recycled from position 0 with its valid
    length zeroed — the new request's output equals a fresh engine's."""
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    sc = ServeConfig(batch_slots=1, max_len=16)
    eng = ServingEngine(cfg, params, sc)
    eng.submit([9, 8, 7])
    while eng.slot_live[0]:          # decode to retirement at max_len
        eng.step()
    assert eng.slot_pos[0] >= sc.max_len - 1
    slot = eng.submit([1, 2, 3, 4])  # recycle
    assert slot == 0 and eng.slot_pos[0] == 4
    for _ in range(3):
        eng.step()
    fresh = ServingEngine(cfg, params, sc)
    fresh.submit([1, 2, 3, 4])
    for _ in range(3):
        fresh.step()
    assert eng.slot_out[0] == fresh.slot_out[0]


def test_retirement_flushes_final_token():
    """Regression: step() used to overwrite the freshly decoded slot_next
    when slot_pos hit max_len - 1, silently dropping the last token of
    every retired stream. The slot must drain — report the pending token —
    before retiring, so the slot stream is a strict prefix-match of an
    unbounded generate() stream of the same length."""
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    prompt = [1, 2, 3]
    M = 8
    eng = ServingEngine(cfg, params, ServeConfig(batch_slots=1, max_len=M))
    eng.submit(prompt)
    toks = []
    while eng.slot_live[0]:
        toks.append(eng.step()[0])
    # prefill token + one decode per remaining cache slot (positions
    # S..M-1), the last of which is flushed by the drain round
    assert len(toks) == M - len(prompt) + 1, toks
    big = ServingEngine(cfg, params, ServeConfig(batch_slots=1, max_len=64))
    want = big.generate(np.asarray([prompt], np.int32),
                        len(toks))[0].tolist()
    assert toks == want           # nothing dropped, nothing reordered


def test_step_honors_temperature():
    """Regression: the continuous-batching path always did greedy argmax
    while generate() sampled. step()/submit() take an optional PRNG key and
    share generate()'s sampling rule."""
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    sc = ServeConfig(batch_slots=1, max_len=32, temperature=2.0)

    def run(seed):
        eng = ServingEngine(cfg, params, sc)
        s = eng.submit([1, 2, 3], key=jax.random.PRNGKey(seed))
        return [eng.step(key=jax.random.PRNGKey(100 * seed + i))[s]
                for i in range(8)]

    sampled = run(1)
    assert sampled == run(1)            # deterministic under the same keys
    greedy_eng = ServingEngine(cfg, params,
                               ServeConfig(batch_slots=1, max_len=32))
    s = greedy_eng.submit([1, 2, 3])
    greedy = [greedy_eng.step()[s] for i in range(8)]
    assert sampled != greedy            # temperature actually applied
    # without a key the tempered engine still serves (greedy fallback)
    eng = ServingEngine(cfg, params, sc)
    s = eng.submit([1, 2, 3])
    assert [eng.step()[s] for i in range(8)] == greedy


def test_submit_rejects_multislot_ssm():
    """SSD/conv recurrent state carries no positions, so masked single-slot
    prefill cannot protect concurrent slots — multi-slot submit() must
    refuse rather than corrupt silently. With batch_slots=1 there is no
    other slot to corrupt, so the single-slot case still serves."""
    cfg = get_smoke_config("mamba2-1.3b", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, ServeConfig(batch_slots=2, max_len=32))
    with pytest.raises(NotImplementedError, match="SSM"):
        eng.submit([1, 2, 3])
    solo = ServingEngine(cfg, params, ServeConfig(batch_slots=1, max_len=32))
    assert solo.submit([1, 2, 3]) == 0
    assert set(solo.step()) == {0}


def test_submit_rejects_oversized_prompt():
    cfg = get_smoke_config("smollm-135m", n_layers=1, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, ServeConfig(batch_slots=1, max_len=8))
    with pytest.raises(ValueError, match="prompt length"):
        eng.submit(list(range(8)))
    with pytest.raises(ValueError, match="prompt length"):
        eng.submit([])


def test_weight_dtype_implies_quantize_at_pack():
    """weight_dtype without pack_weights must still quantize once at engine
    build — quantizing inside the jitted decode would redo the O(K·N) work
    per token."""
    from repro.core.plan import QuantizedPackedWeight
    cfg = get_smoke_config("smollm-135m", n_layers=1, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, ServeConfig(
        batch_slots=1, max_len=16, weight_dtype="int8"))
    assert isinstance(eng.params["head"], QuantizedPackedWeight)


def test_quantized_packed_engine_matches_fp_greedy():
    """ServeConfig(pack_weights=True, weight_dtype="int8"): every projection
    weight becomes a resident QuantizedPackedWeight and greedy decode at
    temperature 0 tracks the unquantized engine. Empirically the smoke
    config is token-identical on the reference platform; the asserted
    floor is a 90% top-1 agreement rate so ulp-level drift across
    jax/XLA versions cannot flake the gate (see docs/quant.md)."""
    from repro.core.plan import GemmPolicy, QuantizedPackedWeight
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    prompts = np.random.default_rng(3).integers(0, 64, (2, 6)).astype(np.int32)
    pol = GemmPolicy(backend="blockflow")
    e_fp = ServingEngine(cfg, params, ServeConfig(
        batch_slots=2, max_len=32, gemm=pol))
    e_q = ServingEngine(cfg, params, ServeConfig(
        batch_slots=2, max_len=32, gemm=pol, pack_weights=True,
        weight_dtype="int8"))
    assert isinstance(e_q.params["head"], QuantizedPackedWeight)
    assert isinstance(e_q.params["layers"]["attn"]["wq"],
                      QuantizedPackedWeight)
    o_fp = e_fp.generate(prompts, 8)
    o_q = e_q.generate(prompts, 8)
    agreement = float((o_fp == o_q).mean())
    assert agreement >= 0.9, f"top-1 agreement {agreement} < 0.9"


def test_fused_attention_token_streams_identical():
    """The acceptance gate: ServingEngine token streams — batched generate
    AND submit()/step() slot streams — must be identical under the fused
    flash-attention path and the unfused baseline."""
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    prompt = [3, 1, 4, 1, 5]
    prompts = np.random.default_rng(5).integers(0, 64, (2, 6)).astype(np.int32)
    streams, gens = {}, {}
    for backend in ("unfused", "fused_interpret"):
        attn = AttentionPolicy(backend=backend, block_q=16, block_k=16)
        eng = ServingEngine(cfg, params, ServeConfig(
            batch_slots=2, max_len=32, attention=attn))
        slot = eng.submit(prompt)
        streams[backend] = [eng.step()[slot] for _ in range(6)]
        eng2 = ServingEngine(cfg, params, ServeConfig(
            batch_slots=2, max_len=32, attention=attn))
        gens[backend] = eng2.generate(prompts, 5)
    assert streams["unfused"] == streams["fused_interpret"]
    np.testing.assert_array_equal(gens["unfused"], gens["fused_interpret"])


def test_fused_interleaved_submit_leaves_other_slots_uncorrupted():
    """Interleaved submit()/step() with the fused attention path enabled:
    the masked position −1 rows must not write K/V through the fused
    kernel — admitting slot 1 mid-stream leaves slot 0's decode
    byte-identical to an uninterrupted run."""
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    attn = AttentionPolicy(backend="fused_interpret", block_q=16, block_k=16)

    def run(interleave: bool):
        eng = ServingEngine(cfg, params, ServeConfig(
            batch_slots=2, max_len=32, attention=attn))
        assert eng.submit([1, 2, 3]) == 0
        outs = []
        for i in range(5):
            if interleave and i == 2:
                assert eng.submit([4, 5]) == 1
            outs.append(eng.step()[0])
        return outs

    assert run(False) == run(True)


def test_decode_prefill_logit_parity_fused_vs_unfused():
    """Same tokens through (a) one full prefill and (b) prefill + cached
    decode steps, on both attention backends: all four last-token logit
    vectors must agree within fp tolerance — decode-vs-prefill consistency
    of the offset/length-mask semantics, fused vs unfused."""
    from repro.serving.engine import make_decode_step, make_prefill_step
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    toks = np.asarray([[7, 3, 11, 5, 2, 9]], np.int32)
    B, S = toks.shape
    logits = {}
    for backend in ("unfused", "fused_interpret"):
        attn = AttentionPolicy(backend=backend, block_q=16, block_k=16)
        prefill = make_prefill_step(cfg, attn=attn)
        decode = make_decode_step(cfg, attn=attn)
        # (a) one prefill over the whole sequence
        caches = T.init_caches(cfg, B, 32, jnp.bfloat16)
        full, _ = prefill(params, {
            "tokens": jnp.asarray(toks),
            "positions": jnp.broadcast_to(jnp.arange(S)[None], (B, S))},
            caches)
        # (b) prefill the prefix, then decode the rest token by token
        caches = T.init_caches(cfg, B, 32, jnp.bfloat16)
        cut = 3
        out, caches = prefill(params, {
            "tokens": jnp.asarray(toks[:, :cut]),
            "positions": jnp.broadcast_to(jnp.arange(cut)[None], (B, cut))},
            caches)
        for i in range(cut, S):
            out, caches = decode(params, jnp.asarray(toks[:, i:i + 1]),
                                 jnp.full((B, 1), i, jnp.int32), caches)
        logits[backend] = (np.asarray(full, np.float32),
                           np.asarray(out, np.float32))
    for a in logits["unfused"] + logits["fused_interpret"]:
        np.testing.assert_allclose(a, logits["unfused"][0],
                                   atol=5e-2, rtol=5e-2)


def test_packed_resident_weights_match_row_major():
    """ServeConfig(pack_weights=True) lays every projection weight out
    block-major once at engine build (the paper's Fig. 5 deployment shape);
    generation must match the row-major engine exactly under the same
    policy."""
    from repro.core.plan import GemmPolicy, PackedWeight
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    pol = GemmPolicy(backend="blockflow", mode="dm")
    prompts = np.random.default_rng(2).integers(0, 64, (2, 6)).astype(np.int32)
    e_row = ServingEngine(cfg, params, ServeConfig(
        batch_slots=2, max_len=32, gemm=pol))
    e_packed = ServingEngine(cfg, params, ServeConfig(
        batch_slots=2, max_len=32, gemm=pol, pack_weights=True))
    assert isinstance(e_packed.params["head"], PackedWeight)
    np.testing.assert_array_equal(e_row.generate(prompts, 4),
                                  e_packed.generate(prompts, 4))


# ---------------------------------------------------------------------------
# Bucketed prefill (bounded recompiles) + generate() input validation
# ---------------------------------------------------------------------------

def test_generate_batch_mismatch_raises_with_shapes():
    """generate() must reject a prompts batch that doesn't match
    batch_slots with a ValueError naming both shapes (was a bare assert)."""
    cfg = get_smoke_config("smollm-135m", n_layers=1, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, ServeConfig(batch_slots=2, max_len=16))
    bad = np.zeros((3, 4), np.int32)
    with pytest.raises(ValueError) as ei:
        eng.generate(bad, 2)
    assert "(3, 4)" in str(ei.value) and "batch_slots=2" in str(ei.value)


def test_bucketed_prefill_stream_unchanged():
    """Satellite regression: submit() pads prompts to the next power-of-two
    width with position −1 columns (bounding per-length recompiles to
    log2(max_len) buckets); the token stream must be unchanged — identical
    to the unpadded batched generate() path — for lengths below, at, and
    above a bucket boundary."""
    from repro.serving.engine import _next_pow2
    assert [_next_pow2(n) for n in (1, 2, 3, 4, 5, 7, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 8, 16]
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    for prompt in ([7, 3, 11], [3, 1, 4, 1, 5], [9, 8, 7, 6, 5, 4, 3, 2]):
        e_batch = ServingEngine(cfg, params,
                                ServeConfig(batch_slots=1, max_len=32))
        want = e_batch.generate(np.asarray([prompt], np.int32),
                                4)[0].tolist()
        e_slot = ServingEngine(cfg, params,
                               ServeConfig(batch_slots=2, max_len=32))
        slot = e_slot.submit(prompt)
        got = [e_slot.step()[slot] for _ in range(4)]
        assert got == want, (prompt, got, want)


# ---------------------------------------------------------------------------
# Paged KV-cache serving (docs/serving.md)
# ---------------------------------------------------------------------------

PAGED8 = AttentionPolicy(backend="paged_interpret", page_size=8, block_q=8)
FUSED8 = AttentionPolicy(backend="fused_interpret", block_q=8, block_k=8)


@pytest.fixture(scope="module")
def paged_setup():
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_paged_token_streams_identical(paged_setup):
    """The acceptance gate: the paged engine's token streams — batched
    generate() AND submit()/step() — must be identical to the fused and
    unfused contiguous engines'."""
    cfg, params = paged_setup
    prompt = [3, 1, 4, 1, 5]
    prompts = np.random.default_rng(5).integers(0, 64, (2, 6)).astype(np.int32)
    streams, gens = {}, {}
    for name, attn in (("unfused", AttentionPolicy(backend="unfused")),
                       ("fused", FUSED8), ("paged", PAGED8)):
        eng = ServingEngine(cfg, params, ServeConfig(
            batch_slots=2, max_len=32, attention=attn))
        h = eng.submit(prompt)
        streams[name] = [eng.step()[h] for _ in range(6)]
        eng2 = ServingEngine(cfg, params, ServeConfig(
            batch_slots=2, max_len=32, attention=attn))
        gens[name] = eng2.generate(prompts, 5)
    assert streams["paged"] == streams["fused"] == streams["unfused"]
    np.testing.assert_array_equal(gens["paged"], gens["fused"])
    np.testing.assert_array_equal(gens["paged"], gens["unfused"])


def test_paged_interleaved_submit_leaves_other_slots_uncorrupted(paged_setup):
    """Admitting a second request mid-stream must not perturb the first:
    page-pool writes go through disjoint block tables, and the masked
    position −1 prefill rows must not write any page."""
    cfg, params = paged_setup

    def run(interleave: bool):
        eng = ServingEngine(cfg, params, ServeConfig(
            batch_slots=2, max_len=32, attention=PAGED8))
        r0 = eng.submit([1, 2, 3])
        outs = []
        for i in range(5):
            if interleave and i == 2:
                assert eng.submit([4, 5]) is not None
            outs.append(eng.step()[r0])
        return outs

    assert run(False) == run(True)


def test_paged_capacity_admission_is_page_bound(paged_setup):
    """The capacity acceptance gate: a request set whose summed
    max_len-padded footprint exceeds the pool budget is served
    *concurrently* — admission tracks pages (resident tokens), not
    slot-count × max_len."""
    cfg, params = paged_setup
    # 4 slots × max_len 32 = 128 padded tokens; pool = 8 pages × 8 = 64.
    sc = ServeConfig(batch_slots=4, max_len=32, attention=PAGED8,
                     cache_pages=8)
    eng = ServingEngine(cfg, params, sc)
    rids = [eng.submit([1 + i, 2, 3]) for i in range(4)]
    assert all(r is not None for r in rids)
    assert int(eng.slot_live.sum()) == 4           # all concurrently live
    padded = 4 * sc.max_len
    pool_tokens = eng.pool.n_pages * eng.pool.page_size
    assert padded > pool_tokens                    # genuinely oversubscribed
    # and the streams are still exact: compare two of them to solo runs
    for _ in range(4):
        eng.step()
    for i in (0, 3):
        solo = ServingEngine(cfg, params, sc)
        r = solo.submit([1 + i, 2, 3])
        want = [solo.step()[r] for _ in range(4)]
        assert eng.request_out[rids[i]] == want


def test_paged_preempt_resume_stream_identical(paged_setup):
    """Pool exhaustion must preempt the youngest request (pages freed,
    request parked) and later resume it with a token stream identical to
    an uninterrupted run — for every request in the workload."""
    cfg, params = paged_setup
    sc = ServeConfig(batch_slots=2, max_len=16, attention=PAGED8,
                     cache_pages=2)       # 2 pages of 8 = half the padded need
    eng = ServingEngine(cfg, params, sc)
    prompts = [[1, 2, 3], [4, 5, 6]]
    rids = [eng.submit(p) for p in prompts]
    assert all(r is not None for r in rids)
    for _ in range(60):
        eng.step()
        if not eng.slot_live.any() and not eng.wait:
            break
    assert eng.n_preemptions > 0                   # pressure actually hit
    assert not eng.slot_live.any() and not eng.wait
    eng.pool.check()
    assert eng.pool.free_pages == eng.pool.n_pages  # everything reclaimed
    for rid, p in zip(rids, prompts):
        solo = ServingEngine(cfg, params, sc)
        r = solo.submit(p)
        want = []
        while solo.slot_live.any():
            st = solo.step()
            if r in st:
                want.append(st[r])
        assert eng.request_out[rid] == want, (rid, p)


def test_paged_cancel_returns_pages(paged_setup):
    cfg, params = paged_setup
    eng = ServingEngine(cfg, params, ServeConfig(
        batch_slots=2, max_len=16, attention=PAGED8))
    r = eng.submit([1, 2, 3, 4, 5])
    assert eng.pool.pages_in_use > 0
    assert eng.cancel(r) is True
    assert eng.pool.free_pages == eng.pool.n_pages
    assert eng.cancel(r) is False                  # already gone
    eng.pool.check()


def test_paged_rejects_undersized_pool(paged_setup):
    """A pool that cannot back even one full-length request would wedge
    the wait queue forever — refuse at construction."""
    cfg, params = paged_setup
    with pytest.raises(ValueError, match="cache_pages"):
        ServingEngine(cfg, params, ServeConfig(
            batch_slots=2, max_len=32, attention=PAGED8, cache_pages=3))


def test_paged_generate_resets_pool(paged_setup):
    """Batched generate() owns the engine: it drops in-flight requests,
    reclaims every page, and two consecutive calls are deterministic."""
    cfg, params = paged_setup
    eng = ServingEngine(cfg, params, ServeConfig(
        batch_slots=2, max_len=32, attention=PAGED8))
    assert eng.submit([1, 2, 3]) is not None
    prompts = np.random.default_rng(2).integers(0, 64, (2, 4)).astype(np.int32)
    o1 = eng.generate(prompts, 4)
    o2 = eng.generate(prompts, 4)
    np.testing.assert_array_equal(o1, o2)
    eng.pool.check()


def test_paged_rejects_ssm_and_mla_families(paged_setup):
    from repro.models.transformer import init_paged_caches
    cfg_ssm = get_smoke_config("mamba2-1.3b", n_layers=2, vocab=64)
    with pytest.raises(NotImplementedError, match="recurrent"):
        init_paged_caches(cfg_ssm, 2, 8, 8, jnp.bfloat16)
    cfg_mla = get_smoke_config("deepseek-v2-236b", n_layers=2, vocab=64)
    with pytest.raises(NotImplementedError, match="MLA"):
        init_paged_caches(cfg_mla, 2, 8, 8, jnp.bfloat16)


def test_paged_generate_then_submit_no_page_leak(paged_setup):
    """Review regression: generate() pre-allocates horizon pages with no
    live slot owning them; a following submit() must not inherit-and-drop
    those tables (pages would leak unreleasable). The pool must stay
    exactly balanced across generate → submit → retire cycles."""
    cfg, params = paged_setup
    eng = ServingEngine(cfg, params, ServeConfig(
        batch_slots=2, max_len=32, attention=PAGED8))
    prompts = np.random.default_rng(4).integers(0, 64, (2, 4)).astype(np.int32)
    eng.generate(prompts, 8)
    assert eng.pool.free_pages == eng.pool.n_pages   # horizon pages returned
    r = eng.submit([1, 2, 3])
    assert r is not None
    for _ in range(3):
        eng.step()
    assert eng.cancel(r)
    eng.pool.check()
    assert eng.pool.free_pages == eng.pool.n_pages


def test_ssm_submit_stream_unaffected_by_bucketing():
    """Review regression: bucket padding columns carry position −1, a
    contract SSD/conv recurrent state is outside of (no positions) — a
    padded prefill would feed the pad tokens into the recurrence. SSM
    submit() (batch_slots=1) must prefill unpadded and match generate()
    token for token on a non-power-of-two prompt."""
    cfg = get_smoke_config("mamba2-1.3b", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    prompt = [7, 3, 11]                        # len 3: would bucket to 4
    gen = ServingEngine(cfg, params, ServeConfig(batch_slots=1, max_len=32))
    want = gen.generate(np.asarray([prompt], np.int32), 4)[0].tolist()
    slot_eng = ServingEngine(cfg, params,
                             ServeConfig(batch_slots=1, max_len=32))
    s = slot_eng.submit(prompt)
    assert [slot_eng.step()[s] for _ in range(4)] == want


# ---------------------------------------------------------------------------
# Prefix cache: COW prompt-page sharing (docs/serving.md#prefix-cache)
# ---------------------------------------------------------------------------

def test_prefix_cache_streams_identical(paged_setup):
    """The golden gate: prefix cache ON must not change one token of any
    stream — shared-prefix prompts served with prefix_cache=True produce
    exactly the no-cache engine's streams, while actually sharing pages
    (hits recorded, fewer pages resident)."""
    cfg, params = paged_setup
    shared = list(range(1, 17))              # two full 8-token pages
    prompts = [shared + [40 + i, 50 + i] for i in range(3)]

    def streams(sc):
        eng = ServingEngine(cfg, params, sc)
        hs = [eng.submit(p) for p in prompts]
        assert all(h is not None for h in hs)
        for _ in range(5):
            eng.step()
        return [list(eng.request_out[h]) for h in hs], eng

    base = dict(batch_slots=4, max_len=32, attention=PAGED8, cache_pages=16)
    want, e0 = streams(ServeConfig(**base))
    got, e1 = streams(ServeConfig(**base, prefix_cache=True))
    assert got == want
    st = e1.stats()
    assert st["prefix_hits"] == 2            # requests 2 and 3 hit
    assert st["prefix_hit_tokens"] >= 2 * 16
    # sharing is real: the cached engine backs the same live set in fewer
    # pages than the private-copies engine
    assert e1.pool.pages_in_use < e0.pool.pages_in_use
    e1.pool.check()
    e1.prefix.check()


def test_prefix_cache_cow_divergence_isolated(paged_setup):
    """A request diverging INSIDE a cached page (COW fork) must match its
    solo stream, and its writes must not leak into the original holder's
    pages — both streams equal their uninterrupted solo runs."""
    cfg, params = paged_setup
    sc = ServeConfig(batch_slots=2, max_len=32, attention=PAGED8,
                     cache_pages=16, prefix_cache=True)
    eng = ServingEngine(cfg, params, sc)
    a = [1, 2, 3, 4, 5, 6, 7, 8, 9, 10]      # one full page + tail
    b = a[:6] + [60, 61, 62, 63]             # diverges inside page 0
    ha = eng.submit(a)
    hb = eng.submit(b)                       # forks the partial match
    assert eng.prefix.cow_forks >= 1
    for _ in range(5):
        eng.step()
    for prompt, h in ((a, ha), (b, hb)):
        solo = ServingEngine(cfg, params, ServeConfig(
            batch_slots=2, max_len=32, attention=PAGED8, cache_pages=16))
        r = solo.submit(prompt)
        want = [solo.step()[r] for _ in range(5)]
        assert eng.request_out[h] == want, prompt
    eng.pool.check()


def test_prefix_preempt_resume_streams_identical(paged_setup):
    """Preempt/resume under prefix sharing + watermark eviction: every
    stream still equals its uninterrupted solo run, and the pool drains
    to exactly the cache-held pages (all reclaimable)."""
    cfg, params = paged_setup
    # 9-token prompts share page 0 → 3 pages admit both; decode growth to
    # max_len 24 needs 3 pages each (5 total shared) > the 4-page pool
    sc = ServeConfig(batch_slots=2, max_len=24, attention=PAGED8,
                     cache_pages=4, prefix_cache=True, prefix_watermark=1)
    eng = ServingEngine(cfg, params, sc)
    prompts = [[1, 2, 3, 4, 5, 6, 7, 8, 9], [1, 2, 3, 4, 5, 6, 7, 8, 11]]
    rids = [eng.submit(p) for p in prompts]
    assert all(r is not None for r in rids)
    for _ in range(80):
        eng.step()
        if not eng.slot_live.any() and not eng.wait:
            break
    assert eng.n_preemptions > 0             # pressure actually hit
    assert not eng.slot_live.any() and not eng.wait
    eng.pool.check()
    eng.prefix.check()
    # every non-free page is a cold cache entry, reclaimable on demand
    assert eng.pool.pages_in_use == eng.prefix.reclaimable()
    for rid, p in zip(rids, prompts):
        solo = ServingEngine(cfg, params, ServeConfig(
            batch_slots=2, max_len=24, attention=PAGED8, cache_pages=4))
        r = solo.submit(p)
        want = []
        while solo.slot_live.any():
            st = solo.step()
            if r in st:
                want.append(st[r])
        assert eng.request_out[rid] == want, (rid, p)


def test_prefix_watermark_restores_free_pages(paged_setup):
    """ServeConfig.prefix_watermark: step() evicts cold cached entries
    until that many pages are free — retired prefixes don't squat the
    pool below the floor."""
    cfg, params = paged_setup
    sc = ServeConfig(batch_slots=2, max_len=32, attention=PAGED8,
                     cache_pages=8, prefix_cache=True, prefix_watermark=7)
    eng = ServingEngine(cfg, params, sc)
    r = eng.submit(list(range(1, 18)))       # 17 tokens → 3 pages, 2 cached
    eng.cancel(r)                            # retire: cache refs remain
    assert eng.pool.free_pages == 6          # 2 cold cached pages squat
    eng.step()                               # watermark sweep runs
    assert eng.pool.free_pages >= 7
    assert eng.stats()["prefix_evictions"] >= 1


def test_prefix_cache_requires_paged_backend(paged_setup):
    cfg, params = paged_setup
    with pytest.raises(ValueError, match="prefix_cache"):
        ServingEngine(cfg, params, ServeConfig(
            batch_slots=2, max_len=32, prefix_cache=True))


def test_engine_stats_dict(paged_setup):
    """ServingEngine.stats() (satellite): one observability dict on both
    backends — counters the launcher prints and the sweep records."""
    cfg, params = paged_setup
    core = {"tick", "live_requests", "waiting_requests", "n_preemptions",
            "prefill_tokens", "decode_tokens"}
    eng = ServingEngine(cfg, params, ServeConfig(batch_slots=2, max_len=32))
    eng.submit([1, 2, 3])
    eng.step()
    st = eng.stats()
    assert core <= set(st)
    assert st["prefill_tokens"] == 3 and st["decode_tokens"] == 1
    assert st["live_requests"] == 1

    eng = ServingEngine(cfg, params, ServeConfig(
        batch_slots=2, max_len=32, attention=PAGED8, cache_pages=8,
        prefix_cache=True))
    eng.submit(list(range(1, 10)))
    eng.step()
    st = eng.stats()
    assert core <= set(st)
    assert {"pool_pages", "pool_free_pages", "pool_pages_in_use",
            "pool_high_water", "prefix_hits", "prefix_hit_rate"} <= set(st)
    assert st["pool_pages"] == 8
    assert st["pool_high_water"] >= st["pool_pages_in_use"] > 0


def test_paged_generate_does_not_accumulate_cache_lens(paged_setup):
    """Review regression: generate() never advances slot_pos, so the paged
    reset must zero cache lens unconditionally — otherwise kv_valid_len
    inflates past the block-table-backed range on every generate() call
    (stale-garbage keys under non-causal attention, dead block-skip under
    causal)."""
    cfg, params = paged_setup
    eng = ServingEngine(cfg, params, ServeConfig(
        batch_slots=2, max_len=32, attention=PAGED8))
    prompts = np.random.default_rng(6).integers(0, 64, (2, 4)).astype(np.int32)
    o1 = eng.generate(prompts, 4)
    lens1 = np.asarray(eng.caches["scan"]["len"]).copy()
    o2 = eng.generate(prompts, 4)
    lens2 = np.asarray(eng.caches["scan"]["len"])
    np.testing.assert_array_equal(lens1, lens2)      # no accumulation
    np.testing.assert_array_equal(lens2, 0)          # reset on completion
    np.testing.assert_array_equal(o1, o2)            # hence deterministic
    assert eng.pool.free_pages == eng.pool.n_pages


# ---------------------------------------------------------------------------
# Quantized int8 KV pages (ServeConfig.kv_dtype — docs/quant.md#kv-pages)
# ---------------------------------------------------------------------------

PAGED8_INT8 = dict(attention=PAGED8, kv_dtype="int8")


def test_kv_int8_streams_self_consistent_and_greedy_match(paged_setup):
    """The int8 engine's submit()/step() streams must equal its own
    batched generate() (shared write path, shared kernel), and — on this
    smoke model, where quantization noise stays under every argmax
    margin — the greedy streams also match the fp paged engine's."""
    cfg, params = paged_setup
    prompt = [3, 1, 4, 1, 5]
    eng = ServingEngine(cfg, params, ServeConfig(
        batch_slots=2, max_len=32, **PAGED8_INT8))
    h = eng.submit(prompt)
    stream = [eng.step()[h] for _ in range(6)]
    eng2 = ServingEngine(cfg, params, ServeConfig(
        batch_slots=2, max_len=32, **PAGED8_INT8))
    batch = np.asarray([prompt, prompt], np.int32)
    gen = eng2.generate(batch, 6)
    assert stream == list(np.asarray(gen)[0])
    fp = ServingEngine(cfg, params, ServeConfig(
        batch_slots=2, max_len=32, attention=PAGED8))
    np.testing.assert_array_equal(np.asarray(fp.generate(batch, 6)),
                                  np.asarray(gen))


def test_kv_int8_preempt_resume_stream_identical(paged_setup):
    """Preempt/resume exactness under the quantized pool: resume
    re-prefills in bulk what was written token-at-a-time before the
    preemption, so this passes ONLY because the frozen-first-row page
    scales make the int8 payload a pure function of logical content
    (tests/test_kv_quant.py proves that invariant bitwise)."""
    cfg, params = paged_setup
    sc = ServeConfig(batch_slots=2, max_len=16, cache_pages=2,
                     **PAGED8_INT8)
    eng = ServingEngine(cfg, params, sc)
    prompts = [[1, 2, 3], [4, 5, 6]]
    rids = [eng.submit(p) for p in prompts]
    assert all(r is not None for r in rids)
    for _ in range(60):
        eng.step()
        if not eng.slot_live.any() and not eng.wait:
            break
    assert eng.n_preemptions > 0                   # pressure actually hit
    assert not eng.slot_live.any() and not eng.wait
    eng.pool.check()
    assert eng.pool.free_pages == eng.pool.n_pages
    for rid, p in zip(rids, prompts):
        solo = ServingEngine(cfg, params, sc)
        r = solo.submit(p)
        want = []
        while solo.slot_live.any():
            st = solo.step()
            if r in st:
                want.append(st[r])
        assert eng.request_out[rid] == want, (rid, p)


def test_kv_int8_prefix_cow_streams_identical(paged_setup):
    """Prefix-cache COW over quantized pages: _copy_page must clone the
    int8 slabs AND the scale rows, so a fork diverging inside a cached
    page still matches its solo stream exactly."""
    cfg, params = paged_setup
    sc = ServeConfig(batch_slots=2, max_len=32, cache_pages=16,
                     prefix_cache=True, **PAGED8_INT8)
    eng = ServingEngine(cfg, params, sc)
    a = [1, 2, 3, 4, 5, 6, 7, 8, 9, 10]      # one full page + tail
    b = a[:6] + [60, 61, 62, 63]             # diverges inside page 0
    ha = eng.submit(a)
    hb = eng.submit(b)                       # forks the partial match
    assert eng.prefix.cow_forks >= 1
    for _ in range(5):
        eng.step()
    for prompt, h in ((a, ha), (b, hb)):
        solo = ServingEngine(cfg, params, ServeConfig(
            batch_slots=2, max_len=32, cache_pages=16, **PAGED8_INT8))
        r = solo.submit(prompt)
        want = [solo.step()[r] for _ in range(5)]
        assert eng.request_out[h] == want, prompt
    eng.pool.check()
    eng.prefix.check()


def test_kv_int8_stats_and_pool_bytes(paged_setup):
    """stats() reports the pool's byte economics; an int8 page must cost
    ≤ 1/1.8 of the bf16 page (2x payload minus the fp32 scale rows) —
    the per-page form of the ≥1.8x capacity gate benchmarks/
    serving_sweep.py::sweep_kv measures end to end."""
    cfg, params = paged_setup
    base = dict(batch_slots=2, max_len=32, cache_pages=8,
                cache_dtype="bfloat16")
    fp = ServingEngine(cfg, params, ServeConfig(**base, attention=PAGED8))
    q8 = ServingEngine(cfg, params, ServeConfig(**base, **PAGED8_INT8))
    st = q8.stats()
    assert st["kv_dtype"] == "int8"
    assert st["kv_page_bytes"] == q8.kv_page_bytes()
    assert st["kv_pool_bytes"] == 8 * st["kv_page_bytes"]
    assert fp.stats()["kv_dtype"] == "bfloat16"
    assert 1.8 * q8.kv_page_bytes() <= fp.kv_page_bytes()
    q8.submit([1, 2, 3])
    st = q8.stats()
    assert st["kv_bytes_in_use"] == \
        st["kv_page_bytes"] * st["pool_pages_in_use"] > 0
    # the pools really are int8 + fp32 scales
    scan = q8.caches["scan"]
    assert scan["kp"].dtype == jnp.int8 and scan["vp"].dtype == jnp.int8
    assert scan["k_scale"].dtype == jnp.float32


def test_kv_dtype_requires_paged_backend(paged_setup):
    """kv_dtype on a dense backend must refuse at construction — dense
    caches have no pages to hang scales off."""
    cfg, params = paged_setup
    with pytest.raises(ValueError, match="kv_dtype"):
        ServingEngine(cfg, params, ServeConfig(
            batch_slots=2, max_len=32, kv_dtype="int8"))
    with pytest.raises(ValueError, match="kv_dtype"):
        ServingEngine(cfg, params, ServeConfig(
            batch_slots=2, max_len=32, attention=FUSED8, kv_dtype="int8"))


def test_misbehaving_scheduler_victim_raises_descriptive_error(paged_setup):
    """Satellite bugfix: a Scheduler.victim subclass returning a rid that
    is not live used to surface as a bare StopIteration out of
    _grow_pages_for_decode's next(); it must raise a RuntimeError naming
    the offending rid and the live set."""
    from repro.serving.scheduler import Scheduler

    class Misbehaving(Scheduler):
        def victim(self, live):
            return 999_999               # no such request

    cfg, params = paged_setup
    sc = ServeConfig(batch_slots=2, max_len=16, attention=PAGED8,
                     cache_pages=2, scheduler=Misbehaving())
    eng = ServingEngine(cfg, params, sc)
    assert eng.submit([1, 2, 3]) is not None
    assert eng.submit([4, 5, 6]) is not None
    with pytest.raises(RuntimeError, match=r"999999.*not a live request"):
        for _ in range(30):              # decode until the pool runs dry
            eng.step()


def test_preempt_of_draining_slot_stream_identical(paged_setup):
    """Satellite audit: preempting a slot whose FINAL token is pending
    (slot_drain set, cache full) must still yield a token-identical
    stream after resume — the drain flag is recomputed on resume and the
    parked pending token is reported, not re-sampled."""
    cfg, params = paged_setup
    sc = ServeConfig(batch_slots=1, max_len=16, attention=PAGED8)
    solo = ServingEngine(cfg, params, sc)
    r = solo.submit([1, 2, 3])
    want = []
    while solo.slot_live.any():
        st = solo.step()
        if r in st:
            want.append(st[r])

    eng = ServingEngine(cfg, params, sc)
    r2 = eng.submit([1, 2, 3])
    got = []
    preempted = False
    for _ in range(60):
        if eng.slot_drain[0] and not preempted:
            eng._preempt(0)              # forced: drain slots are normally
            preempted = True             # spared (no page growth needed)
        st = eng.step()
        if r2 in st:
            got.append(st[r2])
        if not eng.slot_live.any() and not eng.wait:
            break
    assert preempted                     # the drain state was actually hit
    assert got == want
    eng.pool.check()
    assert eng.pool.free_pages == eng.pool.n_pages


def test_bursty_cancel_during_preempt_resume_leaks_no_pages(paged_setup):
    """Satellite: cancel() storms while requests bounce between slots and
    the wait queue (tight pool → constant preempt/resume) must return the
    pool to its baseline free count — no page leaks on any cancel path."""
    cfg, params = paged_setup
    sc = ServeConfig(batch_slots=2, max_len=16, attention=PAGED8,
                     cache_pages=3)
    eng = ServingEngine(cfg, params, sc)
    rng = np.random.default_rng(11)
    live_rids = []
    for i in range(40):
        if len(live_rids) < 4:
            r = eng.submit([int(t) for t in
                            rng.integers(0, 64, int(rng.integers(2, 9)))])
            if r is not None:
                live_rids.append(r)
        eng.step()
        if live_rids and i % 3 == 2:     # bursty cancels: live AND waiting
            burst = [live_rids.pop(rng.integers(len(live_rids)))
                     for _ in range(min(2, len(live_rids)))]
            for r in burst:
                eng.cancel(r)
        eng.pool.check()
    assert eng.n_preemptions > 0         # churn actually happened
    for r in live_rids:
        eng.cancel(r)
    for _ in range(40):                  # drain whatever remains
        if not eng.slot_live.any() and not eng.wait:
            break
        eng.step()
        for h in list(eng.request_out):
            eng.cancel(h)
    eng.pool.check()
    assert eng.pool.free_pages == eng.pool.n_pages
