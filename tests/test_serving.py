"""Serving engine: batched generate, continuous batching slots, greedy
determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models import transformer as T
from repro.serving.engine import ServeConfig, ServingEngine


@pytest.fixture(scope="module")
def engine():
    cfg = get_smoke_config("qwen2-1.5b", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    return ServingEngine(cfg, params, ServeConfig(batch_slots=4, max_len=64))


def test_generate_shapes(engine):
    prompts = np.random.default_rng(0).integers(0, 64, (4, 8)).astype(np.int32)
    out = engine.generate(prompts, n_tokens=5)
    assert out.shape == (4, 5)
    assert out.min() >= 0 and out.max() < 64


def test_generate_greedy_deterministic():
    cfg = get_smoke_config("qwen2-1.5b", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    prompts = np.random.default_rng(1).integers(0, 64, (4, 8)).astype(np.int32)
    e1 = ServingEngine(cfg, params, ServeConfig(batch_slots=4, max_len=64))
    e2 = ServingEngine(cfg, params, ServeConfig(batch_slots=4, max_len=64))
    np.testing.assert_array_equal(e1.generate(prompts, 6),
                                  e2.generate(prompts, 6))


def test_continuous_batching_slots():
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, ServeConfig(batch_slots=2, max_len=32))
    s0 = eng.submit([1, 2, 3])
    s1 = eng.submit([4, 5])
    assert {s0, s1} == {0, 1}
    assert eng.submit([9]) is None          # no free slot
    out = eng.step()
    assert set(out) == {0, 1}               # both slots decoded one token
    out2 = eng.step()
    assert set(out2) == {0, 1}


def test_packed_resident_weights_match_row_major():
    """ServeConfig(pack_weights=True) lays every projection weight out
    block-major once at engine build (the paper's Fig. 5 deployment shape);
    generation must match the row-major engine exactly under the same
    policy."""
    from repro.core.plan import GemmPolicy, PackedWeight
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    pol = GemmPolicy(backend="blockflow", mode="dm")
    prompts = np.random.default_rng(2).integers(0, 64, (2, 6)).astype(np.int32)
    e_row = ServingEngine(cfg, params, ServeConfig(
        batch_slots=2, max_len=32, gemm=pol))
    e_packed = ServingEngine(cfg, params, ServeConfig(
        batch_slots=2, max_len=32, gemm=pol, pack_weights=True))
    assert isinstance(e_packed.params["head"], PackedWeight)
    np.testing.assert_array_equal(e_row.generate(prompts, 4),
                                  e_packed.generate(prompts, 4))
