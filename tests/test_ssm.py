"""Mamba-2 SSD: chunked (dual/GEMM) form vs the sequential-scan oracle,
decode-step recurrence vs chunked prefill, and conv cache behavior."""
import jax
import jax.numpy as jnp
import numpy as np
from hypcompat import given, settings, st

from repro.configs.registry import get_smoke_config
from repro.kernels.ref import ssd_ref
from repro.models import ssm as SSM


def _ssd_inputs(key, B, S, H, P, N):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.normal(k1, (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(k2, (B, S, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(k3, (H,), jnp.float32) * 0.5)
    Bc = jax.random.normal(k4, (B, S, N), jnp.float32) * 0.5
    Cc = jax.random.normal(jax.random.fold_in(k4, 1), (B, S, N),
                           jnp.float32) * 0.5
    return x, dt, A, Bc, Cc


@settings(max_examples=8, deadline=None)
@given(S=st.sampled_from([4, 8, 16, 64]), chunk=st.sampled_from([4, 8, 16]))
def test_chunked_matches_sequential_scan(S, chunk):
    if S % chunk:
        chunk = S
    x, dt, A, Bc, Cc = _ssd_inputs(jax.random.PRNGKey(S * 31 + chunk),
                                   2, S, 3, 8, 16)
    y_ref = ssd_ref(x, dt, A, Bc, Cc)
    y, _ = SSM.ssd_chunked(x, dt, A, Bc, Cc, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)


def test_chunk_size_invariance():
    x, dt, A, Bc, Cc = _ssd_inputs(jax.random.PRNGKey(0), 1, 32, 2, 4, 8)
    y8, h8 = SSM.ssd_chunked(x, dt, A, Bc, Cc, chunk=8)
    y32, h32 = SSM.ssd_chunked(x, dt, A, Bc, Cc, chunk=32)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h8), np.asarray(h32),
                               atol=1e-4, rtol=1e-4)


def test_decode_step_continues_prefill_state():
    """Running S steps of decode recurrence == chunked prefill final state."""
    B, S, H, P, N = 1, 16, 2, 4, 8
    x, dt, A, Bc, Cc = _ssd_inputs(jax.random.PRNGKey(3), B, S, H, P, N)
    y_chunk, hT = SSM.ssd_chunked(x, dt, A, Bc, Cc, chunk=8)
    state = jnp.zeros((B, H, P, N), jnp.float32)
    ys = []
    for t in range(S):
        y_t, state = SSM.ssd_decode_step(
            x[:, t:t + 1], dt[:, t:t + 1], A, Bc[:, t:t + 1], Cc[:, t:t + 1],
            state)
        ys.append(y_t[:, 0])
    np.testing.assert_allclose(np.asarray(state), np.asarray(hT),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_chunk), atol=1e-4, rtol=1e-4)


def test_causal_conv_decode_matches_prefill():
    """Feeding tokens one at a time through the conv cache must reproduce the
    full-sequence causal conv."""
    key = jax.random.PRNGKey(1)
    B, S, C, K = 2, 10, 6, 4
    x = jax.random.normal(key, (B, S, C), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, C), jnp.float32)
    b = jnp.zeros((C,))
    y_full, _ = SSM._causal_conv(x, w, b)
    state = jnp.zeros((B, K - 1, C), jnp.float32)
    outs = []
    for t in range(S):
        y_t, state = SSM._causal_conv(x[:, t:t + 1], w, b, conv_state=state)
        outs.append(y_t[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(y_full), atol=1e-5, rtol=1e-5)


def test_segsum_decay_structure():
    a = jnp.asarray([[0.1, -0.2, 0.3, -0.4]])
    Lm = SSM._segsum_decay(a)[0]
    assert Lm.shape == (4, 4)
    np.testing.assert_allclose(np.asarray(jnp.diag(Lm)), np.ones(4),
                               atol=1e-6)  # no decay on the diagonal
    assert float(Lm[0, 1]) == 0.0          # strictly causal
    # L[2,1] = exp(a_2)
    np.testing.assert_allclose(float(Lm[2, 1]), float(jnp.exp(a[0, 2])),
                               rtol=1e-6)


def test_ssd_block_applies_gating_and_projections():
    cfg = get_smoke_config("mamba2-1.3b")
    key = jax.random.PRNGKey(0)
    p, _ = SSM.init_ssd(key, cfg, cfg.param_dtype)
    x = jax.random.normal(key, (2, 16, cfg.d_model), cfg.param_dtype)
    y, cache = SSM.ssd_block(p, cfg, x)
    assert y.shape == x.shape
    assert cache is None
    assert bool(jnp.isfinite(y.astype(jnp.float32)).all())
