"""Roofline analysis: HLO collective parsing + term arithmetic."""
import pytest

from repro.roofline import analysis as RA

HLO_SAMPLE = """
HloModule test
ENTRY main {
  %p0 = bf16[1024,512]{1,0} parameter(0)
  %ag = bf16[1024,8192]{1,0} all-gather(%p0), dimensions={1}
  %ar = f32[256]{0} all-reduce(%x), to_apply=%add
  %ar2.all-reduce.9 = f32[16,16]{1,0} all-reduce(%y), to_apply=%add
  %rs = bf16[64,64]{1,0} reduce-scatter(%z), dimensions={0}
  %cp = u32[10]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %a2a = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(%u, %v), dimensions={0}
  %not_a_coll = f32[4] add(%a, %b)
}
"""


def test_collective_parse_categories():
    out = RA.collective_bytes_from_hlo(HLO_SAMPLE)
    assert out["all-gather"] == 1024 * 8192 * 2
    assert out["all-reduce"] == 256 * 4 + 16 * 16 * 4
    assert out["reduce-scatter"] == 64 * 64 * 2
    assert out["collective-permute"] == 10 * 4
    assert out["all-to-all"] == 2 * 8 * 8 * 4
    assert out["total"] == sum(out[k] for k in
                               ("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute"))
    assert out["counts"]["all-reduce"] == 2


def test_collective_parse_ignores_names_containing_op_strings():
    """Instruction *names* like %fusion.all-reduce.clone must not count —
    only actual ops after '='."""
    hlo = "%x.all-reduce.clone = f32[8]{0} add(%a, %b)"
    out = RA.collective_bytes_from_hlo(hlo)
    assert out["total"] == 0


def test_collective_parse_start_variant():
    hlo = "%ag = bf16[128,128]{1,0} all-gather-start(%p)"
    out = RA.collective_bytes_from_hlo(hlo)
    assert out["all-gather"] == 128 * 128 * 2


def test_roofline_terms_bottleneck():
    hw = RA.HW()
    cost = {"flops": hw.peak_flops, "bytes accessed": hw.hbm_bw * 2}
    terms = RA.roofline_terms(cost, collective_bytes=hw.ici_bw * 0.5)
    assert terms["t_compute_s"] == pytest.approx(1.0)
    assert terms["t_memory_s"] == pytest.approx(2.0)
    assert terms["t_collective_s"] == pytest.approx(0.5)
    assert terms["bottleneck"] == "memory"
    assert terms["roofline_fraction"] == pytest.approx(0.5)


def test_roofline_useful_flops_ratio():
    terms = RA.roofline_terms({"flops": 100.0, "bytes accessed": 1.0},
                              0.0, model_flops=60.0)
    assert terms["useful_flops_ratio"] == pytest.approx(0.6)


def test_model_flops_estimate():
    assert RA.model_flops_estimate(1e9, 1e6, "train") == 6e15
    assert RA.model_flops_estimate(1e9, 1e6, "infer") == 2e15
