"""Property tests for the MatrixFlow block-major layouts (core/layout.py).

The paper's C1 data structure must be (a) invertible, (b) transfer-contiguous
(each block occupies one contiguous memory region), and (c) strictly cheaper
in DMA descriptors than the conventional row-major feed. Hypothesis sweeps
geometry; numpy asserts exact equality (layout transforms are pure moves).
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.core import layout as L

dims = st.integers(min_value=1, max_value=300)
blocks = st.sampled_from([8, 16, 32, 128, 256])


@settings(max_examples=30, deadline=None)
@given(m=dims, k=dims, bm=blocks, bk=blocks)
def test_block_major_a_roundtrip(m, k, bm, bk):
    a = np.arange(m * k, dtype=np.float32).reshape(m, k)
    a_bm = L.to_block_major_a(jnp.asarray(a), bm, bk)
    back = L.from_block_major_a(a_bm, m, k)
    np.testing.assert_array_equal(np.asarray(back), a)


@settings(max_examples=30, deadline=None)
@given(k=dims, n=dims, bk=blocks, bn=blocks)
def test_block_major_b_roundtrip(k, n, bk, bn):
    b = np.arange(k * n, dtype=np.float32).reshape(k, n)
    b_bm = L.to_block_major_b(jnp.asarray(b), bk, bn)
    back = L.from_block_major_b(b_bm, k, n)
    np.testing.assert_array_equal(np.asarray(back), b)


@settings(max_examples=30, deadline=None)
@given(m=dims, n=dims, bm=blocks, bn=blocks)
def test_block_major_c_roundtrip(m, n, bm, bn):
    c = np.arange(m * n, dtype=np.float32).reshape(m, n)
    c_bm = L.to_block_major_c(jnp.asarray(c), bm, bn)
    back = L.from_block_major_c(c_bm, m, n)
    np.testing.assert_array_equal(np.asarray(back), c)


@settings(max_examples=30, deadline=None)
@given(m=dims, n=dims, k=dims, bm=blocks, bn=blocks, bk=blocks)
def test_block_major_roundtrip_non_divisible(m, n, k, bm, bn, bk):
    """Round-trips on shapes forced to NOT divide the block dims: the
    zero-padding the transforms add must be exactly invisible after the
    inverse (the ragged/odd geometries every kernel padding path relies on).
    """
    m, n, k = m + (0 if m % bm else 1), n + (0 if n % bn else 1), \
        k + (0 if k % bk else 1)
    assert m % bm and n % bn and k % bk
    a = np.arange(m * k, dtype=np.float32).reshape(m, k)
    b = np.arange(k * n, dtype=np.float32).reshape(k, n)
    c = np.arange(m * n, dtype=np.float32).reshape(m, n)
    a_bm = L.to_block_major_a(jnp.asarray(a), bm, bk)
    b_bm = L.to_block_major_b(jnp.asarray(b), bk, bn)
    c_bm = L.to_block_major_c(jnp.asarray(c), bm, bn)
    # padded to full blocks ...
    assert a_bm.shape == (L.cdiv(m, bm), L.cdiv(k, bk), bm, bk)
    assert b_bm.shape == (L.cdiv(n, bn), L.cdiv(k, bk), bk, bn)
    # ... and exactly invertible
    np.testing.assert_array_equal(np.asarray(L.from_block_major_a(a_bm, m, k)), a)
    np.testing.assert_array_equal(np.asarray(L.from_block_major_b(b_bm, k, n)), b)
    np.testing.assert_array_equal(np.asarray(L.from_block_major_c(c_bm, m, n)), c)


def test_block_content_matches_slice():
    """A_bm[i,k] must equal the (i,k) block slice of A — the block a kernel
    tile consumes is exactly the paper's page-aligned rectangle."""
    m, k, bm, bk = 64, 96, 16, 32
    a = np.arange(m * k, dtype=np.int32).reshape(m, k)
    a_bm = np.asarray(L.to_block_major_a(jnp.asarray(a), bm, bk))
    for i in range(m // bm):
        for kk in range(k // bk):
            np.testing.assert_array_equal(
                a_bm[i, kk], a[i * bm:(i + 1) * bm, kk * bk:(kk + 1) * bk])


def test_block_major_b_horizontal_split():
    """B_bm[j, k] == B[k-block rows, j-block cols] — Fig. 4's horizontal
    restructuring: walking K for fixed output column j is the leading-minor
    walk of B_bm[j], i.e. contiguous."""
    k, n, bk, bn = 64, 48, 16, 16
    b = np.arange(k * n, dtype=np.int32).reshape(k, n)
    b_bm = np.asarray(L.to_block_major_b(jnp.asarray(b), bk, bn))
    for j in range(n // bn):
        for kk in range(k // bk):
            np.testing.assert_array_equal(
                b_bm[j, kk], b[kk * bk:(kk + 1) * bk, j * bn:(j + 1) * bn])


def test_blocks_are_memory_contiguous():
    """The last two axes of the block-major array are minor → each block is
    one contiguous strides region (the one-DMA-descriptor property)."""
    a = jnp.zeros((128, 256), jnp.float32)
    a_bm = np.asarray(L.to_block_major_a(a, 32, 64))
    blk = a_bm[1, 2]
    assert blk.flags["C_CONTIGUOUS"]
    # one block's bytes span exactly bm*bk*itemsize of the parent buffer
    assert blk.nbytes == 32 * 64 * 4


@settings(max_examples=50, deadline=None)
@given(m=dims, n=dims, k=dims,
       mode=st.sampled_from(["dc", "dm"]),
       dtype=st.sampled_from(["int8", "bfloat16", "float32"]))
def test_choose_layout_fits_and_aligns(m, n, k, mode, dtype):
    blk = L.choose_layout(m, n, k, jnp.dtype(dtype), mode=mode)
    itemsize = jnp.dtype(dtype).itemsize
    assert blk.vmem_bytes(itemsize) <= 96 * 1024 * 1024
    assert blk.bm % L.SUBLANE == 0 or blk.bm == m
    assert blk.bn % L.MXU_DIM == 0 or blk.bn >= n
    assert blk.bk % L.MXU_DIM == 0 or blk.bk >= k
    g = blk.grid(m, n, k)
    assert all(x >= 1 for x in g)


def test_page_block_shape_is_one_page():
    for dt in (jnp.int8, jnp.bfloat16, jnp.float32):
        rows, lanes = L.page_block_shape(dt)
        assert rows * lanes * jnp.dtype(dt).itemsize == L.PAGE_BYTES


def test_descriptor_counts_favor_matrixflow():
    """Paper Fig. 4: conventional row-major block fetch needs ≥rows
    descriptors (one per row segment, more when rows cross pages);
    MatrixFlow needs ceil(block_bytes/page) — strictly fewer for any
    multi-row block."""
    rows, cols, itemsize = 32, 128, 1            # an int8 32×128 page block
    row_stride = 4096 * itemsize                 # K=4096 row-major parent
    conv = L.descriptors_per_block_conventional(rows, cols, row_stride,
                                                itemsize)
    mf = L.descriptors_per_block_matrixflow(rows, cols, itemsize)
    assert mf == 1                               # exactly one page
    assert conv >= rows                          # ≥ one per row
    assert conv / mf >= 16


def test_dc_mode_finer_than_dm():
    dc = L.choose_layout(2048, 2048, 2048, jnp.bfloat16, mode="dc")
    dm = L.choose_layout(2048, 2048, 2048, jnp.bfloat16, mode="dm")
    assert dc.bk <= dm.bk


@pytest.mark.parametrize("m", [1, 3, 7, 9, 127, 129, 511, 513, 515, 1021,
                               4097])
def test_choose_layout_bm_cap_odd_m(m):
    """Regression: the old bm selection
    ``min(round_up(M, SUBLANE), 512 if M >= 512 else round_up(M, SUBLANE))``
    collapsed to a no-op branch. bm must be the sublane-aligned M, capped at
    512, for every M — including odd / just-past-the-cap sizes."""
    blk = L.choose_layout(m, 256, 256, jnp.float32)
    assert blk.bm == min(L.round_up(m, L.SUBLANE), 512)
    assert blk.bm % L.SUBLANE == 0
    assert blk.bm <= 512
    # the grid still covers all M rows
    assert blk.grid(m, 256, 256)[0] * blk.bm >= m
