"""Observability (repro/obs): metrics registry, Perfetto tracing,
per-request lifecycle records, null-mode zero-cost, engine integration."""
import json

import jax
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.core.plan import AttentionPolicy
from repro.models import transformer as T
from repro.obs import (
    NULL_OBS,
    PHASE_TRACKS,
    Histogram,
    Metrics,
    Observability,
    Timer,
    TraceRecorder,
    aggregate_request_traces,
    merge_histograms,
    quantile,
    validate_metrics_snapshot,
    validate_trace,
)
from repro.serving.engine import ServeConfig, ServingEngine

PAGED8 = AttentionPolicy(backend="paged_interpret", page_size=8, block_q=8)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("smollm-135m", n_layers=2, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


# -- metrics ----------------------------------------------------------------

def test_counter_gauge_basics():
    m = Metrics()
    c = m.counter("reqs_total", kind="fresh")
    c.inc()
    c.inc(3)
    assert c.value == 4
    # memoized: same (name, labels) → same instrument
    assert m.counter("reqs_total", kind="fresh") is c
    assert m.counter("reqs_total", kind="resume") is not c
    g = m.gauge("pool_free")
    g.set(7)
    g.set_max(3)          # set_max never lowers
    assert g.value == 7
    g.set_max(11)
    assert g.value == 11


def test_metric_kind_conflict_raises():
    m = Metrics()
    m.counter("x_total")
    with pytest.raises(ValueError):
        m.gauge("x_total")


def test_histogram_quantile_and_exact_quantile():
    h = Histogram("lat_s", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0):
        h.observe(v)
    assert h.count == 4
    assert h.mean() == pytest.approx((0.05 + 0.5 + 0.5 + 5.0) / 4)
    assert 0.0 <= h.quantile(0.5) <= 1.0     # inside the 0.1–1.0 bucket
    # exact quantile over raw samples (the SLO path)
    assert quantile([1.0, 2.0, 3.0, 4.0], 0.5) == pytest.approx(2.5)
    assert quantile([1.0], 0.99) == 1.0


def test_histogram_merge_associative():
    buckets = (0.01, 0.1, 1.0)
    rng = np.random.default_rng(0)
    hs = []
    for _ in range(3):
        h = Histogram("t_s", buckets=buckets)
        for v in rng.exponential(0.1, 50):
            h.observe(float(v))
        hs.append(h)
    a, b, c = hs
    left = a.merge(b).merge(c).snapshot()
    right = a.merge(b.merge(c)).snapshot()
    assert left == right
    assert merge_histograms(hs).snapshot() == left
    # operands untouched
    assert a.count == 50


def test_histogram_merge_bucket_mismatch_raises():
    with pytest.raises(ValueError):
        Histogram("a", buckets=(1.0,)).merge(Histogram("a", buckets=(2.0,)))


def test_metrics_snapshot_schema_and_roundtrip():
    m = Metrics()
    m.counter("hits_total", cache="prefix").inc(2)
    m.gauge("free_pages").set(5)
    m.histogram("step_s").observe(0.01)
    snap = m.snapshot()
    assert validate_metrics_snapshot(snap) == []
    assert snap == json.loads(json.dumps(snap))
    assert snap["counters"]["hits_total{cache=prefix}"] == 2
    assert snap["gauges"]["free_pages"] == 5
    assert snap["histograms"]["step_s"]["count"] == 1


def test_timer():
    with Timer() as tm:
        sum(range(1000))
    assert tm.dt > 0.0
    assert tm.ms == pytest.approx(tm.dt * 1e3)
    h = Histogram("t_s")
    with Timer(h):
        pass
    assert h.count == 1


# -- tracing ----------------------------------------------------------------

def test_trace_export_valid_and_balanced():
    tr = TraceRecorder()
    t0 = tr.epoch
    tr.complete("decode-step", "decode x2", t0, t0 + 0.001,
                args={"slots": 2})
    tr.instant("evict", "evict 3p")
    tr.async_begin(7, {"prompt_len": 4})
    tr.async_instant(7, "first-token")
    tr.async_end(7, {"n_tokens": 5})
    doc = tr.export()
    assert validate_trace(doc) == []
    evs = doc["traceEvents"]
    # one metadata thread row per phase track, in PHASE_TRACKS order
    names = [e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"]
    assert list(PHASE_TRACKS) == names[:len(PHASE_TRACKS)]
    b = [e for e in evs if e["ph"] == "b"]
    e_ = [e for e in evs if e["ph"] == "e"]
    assert len(b) == len(e_) == 1
    assert b[0]["id"] == e_[0]["id"] == "7"
    assert b[0]["cat"] == "request"


def test_trace_auto_closes_open_async_spans():
    tr = TraceRecorder()
    tr.async_begin(3)
    tr.async_instant(3, "first-token")
    doc = tr.export()                      # request still in flight
    assert validate_trace(doc) == []       # exporter balanced it
    closes = [e for e in doc["traceEvents"] if e["ph"] == "e"]
    assert len(closes) == 1
    assert closes[0]["args"]["truncated"] is True


def test_trace_ring_drops_oldest():
    tr = TraceRecorder(capacity=4)
    for i in range(10):
        tr.instant("admit", f"ev{i}")
    assert len(tr) == 4
    assert tr.dropped == 6
    assert validate_trace(tr.export()) == []


def test_validate_trace_catches_imbalance():
    bad = {"traceEvents": [
        {"ph": "b", "cat": "request", "id": "1", "name": "req 1",
         "pid": 1, "ts": 0.0}]}
    assert validate_trace(bad) != []


# -- null mode --------------------------------------------------------------

def test_null_obs_records_nothing(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, ServeConfig(
        batch_slots=2, max_len=32, attention=PAGED8))   # default NULL_OBS
    assert eng.obs is NULL_OBS
    eng.submit([1, 2, 3])
    for _ in range(4):
        eng.step()
    assert len(eng.obs.trace) == 0
    snap = eng.obs.metrics.snapshot()
    assert all(v == {} for v in snap.values())   # no series registered
    assert eng.request_traces == {}        # no per-request allocation
    # null instruments and recorder are shared no-op singletons
    assert eng.obs.metrics.counter("a") is eng.obs.metrics.gauge("b")
    assert eng.obs.trace.export()["traceEvents"] == []


# -- engine integration -----------------------------------------------------

def test_request_trace_token_exact_across_preempt_resume(setup):
    """The tentpole contract: a preempted+resumed request's trace holds
    exactly the tokens the engine reported — and records the preemption —
    while the trace export stays schema-valid."""
    cfg, params = setup
    sc = ServeConfig(batch_slots=2, max_len=16, attention=PAGED8,
                     cache_pages=2,        # half the padded need → pressure
                     obs=Observability())
    eng = ServingEngine(cfg, params, sc)
    prompts = [[1, 2, 3], [4, 5, 6]]
    rids = [eng.submit(p) for p in prompts]
    streams = {r: [] for r in rids}
    for _ in range(60):
        for h, t in eng.step().items():
            streams[h].append(t)
        if not eng.slot_live.any() and not eng.wait:
            break
    assert eng.n_preemptions > 0
    preempted = 0
    for r in rids:
        rt = eng.request_trace(r)
        assert rt is not None
        assert rt.tokens == streams[r]                 # token-exact
        assert rt.ttft_s() is not None and rt.ttft_s() > 0
        assert rt.retire_s is not None
        assert rt.prompt_len == 3
        assert rt.itl.count == len(rt.tokens) - 1
        assert len(rt.itl_list()) == len(rt.tokens) - 1
        assert rt.pages_timeline                       # pages were tracked
        preempted += rt.n_preemptions
        assert json.loads(json.dumps(rt.to_json())) == rt.to_json()
    assert preempted == eng.n_preemptions
    agg = aggregate_request_traces(
        [eng.request_trace(r) for r in rids])
    assert agg["n_requests"] == 2
    assert agg["total_tokens"] == sum(len(s) for s in streams.values())
    assert agg["preemptions"] == eng.n_preemptions
    assert agg["ttft_s"]["p50"] is not None
    # the trace document is Perfetto-valid with the preempt track populated
    doc = sc.obs.trace.export()
    assert validate_trace(doc) == []
    tracks = {e.get("args", {}).get("name")
              for e in doc["traceEvents"] if e["ph"] == "M"}
    assert "preempt" in tracks and "resume" in tracks
    assert validate_metrics_snapshot(sc.obs.metrics.snapshot()) == []
    snap = sc.obs.metrics.snapshot()
    assert snap["counters"]["engine_preemptions_total"] == eng.n_preemptions


def test_engine_metrics_match_stats(setup):
    """Registry counters must agree with the engine's own stats() ints."""
    cfg, params = setup
    obs = Observability()
    eng = ServingEngine(cfg, params, ServeConfig(
        batch_slots=2, max_len=32, attention=PAGED8, prefix_cache=True,
        obs=obs))
    eng.submit(list(range(1, 10)))
    eng.submit(list(range(1, 10)))      # same prompt → prefix hit
    for _ in range(3):
        eng.step()
    st = eng.stats()
    snap = obs.metrics.snapshot()
    c = snap["counters"]
    assert c["engine_tokens_total{stage=prefill}"] == st["prefill_tokens"]
    assert c["engine_tokens_total{stage=decode}"] == st["decode_tokens"]
    assert c["prefix_hits_total"] == st["prefix_hits"]
    assert c["prefix_hit_tokens_total"] == st["prefix_hit_tokens"]
    assert snap["gauges"]["pool_pages_in_use"] == st["pool_pages_in_use"]
    assert snap["gauges"]["pool_high_water_pages"] == st["pool_high_water"]
    assert snap["histograms"]["engine_prefill_chunk_s"]["count"] >= 1
    assert snap["histograms"]["engine_decode_step_s"]["count"] >= 1
    # prefix hit recorded on the request's own trace too
    rts = sorted(eng.request_traces.values(), key=lambda t: t.rid)
    assert rts[1].prefix_hit_tokens > 0


def test_stats_json_roundtrip_both_backends(setup):
    """Satellite: stats() returns plain JSON types on both backends —
    json.dumps round-trips and the key schema is pinned."""
    cfg, params = setup
    core = {"tick", "live_requests", "waiting_requests", "n_preemptions",
            "prefill_tokens", "decode_tokens"}
    paged_keys = core | {
        "pool_pages", "pool_free_pages", "pool_pages_in_use",
        "pool_high_water", "kv_dtype", "kv_page_bytes", "kv_pool_bytes",
        "kv_bytes_in_use", "prefix_hits", "prefix_misses",
        "prefix_evictions", "prefix_cow_forks", "prefix_cached_pages",
        "prefix_hit_tokens", "prefix_lookup_tokens", "prefix_hit_rate"}
    for sc, want in ((ServeConfig(batch_slots=2, max_len=32), core),
                     (ServeConfig(batch_slots=2, max_len=32,
                                  attention=PAGED8, prefix_cache=True),
                      paged_keys)):
        eng = ServingEngine(cfg, params, sc)
        eng.submit([1, 2, 3])
        eng.step()
        st = eng.stats()
        assert want <= set(st)
        assert json.loads(json.dumps(st)) == st
        for k, v in st.items():
            assert type(v) in (int, float, str, bool, type(None)), (k, v)


def test_frontend_slo_report(setup):
    import asyncio

    from repro.serving.frontend import AsyncServingEngine
    cfg, params = setup
    eng = ServingEngine(cfg, params, ServeConfig(
        batch_slots=2, max_len=32, attention=PAGED8, obs=Observability()))
    aeng = AsyncServingEngine(eng)

    async def demo():
        return await asyncio.gather(
            aeng.complete([1, 2, 3], 4),
            aeng.complete([4, 5], 4, deadline=0.0))   # already-past deadline

    outs = asyncio.run(demo())
    assert all(len(o) == 4 for o in outs)
    rep = aeng.slo_report()
    assert rep["n_completed"] == 2
    assert rep["n_first_tokens"] == 2
    assert rep["ttft_s"]["p50"] is not None
    assert rep["itl_s"]["p95"] is not None
    assert rep["deadline_misses"] == 1
    assert json.loads(json.dumps(rep)) == rep


def test_instrumented_engine_trace_lint_clean(setup):
    """All telemetry must stay host-side of the jit boundary: the jaxpr
    lint over the instrumented engine's prefill/decode finds nothing."""
    from repro.analysis.trace_lint import lint_engine
    cfg, params = setup
    eng = ServingEngine(cfg, params, ServeConfig(
        batch_slots=2, max_len=32, obs=Observability()))
    assert lint_engine(eng) == []


def test_cancel_live_slot_emits_cancelled_end(setup):
    """Satellite bugfix: cancel() of a LIVE request must close its async
    span with {"cancelled": true} and move the cancelled counter, not the
    retired one — previously only the wait-queue branch did, so a live
    cancel was indistinguishable from a natural completion in traces and
    slo_report()."""
    cfg, params = setup
    for sc in (ServeConfig(batch_slots=2, max_len=32,
                           obs=Observability()),
               ServeConfig(batch_slots=2, max_len=32, attention=PAGED8,
                           obs=Observability())):
        eng = ServingEngine(cfg, params, sc)
        h = eng.submit([1, 2, 3])
        eng.step()
        eng.step()
        assert eng.cancel(h) is True
        c = sc.obs.metrics.snapshot()["counters"]
        assert c["engine_cancelled_total"] == 1
        assert c.get("engine_retired_total", 0) == 0
        ends = [e for e in sc.obs.trace.export()["traceEvents"]
                if e["ph"] == "e" and e["id"] == str(h)]
        assert len(ends) == 1
        assert ends[0]["args"]["cancelled"] is True
        assert ends[0]["args"]["n_tokens"] == 2


def test_cancel_waiting_request_still_counts_cancelled(setup):
    """The wait-queue cancel branch moves the same counter as the
    live-slot branch — one counter, both abort paths."""
    cfg, params = setup
    obs = Observability()
    eng = ServingEngine(cfg, params, ServeConfig(
        batch_slots=2, max_len=16, attention=PAGED8, cache_pages=2,
        obs=obs))
    r0 = eng.submit([1, 2, 3])
    assert r0 is not None
    for _ in range(12):                   # decode until r0 gets preempted
        eng.step()
        if any(w.rid == r0 for w in eng.wait):
            break
    # force the queue case if pressure alone didn't park it
    if not any(w.rid == r0 for w in eng.wait):
        s = next(s for s in range(2) if eng.slot_live[s]
                 and int(eng.slot_rid[s]) == r0)
        eng._preempt(s)
    assert eng.cancel(r0) is True
    c = obs.metrics.snapshot()["counters"]
    assert c["engine_cancelled_total"] == 1
    assert c.get("engine_retired_total", 0) == 0


def test_spec_metrics_and_phase_spans(setup):
    """Speculative decoding telemetry: accepted/rejected counters match
    stats(), the acceptance histogram fills, and draft/verify spans land
    on their own phase tracks."""
    from repro.serving.spec_decode import NGramDrafter
    cfg, params = setup
    obs = Observability()
    eng = ServingEngine(cfg, params, ServeConfig(
        batch_slots=2, max_len=48, attention=PAGED8,
        spec=NGramDrafter(k=4), obs=obs))
    eng.submit([7, 7, 7, 7, 7, 7])
    eng.submit([1, 2, 3, 1, 2, 3])
    for _ in range(10):
        eng.step()
    st = eng.stats()
    assert st["spec_accepted_tokens"] + st["spec_rejected_tokens"] > 0
    snap = obs.metrics.snapshot()
    c = snap["counters"]
    assert c["spec_tokens_total{verdict=accepted}"] == \
        st["spec_accepted_tokens"]
    assert c["spec_tokens_total{verdict=rejected}"] == \
        st["spec_rejected_tokens"]
    assert c.get("spec_rollback_pages_total", 0) == \
        st["spec_rollback_pages"]
    assert snap["histograms"]["spec_acceptance_rate"]["count"] >= 1
    doc = obs.trace.export()
    assert validate_trace(doc) == []
    tracks = {e["args"]["name"] for e in doc["traceEvents"]
              if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"draft", "verify"} <= tracks
    spans = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert any(n.startswith("draft x") for n in spans)
    assert any(n.startswith("verify x") for n in spans)
