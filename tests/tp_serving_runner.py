"""Subprocess body for tests/test_tp_serving.py: TP stream equivalence.

Runs on a *forced* multi-device host (the parent sets
``XLA_FLAGS=--xla_force_host_platform_device_count=4``; conftest.py must
stay 1-device, hence the subprocess isolation) and proves the golden
stream-equivalence gate: a TP=2 tensor-parallel paged serving engine
(ServeConfig.mesh over a (data, model) host mesh — repro/distributed/tp.py)
produces token streams **identical** to the single-device engine on the
same prompts:

  * batched greedy generate();
  * continuous-batching submit()/step() greedy streams;
  * seeded-temperature sampling (same PRNG keys both sides);
  * a forced preempt/resume cycle (a pool too small for both requests —
    the TP engine must preempt, resume, and still match the single-device
    engine, whose host-side scheduling is identical by construction).

The model is an fp32 smoke config with the TP-relevant head shapes
(GQA H=4, Hkv=2 → both shard at TP=2) and the kv_heads override cleared so
the KV pool actually splits. fp32 keeps the only TP-vs-1-device numeric
difference — the row-parallel psum's fp32 summation order — at ~1e-7
relative, far below any argmax/sampling decision boundary.

Exit 0 + "TP-EQUIV PASS <scenario>" markers on success; nonzero with a
traceback on the first divergence.
"""
import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

import jax                                                    # noqa: E402
import numpy as np                                            # noqa: E402

from repro.configs.registry import get_smoke_config           # noqa: E402
from repro.core.plan import AttentionPolicy                   # noqa: E402
from repro.launch.mesh import make_host_mesh                  # noqa: E402
from repro.models import transformer as T                     # noqa: E402
from repro.serving.engine import ServeConfig, ServingEngine   # noqa: E402

PAGED = AttentionPolicy(backend="paged_interpret", page_size=8, block_q=8)


def build():
    assert len(jax.devices()) >= 2, (
        "runner needs the forced multi-device host; run it via "
        "tests/test_tp_serving.py or set XLA_FLAGS="
        "--xla_force_host_platform_device_count=4")
    # qwen3 smoke = GQA (H=4, Hkv=2) + qk_norm; clear the kv_heads override
    # so TP=2 shards the KV pool (the per-shard paged-cache path), and run
    # fp32 so psum reordering stays under sampling decision noise.
    cfg = get_smoke_config("qwen3-8b", n_layers=2, vocab=64,
                           sharding_overrides=(), dtype="float32")
    params, axes = T.init_model(jax.random.PRNGKey(0), cfg)
    mesh = make_host_mesh(model=2)
    return cfg, params, axes, mesh


def engines(cfg, params, axes, mesh, **sc_kw):
    sc = dict(batch_slots=2, max_len=32, attention=PAGED,
              cache_dtype="float32")
    sc.update(sc_kw)
    base = ServingEngine(cfg, params, ServeConfig(**sc))
    tp = ServingEngine(cfg, params, ServeConfig(**sc, mesh=mesh), axes=axes)
    assert tp.tp is not None and tp.tp.model_size == 2
    assert tp.kv_shards() == 2, "KV pool must actually split at TP=2"
    return base, tp


def scenario_greedy(cfg, params, axes, mesh):
    base, tp = engines(cfg, params, axes, mesh)
    prompts = np.random.default_rng(7).integers(0, 64, (2, 6)).astype(np.int32)
    want = base.generate(prompts, 8)
    got = tp.generate(prompts, 8)
    np.testing.assert_array_equal(got, want)

    base, tp = engines(cfg, params, axes, mesh)
    for eng in (base, tp):
        assert eng.submit([3, 1, 4, 1, 5]) is not None
        assert eng.submit([9, 2, 6]) is not None
    for _ in range(6):
        sb, st = base.step(), tp.step()
        assert sb == st, (sb, st)
    print("TP-EQUIV PASS greedy")


def scenario_temperature(cfg, params, axes, mesh):
    base, tp = engines(cfg, params, axes, mesh, temperature=0.8)
    prompts = np.random.default_rng(11).integers(0, 64, (2, 5)).astype(np.int32)
    want = base.generate(prompts, 8, key=jax.random.PRNGKey(42))
    got = tp.generate(prompts, 8, key=jax.random.PRNGKey(42))
    np.testing.assert_array_equal(got, want)

    base, tp = engines(cfg, params, axes, mesh, temperature=0.8)
    for eng in (base, tp):
        assert eng.submit([5, 4, 3], key=jax.random.PRNGKey(1)) is not None
    for i in range(6):
        k = jax.random.PRNGKey(100 + i)
        sb, st = base.step(key=k), tp.step(key=k)
        assert sb == st, (i, sb, st)
    print("TP-EQUIV PASS temperature")


def scenario_preempt(cfg, params, axes, mesh):
    # 2 pages of 8 tokens = half of 2 slots x max_len 16: decode growth
    # must exhaust the pool and preempt. Both engines share the host-side
    # scheduler, so the preempt/resume choreography — and hence the
    # streams — must match exactly.
    base, tp = engines(cfg, params, axes, mesh, max_len=16, cache_pages=2)
    prompts = [[1, 2, 3], [4, 5, 6]]
    rb = [base.submit(p) for p in prompts]
    rt = [tp.submit(p) for p in prompts]
    assert all(r is not None for r in rb + rt)
    for _ in range(80):
        base.step()
        tp.step()
        if (not base.slot_live.any() and not base.wait
                and not tp.slot_live.any() and not tp.wait):
            break
    assert tp.n_preemptions > 0, "pool pressure never hit — dead scenario"
    assert tp.n_preemptions == base.n_preemptions
    for hb, ht, p in zip(rb, rt, prompts):
        assert base.request_out[hb] == tp.request_out[ht], \
            (p, base.request_out[hb], tp.request_out[ht])
    tp.pool.check()
    assert tp.pool.free_pages == tp.pool.n_pages
    print("TP-EQUIV PASS preempt-resume")


def scenario_prefix(cfg, params, axes, mesh):
    # Prefix cache + COW under TP: one host-side cache drives every
    # shard's identical page slice, so the TP engine must stay in
    # LOCKSTEP with the single-device engine — same streams, same page
    # accounting, same hit/fork counters. The second prompt shares a full
    # page; the third diverges inside it (exercises the sharded-page
    # device copy in _copy_page: page axis is unsharded, head axis is).
    base, tp = engines(cfg, params, axes, mesh, batch_slots=3,
                       prefix_cache=True, cache_pages=12)
    shared = list(range(1, 12))              # 11 tokens: 1 full page + tail
    prompts = [shared + [40, 41], shared + [50, 51],
               shared[:5] + [60, 61, 62, 63]]
    for eng in (base, tp):
        for p in prompts:
            assert eng.submit(p) is not None
    assert tp.prefix.hits == base.prefix.hits > 0
    assert tp.prefix.cow_forks == base.prefix.cow_forks >= 1
    for _ in range(6):
        sb, st = base.step(), tp.step()
        assert sb == st, (sb, st)
        # lockstep page accounting: the TP pool mirrors the base pool
        assert tp.pool.free_pages == base.pool.free_pages
        assert tp.pool.pages_in_use == base.pool.pages_in_use
    assert tp.prefix.stats() == base.prefix.stats()
    tp.pool.check()
    tp.prefix.check()
    print("TP-EQUIV PASS prefix")


def scenario_kv_int8(cfg, params, axes, mesh):
    # Quantized KV pages under TP: the int8 pools shard on Hkv (axis -2)
    # and their (P, Hkv) scale side-tensors on Hkv (axis -1), so each
    # shard quantizes/dequantizes its own heads with its own scales.
    # Streams must match the single-device int8 engine token for token,
    # including across a forced preempt/resume cycle.
    base, tp = engines(cfg, params, axes, mesh, kv_dtype="int8")
    prompts = np.random.default_rng(13).integers(0, 64, (2, 6)).astype(np.int32)
    np.testing.assert_array_equal(tp.generate(prompts, 8),
                                  base.generate(prompts, 8))

    base, tp = engines(cfg, params, axes, mesh, kv_dtype="int8",
                       max_len=16, cache_pages=2)
    for eng in (base, tp):
        assert eng.submit([1, 2, 3]) is not None
        assert eng.submit([4, 5, 6]) is not None
    for _ in range(80):
        base.step()
        tp.step()
        if (not base.slot_live.any() and not base.wait
                and not tp.slot_live.any() and not tp.wait):
            break
    assert tp.n_preemptions > 0, "pool pressure never hit — dead scenario"
    assert tp.n_preemptions == base.n_preemptions
    assert base.request_out == tp.request_out
    tp.pool.check()
    print("TP-EQUIV PASS kv-int8")


SCENARIOS = {"greedy": scenario_greedy, "temperature": scenario_temperature,
             "preempt": scenario_preempt, "prefix": scenario_prefix,
             "kv-int8": scenario_kv_int8}


def main(argv=None):
    argv = argv if argv is not None else sys.argv[1:]
    picks = argv or list(SCENARIOS)
    cfg, params, axes, mesh = build()
    for name in picks:
        SCENARIOS[name](cfg, params, axes, mesh)
    print(f"TP-EQUIV PASS all ({', '.join(picks)})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
