"""Layer zoo unit tests: norms, RoPE, GQA/MLA attention vs reference,
MoE dispatch invariants."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.kernels.ref import mha_ref
from repro.models import layers as Lyr
from repro.models.config import ModelConfig

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def test_rmsnorm_unit_scale():
    x = jax.random.normal(KEY, (2, 8, 16), jnp.float32) * 5
    p = {"scale": jnp.ones((16,))}
    y = Lyr.rmsnorm(p, x)
    rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, atol=1e-3)


def test_layernorm_zero_mean_unit_var():
    x = jax.random.normal(KEY, (4, 32), jnp.float32) * 3 + 7
    p = {"scale": jnp.ones((32,)), "bias": jnp.zeros((32,))}
    y = Lyr.layernorm(p, x)
    np.testing.assert_allclose(np.asarray(jnp.mean(y, -1)), 0.0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(jnp.std(y, -1)), 1.0, atol=1e-3)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def test_rope_preserves_norm():
    x = jax.random.normal(KEY, (2, 6, 4, 32), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(6)[None], (2, 6))
    y = Lyr.rope(x, pos, 1e4)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)


def test_rope_relative_property():
    """⟨RoPE(q,m), RoPE(k,n)⟩ depends only on (m−n)."""
    d = 16
    q = jax.random.normal(KEY, (1, 1, 1, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 1, 1, d),
                          jnp.float32)

    def dot_at(m, n):
        qm = Lyr.rope(q, jnp.asarray([[m]]), 1e4)[0, 0, 0]
        kn = Lyr.rope(k, jnp.asarray([[n]]), 1e4)[0, 0, 0]
        return float(jnp.dot(qm, kn))

    assert dot_at(3, 1) == pytest.approx(dot_at(10, 8), abs=1e-4)
    assert dot_at(5, 5) == pytest.approx(dot_at(0, 0), abs=1e-4)


def test_rope_position_zero_identity():
    x = jax.random.normal(KEY, (1, 1, 2, 8), jnp.float32)
    y = Lyr.rope(x, jnp.zeros((1, 1), jnp.int32), 1e4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)


# ---------------------------------------------------------------------------
# Attention vs reference
# ---------------------------------------------------------------------------

def _plain_cfg(**kw):
    base = dict(name="t", family="dense", n_layers=1, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab=32, rope_theta=1e4,
                dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def test_attention_matches_reference_no_rope_effectless_check():
    """Full causal self-attention (no cache) equals mha_ref applied to the
    same projected+roped q/k/v."""
    cfg = _plain_cfg()
    p, _ = Lyr.init_attention(KEY, cfg, jnp.float32)
    B, S, D = 2, 10, cfg.d_model
    x = jax.random.normal(KEY, (B, S, D), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    y, _ = Lyr.attention(p, cfg, x, positions=pos)
    # manual recomputation
    dh = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, dh)
    k = (x @ p["wk"]).reshape(B, S, cfg.n_kv_heads, dh)
    v = (x @ p["wv"]).reshape(B, S, cfg.n_kv_heads, dh)
    q, k = Lyr.rope(q, pos, cfg.rope_theta), Lyr.rope(k, pos, cfg.rope_theta)
    ref = mha_ref(q, k, v, causal=True, scale=1 / math.sqrt(dh))
    ref_y = ref.reshape(B, S, -1) @ p["wo"]
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref_y),
                               atol=1e-4, rtol=1e-4)


def test_attention_causality():
    """Changing a future token must not change past positions' outputs."""
    cfg = _plain_cfg()
    p, _ = Lyr.init_attention(KEY, cfg, jnp.float32)
    B, S = 1, 8
    x = jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    y1, _ = Lyr.attention(p, cfg, x, positions=pos)
    x2 = x.at[:, -1].add(10.0)
    y2, _ = Lyr.attention(p, cfg, x2, positions=pos)
    np.testing.assert_allclose(np.asarray(y1[:, :-1]),
                               np.asarray(y2[:, :-1]), atol=1e-5)


def test_mqa_single_kv_head():
    cfg = _plain_cfg(n_kv_heads=1)
    p, _ = Lyr.init_attention(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 6, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(6)[None], (2, 6))
    y, _ = Lyr.attention(p, cfg, x, positions=pos)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())


def test_qk_norm_and_bias_paths():
    cfg = _plain_cfg(qk_norm=True, qkv_bias=True)
    p, _ = Lyr.init_attention(KEY, cfg, jnp.float32)
    assert "q_norm" in p and "bq" in p
    x = jax.random.normal(KEY, (1, 4, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(4)[None], (1, 4))
    y, _ = Lyr.attention(p, cfg, x, positions=pos)
    assert bool(jnp.isfinite(y).all())


def test_mla_attention_shapes_and_cache():
    cfg = get_smoke_config("deepseek-v2-236b", n_layers=1)
    p, _ = Lyr.init_mla(KEY, cfg, jnp.float32)
    B, S = 2, 6
    x = jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    y, _ = Lyr.mla_attention(p, cfg, x, positions=pos)
    assert y.shape == (B, S, cfg.d_model)
    cache = Lyr.init_mla_cache(cfg, B, 16, jnp.float32)
    # latent cache is rank-r, not per-head — the MLA memory saving
    assert cache["ckv"].shape == (B, 16, cfg.kv_lora_rank)
    y2, cache = Lyr.mla_attention(p, cfg, x, positions=pos, cache=cache)
    assert int(cache["len"][0]) == S


# ---------------------------------------------------------------------------
# MoE dispatch
# ---------------------------------------------------------------------------

def _moe_cfg(E=4, k=2, cap=4.0):
    return _plain_cfg(n_experts=E, n_experts_active=k, moe_d_ff=32,
                      capacity_factor=cap)


def test_moe_output_shape_and_aux():
    cfg = _moe_cfg()
    p, _ = Lyr.init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 8, cfg.d_model), jnp.float32)
    y, aux = Lyr.moe(p, cfg, x)
    assert y.shape == x.shape
    assert float(aux) > 0.0   # load-balance loss strictly positive


def test_moe_matches_dense_reference_at_high_capacity():
    """With capacity ≥ tokens, GShard dispatch must equal the dense
    per-token top-k mixture computed naively."""
    cfg = _moe_cfg(E=4, k=2, cap=8.0)
    p, _ = Lyr.init_moe(KEY, cfg, jnp.float32)
    B, S = 1, 8
    x = jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32)
    y, _ = Lyr.moe(p, cfg, x)

    # naive reference
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    gate, ids = jax.lax.top_k(probs, cfg.n_experts_active)
    gate = gate / gate.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xt)
    for t in range(xt.shape[0]):
        acc = jnp.zeros((cfg.d_model,))
        for j in range(cfg.n_experts_active):
            e = int(ids[t, j])
            h = xt[t] @ p["wi"][e]
            g_, u = jnp.split(h, 2)
            acc += gate[t, j] * ((jax.nn.silu(g_) * u) @ p["wo"][e])
        ref = ref.at[t].set(acc)
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)),
                               np.asarray(ref), atol=1e-3, rtol=1e-3)


def test_moe_capacity_drops_tokens():
    """With capacity_factor tiny, some tokens must be dropped (their
    contribution is zero), not corrupt other tokens."""
    cfg = _moe_cfg(E=2, k=1, cap=0.25)
    p, _ = Lyr.init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (1, 16, cfg.d_model), jnp.float32)
    y, _ = Lyr.moe(p, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())


def test_moe_shared_expert_added():
    cfg_s = _plain_cfg(n_experts=2, n_experts_active=1, moe_d_ff=32,
                       n_shared_experts=1, capacity_factor=4.0)
    p, _ = Lyr.init_moe(KEY, cfg_s, jnp.float32)
    assert "shared" in p
    x = jax.random.normal(KEY, (1, 4, cfg_s.d_model), jnp.float32)
    y, _ = Lyr.moe(p, cfg_s, x)
    assert bool(jnp.isfinite(y).all())


def test_moe_local_combine_equals_gather():
    """The H4 scatter-add local combine is numerically identical to the
    replicated-gather combine, with and without capacity drops."""
    for cap in (8.0, 0.25):
        cfg = _moe_cfg(E=4, k=2, cap=cap)
        p, _ = Lyr.init_moe(KEY, cfg, jnp.float32)
        x = jax.random.normal(KEY, (2, 8, cfg.d_model), jnp.float32)
        y_gather, _ = Lyr.moe(p, cfg, x)
        y_local, _ = Lyr.moe(
            p, dataclasses.replace(cfg, moe_combine="local"), x)
        np.testing.assert_allclose(np.asarray(y_local),
                                   np.asarray(y_gather), atol=1e-6)


def test_moe_groups_divisor():
    assert Lyr._moe_groups(1024) == 32
    assert Lyr._moe_groups(7) == 7
    assert Lyr._moe_groups(1) == 1
    for T in (6, 96, 100, 4096):
        g = Lyr._moe_groups(T)
        assert T % g == 0 and 1 <= g <= 32
