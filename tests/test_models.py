"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and finiteness (assignment deliverable f).

Also: prefill+decode ≡ full-forward consistency, which exercises every cache
flavor (GQA KV, MLA latent, SSD conv+state, zamba hybrid tuple).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_config, get_smoke_config
from repro.launch.steps import make_train_step_fn
from repro.models import transformer as T
from repro.optim import adamw_init

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B, S, key=KEY, with_embeds=True):
    if cfg.n_codebooks:
        return {"tokens": jax.random.randint(key, (B, S, cfg.n_codebooks),
                                             0, cfg.vocab)}
    if cfg.family == "vlm" and with_embeds:
        n_img = 8
        return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
                "embeds": jax.random.normal(key, (B, n_img, cfg.d_model),
                                            cfg.param_dtype)}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", list(ARCHS))
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    params, axes = T.init_model(KEY, cfg)
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    logits, _, aux = T.forward(params, cfg, batch)
    S_out = S + (batch["embeds"].shape[1] if "embeds" in batch else 0)
    if cfg.n_codebooks:
        assert logits.shape == (B, S_out, cfg.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (B, S_out, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", list(ARCHS))
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params, _ = T.init_model(KEY, cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step_fn(cfg))
    batch = _batch(cfg, 2, 32, with_embeds=False)
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    # params actually moved
    deltas = jax.tree_util.tree_map(
        lambda a, b: float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, params2)
    assert sum(jax.tree_util.tree_leaves(deltas)) > 0.0


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "smollm-135m", "granite-20b",
                                  "qwen3-8b", "deepseek-v2-236b",
                                  "mamba2-1.3b", "zamba2-2.7b"])
def test_prefill_decode_matches_full_forward(arch):
    """Causal consistency: logits from (prefill S tokens, then decode one) must
    equal the last-position logits of a full (S+1)-token forward."""
    cfg = get_smoke_config(arch)
    cfg = dataclasses.replace(cfg, remat=False)
    params, _ = T.init_model(KEY, cfg)
    B, S, maxlen = 2, 12, 24
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S + 1), 0, cfg.vocab)
    # full forward over S+1 tokens
    full_logits, _, _ = T.forward(params, cfg, {"tokens": toks})
    # prefill S, decode token S
    caches = T.init_caches(cfg, B, maxlen, cfg.param_dtype)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    _, caches, _ = T.forward(params, cfg,
                             {"tokens": toks[:, :S], "positions": pos},
                             caches=caches)
    dpos = jnp.full((B, 1), S, jnp.int32)
    dec_logits, _, _ = T.forward(params, cfg,
                                 {"tokens": toks[:, S:S + 1],
                                  "positions": dpos}, caches=caches)
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32),
        atol=5e-2, rtol=5e-2)  # bf16 params → loose tol, same argmax expected
    assert bool(jnp.all(jnp.argmax(dec_logits[:, 0], -1)
                        == jnp.argmax(full_logits[:, -1], -1)))


def test_musicgen_decode_shapes():
    cfg = get_smoke_config("musicgen-medium")
    params, _ = T.init_model(KEY, cfg)
    B, S, maxlen = 2, 8, 16
    caches = T.init_caches(cfg, B, maxlen, cfg.param_dtype)
    toks = jax.random.randint(KEY, (B, S, cfg.n_codebooks), 0, cfg.vocab)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    logits, caches, _ = T.forward(params, cfg,
                                  {"tokens": toks, "positions": pos},
                                  caches=caches)
    assert logits.shape == (B, S, cfg.n_codebooks, cfg.vocab)


def test_full_configs_match_assignment():
    """The full (not reduced) configs carry the exact published dims."""
    spec = {
        "deepseek-v2-236b": dict(n_layers=60, d_model=5120, n_heads=128,
                                 vocab=102400, n_experts=160,
                                 n_experts_active=6, kv_lora_rank=512),
        "dbrx-132b": dict(n_layers=40, d_model=6144, n_heads=48,
                          n_kv_heads=8, d_ff=10752, vocab=100352,
                          n_experts=16, n_experts_active=4),
        "mamba2-1.3b": dict(n_layers=48, d_model=2048, ssm_state=128,
                            vocab=50280),
        "musicgen-medium": dict(n_layers=48, d_model=1536, n_heads=24,
                                n_kv_heads=24, d_ff=6144, vocab=2048,
                                n_codebooks=4),
        "qwen3-8b": dict(n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
                         d_ff=12288, vocab=151936, qk_norm=True),
        "smollm-135m": dict(n_layers=30, d_model=576, n_heads=9,
                            n_kv_heads=3, d_ff=1536, vocab=49152),
        "granite-20b": dict(n_layers=52, d_model=6144, n_heads=48,
                            n_kv_heads=1, d_ff=24576, vocab=49152),
        "qwen2-1.5b": dict(n_layers=28, d_model=1536, n_heads=12,
                           n_kv_heads=2, d_ff=8960, vocab=151936,
                           qkv_bias=True),
        "internvl2-76b": dict(n_layers=80, d_model=8192, n_heads=64,
                              n_kv_heads=8, d_ff=28672, vocab=128256),
        "zamba2-2.7b": dict(n_layers=54, d_model=2560, n_heads=32,
                            d_ff=10240, vocab=32000, ssm_state=64,
                            attn_every=6),
    }
    for arch, expect in spec.items():
        cfg = get_config(arch)
        for k, v in expect.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_param_counts_in_family_ballpark():
    """Sanity: full-config param counts land near the published sizes."""
    import numpy as np
    from repro.launch.specs import abstract_params_and_axes
    expect_b = {"smollm-135m": (0.09, 0.2), "qwen2-1.5b": (1.2, 2.1),
                "mamba2-1.3b": (1.0, 1.6), "zamba2-2.7b": (2.0, 3.3),
                "qwen3-8b": (7.0, 9.5), "granite-20b": (18, 23),
                "dbrx-132b": (125, 140), "deepseek-v2-236b": (225, 250),
                "internvl2-76b": (68, 80), "musicgen-medium": (1.2, 2.4)}
    for arch, (lo, hi) in expect_b.items():
        params, _ = abstract_params_and_axes(get_config(arch))
        n = sum(int(np.prod(leaf.shape))
                for leaf in jax.tree_util.tree_leaves(params)) / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B not in [{lo},{hi}]"
