"""Optional-hypothesis shim: property tests degrade to skips without it.

The suite must pass on a bare environment (`pip install jax pytest`) — see
pyproject.toml's [test] extra for the full dev set. Test modules import
``given``/``settings``/``st`` from here instead of hard-importing
hypothesis; when hypothesis is absent each @given test becomes a
pytest.skip (the importorskip contract, applied per-test so the rest of
the module still runs).
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis missing
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            # Zero-arg replacement: the strategy-bound params must not be
            # mistaken for pytest fixtures.
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies:
        """Stands in for hypothesis.strategies at decoration time only."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()
