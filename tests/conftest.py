"""Shared fixtures. NB: do NOT set xla_force_host_platform_device_count here —
smoke tests and benches must see the real (1-device) CPU platform; only
launch/dryrun.py requests 512 placeholder devices."""
import os

import numpy as np
import pytest

os.environ.setdefault("JAX_PLATFORMS", "cpu")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
