"""Paper Fig. 8 — runtime breakdown by layer class (QKV / scores / attn·V /
proj / FF1 / FF2 / non-GEMM / control) for baseline, Neon, TiC-SAT and
MatrixFlow on BERT-base.

Paper anchors (§4.5): baseline GEMM ≈ 99 % (FF > 87.7 % of it);
MatrixFlow non-GEMM ≈ 13.3 %, control ≈ 24.25 %.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import sysmodel as SM
from repro.core.workloads import paper_workload


def run():
    wl = paper_workload("bert-base")
    for backend in ("cpu1", "neon", "ticsat", "mf_dc"):
        r = SM.workload_time(wl, "int32", backend)
        total = r["total"]
        shares = {k: v / total for k, v in r["parts"].items()}
        gemm_share = r["gemm"] / total
        nongemm_share = r["nongemm"] / total
        control_share = r["control"] / total
        ff_share = (r["parts"].get("FF1", 0) + r["parts"].get("FF2", 0)) / total
        emit("fig8_breakdown", f"{backend}_gemm_share",
             round(gemm_share * 100, 1), "%",
             paper="99%" if backend == "cpu1" else "")
        emit("fig8_breakdown", f"{backend}_ff_share",
             round(ff_share * 100, 1), "%",
             paper=">87.7% of GEMM" if backend == "cpu1" else "")
        emit("fig8_breakdown", f"{backend}_nongemm_share",
             round(nongemm_share * 100, 1), "%",
             paper="13.32%" if backend == "mf_dc" else "")
        if backend == "mf_dc":
            emit("fig8_breakdown", f"{backend}_control_share",
                 round(control_share * 100, 1), "%", paper="24.25%")
        top = sorted(shares.items(), key=lambda kv: -kv[1])[:3]
        emit("fig8_breakdown", f"{backend}_top_classes",
             "; ".join(f"{k}:{v * 100:.0f}%" for k, v in top), "")


if __name__ == "__main__":
    run()
