"""Paper Fig. 7 — GEMM speedup vs matrix size (64² … 2048², int8).

Two layers of evidence:
  * the calibrated system model's speedups vs the paper's reported curve
    (DC up to ~400× at 1024, DM close behind, OMP stagnant);
  * measured wall-clock of the actual JAX implementations on this host
    (blockflow lax vs jnp.dot) as a sanity signal that the block
    decomposition does not regress dense-GEMM throughput.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import sysmodel as SM
from repro.core.blockflow import block_matmul_jit


def run():
    # -- model speedups (paper comparison) ----------------------------------
    for n in (64, 128, 256, 512, 1024, 2048):
        wl = ((SM.Gemm(n, n, n),), ())
        t = SM.speedup_table(wl, "int8", include_layout_cost=True)
        emit("fig7_gemm_size", f"speedup_dc_{n}", round(t["mf_dc"], 1), "x",
             paper="~400x at 1024" if n == 1024 else "")
        emit("fig7_gemm_size", f"speedup_dm_{n}", round(t["mf_dm"], 1), "x")
        emit("fig7_gemm_size", f"speedup_omp_{n}", round(t["omp"], 1), "x")

    # -- measured wall-clock (this host) ------------------------------------
    rng = np.random.default_rng(0)
    dense = jax.jit(lambda a, b: jnp.dot(a, b,
                                         preferred_element_type=jnp.float32))
    for n in (256, 512, 1024):
        a = jnp.asarray(rng.standard_normal((n, n), np.float32))
        b = jnp.asarray(rng.standard_normal((n, n), np.float32))
        t_dense = time_fn(dense, a, b)
        t_block = time_fn(block_matmul_jit, a, b)
        emit("fig7_gemm_size", f"host_dense_{n}",
             round(t_dense * 1e6, 1), "us")
        emit("fig7_gemm_size", f"host_blockflow_{n}",
             round(t_block * 1e6, 1), "us",
             ratio=round(t_block / t_dense, 2))


if __name__ == "__main__":
    run()
