"""Serving sweep: paged vs contiguous KV cache under a skewed-length mix.

The workload is the shape the paged subsystem exists for: 90 % short
prompts, 10 % near-``max_len`` prompts (the "millions of users, wildly
mixed lengths" regime in ROADMAP.md). The contiguous engine reserves
``batch_slots × max_len`` KV rows no matter what arrives; the paged engine
(docs/serving.md) backs only resident tokens, so the same pool serves a
request set whose summed max_len-padded footprint *exceeds* the pool — the
capacity acceptance gate (asserted hard in tests/test_serving.py, reported
here as the ``oversubscription`` column).

Reported per engine: tokens/s, peak cache bytes actually backing tokens,
**per-shard** peak cache bytes (the resident KV footprint each model shard
holds — pool tensors split on the KV-head dim under TP, docs/serving.md),
peak concurrently-live requests, preemptions, and oversubscription =
(peak live requests × max_len-padded bytes) / cache budget. On CPU the
paged kernel runs in Pallas *interpret* mode — a correctness substrate, not
a speed one — so tokens/s only becomes a fair fight on TPU (backend
"paged" vs "fused"); the memory columns are platform-independent.

``--tp N`` adds a ``paged_tpN`` cell: the same paged engine sharded over a
(data, model) host mesh with an N-way model axis (ServeConfig.mesh,
repro/distributed/tp.py). Needs ``len(jax.devices())`` divisible by N —
force host devices via XLA_FLAGS=--xla_force_host_platform_device_count.

``--kv-suite`` runs the quantized-KV capacity cells instead (``sweep_kv``):
the same one-page-per-request mix served from a bf16 pool and from an int8
pool (``ServeConfig(kv_dtype="int8")``, docs/quant.md#kv-pages) holding at
most the same pool *bytes* — the gate is ≥1.8× peak resident requests
under int8.

``--spec-suite`` runs the speculative-decoding cells instead
(``sweep_spec``): plain greedy vs n-gram self-speculation
(``ServeConfig(spec=NGramDrafter(k))``, docs/serving.md
#speculative-decoding) over one request set, asserting the streams are
token-identical and the spec cell clears ≥1.5× tokens/s.

Rows go to the shared CSV (benchmarks/common.py) and, matching
benchmarks/hillclimb.py, to ``serving_sweep.jsonl`` (``serving_kv.jsonl``
/ ``serving_spec.jsonl`` for the kv / spec suites).

  python -m benchmarks.serving_sweep
  python -m benchmarks.serving_sweep --max-len 128 --n-requests 24 \
      --cache-pages-frac 0.5
  XLA_FLAGS=--xla_force_host_platform_device_count=2 \
      python -m benchmarks.serving_sweep --tp 2
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import List, Optional, Sequence

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.registry import get_smoke_config
from repro.core.plan import AttentionPolicy
from repro.models import transformer as T
from repro.serving.engine import ServeConfig, ServingEngine
from repro.serving.scheduler import Scheduler
from repro.serving.spec_decode import NGramDrafter


def skewed_prompts(rng, n: int, max_len: int, short_frac: float = 0.9
                   ) -> List[List[int]]:
    """90 % short (2–6 tokens), 10 % near-max_len (~3/4 of it)."""
    prompts = []
    for i in range(n):
        if rng.random() < short_frac:
            L = int(rng.integers(2, 7))
        else:
            L = max(2, (3 * max_len) // 4)
        prompts.append(rng.integers(0, 64, L).tolist())
    return prompts


def shared_prefix_prompts(rng, n: int, prefix_len: int, tail_lo: int = 2,
                          tail_hi: int = 8) -> List[List[int]]:
    """The system-prompt traffic shape (docs/serving.md#prefix-cache):
    every request opens with the same ``prefix_len`` tokens and appends a
    short random tail — the mix the prefix cache turns from O(prompt) into
    O(tail) prefill work and from private to shared pages."""
    shared = rng.integers(0, 64, prefix_len).tolist()
    return [shared
            + rng.integers(0, 64,
                           int(rng.integers(tail_lo, tail_hi + 1))).tolist()
            for _ in range(n)]


def poisson_arrival_steps(rng, n: int, rate: float) -> List[int]:
    """Bursty arrivals: request i becomes eligible at engine step
    ``steps[i]`` (cumulative exponential inter-arrival gaps at ``rate``
    requests per step — the Poisson process, measured in steps so the
    trace is platform-independent)."""
    gaps = rng.exponential(1.0 / rate, n)
    return np.floor(np.cumsum(gaps)).astype(int).tolist()


def kv_bytes_per_token(cfg, cache_dtype: str = "bfloat16") -> int:
    """K + V payload bytes per cached token per layer stack at
    ``cache_dtype``. Paged cells don't use this estimate: serve_workload
    reads the engine's own exact per-page bytes (engine.kv_page_bytes()),
    which also folds in the int8 pools' fp32 scale side-tensors."""
    elem = jax.numpy.dtype(cache_dtype).itemsize
    return 2 * cfg.n_kv_heads * cfg.head_dim * elem * cfg.n_layers


def serve_workload(cfg, params, sc: ServeConfig, prompts: List[List[int]],
                   gen_len: int, axes=None,
                   arrival_steps: Optional[List[int]] = None):
    """Serve every prompt for gen_len tokens via submit()/step(); returns
    measured stats. Peak memory is sampled after every step.

    ``arrival_steps`` makes the trace bursty: request i only becomes
    eligible for submission at that engine step (None → everything arrives
    up front). TTFT is wall-clock from a request's eligibility to its
    first reported token — queueing delay included, which is exactly what
    admission capacity (prefix sharing) and chunked prefill move."""
    eng = ServingEngine(cfg, params, sc, axes=axes)
    # paged: exact bytes from the engine (int8 payload + scale tensors
    # included); contiguous: the analytic cache_dtype estimate
    per_tok = (eng.kv_page_bytes() // eng.pool.page_size if eng.paged
               else kv_bytes_per_token(cfg, sc.cache_dtype))
    n = len(prompts)
    arrivals = (list(arrival_steps) if arrival_steps is not None
                else [0] * n)
    queue = sorted(range(n), key=lambda i: arrivals[i])
    done: dict = {}
    live_handles: dict = {}
    arrive_t: dict = {}
    ttft: dict = {}
    last_t: dict = {}
    itls: List[float] = []
    total_done = 0
    n_finished = 0
    peak_live = 0
    peak_resident = 0
    peak_tokens = 0
    n_steps = 0
    t0 = time.perf_counter()
    while queue or live_handles:
        while queue and arrivals[queue[0]] <= n_steps:
            i = queue[0]
            arrive_t.setdefault(i, time.perf_counter())
            h = eng.submit(prompts[i])
            if h is None:
                break
            live_handles[h] = i
            queue.pop(0)
        stepped = eng.step()
        n_steps += 1
        now = time.perf_counter()
        for h, t in stepped.items():
            if h not in live_handles:
                continue
            i = live_handles[h]
            done[h] = done.get(h, 0) + 1
            if done[h] == 1:
                ttft[i] = now - arrive_t[i]
            else:
                itls.append(now - last_t[i])   # inter-token latency
            last_t[i] = now
            if done[h] >= gen_len:
                eng.cancel(h)
                del live_handles[h]
                last_t.pop(i, None)
                total_done += done.pop(h)   # contiguous handles (slot ids)
                n_finished += 1             # recycle — don't inherit counts
        # paged: waiting requests are parked host-side, resident = pool use
        n_live = len(live_handles)
        peak_live = max(peak_live, n_live)
        # resident = requests actually occupying device slots right now
        # (admitted and not preempted) — the capacity a pool byte buys
        peak_resident = max(peak_resident, int(eng.slot_live.sum()))
        if eng.paged:
            resident = eng.pool.pages_in_use * eng.pool.page_size
        else:
            resident = eng.sc.batch_slots * eng.sc.max_len
        peak_tokens = max(peak_tokens, resident)
        if n_steps > 10000:  # safety valve
            break
    dt = time.perf_counter() - t0
    total = total_done + sum(done.values())
    budget_tokens = (eng.pool.n_pages * eng.pool.page_size if eng.paged
                     else eng.sc.batch_slots * eng.sc.max_len)
    kv_shards = eng.kv_shards()

    def pct(xs, q):
        return round(float(np.percentile(xs, q)), 4) if xs else 0.0
    waits = sorted(ttft.values())
    return {
        "tokens": total,
        "finished": n_finished,
        "tok_per_s": total / max(dt, 1e-9),
        "ttft_p50_s": pct(waits, 50),
        "ttft_p95_s": pct(waits, 95),
        "ttft_p99_s": pct(waits, 99),
        "itl_p50_s": pct(itls, 50),
        "itl_p95_s": pct(itls, 95),
        "itl_p99_s": pct(itls, 99),
        "peak_cache_bytes": peak_tokens * per_tok,
        # what each model shard actually holds resident: the pool splits
        # on the KV-head dim, the page *count* is identical per shard
        "kv_shards": kv_shards,
        "per_shard_peak_cache_bytes": peak_tokens * per_tok // kv_shards,
        "budget_cache_bytes": budget_tokens * per_tok,
        "padded_peak_bytes": peak_live * sc.max_len * per_tok,
        "oversubscription": (peak_live * sc.max_len) / budget_tokens,
        "peak_live_requests": peak_live,
        "peak_resident_requests": peak_resident,
        "preemptions": eng.n_preemptions if eng.paged else 0,
        "steps": n_steps,
        # the engine's own observability dict: prefill/decode token split,
        # pool high-water mark, prefix hit/miss/evict counters + hit rate
        **{k: v for k, v in eng.stats().items()
           if k not in ("tick", "live_requests", "waiting_requests")},
    }


def sweep(arch: str = "smollm-135m", n_layers: int = 2, max_len: int = 64,
          batch_slots: int = 4, n_requests: int = 12, gen_len: int = 8,
          page_size: int = 8, cache_pages_frac: float = 0.5,
          seed: int = 0, jsonl_path: Optional[str] = None, tp: int = 1):
    # --tp shards the KV pool only when the smoke config's heads divide the
    # model axis AND the kv_heads rule allows it; clear the per-arch
    # replication overrides so the TP cell measures an actually-split pool.
    cfg_kw = dict(n_layers=n_layers, vocab=64)
    if tp > 1:
        cfg_kw["sharding_overrides"] = ()
    cfg = get_smoke_config(arch, **cfg_kw)
    params, axes = T.init_model(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    prompts = skewed_prompts(rng, n_requests, max_len)

    n_blocks = -(-max_len // page_size)
    cache_pages = max(n_blocks,
                      int(batch_slots * n_blocks * cache_pages_frac))
    paged_attn = AttentionPolicy(backend="paged_interpret",
                                 page_size=page_size, block_q=16)
    cells = {
        "contiguous": ServeConfig(
            batch_slots=batch_slots, max_len=max_len,
            attention=AttentionPolicy(backend="unfused")),
        "paged": ServeConfig(
            batch_slots=batch_slots, max_len=max_len, attention=paged_attn,
            cache_pages=cache_pages),
    }
    if tp > 1:
        from repro.launch.mesh import make_host_mesh
        if len(jax.devices()) % tp:
            print(f"[serving] skipping --tp {tp}: {len(jax.devices())} "
                  f"device(s) not divisible (set XLA_FLAGS="
                  f"--xla_force_host_platform_device_count=N)")
        else:
            cells[f"paged_tp{tp}"] = ServeConfig(
                batch_slots=batch_slots, max_len=max_len,
                attention=paged_attn, cache_pages=cache_pages,
                mesh=make_host_mesh(model=tp))
    rows = []
    for name, sc in cells.items():
        stats = serve_workload(cfg, params, sc, prompts, gen_len, axes=axes)
        row = {"engine": name, "arch": cfg.name, "max_len": max_len,
               "batch_slots": batch_slots, "page_size": page_size,
               "cache_pages": cache_pages if name.startswith("paged")
               else None, "tp": tp if name.endswith(f"tp{tp}") else 1,
               **stats}
        rows.append(row)
        emit("serving", f"{name}_tok_per_s", round(stats["tok_per_s"], 2),
             "tok/s", requests=n_requests, gen_len=gen_len)
        emit("serving", f"{name}_peak_cache", stats["peak_cache_bytes"],
             "bytes", budget=stats["budget_cache_bytes"],
             per_shard=stats["per_shard_peak_cache_bytes"],
             kv_shards=stats["kv_shards"],
             oversubscription=round(stats["oversubscription"], 3),
             peak_live=stats["peak_live_requests"],
             preemptions=stats["preemptions"])
    out = jsonl_path or os.path.join(os.path.dirname(__file__),
                                     "serving_sweep.jsonl")
    with open(out, "w") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")
    print(f"[serving] wrote {len(rows)} rows to {out}")
    paged = next(r for r in rows if r["engine"] == "paged")
    if paged["oversubscription"] > 1.0:
        print(f"[serving] capacity: paged served a live set "
              f"{paged['oversubscription']:.2f}x its cache budget "
              f"(admission is page-bound, not slot-bound)")
    return rows


def sweep_prefix(arch: str = "smollm-135m", n_layers: int = 2,
                 max_len: int = 96, batch_slots: int = 8,
                 n_requests: int = 20, gen_len: int = 3, page_size: int = 8,
                 prefix_len: int = 72, cache_pages: Optional[int] = None,
                 arrival_rate: float = 0.4, seed: int = 0,
                 jsonl_path: Optional[str] = None):
    """Prefix-cache acceptance sweep (ISSUE 6): a shared-prefix mix and a
    bursty (Poisson-arrival) mix, each served by the paged engine with and
    without the prefix cache at a FIXED pool size. Reports tokens/s, TTFT
    p50/p95, peak admitted concurrency, and the prefix hit rate — the
    gates are ≥2× peak concurrent requests and ≥1.5× tokens/s on the
    shared-prefix mix.

    Default shapes are prefill-dominated (long shared prefix, short
    tails and gen_len) on purpose: the paged kernel here runs in Pallas
    *interpret* mode, where decode cost grows with the summed resident
    key blocks of the live set — host-sequential, so the extra
    concurrency the cache unlocks does not amortize decode the way real
    hardware does. Prefill work elided by the cache (O(prompt) →
    O(tail)) is the platform-independent win; decode-heavy mixes need a
    compiled backend for the throughput gate to be a fair fight."""
    cfg = get_smoke_config(arch, n_layers=n_layers, vocab=64)
    params, axes = T.init_model(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    prompts = shared_prefix_prompts(rng, n_requests, prefix_len)
    arrivals = poisson_arrival_steps(rng, n_requests, arrival_rate)

    n_blocks = -(-max_len // page_size)
    # pool sized so an UNSHARED request set is page-starved (~2 concurrent)
    # while shared prefixes fit many: the capacity the cache must unlock
    pages = cache_pages if cache_pages is not None else 2 * n_blocks
    paged_attn = AttentionPolicy(backend="paged_interpret",
                                 page_size=page_size, block_q=16)
    base = dict(batch_slots=batch_slots, max_len=max_len,
                attention=paged_attn, cache_pages=pages)
    cells = {
        "nocache": (ServeConfig(**base), None),
        "prefix": (ServeConfig(**base, prefix_cache=True), None),
        "nocache_bursty": (ServeConfig(**base), arrivals),
        "prefix_bursty": (ServeConfig(**base, prefix_cache=True,
                                      scheduler=Scheduler(prefill_chunk=16)),
                          arrivals),
    }
    rows = []
    for name, (sc, arr) in cells.items():
        stats = serve_workload(cfg, params, sc, prompts, gen_len, axes=axes,
                               arrival_steps=arr)
        row = {"engine": name, "arch": cfg.name, "max_len": max_len,
               "batch_slots": batch_slots, "page_size": page_size,
               "cache_pages": pages, "prefix_len": prefix_len,
               "n_requests": n_requests, "gen_len": gen_len,
               "arrival_rate": arrival_rate if arr is not None else None,
               **stats}
        rows.append(row)
        emit("serving-prefix", f"{name}_tok_per_s",
             round(stats["tok_per_s"], 2), "tok/s",
             peak_live=stats["peak_live_requests"],
             ttft_p50_s=stats["ttft_p50_s"],
             ttft_p95_s=stats["ttft_p95_s"],
             hit_rate=stats.get("prefix_hit_rate", 0.0))
    out = jsonl_path or os.path.join(os.path.dirname(__file__),
                                     "serving_prefix.jsonl")
    with open(out, "w") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")
    print(f"[serving-prefix] wrote {len(rows)} rows to {out}")
    by = {r["engine"]: r for r in rows}
    live_x = (by["prefix"]["peak_live_requests"]
              / max(by["nocache"]["peak_live_requests"], 1))
    tput_x = (by["prefix"]["tok_per_s"]
              / max(by["nocache"]["tok_per_s"], 1e-9))
    print(f"[serving-prefix] shared-prefix mix at {pages} pages: "
          f"{live_x:.2f}x peak concurrent requests "
          f"({by['nocache']['peak_live_requests']} -> "
          f"{by['prefix']['peak_live_requests']}), "
          f"{tput_x:.2f}x tokens/s, hit rate "
          f"{by['prefix'].get('prefix_hit_rate', 0.0):.1%}")
    print(f"[serving-prefix] bursty (Poisson {arrival_rate}/step): TTFT "
          f"p50 {by['nocache_bursty']['ttft_p50_s']:.3f}s -> "
          f"{by['prefix_bursty']['ttft_p50_s']:.3f}s, p95 "
          f"{by['nocache_bursty']['ttft_p95_s']:.3f}s -> "
          f"{by['prefix_bursty']['ttft_p95_s']:.3f}s")
    return rows


def sweep_kv(arch: str = "smollm-135m", n_layers: int = 2,
             max_len: int = 16, batch_slots: int = 16,
             n_requests: int = 24, prompt_len: int = 3, gen_len: int = 4,
             page_size: int = 8, fp_pages: int = 8, seed: int = 0,
             jsonl_path: Optional[str] = None):
    """Quantized-KV capacity sweep (docs/quant.md#kv-pages): the same
    request mix served by the paged engine with a model-dtype (bf16) pool
    and with an int8 pool holding AT MOST the same pool **bytes** — int8
    pages = floor(byte budget / int8 page bytes), where an int8 page costs
    half the payload plus two (P, Hkv) fp32 scale rows per layer.

    Requests are sized to live inside exactly one page (prompt_len +
    gen_len + 1 <= page_size, counting the pending-token write), so peak
    resident concurrency == pages the pool can hold — the cleanest
    possible read of "live requests per pool byte". Gate (asserted in
    tests/test_serving.py, printed here): >=1.8x peak resident requests
    under int8 (2x payload minus the scale side-tensors' overhead)."""
    assert prompt_len + gen_len + 1 <= page_size, "requests must fit 1 page"
    cfg = get_smoke_config(arch, n_layers=n_layers, vocab=64)
    params, axes = T.init_model(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, 64, prompt_len).tolist()
               for _ in range(n_requests)]

    # equal pool-BYTE budget across the two cells
    fp_page = page_size * kv_bytes_per_token(cfg, "bfloat16")
    int8_page = (page_size * kv_bytes_per_token(cfg, "int8")
                 + 2 * cfg.n_kv_heads * 4 * cfg.n_layers)   # fp32 scales
    budget = fp_pages * fp_page
    int8_pages = budget // int8_page
    paged_attn = AttentionPolicy(backend="paged_interpret",
                                 page_size=page_size, block_q=16)
    base = dict(batch_slots=batch_slots, max_len=max_len,
                attention=paged_attn, cache_dtype="bfloat16")
    cells = {
        "kv_bf16": ServeConfig(**base, cache_pages=fp_pages),
        "kv_int8": ServeConfig(**base, cache_pages=int8_pages,
                               kv_dtype="int8"),
    }
    rows = []
    for name, sc in cells.items():
        stats = serve_workload(cfg, params, sc, prompts, gen_len, axes=axes)
        assert stats["kv_pool_bytes"] <= budget, (
            name, stats["kv_pool_bytes"], budget)
        row = {"engine": name, "arch": cfg.name, "max_len": max_len,
               "batch_slots": batch_slots, "page_size": page_size,
               "cache_pages": sc.cache_pages, "n_requests": n_requests,
               "prompt_len": prompt_len, "gen_len": gen_len,
               "budget_pool_bytes": budget, **stats}
        rows.append(row)
        emit("serving-kv", f"{name}_peak_resident",
             stats["peak_resident_requests"], "requests",
             pool_bytes=stats["kv_pool_bytes"], pages=sc.cache_pages,
             tok_per_s=round(stats["tok_per_s"], 2),
             preemptions=stats["preemptions"])
    out = jsonl_path or os.path.join(os.path.dirname(__file__),
                                     "serving_kv.jsonl")
    with open(out, "w") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")
    print(f"[serving-kv] wrote {len(rows)} rows to {out}")
    by = {r["engine"]: r for r in rows}
    ratio = (by["kv_int8"]["peak_resident_requests"]
             / max(by["kv_bf16"]["peak_resident_requests"], 1))
    print(f"[serving-kv] capacity at a {budget}-byte pool budget: "
          f"{ratio:.2f}x peak resident requests "
          f"({by['kv_bf16']['peak_resident_requests']} -> "
          f"{by['kv_int8']['peak_resident_requests']}; "
          f"{by['kv_bf16']['cache_pages']} bf16 pages @ "
          f"{by['kv_bf16']['kv_page_bytes']} B vs "
          f"{by['kv_int8']['cache_pages']} int8 pages @ "
          f"{by['kv_int8']['kv_page_bytes']} B) "
          f"[gate: >=1.8x]")
    return rows


def _serve_spec_streams(cfg, params, sc: ServeConfig,
                        prompts: List[List[int]], gen_len: int, axes=None):
    """Serve every prompt for exactly ``gen_len`` tokens, collecting the
    FULL per-request stream. A speculative engine's step() returns
    multi-token *bursts* per handle — ``serve_workload``'s one-token-per-
    step accounting would undercount them, and the spec gate needs the
    literal token sequences to prove stream identity anyway. Returns
    ({prompt index: stream}, stats)."""
    eng = ServingEngine(cfg, params, sc, axes=axes)
    streams = {i: [] for i in range(len(prompts))}
    hmap: dict = {}
    queue = list(range(len(prompts)))
    n_steps = 0
    t0 = time.perf_counter()
    while queue or hmap:
        while queue:
            h = eng.submit(list(prompts[queue[0]]))
            if h is None:
                break
            hmap[h] = queue.pop(0)
        stepped = eng.step()
        n_steps += 1
        for h, t in stepped.items():
            i = hmap.get(h)
            if i is None:
                continue
            streams[i].extend(t if isinstance(t, list) else [t])
            if len(streams[i]) >= gen_len:
                eng.cancel(h)
                del hmap[h]
        assert n_steps <= 10_000, "spec workload failed to converge"
    dt = time.perf_counter() - t0
    streams = {i: s[:gen_len] for i, s in streams.items()}
    st = eng.stats()
    total = sum(len(s) for s in streams.values())
    return streams, {
        "tokens": total,
        "steps": n_steps,
        "wall_s": round(dt, 3),
        "tok_per_s": total / max(dt, 1e-9),
        "spec_acceptance_rate": st.get("spec_acceptance_rate"),
        "spec_accepted_tokens": st.get("spec_accepted_tokens", 0),
        "spec_rejected_tokens": st.get("spec_rejected_tokens", 0),
        "spec_rollback_pages": st.get("spec_rollback_pages", 0),
        "rollback_pages_per_s": round(
            st.get("spec_rollback_pages", 0) / max(dt, 1e-9), 3),
    }


def sweep_spec(arch: str = "smollm-135m", n_layers: int = 2,
               max_len: Optional[int] = None, batch_slots: int = 4,
               n_requests: int = 4, gen_len: int = 384, page_size: int = 8,
               draft_k: int = 8, seed: int = 0,
               jsonl_path: Optional[str] = None):
    """Speculative-decoding acceptance sweep (docs/serving.md
    #speculative-decoding): the same greedy request set served by the
    paged engine with and without n-gram self-speculation
    (``ServeConfig(spec=NGramDrafter(k))``). Asserted gates:

    * **stream identity** — every spec stream equals the non-spec stream
      token for token (the tentpole invariant, end to end through the
      benchmark's own submit/step/cancel loop);
    * **>=1.5x tokens/s** for the spec cell on this workload.

    Long greedy generations from the smoke model are eventually periodic
    (tiny vocab + deterministic argmax -> the streams fall into constant
    runs and short cycles), which is exactly the regime prompt-lookup
    drafting exploits: the drafter locks onto the period and the verify
    pass accepts near-full bursts, so one fixed-shape Sq=1+k forward
    replaces up to k+1 sequential decode steps. The ~1.5x+ here is the
    *host-interpret* win (fewer Pallas interpret passes); on real
    hardware the same step reduction applies to the memory-bound decode
    loop. Also reported: acceptance rate and rollback pages/s — the cost
    side of speculation (rejected drafts shedding their tail pages)."""
    cfg = get_smoke_config(arch, n_layers=n_layers, vocab=64)
    params, axes = T.init_model(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, 64, int(rng.integers(6, 13))).tolist()
               for _ in range(n_requests)]
    if max_len is None:
        # headroom for the longest prompt + gen_len with pages to spare
        max_len = gen_len + 16
    paged_attn = AttentionPolicy(backend="paged_interpret",
                                 page_size=page_size, block_q=16)
    base = dict(batch_slots=batch_slots, max_len=max_len,
                attention=paged_attn)
    cells = {
        "greedy": ServeConfig(**base),
        "spec_ngram": ServeConfig(**base, spec=NGramDrafter(k=draft_k)),
    }
    rows, streams = [], {}
    for name, sc in cells.items():
        s, stats = _serve_spec_streams(cfg, params, sc, prompts, gen_len,
                                       axes=axes)
        streams[name] = s
        row = {"engine": name, "arch": cfg.name, "max_len": max_len,
               "batch_slots": batch_slots, "page_size": page_size,
               "n_requests": n_requests, "gen_len": gen_len,
               "draft_k": draft_k if name != "greedy" else None, **stats}
        rows.append(row)
        emit("serving-spec", f"{name}_tok_per_s",
             round(stats["tok_per_s"], 2), "tok/s",
             steps=stats["steps"],
             acceptance=stats["spec_acceptance_rate"],
             rollback_pages_per_s=stats["rollback_pages_per_s"])
    for i in range(n_requests):
        assert streams["spec_ngram"][i] == streams["greedy"][i], (
            f"stream identity violated for request {i}: speculative "
            f"greedy decoding must be token-identical to plain greedy")
    out = jsonl_path or os.path.join(os.path.dirname(__file__),
                                     "serving_spec.jsonl")
    with open(out, "w") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")
    print(f"[serving-spec] wrote {len(rows)} rows to {out}")
    by = {r["engine"]: r for r in rows}
    ratio = (by["spec_ngram"]["tok_per_s"]
             / max(by["greedy"]["tok_per_s"], 1e-9))
    print(f"[serving-spec] identical streams over {n_requests} requests x "
          f"{gen_len} tokens: {ratio:.2f}x tokens/s "
          f"({by['greedy']['tok_per_s']:.1f} -> "
          f"{by['spec_ngram']['tok_per_s']:.1f}; "
          f"{by['greedy']['steps']} -> {by['spec_ngram']['steps']} steps), "
          f"acceptance {by['spec_ngram']['spec_acceptance_rate']:.1%}, "
          f"rollback {by['spec_ngram']['rollback_pages_per_s']:.1f} "
          f"pages/s [gate: >=1.5x]")
    assert ratio >= 1.5, (
        f"speculative decoding gate failed: {ratio:.2f}x tokens/s < 1.5x "
        f"(acceptance {by['spec_ngram']['spec_acceptance_rate']:.1%})")
    return rows


def run():
    """Default suite entry (benchmarks.run): CPU-safe sizes."""
    sweep()


def run_prefix():
    """Prefix-cache suite entry (benchmarks.run serving-prefix): the
    shared-prefix and bursty mixes at CPU-safe sizes."""
    sweep_prefix()


def run_kv():
    """Quantized-KV suite entry (benchmarks.run serving-kv): the
    equal-pool-byte bf16-vs-int8 capacity cells at CPU-safe sizes."""
    sweep_kv()


def run_spec():
    """Speculative-decoding suite entry (benchmarks.run serving-spec):
    the identical-streams throughput gate (>=1.5x tokens/s with n-gram
    self-speculation) at CPU-safe sizes."""
    sweep_spec()


def run_tp():
    """TP suite entry (benchmarks.run serving-tp): adds the paged_tp2 cell
    when the host has the devices for a 2-way model axis; prints a skip on
    the stock 1-device CPU (force devices via XLA_FLAGS to enable)."""
    if len(jax.devices()) < 2:
        print("[serving] serving-tp skipped: 1 local device (set XLA_FLAGS="
              "--xla_force_host_platform_device_count=2 before jax init)")
        return
    sweep(arch="qwen3-8b", tp=2)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--n-layers", type=int, default=2)
    # shape flags default to None → each suite's own defaults apply
    # (the skewed sweep and the prefix suite tune them differently)
    ap.add_argument("--max-len", type=int, default=None)
    ap.add_argument("--batch-slots", type=int, default=None)
    ap.add_argument("--n-requests", type=int, default=None)
    ap.add_argument("--gen-len", type=int, default=None)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--cache-pages-frac", type=float, default=0.5,
                    help="paged pool size as a fraction of the contiguous-"
                         "equivalent page count")
    ap.add_argument("--tp", type=int, default=1,
                    help="add a paged_tpN cell: the paged engine over a "
                         "(data, model) host mesh with an N-way model axis "
                         "(tokens/s + per-shard peak cache bytes)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefix-suite", action="store_true",
                    help="run the prefix-cache acceptance sweep (shared-"
                         "prefix + bursty Poisson mixes) instead of the "
                         "paged-vs-contiguous skewed-length sweep")
    ap.add_argument("--prefix-len", type=int, default=None,
                    help="prefix suite: shared tokens heading every prompt")
    ap.add_argument("--kv-suite", action="store_true",
                    help="run the quantized-KV capacity sweep instead: "
                         "bf16 vs int8 KV pages at an equal pool-byte "
                         "budget (docs/quant.md#kv-pages)")
    ap.add_argument("--spec-suite", action="store_true",
                    help="run the speculative-decoding sweep instead: "
                         "greedy vs n-gram self-speculation at identical "
                         "streams (docs/serving.md#speculative-decoding)")
    ap.add_argument("--draft-k", type=int, default=None,
                    help="spec suite: per-step draft budget k")
    args = ap.parse_args(argv)
    shape = {k: v for k, v in (("max_len", args.max_len),
                               ("batch_slots", args.batch_slots),
                               ("n_requests", args.n_requests),
                               ("gen_len", args.gen_len))
             if v is not None}
    if args.prefix_suite:
        if args.prefix_len is not None:
            shape["prefix_len"] = args.prefix_len
        sweep_prefix(arch=args.arch, n_layers=args.n_layers,
                     page_size=args.page_size, seed=args.seed, **shape)
        return 0
    if args.kv_suite:
        sweep_kv(arch=args.arch, n_layers=args.n_layers,
                 page_size=args.page_size, seed=args.seed, **shape)
        return 0
    if args.spec_suite:
        if args.draft_k is not None:
            shape["draft_k"] = args.draft_k
        sweep_spec(arch=args.arch, n_layers=args.n_layers,
                   page_size=args.page_size, seed=args.seed, **shape)
        return 0
    sweep(arch=args.arch, n_layers=args.n_layers, page_size=args.page_size,
          cache_pages_frac=args.cache_pages_frac, seed=args.seed,
          tp=args.tp, **shape)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
