"""Serving sweep: paged vs contiguous KV cache under a skewed-length mix.

The workload is the shape the paged subsystem exists for: 90 % short
prompts, 10 % near-``max_len`` prompts (the "millions of users, wildly
mixed lengths" regime in ROADMAP.md). The contiguous engine reserves
``batch_slots × max_len`` KV rows no matter what arrives; the paged engine
(docs/serving.md) backs only resident tokens, so the same pool serves a
request set whose summed max_len-padded footprint *exceeds* the pool — the
capacity acceptance gate (asserted hard in tests/test_serving.py, reported
here as the ``oversubscription`` column).

Reported per engine: tokens/s, peak cache bytes actually backing tokens,
peak concurrently-live requests, preemptions, and oversubscription =
(peak live requests × max_len-padded bytes) / cache budget. On CPU the
paged kernel runs in Pallas *interpret* mode — a correctness substrate, not
a speed one — so tokens/s only becomes a fair fight on TPU (backend
"paged" vs "fused"); the memory columns are platform-independent.

Rows go to the shared CSV (benchmarks/common.py) and, matching
benchmarks/hillclimb.py, to ``serving_sweep.jsonl``.

  python -m benchmarks.serving_sweep
  python -m benchmarks.serving_sweep --max-len 128 --n-requests 24 \
      --cache-pages-frac 0.5
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import List, Optional, Sequence

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.registry import get_smoke_config
from repro.core.plan import AttentionPolicy
from repro.models import transformer as T
from repro.serving.engine import ServeConfig, ServingEngine


def skewed_prompts(rng, n: int, max_len: int, short_frac: float = 0.9
                   ) -> List[List[int]]:
    """90 % short (2–6 tokens), 10 % near-max_len (~3/4 of it)."""
    prompts = []
    for i in range(n):
        if rng.random() < short_frac:
            L = int(rng.integers(2, 7))
        else:
            L = max(2, (3 * max_len) // 4)
        prompts.append(rng.integers(0, 64, L).tolist())
    return prompts


def kv_bytes_per_token(cfg) -> int:
    """K + V bytes per cached token per layer stack (bf16 cache)."""
    return 2 * cfg.n_kv_heads * cfg.head_dim * 2 * cfg.n_layers


def serve_workload(cfg, params, sc: ServeConfig, prompts: List[List[int]],
                   gen_len: int):
    """Serve every prompt for gen_len tokens via submit()/step(); returns
    measured stats. Peak memory is sampled after every step."""
    eng = ServingEngine(cfg, params, sc)
    per_tok = kv_bytes_per_token(cfg)
    pending = [list(p) for p in prompts]
    done: dict = {}
    live_handles: dict = {}
    total_done = 0
    n_finished = 0
    peak_live = 0
    peak_tokens = 0
    n_steps = 0
    t0 = time.perf_counter()
    while pending or live_handles:
        while pending:
            h = eng.submit(pending[0])
            if h is None:
                break
            live_handles[h] = len(pending[0])
            pending.pop(0)
        stepped = eng.step()
        n_steps += 1
        for h, t in stepped.items():
            if h not in live_handles:
                continue
            done[h] = done.get(h, 0) + 1
            if done[h] >= gen_len:
                eng.cancel(h)
                del live_handles[h]
                total_done += done.pop(h)   # contiguous handles (slot ids)
                n_finished += 1             # recycle — don't inherit counts
        # paged: waiting requests are parked host-side, resident = pool use
        n_live = len(live_handles)
        peak_live = max(peak_live, n_live)
        if eng.paged:
            resident = eng.pool.pages_in_use * eng.pool.page_size
        else:
            resident = eng.sc.batch_slots * eng.sc.max_len
        peak_tokens = max(peak_tokens, resident)
        if n_steps > 10000:  # safety valve
            break
    dt = time.perf_counter() - t0
    total = total_done + sum(done.values())
    budget_tokens = (eng.pool.n_pages * eng.pool.page_size if eng.paged
                     else eng.sc.batch_slots * eng.sc.max_len)
    return {
        "tokens": total,
        "finished": n_finished,
        "tok_per_s": total / max(dt, 1e-9),
        "peak_cache_bytes": peak_tokens * per_tok,
        "budget_cache_bytes": budget_tokens * per_tok,
        "padded_peak_bytes": peak_live * sc.max_len * per_tok,
        "oversubscription": (peak_live * sc.max_len) / budget_tokens,
        "peak_live_requests": peak_live,
        "preemptions": eng.n_preemptions if eng.paged else 0,
        "steps": n_steps,
    }


def sweep(arch: str = "smollm-135m", n_layers: int = 2, max_len: int = 64,
          batch_slots: int = 4, n_requests: int = 12, gen_len: int = 8,
          page_size: int = 8, cache_pages_frac: float = 0.5,
          seed: int = 0, jsonl_path: Optional[str] = None):
    cfg = get_smoke_config(arch, n_layers=n_layers, vocab=64)
    params, _ = T.init_model(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    prompts = skewed_prompts(rng, n_requests, max_len)

    n_blocks = -(-max_len // page_size)
    cache_pages = max(n_blocks,
                      int(batch_slots * n_blocks * cache_pages_frac))
    cells = {
        "contiguous": ServeConfig(
            batch_slots=batch_slots, max_len=max_len,
            attention=AttentionPolicy(backend="unfused")),
        "paged": ServeConfig(
            batch_slots=batch_slots, max_len=max_len,
            attention=AttentionPolicy(backend="paged_interpret",
                                      page_size=page_size, block_q=16),
            cache_pages=cache_pages),
    }
    rows = []
    for name, sc in cells.items():
        stats = serve_workload(cfg, params, sc, prompts, gen_len)
        row = {"engine": name, "arch": cfg.name, "max_len": max_len,
               "batch_slots": batch_slots, "page_size": page_size,
               "cache_pages": cache_pages if name == "paged" else None,
               **stats}
        rows.append(row)
        emit("serving", f"{name}_tok_per_s", round(stats["tok_per_s"], 2),
             "tok/s", requests=n_requests, gen_len=gen_len)
        emit("serving", f"{name}_peak_cache", stats["peak_cache_bytes"],
             "bytes", budget=stats["budget_cache_bytes"],
             oversubscription=round(stats["oversubscription"], 3),
             peak_live=stats["peak_live_requests"],
             preemptions=stats["preemptions"])
    out = jsonl_path or os.path.join(os.path.dirname(__file__),
                                     "serving_sweep.jsonl")
    with open(out, "w") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")
    print(f"[serving] wrote {len(rows)} rows to {out}")
    paged = next(r for r in rows if r["engine"] == "paged")
    if paged["oversubscription"] > 1.0:
        print(f"[serving] capacity: paged served a live set "
              f"{paged['oversubscription']:.2f}x its cache budget "
              f"(admission is page-bound, not slot-bound)")
    return rows


def run():
    """Default suite entry (benchmarks.run): CPU-safe sizes."""
    sweep()


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--n-layers", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--n-requests", type=int, default=12)
    ap.add_argument("--gen-len", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--cache-pages-frac", type=float, default=0.5,
                    help="paged pool size as a fraction of the contiguous-"
                         "equivalent page count")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    sweep(arch=args.arch, n_layers=args.n_layers, max_len=args.max_len,
          batch_slots=args.batch_slots, n_requests=args.n_requests,
          gen_len=args.gen_len, page_size=args.page_size,
          cache_pages_frac=args.cache_pages_frac, seed=args.seed)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
