"""Paper Fig. 6 — GEMM performance across data types at 512².

Model layer: speedups per dtype/backend with the paper's MAC-unit PPA
constraints (Table 2: int @1 GHz, fp @600 MHz; fp16 CPU penalty §4.3.2).
Host layer: Pallas kernel (interpret) per dtype vs oracle for throughput
sanity + correctness.

``--dtype <dt>`` drives one dtype end-to-end through the ExecutionPlan
policy path (api.matmul/linear under a GemmPolicy) across every backend;
``--dtype int8`` additionally sweeps the quantized W8A8 weight route
(GemmPolicy(weight_dtype="int8"), resident QuantizedPackedWeight).
"""
from __future__ import annotations

import argparse
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import api
from repro.core import sysmodel as SM
from repro.core.plan import GemmPolicy
from repro.kernels.matrixflow_gemm import matrixflow_gemm

POLICY_BACKENDS = ("xla", "blockflow", "pallas_interpret")


def _load_parity():
    """Import tests/parity.py — the single source of operands, references,
    and per-dtype tolerances, so the benchmark's pass/fail can never drift
    from the parity gate's."""
    import importlib
    import os
    import sys
    tests_dir = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "tests")
    if tests_dir not in sys.path:
        sys.path.insert(0, tests_dir)
    return importlib.import_module("parity")


def run_policy_path(dtype: str, size: int = 256):
    """One dtype through api.matmul/linear under each backend's GemmPolicy —
    the route every model layer takes (plan cache, registry dispatch,
    layouts), not the raw kernel entry points. Timing is measured here;
    correctness per cell is exactly tests/parity.py's differential check."""
    parity = _load_parity()
    shape = (size, size, size)
    a, b = parity.make_operands(dtype, *shape)
    for backend in POLICY_BACKENDS:
        pol = GemmPolicy(backend=backend)
        t = time_fn(lambda: api.matmul(a, b, policy=pol), warmup=1, iters=2)
        try:
            err, ok = parity.check_cell(backend, dtype, shape).max_err, True
        except AssertionError:
            err, ok = float("nan"), False
        emit("fig6_dtype", f"policy_{backend}_{dtype}",
             round(t * 1e3, 2), "ms", max_err=f"{err:.1e}", ok=ok)
    if dtype != "int8":
        return
    # the quantized W8A8 weight route: fp activations, int8 resident weights
    x, w = parity.make_operands("float32", *shape, seed=1)
    for backend in POLICY_BACKENDS:
        pol = GemmPolicy(backend=backend, weight_dtype="int8")
        qw = api.pack_weight(w, pol)           # quantize-at-pack, resident
        t = time_fn(lambda: api.linear(x, qw, policy=pol),
                    warmup=1, iters=2)
        try:
            err, ok = (parity.check_quantized_cell(backend, shape).max_err,
                       True)
        except AssertionError:
            err, ok = float("nan"), False
        emit("fig6_dtype", f"policy_{backend}_w8a8",
             round(t * 1e3, 2), "ms", max_err=f"{err:.1e}", ok=ok)


def run():
    wl = ((SM.Gemm(512, 512, 512),), ())
    for dt in ("int8", "int16", "int32", "fp16", "fp32"):
        t = SM.speedup_table(wl, dt)
        emit("fig6_dtype", f"accel_dc_{dt}", round(t["mf_dc"], 1), "x")
        emit("fig6_dtype", f"neon_{dt}", round(t["neon"], 1), "x")
        emit("fig6_dtype", f"omp_{dt}", round(t["omp"], 1), "x")

    # host-side kernel sweep (correctness + relative cost)
    rng = np.random.default_rng(0)
    for dt, tol in ((jnp.float32, 1e-4), (jnp.bfloat16, 5e-2),
                    (jnp.int8, 0)):
        if dt == jnp.int8:
            a = jnp.asarray(rng.integers(-8, 8, (256, 256)).astype(np.int8))
            b = jnp.asarray(rng.integers(-8, 8, (256, 256)).astype(np.int8))
        else:
            a = jnp.asarray(rng.standard_normal((256, 256),
                                                np.float32)).astype(dt)
            b = jnp.asarray(rng.standard_normal((256, 256),
                                                np.float32)).astype(dt)
        t = time_fn(lambda a=a, b=b: matrixflow_gemm(a, b, interpret=True),
                    warmup=1, iters=2)
        ref = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
        out = matrixflow_gemm(a, b, interpret=True).astype(jnp.float32)
        err = float(jnp.abs(out - ref).max())
        ok = err <= max(tol * float(jnp.abs(ref).max()), 1e-3)
        emit("fig6_dtype", f"kernel_interpret_{jnp.dtype(dt).name}",
             round(t * 1e3, 1), "ms", max_err=f"{err:.1e}", ok=ok)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dtype", default=None,
                    choices=["float32", "bfloat16", "int8"],
                    help="sweep one dtype through the ExecutionPlan policy "
                         "path instead of the full Fig. 6 table")
    args = ap.parse_args(argv)
    if args.dtype is not None:
        run_policy_path(args.dtype)
    else:
        run()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
